//! Synthetic downstream tasks (S9) — the GSM8k / HumanEval / chat
//! stand-ins (DESIGN.md §2 substitution table).
//!
//! All tasks share one vocabulary and produce (prompt, completion)
//! pairs scored by exact match of the completion — the same eval shape
//! as the paper's GSM8k answer-match and HumanEval pass@1.
//!
//! * **Math** (`WizardMath` stand-in): `a ⊕ b =` → result token, with
//!   `⊕ ∈ {+, −, ×}` over `Z_256`.
//! * **Code** (`WizardCoder` stand-in): a prefix of nested brackets →
//!   the exact closing sequence.
//! * **Chat** (`WizardLM` stand-in): echo the payload through a fixed
//!   token permutation (the "style" the fine-tune learns).

use crate::tensor::Pcg64;

/// Shared vocabulary layout (vocab_size ≥ 272).
pub mod vocab {
    /// Padding token.
    pub const PAD: u32 = 0;
    /// Beginning-of-sequence token.
    pub const BOS: u32 = 1;
    /// End-of-sequence token (greedy decode stops here).
    pub const EOS: u32 = 2;
    /// `=` — separates a math problem from its answer.
    pub const EQ: u32 = 3;
    /// `+` operator.
    pub const PLUS: u32 = 4;
    /// `−` operator.
    pub const MINUS: u32 = 5;
    /// `×` operator.
    pub const TIMES: u32 = 6;
    /// `(` — code-task bracket.
    pub const OPEN_P: u32 = 7;
    /// `)` — code-task bracket.
    pub const CLOSE_P: u32 = 8;
    /// `[` — code-task bracket.
    pub const OPEN_B: u32 = 9;
    /// `]` — code-task bracket.
    pub const CLOSE_B: u32 = 10;
    /// Prompt/payload separator for the chat task.
    pub const SEP: u32 = 11;
    /// Numbers 0..=255 map to tokens NUM0..NUM0+255.
    pub const NUM0: u32 = 16;
    /// Size of the number token range.
    pub const NUM_COUNT: u32 = 256;

    /// Token for the number `v` (`v < NUM_COUNT`).
    pub fn num(v: u32) -> u32 {
        assert!(v < NUM_COUNT);
        NUM0 + v
    }
}

/// Which downstream task a tenant model is fine-tuned for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Modular arithmetic (`WizardMath` stand-in).
    Math,
    /// Bracket completion (`WizardCoder` stand-in).
    Code,
    /// Permutation echo (`WizardLM` stand-in).
    Chat,
}

impl TaskKind {
    /// Stable lower-case name ("math" / "code" / "chat").
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Math => "math",
            TaskKind::Code => "code",
            TaskKind::Chat => "chat",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn parse(s: &str) -> Option<TaskKind> {
        match s {
            "math" => Some(TaskKind::Math),
            "code" => Some(TaskKind::Code),
            "chat" => Some(TaskKind::Chat),
            _ => None,
        }
    }
}

/// One evaluation sample.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sample {
    /// Conditioning tokens fed to the model.
    pub prompt: Vec<u32>,
    /// Reference completion the model must reproduce (without EOS).
    pub completion: Vec<u32>,
}

impl Sample {
    /// Full sequence (prompt ++ completion ++ EOS) for LM training /
    /// perplexity.
    pub fn full_sequence(&self) -> Vec<u32> {
        let mut s = self.prompt.clone();
        s.extend_from_slice(&self.completion);
        s.push(vocab::EOS);
        s
    }
}

/// Operand / result modulus of the math task. Kept at 64 so the
/// combinatorial space (3 · 64² ≈ 12k problems) is learnable by the
/// tiny-scale models in a few thousand CPU training steps while still
/// requiring real structure (modular add/sub/mul).
pub const MATH_MOD: u32 = 64;

/// Generate one math sample: `BOS a ⊕ b EQ` → `c EOS` over `Z_64` with
/// `⊕ ∈ {+, −}`. (Modular multiplication is a grokking-regime task that
/// the tiny CPU-trainable models cannot reach in a few hundred steps;
/// add/sub keeps the eval discriminative — see DESIGN.md §2.)
pub fn gen_math(rng: &mut Pcg64) -> Sample {
    let a = rng.below(MATH_MOD as u64) as u32;
    let b = rng.below(MATH_MOD as u64) as u32;
    let (op_tok, c) = match rng.below(2) {
        0 => (vocab::PLUS, (a + b) % MATH_MOD),
        _ => (vocab::MINUS, (a + MATH_MOD - b) % MATH_MOD),
    };
    Sample {
        prompt: vec![vocab::BOS, vocab::num(a), op_tok, vocab::num(b), vocab::EQ],
        completion: vec![vocab::num(c)],
    }
}

/// Generate one code sample: a random well-formed bracket prefix with
/// `depth ≥ 1` unclosed brackets → the exact closing sequence.
pub fn gen_code(rng: &mut Pcg64, max_len: usize) -> Sample {
    let mut prompt = vec![vocab::BOS];
    let mut stack: Vec<u32> = Vec::new();
    let target_len = 4 + rng.below_usize(max_len.saturating_sub(4).max(1));
    while prompt.len() < target_len {
        let can_close = !stack.is_empty();
        // bias toward opening early, closing late
        let open = !can_close || rng.bernoulli(0.55);
        if open && stack.len() < 8 {
            if rng.bernoulli(0.5) {
                prompt.push(vocab::OPEN_P);
                stack.push(vocab::CLOSE_P);
            } else {
                prompt.push(vocab::OPEN_B);
                stack.push(vocab::CLOSE_B);
            }
        } else if can_close {
            prompt.push(stack.pop().unwrap());
        }
    }
    // ensure at least one unclosed bracket so the completion is nonempty
    if stack.is_empty() {
        prompt.push(vocab::OPEN_P);
        stack.push(vocab::CLOSE_P);
    }
    let completion: Vec<u32> = stack.iter().rev().copied().collect();
    Sample { prompt, completion }
}

/// Value space of the chat payload (kept small so the 64-entry style
/// table is learnable in a few hundred SFT steps).
pub const CHAT_MOD: u32 = 64;

/// The chat "style" permutation over number tokens: an affine map
/// `v ↦ (5·v + 7) mod 64` (odd multiplier → invertible). Fixed
/// constants — the *task* is fixed; models learn it from data.
pub fn chat_permute(v: u32) -> u32 {
    (v * 5 + 7) % CHAT_MOD
}

/// Generate one chat sample: `BOS SEP t1..tk SEP` → permuted payload.
pub fn gen_chat(rng: &mut Pcg64, payload_len: usize) -> Sample {
    let k = 1 + rng.below_usize(payload_len.max(1));
    let payload: Vec<u32> = (0..k).map(|_| rng.below(CHAT_MOD as u64) as u32).collect();
    let mut prompt = vec![vocab::BOS, vocab::SEP];
    prompt.extend(payload.iter().map(|&v| vocab::num(v)));
    prompt.push(vocab::SEP);
    let completion = payload.iter().map(|&v| vocab::num(chat_permute(v))).collect();
    Sample { prompt, completion }
}

/// Generate a dataset of `n` samples for a task, deterministically.
pub fn gen_dataset(task: TaskKind, n: usize, seed: u64) -> Vec<Sample> {
    let mut rng = Pcg64::new(seed, task as u64 + 100);
    (0..n)
        .map(|_| match task {
            TaskKind::Math => gen_math(&mut rng),
            TaskKind::Code => gen_code(&mut rng, 24),
            TaskKind::Chat => gen_chat(&mut rng, 6),
        })
        .collect()
}

/// Serialize a dataset to the binary `.dqt` format the python trainer
/// reads (u32 count; per sample u16 prompt_len, u16 completion_len,
/// u16 tokens...).
pub fn save_dataset(path: &std::path::Path, samples: &[Sample]) -> anyhow::Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(b"DDQT")?;
    w.write_all(&(samples.len() as u32).to_le_bytes())?;
    for s in samples {
        w.write_all(&(s.prompt.len() as u16).to_le_bytes())?;
        w.write_all(&(s.completion.len() as u16).to_le_bytes())?;
        for &t in s.prompt.iter().chain(&s.completion) {
            w.write_all(&(t as u16).to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Load a `.dqt` dataset.
pub fn load_dataset(path: &std::path::Path) -> anyhow::Result<Vec<Sample>> {
    use anyhow::{bail, Context};
    use std::io::Read;
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != b"DDQT" {
        bail!("bad dataset magic");
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let count = u32::from_le_bytes(b4) as usize;
    let mut out = Vec::with_capacity(count);
    let mut b2 = [0u8; 2];
    for i in 0..count {
        r.read_exact(&mut b2).with_context(|| format!("sample {i}"))?;
        let plen = u16::from_le_bytes(b2) as usize;
        r.read_exact(&mut b2)?;
        let clen = u16::from_le_bytes(b2) as usize;
        let mut toks = Vec::with_capacity(plen + clen);
        for _ in 0..plen + clen {
            r.read_exact(&mut b2)?;
            toks.push(u16::from_le_bytes(b2) as u32);
        }
        let completion = toks.split_off(plen);
        out.push(Sample { prompt: toks, completion });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn math_answers_are_correct() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..200 {
            let s = gen_math(&mut rng);
            assert_eq!(s.prompt.len(), 5);
            assert_eq!(s.completion.len(), 1);
            let a = s.prompt[1] - vocab::NUM0;
            let b = s.prompt[3] - vocab::NUM0;
            let c = s.completion[0] - vocab::NUM0;
            assert!(a < MATH_MOD && b < MATH_MOD && c < MATH_MOD);
            let want = match s.prompt[2] {
                vocab::PLUS => (a + b) % MATH_MOD,
                vocab::MINUS => (a + MATH_MOD - b) % MATH_MOD,
                vocab::TIMES => (a * b) % MATH_MOD,
                t => panic!("bad op {t}"),
            };
            assert_eq!(c, want);
        }
    }

    #[test]
    fn code_completions_close_brackets() {
        let mut rng = Pcg64::seeded(2);
        for _ in 0..200 {
            let s = gen_code(&mut rng, 24);
            assert!(!s.completion.is_empty());
            // simulate: the full bracket string must be balanced
            let mut stack = Vec::new();
            for &t in s.prompt[1..].iter().chain(&s.completion) {
                match t {
                    vocab::OPEN_P => stack.push(vocab::CLOSE_P),
                    vocab::OPEN_B => stack.push(vocab::CLOSE_B),
                    close => assert_eq!(Some(close), stack.pop(), "mismatched close"),
                }
            }
            assert!(stack.is_empty(), "unbalanced after completion");
        }
    }

    #[test]
    fn chat_permutation_is_bijective() {
        let mut seen = [false; CHAT_MOD as usize];
        for v in 0..CHAT_MOD {
            let p = chat_permute(v) as usize;
            assert!(!seen[p], "collision at {v}");
            seen[p] = true;
        }
    }

    #[test]
    fn chat_samples_apply_permutation() {
        let mut rng = Pcg64::seeded(3);
        let s = gen_chat(&mut rng, 6);
        let payload: Vec<u32> = s.prompt[2..s.prompt.len() - 1]
            .iter()
            .map(|&t| t - vocab::NUM0)
            .collect();
        assert_eq!(s.completion.len(), payload.len());
        for (p, c) in payload.iter().zip(&s.completion) {
            assert_eq!(c - vocab::NUM0, chat_permute(*p));
        }
    }

    #[test]
    fn datasets_are_deterministic() {
        let a = gen_dataset(TaskKind::Math, 50, 7);
        let b = gen_dataset(TaskKind::Math, 50, 7);
        assert_eq!(a, b);
        let c = gen_dataset(TaskKind::Math, 50, 8);
        assert_ne!(a, c);
        // different tasks use different streams
        let m = gen_dataset(TaskKind::Math, 10, 7);
        let ch = gen_dataset(TaskKind::Chat, 10, 7);
        assert_ne!(m, ch);
    }

    #[test]
    fn dataset_file_roundtrip() {
        let dir = std::env::temp_dir().join("deltadq-test-tasks");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("math.dqt");
        let samples = gen_dataset(TaskKind::Math, 64, 9);
        save_dataset(&path, &samples).unwrap();
        let loaded = load_dataset(&path).unwrap();
        assert_eq!(loaded, samples);
    }

    #[test]
    fn tokens_fit_tiny_vocab() {
        for task in [TaskKind::Math, TaskKind::Code, TaskKind::Chat] {
            for s in gen_dataset(task, 100, 11) {
                for &t in s.prompt.iter().chain(&s.completion) {
                    assert!(t < 512, "token {t} exceeds vocab");
                }
            }
        }
    }

    #[test]
    fn sequences_fit_max_seq() {
        for task in [TaskKind::Math, TaskKind::Code, TaskKind::Chat] {
            for s in gen_dataset(task, 200, 13) {
                assert!(s.full_sequence().len() <= 64, "{task:?} too long");
            }
        }
    }
}
