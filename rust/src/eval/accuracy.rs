//! Exact-match accuracy — the stand-in for GSM8k answer accuracy and
//! HumanEval pass@1. A sample scores 1 iff greedy decoding reproduces
//! the reference completion exactly (and stops at EOS).

use crate::eval::tasks::{vocab, Sample};
use crate::model::forward::{generate, WeightSource};

/// Evaluation result over a dataset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracyReport {
    /// Samples whose greedy decode matched the reference exactly.
    pub correct: usize,
    /// Samples evaluated.
    pub total: usize,
}

impl AccuracyReport {
    /// Accuracy in percent (paper tables report e.g. "55.49").
    pub fn percent(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            100.0 * self.correct as f64 / self.total as f64
        }
    }
}

/// Max-abs difference between two logit vectors (audit divergence).
pub fn logit_maxabs(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "logit dims");
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).fold(0.0, f64::max)
}

/// KL divergence `KL(softmax(a) ‖ softmax(b))` in nats — the audit
/// subsystem's distributional drift measure at the final position.
pub fn logit_kl(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "logit dims");
    let pa = softmax64(a);
    let pb = softmax64(b);
    let mut kl = 0.0;
    for (p, q) in pa.iter().zip(&pb) {
        if *p > 0.0 {
            kl += p * (p / q.max(f64::MIN_POSITIVE)).ln();
        }
    }
    kl.max(0.0) // guard the tiny negative from rounding when a == b
}

fn softmax64(xs: &[f32]) -> Vec<f64> {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = xs.iter().map(|&x| ((x as f64) - max).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

/// Index of the largest logit, ties to the lowest index (greedy decode's
/// argmax convention).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Greedy-decode each prompt and exact-match the completion.
pub fn evaluate<S: WeightSource>(source: &S, samples: &[Sample]) -> AccuracyReport {
    let mut correct = 0;
    for s in samples {
        // allow a couple of extra tokens so an over-generation fails the
        // match rather than being silently truncated to a "pass"
        let out = generate(source, &s.prompt, s.completion.len() + 2, Some(vocab::EOS));
        if out == s.completion {
            correct += 1;
        }
    }
    AccuracyReport { correct, total: samples.len() }
}

/// Evaluate in parallel across OS threads (samples are independent).
pub fn evaluate_parallel<S: WeightSource + Sync>(
    source: &S,
    samples: &[Sample],
    threads: usize,
) -> AccuracyReport {
    let threads = threads.max(1).min(samples.len().max(1));
    if threads <= 1 {
        return evaluate(source, samples);
    }
    let chunk = samples.len().div_ceil(threads);
    let correct = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for block in samples.chunks(chunk) {
            let correct = &correct;
            scope.spawn(move || {
                let r = evaluate(source, block);
                correct.fetch_add(r.correct, std::sync::atomic::Ordering::Relaxed);
            });
        }
    });
    AccuracyReport {
        correct: correct.load(std::sync::atomic::Ordering::Relaxed),
        total: samples.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::tasks::{gen_dataset, TaskKind};
    use crate::model::{ModelConfig, ModelWeights};
    use crate::tensor::Pcg64;

    #[test]
    fn random_model_scores_near_zero_on_math() {
        let mut rng = Pcg64::seeded(1);
        let w = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let data = gen_dataset(TaskKind::Math, 40, 2);
        let r = evaluate(&w, &data);
        assert_eq!(r.total, 40);
        // untrained: ~1/256 chance per sample
        assert!(r.percent() < 15.0, "{}", r.percent());
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Pcg64::seeded(3);
        let w = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let data = gen_dataset(TaskKind::Code, 24, 4);
        let serial = evaluate(&w, &data);
        for threads in [2, 4] {
            let par = evaluate_parallel(&w, &data, threads);
            assert_eq!(par, serial);
        }
    }

    #[test]
    fn percent_math() {
        assert_eq!(AccuracyReport { correct: 1, total: 2 }.percent(), 50.0);
        assert_eq!(AccuracyReport { correct: 0, total: 0 }.percent(), 0.0);
    }

    #[test]
    fn divergence_zero_on_identical_logits() {
        let a = [0.5f32, -1.0, 2.0, 0.0];
        assert_eq!(logit_maxabs(&a, &a), 0.0);
        assert_eq!(logit_kl(&a, &a), 0.0);
        assert_eq!(argmax(&a), 2);
        assert_eq!(argmax(&[1.0f32, 1.0]), 0); // ties go low
    }

    #[test]
    fn divergence_grows_with_perturbation() {
        let a = [0.5f32, -1.0, 2.0, 0.0];
        let b = [0.5f32, -1.0, 1.0, 0.4];
        assert!((logit_maxabs(&a, &b) - 1.0).abs() < 1e-6);
        let small = logit_kl(&a, &[0.5f32, -1.0, 1.9, 0.05]);
        let big = logit_kl(&a, &b);
        assert!(big > small && small > 0.0, "big {big} small {small}");
    }
}
