//! Perplexity over task sequences — a smoother quality signal than
//! exact match, used by ablation benches and the training-curve checks.

use crate::eval::tasks::Sample;
use crate::model::forward::{forward, WeightSource};
use crate::tensor::ops::cross_entropy;

/// Mean next-token cross-entropy (nats) and perplexity over samples.
#[derive(Debug, Clone, Copy)]
pub struct PerplexityReport {
    /// Mean per-token cross-entropy in nats.
    pub mean_ce: f64,
    /// Tokens the mean was taken over.
    pub tokens: usize,
}

impl PerplexityReport {
    /// Perplexity = exp(mean cross-entropy).
    pub fn perplexity(&self) -> f64 {
        self.mean_ce.exp()
    }
}

/// Teacher-forced CE over each sample's full sequence (predicting token
/// `i+1` from prefix `..=i`).
pub fn evaluate_perplexity<S: WeightSource>(source: &S, samples: &[Sample]) -> PerplexityReport {
    let mut total_ce = 0.0f64;
    let mut total_tokens = 0usize;
    for s in samples {
        let seq = s.full_sequence();
        if seq.len() < 2 {
            continue;
        }
        let logits = forward(source, &seq[..seq.len() - 1]);
        let targets = &seq[1..];
        let ce = cross_entropy(&logits, targets);
        total_ce += ce * targets.len() as f64;
        total_tokens += targets.len();
    }
    PerplexityReport {
        mean_ce: if total_tokens == 0 { 0.0 } else { total_ce / total_tokens as f64 },
        tokens: total_tokens,
    }
}

/// CE restricted to completion positions only (the tokens the task
/// actually grades) — closer to what exact-match measures.
pub fn evaluate_completion_ce<S: WeightSource>(source: &S, samples: &[Sample]) -> PerplexityReport {
    let mut total_ce = 0.0f64;
    let mut total_tokens = 0usize;
    for s in samples {
        let seq = s.full_sequence();
        if seq.len() < 2 {
            continue;
        }
        let logits = forward(source, &seq[..seq.len() - 1]);
        // completion tokens start at index prompt.len() in `seq`, i.e.
        // they are predicted from logits rows prompt.len()-1 ..
        let start = s.prompt.len() - 1;
        let mut ce = 0.0f64;
        let mut n = 0usize;
        for (row, &target) in (start..logits.rows()).zip(&seq[start + 1..]) {
            let r = logits.row(row);
            let max = r.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let logsum = r.iter().map(|v| ((v - max) as f64).exp()).sum::<f64>().ln();
            ce += logsum - (r[target as usize] - max) as f64;
            n += 1;
        }
        total_ce += ce;
        total_tokens += n;
    }
    PerplexityReport {
        mean_ce: if total_tokens == 0 { 0.0 } else { total_ce / total_tokens as f64 },
        tokens: total_tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::tasks::{gen_dataset, TaskKind};
    use crate::model::{ModelConfig, ModelWeights};
    use crate::tensor::Pcg64;

    #[test]
    fn random_model_near_uniform_ce() {
        let mut rng = Pcg64::seeded(1);
        let w = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let data = gen_dataset(TaskKind::Math, 16, 2);
        let r = evaluate_perplexity(&w, &data);
        // near ln(512) ≈ 6.24 for an untrained model
        assert!((r.mean_ce - (512f64).ln()).abs() < 1.0, "ce {}", r.mean_ce);
        assert!(r.tokens > 0);
        assert!(r.perplexity() > 100.0);
    }

    #[test]
    fn completion_ce_counts_only_completions() {
        let mut rng = Pcg64::seeded(3);
        let w = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let data = gen_dataset(TaskKind::Math, 8, 4);
        let r = evaluate_completion_ce(&w, &data);
        // math completions are 1 token + EOS = 2 graded positions
        assert_eq!(r.tokens, 8 * 2);
    }
}
