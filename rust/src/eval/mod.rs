//! Evaluation harness (S9): synthetic downstream tasks, exact-match
//! accuracy (the GSM8k / HumanEval stand-in metric), and perplexity.

pub mod accuracy;
pub mod perplexity;
pub mod tasks;

pub use accuracy::{evaluate, evaluate_parallel, AccuracyReport};
pub use perplexity::{evaluate_completion_ce, evaluate_perplexity, PerplexityReport};
pub use tasks::{gen_dataset, load_dataset, save_dataset, Sample, TaskKind};
