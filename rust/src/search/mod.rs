//! Optimal group-size search for Group-wise Dropout (S8; paper §3.3,
//! Eq. 5, Table 4).
//!
//! Two selection methods over the grid `{α, 2α, 4α, …, h_in}`:
//!
//! * **Direct** — compress the whole model at each candidate `h_g`,
//!   run full task-accuracy evaluation, keep the best. Expensive.
//! * **Proxy** — compress only the first layer's `wq`/`wk`, measure the
//!   attention-score error `‖Q₁K₁ᵀ − Q̂₁K̂₁ᵀ‖²` on ~1 % of the eval
//!   data, keep the `h_g` with the smallest error. The shallow layers
//!   are the most compression-sensitive (Yin et al. 2023), so layer 1
//!   is the signal-richest cheap probe.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::compress::pipeline::{compress_model_deltas, reconstruct_weights};
use crate::compress::{DeltaDq, DeltaDqConfig};
use crate::dropout::group_size_grid;
use crate::eval::accuracy::evaluate;
use crate::eval::tasks::Sample;
use crate::model::weights::ModelWeights;
use crate::tensor::ops;
use crate::tensor::{Matrix, Pcg64};

/// Result of one group-size search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// The winning group size `h_g*`.
    pub best_group_size: usize,
    /// (h_g, score) for every candidate. Score semantics depend on the
    /// method: accuracy-% for Direct (higher better), attention error
    /// for Proxy (lower better).
    pub candidates: Vec<(usize, f64)>,
    /// Wall-clock time of the search.
    pub elapsed: Duration,
}

/// Selection method (Table 4 compares both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMethod {
    /// Full compression + full accuracy eval per candidate.
    Direct,
    /// First-layer attention-score-error probe per candidate.
    Proxy,
}

/// Direct search: full compression + full task-accuracy eval per
/// candidate group size.
pub fn search_direct(
    base: &ModelWeights,
    deltas: &BTreeMap<String, Matrix>,
    alpha: f64,
    eval_data: &[Sample],
    seed: u64,
) -> SearchResult {
    let start = Instant::now();
    let h_in = base.config.hidden;
    let mut candidates = Vec::new();
    let mut best = (0usize, f64::NEG_INFINITY);
    for h_g in group_size_grid(h_in, alpha) {
        let mut rng = Pcg64::new(seed, h_g as u64);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(alpha, Some(h_g)));
        let set = compress_model_deltas(deltas, &dq, &BTreeMap::new(), &mut rng);
        let weights = reconstruct_weights(base, &set);
        let acc = evaluate(&weights, eval_data).percent();
        candidates.push((h_g, acc));
        if acc > best.1 {
            best = (h_g, acc);
        }
    }
    SearchResult { best_group_size: best.0, candidates, elapsed: start.elapsed() }
}

/// Attention-score error of layer `layer` under compressed q/k deltas
/// (Eq. 5): `Σ_samples ‖Q Kᵀ − Q̂ K̂ᵀ‖²`, summed per head.
pub fn attention_error(
    base: &ModelWeights,
    deltas: &BTreeMap<String, Matrix>,
    compressed_q: &Matrix,
    compressed_k: &Matrix,
    layer: usize,
    eval_data: &[Sample],
) -> f64 {
    let c = base.config;
    let d = c.head_dim();
    let wq_name = format!("layers.{layer}.attn.wq");
    let wk_name = format!("layers.{layer}.attn.wk");
    // Original fine-tuned projections: base + exact delta.
    let wq = base.get(&wq_name).add(&deltas[&wq_name]);
    let wk = base.get(&wk_name).add(&deltas[&wk_name]);
    // Compressed: base + compressed delta.
    let wq_hat = base.get(&wq_name).add(compressed_q);
    let wk_hat = base.get(&wk_name).add(compressed_k);
    let mut err = 0.0f64;
    for s in eval_data {
        let seq = s.full_sequence();
        let x = layer_input(base, deltas, layer, &seq);
        let q = x.matmul_nt(&wq);
        let k = x.matmul_nt(&wk);
        let q_hat = x.matmul_nt(&wq_hat);
        let k_hat = x.matmul_nt(&wk_hat);
        for head in 0..c.n_heads {
            let lo = head * d;
            let hi = lo + d;
            let scores = q.slice_cols(lo, hi).matmul_nt(&k.slice_cols(lo, hi));
            let scores_hat = q_hat.slice_cols(lo, hi).matmul_nt(&k_hat.slice_cols(lo, hi));
            err += scores.sq_distance(&scores_hat);
        }
    }
    err
}

/// Input activations feeding layer `layer`'s attention block for one
/// sequence, computed through the *fine-tuned* model (base+deltas).
/// For `layer = 0` (the proxy's choice) this is embeddings + norm only.
fn layer_input(
    base: &ModelWeights,
    deltas: &BTreeMap<String, Matrix>,
    layer: usize,
    seq: &[u32],
) -> Matrix {
    let c = base.config;
    let mut x = ops::embed(base.get("tok_emb"), seq);
    let pos = base.get("pos_emb");
    for (i, row) in x.data_mut().chunks_exact_mut(c.hidden).enumerate() {
        for (a, b) in row.iter_mut().zip(pos.row(i)) {
            *a += b;
        }
    }
    for l in 0..layer {
        let merged = merged_layer_weights(base, deltas, l);
        x = merged.block_forward(&x);
    }
    let mut normed = x;
    ops::rmsnorm_rows(&mut normed, base.get(&format!("layers.{layer}.attn_norm")).row(0), 1e-6);
    normed
}

/// Dense per-layer weights for walking prefix layers in the proxy.
struct MergedLayer {
    wq: Matrix,
    wk: Matrix,
    wv: Matrix,
    wo: Matrix,
    gate: Matrix,
    up: Matrix,
    down: Matrix,
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    n_heads: usize,
}

fn merged_layer_weights(
    base: &ModelWeights,
    deltas: &BTreeMap<String, Matrix>,
    l: usize,
) -> MergedLayer {
    let g = |t: &str| {
        let name = format!("layers.{l}.{t}");
        match deltas.get(&name) {
            Some(d) => base.get(&name).add(d),
            None => base.get(&name).clone(),
        }
    };
    MergedLayer {
        wq: g("attn.wq"),
        wk: g("attn.wk"),
        wv: g("attn.wv"),
        wo: g("attn.wo"),
        gate: g("mlp.gate"),
        up: g("mlp.up"),
        down: g("mlp.down"),
        attn_norm: base.get(&format!("layers.{l}.attn_norm")).row(0).to_vec(),
        mlp_norm: base.get(&format!("layers.{l}.mlp_norm")).row(0).to_vec(),
        n_heads: base.config.n_heads,
    }
}

impl MergedLayer {
    fn block_forward(&self, x: &Matrix) -> Matrix {
        let (t, h) = x.shape();
        let d = h / self.n_heads;
        let mut normed = x.clone();
        ops::rmsnorm_rows(&mut normed, &self.attn_norm, 1e-6);
        let q = normed.matmul_nt(&self.wq);
        let k = normed.matmul_nt(&self.wk);
        let v = normed.matmul_nt(&self.wv);
        let mut ctx = Matrix::zeros(t, h);
        let scale = 1.0 / (d as f32).sqrt();
        for head in 0..self.n_heads {
            let lo = head * d;
            let hi = lo + d;
            let mut scores = q.slice_cols(lo, hi).matmul_nt(&k.slice_cols(lo, hi));
            scores.scale(scale);
            ops::apply_causal_mask(&mut scores);
            ops::softmax_rows(&mut scores);
            ctx.set_cols(lo, &scores.matmul_nn(&v.slice_cols(lo, hi)));
        }
        let mut out = x.clone();
        out.add_assign(&ctx.matmul_nt(&self.wo));
        let mut normed = out.clone();
        ops::rmsnorm_rows(&mut normed, &self.mlp_norm, 1e-6);
        let mut gate = normed.matmul_nt(&self.gate);
        ops::silu(&mut gate);
        let fused = gate.hadamard(&normed.matmul_nt(&self.up));
        out.add_assign(&fused.matmul_nt(&self.down));
        out
    }
}

/// Proxy search: per candidate `h_g`, compress only layer-0 `wq`/`wk`
/// and score by attention error on `proxy_fraction` of the eval data.
pub fn search_proxy(
    base: &ModelWeights,
    deltas: &BTreeMap<String, Matrix>,
    alpha: f64,
    eval_data: &[Sample],
    proxy_fraction: f64,
    seed: u64,
) -> SearchResult {
    let start = Instant::now();
    let n_proxy = ((eval_data.len() as f64 * proxy_fraction).ceil() as usize)
        .clamp(1, eval_data.len().max(1));
    let proxy_data = &eval_data[..n_proxy];
    let h_in = base.config.hidden;
    let wq_name = "layers.0.attn.wq".to_string();
    let wk_name = "layers.0.attn.wk".to_string();
    let mut candidates = Vec::new();
    let mut best = (0usize, f64::INFINITY);
    for h_g in group_size_grid(h_in, alpha) {
        let mut rng = Pcg64::new(seed, h_g as u64);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(alpha, Some(h_g)));
        let cq = dq.sparsify(&deltas[&wq_name], &mut rng).to_dense();
        let ck = dq.sparsify(&deltas[&wk_name], &mut rng).to_dense();
        let err = attention_error(base, deltas, &cq, &ck, 0, proxy_data);
        candidates.push((h_g, err));
        if err < best.1 {
            best = (h_g, err);
        }
    }
    SearchResult { best_group_size: best.0, candidates, elapsed: start.elapsed() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::extract::extract_deltas;
    use crate::eval::tasks::{gen_dataset, TaskKind};
    use crate::model::ModelConfig;

    fn setup() -> (ModelWeights, BTreeMap<String, Matrix>) {
        let mut rng = Pcg64::seeded(1);
        let base = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let mut ft = base.clone();
        let mut rng2 = Pcg64::seeded(2);
        for name in base.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            ft.get_mut(&name).add_assign(&Matrix::randn(r, c, 0.001, &mut rng2));
        }
        let deltas = extract_deltas(&base, &ft);
        (base, deltas)
    }

    #[test]
    fn grids_match_between_methods() {
        let (base, deltas) = setup();
        let data = gen_dataset(TaskKind::Math, 8, 3);
        let d = search_direct(&base, &deltas, 4.0, &data[..2], 42);
        let p = search_proxy(&base, &deltas, 4.0, &data, 0.25, 42);
        let dg: Vec<usize> = d.candidates.iter().map(|(g, _)| *g).collect();
        let pg: Vec<usize> = p.candidates.iter().map(|(g, _)| *g).collect();
        assert_eq!(dg, pg);
        assert_eq!(dg, vec![4, 8, 16, 32, 64]);
    }

    #[test]
    fn proxy_is_faster_than_direct() {
        let (base, deltas) = setup();
        let data = gen_dataset(TaskKind::Math, 32, 4);
        let d = search_direct(&base, &deltas, 8.0, &data, 42);
        let p = search_proxy(&base, &deltas, 8.0, &data, 0.05, 42);
        assert!(
            p.elapsed < d.elapsed,
            "proxy {:?} should beat direct {:?}",
            p.elapsed,
            d.elapsed
        );
    }

    #[test]
    fn proxy_error_zero_for_lossless_compression() {
        let (base, deltas) = setup();
        let data = gen_dataset(TaskKind::Math, 4, 5);
        // alpha = 1 keeps everything: attention error must be ~0
        let p = search_proxy(&base, &deltas, 1.0, &data, 1.0, 42);
        for (g, err) in &p.candidates {
            assert!(*err < 1e-6, "h_g={g} err={err}");
        }
    }

    #[test]
    fn attention_error_increases_with_alpha() {
        let (base, deltas) = setup();
        let data = gen_dataset(TaskKind::Math, 4, 6);
        let mut errs = Vec::new();
        for alpha in [2.0, 8.0, 32.0] {
            let mut rng = Pcg64::seeded(7);
            let dq = DeltaDq::new(DeltaDqConfig::dropout_only(alpha, Some(16)));
            let cq = dq.sparsify(&deltas["layers.0.attn.wq"], &mut rng).to_dense();
            let ck = dq.sparsify(&deltas["layers.0.attn.wk"], &mut rng).to_dense();
            errs.push(attention_error(&base, &deltas, &cq, &ck, 0, &data));
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }
}
