//! Dropout-based sparsification of delta weights (S4; paper §3.3).
//!
//! Three mask granularities, all unbiased (`E[ΔŴ] = ΔW` via the ×α
//! rescale):
//!
//! * **Global** — i.i.d. Bernoulli keep with p = 1/α over the whole
//!   tensor (what DARE does).
//! * **Row-wise** — each row keeps *exactly* `h_in/α` random elements
//!   (paper's "Row-wise Drop": `1 − 1/α` of each mask vector is zero).
//! * **Group-wise** — each row is split into groups of `h_g`; each group
//!   keeps exactly `h_g/α` elements. `h_g = h_in` degenerates to
//!   row-wise; `h_g` small pins the surviving mass evenly along the
//!   matrix-computation dimension, which is what exploits the Balanced
//!   Intermediate Results phenomenon.

use crate::tensor::{Matrix, Pcg64};

/// Mask granularity for [`dropout`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropoutKind {
    /// I.i.d. Bernoulli over all elements (DARE-style).
    Global,
    /// Exact per-row keep counts.
    RowWise,
    /// Exact per-group keep counts with the given group size `h_g`.
    GroupWise { group_size: usize },
}

/// Outcome of a dropout pass.
#[derive(Debug, Clone)]
pub struct DropoutResult {
    /// Sparsified, rescaled delta (`α · (ΔW ⊙ M)`).
    pub matrix: Matrix,
    /// Fraction of elements kept (measured, not nominal).
    pub kept_fraction: f64,
}

/// Apply dropout with compression ratio `alpha` (keep probability 1/α)
/// and rescale survivors by ×α. Deterministic given `rng` state.
pub fn dropout(delta: &Matrix, alpha: f64, kind: DropoutKind, rng: &mut Pcg64) -> DropoutResult {
    assert!(alpha >= 1.0, "alpha {alpha} must be ≥ 1");
    let (rows, cols) = delta.shape();
    let mut out = delta.clone();
    let scale = alpha as f32;
    let mut kept = 0usize;
    match kind {
        DropoutKind::Global => {
            let p = 1.0 / alpha;
            for v in out.data_mut() {
                if rng.bernoulli(p) {
                    *v *= scale;
                    kept += 1;
                } else {
                    *v = 0.0;
                }
            }
        }
        DropoutKind::RowWise => {
            kept = dropout_grouped(&mut out, alpha, cols.max(1), rng);
        }
        DropoutKind::GroupWise { group_size } => {
            assert!(group_size > 0, "group size must be positive");
            kept = dropout_grouped(&mut out, alpha, group_size, rng);
        }
    }
    let total = rows * cols;
    DropoutResult {
        matrix: out,
        kept_fraction: if total == 0 { 0.0 } else { kept as f64 / total as f64 },
    }
}

/// Exact-count dropout over contiguous groups of `group_size` within each
/// row. Returns number of kept elements. Survivors are scaled ×α in place;
/// dropped elements are zeroed.
fn dropout_grouped(out: &mut Matrix, alpha: f64, group_size: usize, rng: &mut Pcg64) -> usize {
    let cols = out.cols();
    let scale = alpha as f32;
    let mut keep_idx: Vec<usize> = Vec::new();
    let mut keep_flags = vec![false; group_size.min(cols)];
    let mut kept = 0usize;
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let mut start = 0usize;
        while start < cols {
            let len = group_size.min(cols - start);
            let group = &mut row[start..start + len];
            let k = keep_count(len, alpha);
            rng.sample_indices(len, k, &mut keep_idx);
            let flags = &mut keep_flags[..len];
            flags.iter_mut().for_each(|f| *f = false);
            for &i in &keep_idx {
                flags[i] = true;
            }
            for (v, &f) in group.iter_mut().zip(flags.iter()) {
                if f {
                    *v *= scale;
                } else {
                    *v = 0.0;
                }
            }
            kept += k;
            start += len;
        }
    }
    kept
}

/// Number of survivors in a group of `len` at ratio `alpha`:
/// `round(len/α)`, clamped to `[0, len]`.
pub fn keep_count(len: usize, alpha: f64) -> usize {
    ((len as f64 / alpha).round() as usize).min(len)
}

/// The valid group-size search grid for Group-wise Dropout (paper §3.3):
/// `{α, 2α, 4α, …}` capped at `h_in` (always including `h_in` itself,
/// the row-wise case). `alpha` is rounded up to an integer group seed.
pub fn group_size_grid(h_in: usize, alpha: f64) -> Vec<usize> {
    let mut grid = Vec::new();
    let mut g = (alpha.ceil() as usize).max(1);
    while g < h_in {
        grid.push(g);
        g *= 2;
    }
    grid.push(h_in);
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::randn(rows, cols, 0.02, &mut rng)
    }

    #[test]
    fn rowwise_keeps_exact_count_per_row() {
        let d = delta(16, 64, 1);
        let mut rng = Pcg64::seeded(2);
        let r = dropout(&d, 4.0, DropoutKind::RowWise, &mut rng);
        for row in r.matrix.rows_iter() {
            let nnz = row.iter().filter(|v| **v != 0.0).count();
            assert_eq!(nnz, 16, "exactly 64/4 survivors per row");
        }
        assert!((r.kept_fraction - 0.25).abs() < 1e-9);
    }

    #[test]
    fn groupwise_keeps_exact_count_per_group() {
        let d = delta(8, 64, 3);
        let mut rng = Pcg64::seeded(4);
        let r = dropout(&d, 8.0, DropoutKind::GroupWise { group_size: 16 }, &mut rng);
        for row in r.matrix.rows_iter() {
            for group in row.chunks(16) {
                let nnz = group.iter().filter(|v| **v != 0.0).count();
                assert_eq!(nnz, 2, "16/8 survivors per group");
            }
        }
    }

    #[test]
    fn survivors_are_rescaled_by_alpha() {
        let d = Matrix::full(4, 32, 1.0);
        let mut rng = Pcg64::seeded(5);
        let r = dropout(&d, 2.0, DropoutKind::GroupWise { group_size: 8 }, &mut rng);
        for &v in r.matrix.data() {
            assert!(v == 0.0 || (v - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn unbiasedness_expectation_preserved() {
        // Mean of many dropout draws converges to the original delta.
        let d = delta(4, 32, 6);
        let mut rng = Pcg64::seeded(7);
        let trials = 600;
        let mut acc = Matrix::zeros(4, 32);
        for _ in 0..trials {
            let r = dropout(&d, 4.0, DropoutKind::GroupWise { group_size: 8 }, &mut rng);
            acc.add_assign(&r.matrix);
        }
        acc.scale(1.0 / trials as f32);
        // elementwise close to original (statistical tolerance)
        let err = acc.sq_distance(&d).sqrt() / d.frobenius_norm() as f64;
        assert!(err < 0.25, "relative error {err}");
    }

    #[test]
    fn global_matches_nominal_rate() {
        let d = delta(64, 64, 8);
        let mut rng = Pcg64::seeded(9);
        let r = dropout(&d, 8.0, DropoutKind::Global, &mut rng);
        assert!((r.kept_fraction - 0.125).abs() < 0.02);
    }

    #[test]
    fn groupsize_equal_hin_matches_rowwise_structure() {
        let d = delta(8, 32, 10);
        let mut rng1 = Pcg64::seeded(11);
        let mut rng2 = Pcg64::seeded(11);
        let a = dropout(&d, 4.0, DropoutKind::RowWise, &mut rng1);
        let b = dropout(&d, 4.0, DropoutKind::GroupWise { group_size: 32 }, &mut rng2);
        assert_eq!(a.matrix, b.matrix, "same rng, same masks");
    }

    #[test]
    fn alpha_one_keeps_everything() {
        let d = delta(4, 16, 12);
        let mut rng = Pcg64::seeded(13);
        let r = dropout(&d, 1.0, DropoutKind::GroupWise { group_size: 4 }, &mut rng);
        assert_eq!(r.matrix, d);
        assert_eq!(r.kept_fraction, 1.0);
    }

    #[test]
    fn ragged_last_group_handled() {
        // cols=50, group=16 -> groups of 16,16,16,2
        let d = delta(4, 50, 14);
        let mut rng = Pcg64::seeded(15);
        let r = dropout(&d, 2.0, DropoutKind::GroupWise { group_size: 16 }, &mut rng);
        for row in r.matrix.rows_iter() {
            let nnz = row.iter().filter(|v| **v != 0.0).count();
            assert_eq!(nnz, 8 + 8 + 8 + 1);
        }
    }

    #[test]
    fn keep_count_rounds() {
        assert_eq!(keep_count(64, 4.0), 16);
        assert_eq!(keep_count(2, 8.0), 0);
        assert_eq!(keep_count(16, 3.0), 5);
        assert_eq!(keep_count(10, 1.0), 10);
    }

    #[test]
    fn group_grid_shape() {
        let g = group_size_grid(1024, 8.0);
        assert_eq!(g, vec![8, 16, 32, 64, 128, 256, 512, 1024]);
        let g2 = group_size_grid(100, 8.0);
        assert_eq!(g2, vec![8, 16, 32, 64, 100]);
        // alpha larger than h_in: just the row itself
        let g3 = group_size_grid(4, 8.0);
        assert_eq!(g3, vec![4]);
    }

    #[test]
    fn deterministic_given_seed() {
        let d = delta(8, 32, 16);
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        let ra = dropout(&d, 4.0, DropoutKind::GroupWise { group_size: 8 }, &mut a);
        let rb = dropout(&d, 4.0, DropoutKind::GroupWise { group_size: 8 }, &mut b);
        assert_eq!(ra.matrix, rb.matrix);
    }
}
