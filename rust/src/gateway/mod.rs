//! Network gateway: the HTTP/1.1 serving front-end over the
//! multi-tenant coordinator (std-only — `TcpListener` plus a bounded
//! connection worker pool in the style of [`crate::runtime::pool`]).
//!
//! ```text
//!   TcpListener (accept thread)
//!        │  bounded handoff queue (overflow → immediate 503)
//!        ▼
//!   connection workers (max_connections threads, keep-alive loop)
//!        │  POST /v1/completions ──▶ Server::submit / submit_stream
//!        │       429 + Retry-After on queue backpressure
//!        │       404 on unknown tenant · SSE chunks per token
//!        │  GET /metrics ──▶ Prometheus text from Metrics snapshot
//!        │  GET /healthz
//!        ▼
//!   coordinator worker pool (batching, tiers, backends — PR 1–3)
//! ```
//!
//! Shutdown is graceful: the accept loop stops taking connections,
//! queued + in-flight connections finish their current exchange (new
//! keep-alive requests are turned away with `Connection: close`), and
//! only then do the worker threads join.

pub mod http;
pub mod loadgen;
pub mod routes;
pub mod sse;

use std::collections::VecDeque;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::Server;

/// Gateway construction knobs (a subset of
/// [`crate::config::ServeConfig`] resolved to concrete values).
#[derive(Debug, Clone)]
pub struct GatewayOptions {
    /// Connection worker threads == max concurrently served
    /// connections. Accepted sockets beyond `2 ×` this wait in the
    /// handoff queue; past that they get an immediate 503.
    pub max_connections: usize,
    /// Per-connection socket read timeout (idle keep-alive reaper).
    pub read_timeout: Duration,
    /// Per-connection socket write timeout: a client that stops
    /// reading mid-stream must not wedge a worker (or shutdown's
    /// join) once the kernel send buffer fills.
    pub write_timeout: Duration,
}

impl Default for GatewayOptions {
    fn default() -> GatewayOptions {
        GatewayOptions {
            max_connections: 64,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
        }
    }
}

/// Accept-queue state shared between the accept thread and workers.
struct Shared {
    server: Arc<Server>,
    queue: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    closing: AtomicBool,
    max_pending: usize,
    read_timeout: Duration,
    write_timeout: Duration,
}

/// The running HTTP front-end. Bind with [`Gateway::start`]; stop with
/// [`Gateway::shutdown`] (drains in-flight connections).
pub struct Gateway {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Gateway {
    /// Bind `listen_addr` (e.g. `"127.0.0.1:8080"`; port `0` picks an
    /// ephemeral port — read it back via [`Gateway::local_addr`]) and
    /// start serving the coordinator over HTTP.
    pub fn start(server: Arc<Server>, listen_addr: &str, opts: GatewayOptions) -> Result<Gateway> {
        let listener =
            TcpListener::bind(listen_addr).with_context(|| format!("bind {listen_addr}"))?;
        let local_addr = listener.local_addr()?;
        let workers_n = opts.max_connections.max(1);
        let shared = Arc::new(Shared {
            server,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closing: AtomicBool::new(false),
            max_pending: workers_n * 2,
            read_timeout: opts.read_timeout,
            write_timeout: opts.write_timeout,
        });

        let mut workers = Vec::with_capacity(workers_n);
        for _ in 0..workers_n {
            let shared = shared.clone();
            workers.push(std::thread::spawn(move || connection_worker(&shared)));
        }

        let accept_shared = shared.clone();
        let accept_thread = std::thread::spawn(move || accept_loop(&listener, &accept_shared));

        Ok(Gateway { local_addr, shared, accept_thread: Some(accept_thread), workers })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Graceful shutdown: stop accepting, serve every connection
    /// already accepted to completion, join all threads. The
    /// coordinator [`Server`] is left running (the caller owns it).
    pub fn shutdown(mut self) {
        self.shared.closing.store(true, Ordering::Release);
        // unblock the accept() call with a throwaway connection
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        // take and release the queue lock before notifying: a worker
        // that read `closing == false` but hasn't entered cv.wait yet
        // holds the lock, so this serializes against it and the
        // notification can't be lost (classic lost-wakeup race)
        drop(self.shared.queue.lock().unwrap());
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// `deltadq serve --listen ADDR`: load the configured server, expose it
/// over HTTP, and serve until the process is killed. The bound address
/// is printed (and flushed) as `gateway listening on http://ADDR` so
/// scripts driving an ephemeral port (`--listen 127.0.0.1:0`) can
/// scrape it.
pub fn run_serve(serve: &crate::config::ServeConfig, tenants_csv: &str) -> Result<()> {
    let listen = serve.listen_addr.as_deref().context("no [serve] listen_addr configured")?;
    let tenants: Vec<String> = tenants_csv.split(',').map(|s| s.trim().to_string()).collect();
    let server = Arc::new(crate::coordinator::load_server(serve, &tenants)?);
    let opts = GatewayOptions {
        max_connections: serve.max_connections.max(1),
        ..GatewayOptions::default()
    };
    let gateway = Gateway::start(server.clone(), listen, opts)?;
    println!(
        "serving {} tenants on '{}' preset via '{}' backend: {:?}",
        tenants.len(),
        serve.model,
        server.backend_name(),
        server.tenants()
    );
    println!("gateway listening on http://{}", gateway.local_addr());
    std::io::stdout().flush().ok();
    // serve until killed; periodically surface the metrics snapshot so
    // an operator tailing the log sees liveness without hitting /metrics
    loop {
        std::thread::sleep(Duration::from_secs(60));
        println!("metrics: {}", server.metrics.snapshot().to_string());
        std::io::stdout().flush().ok();
    }
}

fn accept_loop(listener: &TcpListener, shared: &Shared) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(e) => {
                if shared.closing.load(Ordering::Acquire) {
                    return;
                }
                eprintln!("gateway: accept failed: {e}");
                // persistent failures (e.g. EMFILE under connection
                // floods) must not busy-spin the accept thread
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        if shared.closing.load(Ordering::Acquire) {
            return; // the wake-up connection (or a late client) — drop it
        }
        let mut queue = shared.queue.lock().unwrap();
        if queue.len() >= shared.max_pending {
            // accept queue saturated: shed load immediately rather
            // than letting the client hang unserved
            drop(queue);
            let mut stream = stream;
            let hint = shared.server.retry_after_s();
            let _ =
                routes::error_response_retry(&mut stream, 503, "gateway at capacity", false, hint);
            let _ = stream.shutdown(std::net::Shutdown::Both);
            continue;
        }
        queue.push_back(stream);
        drop(queue);
        shared.cv.notify_one();
    }
}

/// Worker: pull accepted connections and serve them until shutdown.
/// On shutdown the queue is drained first — accepted clients always
/// get answers.
fn connection_worker(shared: &Shared) {
    loop {
        let stream = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if let Some(s) = queue.pop_front() {
                    break s;
                }
                if shared.closing.load(Ordering::Acquire) {
                    return;
                }
                queue = shared.cv.wait(queue).unwrap();
            }
        };
        if let Err(e) = serve_connection(shared, stream) {
            // connection-level failures (resets, timeouts) are normal
            // under open-loop load; they must never take the worker down
            eprintln!("gateway: connection error: {e:#}");
        }
    }
}

fn serve_connection(shared: &Shared, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(shared.read_timeout))?;
    stream.set_write_timeout(Some(shared.write_timeout))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return Ok(()), // peer closed between requests
            Err(e) => {
                // idle keep-alive connections hitting the read timeout
                // are a clean close, not a protocol error
                use std::io::ErrorKind;
                let timed_out = e
                    .root_cause()
                    .downcast_ref::<std::io::Error>()
                    .is_some_and(|io| {
                        matches!(io.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
                    });
                if !timed_out {
                    let _ = routes::error_response(&mut writer, 400, &format!("{e:#}"), false);
                }
                return Ok(());
            }
        };
        // during drain the response must advertise the close we are
        // about to perform, so keep-alive clients don't fire a next
        // request into a dead socket
        let draining = shared.closing.load(Ordering::Acquire);
        // trace the exchange, not the keep-alive idle time: the span
        // opens after read_request returns a parsed request
        let mut handle_span = crate::util::trace::span("gw.handle");
        handle_span.attr_str("method", &req.method);
        handle_span.attr_str("path", &req.path);
        // fault injection: a failed socket write mid-exchange closes
        // only this connection (connection_worker logs and moves on)
        crate::util::failpoint::hit("gateway.write")?;
        let keep = routes::handle(&shared.server, &req, &mut writer, draining)?;
        writer.flush()?;
        drop(handle_span);
        if !keep {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::coordinator::ServerOptions;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::tensor::Pcg64;

    fn tiny_server() -> Arc<Server> {
        let mut rng = Pcg64::seeded(11);
        let base = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));
        Arc::new(Server::start(base, ServerOptions {
            workers: 1,
            batch_window: Duration::from_millis(0),
            ..Default::default()
        }))
    }

    fn get(addr: SocketAddr, path: &str) -> http::HttpResponse {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        write!(w, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
        w.flush().unwrap();
        http::read_response(&mut BufReader::new(stream)).unwrap()
    }

    fn small_opts() -> GatewayOptions {
        GatewayOptions { max_connections: 4, ..Default::default() }
    }

    #[test]
    fn healthz_and_unknown_route() {
        let server = tiny_server();
        let gw = Gateway::start(server.clone(), "127.0.0.1:0", small_opts()).unwrap();
        let ok = get(gw.local_addr(), "/healthz");
        assert_eq!(ok.status, 200);
        assert!(String::from_utf8_lossy(&ok.body).contains("\"status\":\"ok\""));
        let missing = get(gw.local_addr(), "/nope");
        assert_eq!(missing.status, 404);
        gw.shutdown();
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }

    #[test]
    fn keep_alive_serves_sequential_requests() {
        let server = tiny_server();
        let gw = Gateway::start(server.clone(), "127.0.0.1:0", small_opts()).unwrap();
        let stream = TcpStream::connect(gw.local_addr()).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        for _ in 0..3 {
            write!(w, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
            w.flush().unwrap();
            let head = http::read_response_head(&mut r).unwrap();
            assert_eq!(head.status, 200);
            let len: usize = head.header("content-length").unwrap().parse().unwrap();
            let mut body = vec![0u8; len];
            std::io::Read::read_exact(&mut r, &mut body).unwrap();
        }
        // close the client first: shutdown drains in-flight connections,
        // so a live idle keep-alive would hold the join until its read
        // timeout fires
        drop(w);
        drop(r);
        gw.shutdown();
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }

    #[test]
    fn shutdown_with_no_traffic_joins_cleanly() {
        let server = tiny_server();
        let gw = Gateway::start(server.clone(), "127.0.0.1:0", GatewayOptions {
            max_connections: 2,
            ..Default::default()
        })
        .unwrap();
        gw.shutdown();
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }
}
