//! Open-loop load generator for the gateway (`deltadq loadgen`).
//!
//! Open-loop means arrivals follow the configured rate regardless of
//! how fast the server answers — the schedule never waits for
//! responses, so queueing delay shows up in the measured latency
//! instead of silently throttling the offered load (the classic
//! closed-loop coordinated-omission trap). Each request runs on its own
//! thread against a fresh connection; tenants are drawn from a Zipf(s)
//! law over the tenant list (rank 0 hottest), prompts are synthesized
//! from the shared numeric vocab range so any model preset accepts
//! them.
//!
//! Streaming-aware measurement: for `stream: true` requests the client
//! records TTFT (request start → first token frame), per-token
//! inter-arrival gaps, and total latency, all into the shared
//! log-bucketed [`LatencyHistogram`]; non-streaming requests record
//! TTFT at the response head and no inter-token samples.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::eval::tasks::vocab;
use crate::gateway::http::{read_response, read_response_head, ChunkReader};
use crate::gateway::sse;
use crate::tensor::Pcg64;
use crate::util::hist::LatencyHistogram;
use crate::util::json::Json;
use crate::util::zipf::Zipf;

/// Load-generation knobs (`deltadq loadgen --help` mirrors these).
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Gateway address, `host:port`.
    pub addr: String,
    /// Tenant mix, hottest first (Zipf rank order).
    pub tenants: Vec<String>,
    /// Total requests to fire.
    pub requests: usize,
    /// Target arrival rate (requests/second), open-loop.
    pub rps: f64,
    /// Zipf skew across tenants (1.0+ = realistic multi-tenant skew;
    /// 0.0 = uniform).
    pub zipf_s: f64,
    /// Prompt length in tokens (synthesized ids).
    pub prompt_len: usize,
    /// `max_tokens` per request.
    pub max_tokens: usize,
    /// Fraction of requests in the *long* class (0.0–1.0): those use
    /// `long_max_tokens` instead of `max_tokens`. This reproduces the
    /// short-vs-long mix that iteration-level scheduling helps —
    /// without it every short request behind a long generation pays the
    /// long request's decode time in TTFT.
    pub long_frac: f64,
    /// `max_tokens` for the long class.
    pub long_max_tokens: usize,
    /// Request SSE streaming (per-token TTFT/inter-arrival recording).
    pub stream: bool,
    /// Honor `Retry-After` hints: a 429/503 carrying one pauses this
    /// tenant's arrivals for the hinted interval (later arrivals wait
    /// the pause out before connecting, counted as *deferred*) and
    /// re-fires the rejected request after the pause (up to two
    /// retries, counted as *retried*). Off = classic open loop where
    /// rejections are terminal.
    pub honor_retry_after: bool,
    /// Arrival/tenant/prompt randomness seed.
    pub seed: u64,
    /// Per-request client timeout.
    pub timeout: Duration,
}

impl Default for LoadgenOptions {
    fn default() -> LoadgenOptions {
        LoadgenOptions {
            addr: "127.0.0.1:8080".to_string(),
            tenants: vec!["math".to_string()],
            requests: 64,
            rps: 32.0,
            zipf_s: 1.1,
            prompt_len: 8,
            max_tokens: 8,
            long_frac: 0.0,
            long_max_tokens: 32,
            stream: true,
            honor_retry_after: false,
            seed: 0x10AD,
            timeout: Duration::from_secs(120),
        }
    }
}

/// Per-tenant `--honor-retry-after` counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct TenantBackoff {
    /// Requests re-fired after a 429/503 carried a `Retry-After` hint.
    pub retried: u64,
    /// Arrivals delayed because their tenant was inside a hinted pause.
    pub deferred: u64,
}

/// Aggregated results of one loadgen run (merge-able across threads).
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Requests attempted (every outcome below is a subset of these).
    pub submitted: usize,
    /// 2xx responses with a well-formed body.
    pub ok: usize,
    /// 429 backpressure rejections (the server shedding load correctly).
    pub rejected_429: usize,
    /// Other non-2xx statuses (4xx/5xx).
    pub http_errors: usize,
    /// Connect/read/parse failures (no status received).
    pub transport_errors: usize,
    /// Tokens received across all ok responses.
    pub tokens: u64,
    /// Request start → first token frame (stream) / response head.
    pub ttft: LatencyHistogram,
    /// TTFT of short-class requests only (`max_tokens` requests).
    pub ttft_short: LatencyHistogram,
    /// TTFT of long-class requests only (`long_max_tokens` requests;
    /// empty when `long_frac == 0`).
    pub ttft_long: LatencyHistogram,
    /// Gap between consecutive token frames (stream only).
    pub inter_token: LatencyHistogram,
    /// Request start → final byte.
    pub total: LatencyHistogram,
    /// Wall-clock of the whole run (seconds; set by [`run`]).
    pub elapsed_s: f64,
    /// `(request_id, total_seconds)` of every ok response — the ids the
    /// server returned over the wire, kept so `--trace-slowest` can
    /// fetch the span trees of the slowest requests after the run.
    pub samples: Vec<(u64, f64)>,
    /// Requests re-fired after honoring a `Retry-After` hint
    /// (`--honor-retry-after` only; 0 otherwise).
    pub retried: u64,
    /// Requests whose start was delayed by a standing tenant pause
    /// (`--honor-retry-after` only; 0 otherwise).
    pub deferred: u64,
    /// Per-tenant retried/deferred breakdown (honor mode only).
    pub backoff: BTreeMap<String, TenantBackoff>,
}

impl LoadReport {
    /// Fold another worker's report into this one (counters add,
    /// histograms merge; `elapsed_s` is left to the caller).
    pub fn merge(&mut self, other: &LoadReport) {
        self.submitted += other.submitted;
        self.ok += other.ok;
        self.rejected_429 += other.rejected_429;
        self.http_errors += other.http_errors;
        self.transport_errors += other.transport_errors;
        self.tokens += other.tokens;
        self.ttft.merge(&other.ttft);
        self.ttft_short.merge(&other.ttft_short);
        self.ttft_long.merge(&other.ttft_long);
        self.inter_token.merge(&other.inter_token);
        self.total.merge(&other.total);
        self.samples.extend_from_slice(&other.samples);
        self.retried += other.retried;
        self.deferred += other.deferred;
        for (tenant, b) in &other.backoff {
            let e = self.backoff.entry(tenant.clone()).or_default();
            e.retried += b.retried;
            e.deferred += b.deferred;
        }
    }

    /// The `n` slowest ok requests as `(request_id, total_seconds)`,
    /// slowest first.
    pub fn slowest(&self, n: usize) -> Vec<(u64, f64)> {
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| b.1.total_cmp(&a.1));
        sorted.truncate(n);
        sorted
    }

    /// Completed-request throughput actually achieved.
    pub fn achieved_rps(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.ok as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    /// JSON summary (the `BENCH_gateway.json` per-phase schema).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("submitted", self.submitted)
            .set("ok", self.ok)
            .set("rejected_429", self.rejected_429)
            .set("http_errors", self.http_errors)
            .set("transport_errors", self.transport_errors)
            .set("tokens", self.tokens)
            .set("achieved_rps", self.achieved_rps())
            .set("elapsed_s", self.elapsed_s)
            .set("ttft_ms", self.ttft.summary_ms())
            .set("ttft_short_ms", self.ttft_short.summary_ms())
            .set("ttft_long_ms", self.ttft_long.summary_ms())
            .set("inter_token_ms", self.inter_token.summary_ms())
            .set("total_ms", self.total.summary_ms())
            .set("retried", self.retried)
            .set("deferred", self.deferred);
        if !self.backoff.is_empty() {
            let mut per_tenant = Json::obj();
            for (tenant, b) in &self.backoff {
                let mut t = Json::obj();
                t.set("retried", b.retried).set("deferred", b.deferred);
                per_tenant.set(tenant, t);
            }
            o.set("backoff", per_tenant);
        }
        o
    }

    /// Human-readable multi-line report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests: {} submitted, {} ok, {} 429-rejected, {} http errors, {} transport errors\n",
            self.submitted, self.ok, self.rejected_429, self.http_errors, self.transport_errors
        ));
        out.push_str(&format!(
            "tokens: {} received, throughput {:.1} req/s over {:.2}s\n",
            self.tokens,
            self.achieved_rps(),
            self.elapsed_s
        ));
        if self.retried > 0 || self.deferred > 0 {
            out.push_str(&format!(
                "backoff: {} retried, {} deferred (honoring Retry-After)\n",
                self.retried, self.deferred
            ));
            for (tenant, b) in &self.backoff {
                out.push_str(&format!(
                    "  {tenant}: {} retried, {} deferred\n",
                    b.retried, b.deferred
                ));
            }
        }
        out.push_str(&self.ttft.report_ms("ttft"));
        out.push('\n');
        if !self.ttft_long.is_empty() {
            out.push_str(&self.ttft_short.report_ms("ttft[short]"));
            out.push('\n');
            out.push_str(&self.ttft_long.report_ms("ttft[long]"));
            out.push('\n');
        }
        if !self.inter_token.is_empty() {
            out.push_str(&self.inter_token.report_ms("inter-token"));
            out.push('\n');
        }
        out.push_str(&self.total.report_ms("total"));
        out.push('\n');
        out
    }
}

/// One planned request.
struct Arrival {
    at: Duration,
    tenant: String,
    prompt: Vec<u32>,
    max_tokens: usize,
    /// Long-class request (drawn with probability `long_frac`).
    long: bool,
}

/// Fire `opts.requests` requests open-loop and gather the merged
/// report. Blocks until every in-flight request resolves.
pub fn run(opts: &LoadgenOptions) -> Result<LoadReport> {
    if opts.tenants.is_empty() {
        bail!("loadgen needs at least one tenant");
    }
    if opts.rps <= 0.0 || !opts.rps.is_finite() {
        bail!("--rps must be positive");
    }
    let mut rng = Pcg64::seeded(opts.seed);
    let zipf = Zipf::new(opts.tenants.len(), opts.zipf_s.max(0.0));

    // the whole schedule is drawn up front so worker timing can't
    // perturb the arrival process
    let mut at = Duration::ZERO;
    let arrivals: Vec<Arrival> = (0..opts.requests)
        .map(|_| {
            at += Duration::from_secs_f64(rng.exponential(opts.rps));
            let tenant = opts.tenants[zipf.sample(&mut rng)].clone();
            let mut prompt = Vec::with_capacity(opts.prompt_len.max(1));
            prompt.push(vocab::BOS);
            while prompt.len() < opts.prompt_len.max(1) {
                prompt.push(vocab::NUM0 + (rng.next_f64() * vocab::NUM_COUNT as f64) as u32);
            }
            let long = rng.next_f64() < opts.long_frac;
            let max_tokens = if long { opts.long_max_tokens } else { opts.max_tokens };
            Arrival { at, tenant, prompt, max_tokens, long }
        })
        .collect();

    let t0 = Instant::now();
    // honor mode's shared pause map: tenant → earliest next-fire time,
    // stamped from Retry-After hints; workers wait standing pauses out
    let pauses: Arc<Mutex<HashMap<String, Instant>>> = Arc::new(Mutex::new(HashMap::new()));
    let mut handles = Vec::with_capacity(arrivals.len());
    for arrival in arrivals {
        if let Some(wait) = arrival.at.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let spec = RequestSpec {
            addr: opts.addr.clone(),
            stream: opts.stream,
            timeout: opts.timeout,
            honor: opts.honor_retry_after,
            pauses: pauses.clone(),
            arrival,
        };
        handles.push(std::thread::spawn(move || one_request(&spec)));
    }
    let mut report = LoadReport::default();
    for h in handles {
        match h.join() {
            Ok(r) => report.merge(&r),
            Err(_) => report.transport_errors += 1,
        }
        report.submitted += 1;
    }
    report.elapsed_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Fetch one request's span tree from `GET /debug/trace/<id>` (used by
/// `loadgen --trace-slowest` after the run finishes, so the fetch never
/// perturbs the measured requests).
pub fn fetch_trace(addr: &str, id: u64, timeout: Duration) -> Result<Json> {
    let conn = TcpStream::connect(addr).context("connect")?;
    conn.set_read_timeout(Some(timeout)).context("set timeout")?;
    let mut w = conn.try_clone().context("clone stream")?;
    write!(w, "GET /debug/trace/{id} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
        .context("send request")?;
    w.flush().context("flush request")?;
    let mut reader = BufReader::new(conn);
    let resp = read_response(&mut reader).context("response")?;
    if resp.status != 200 {
        bail!("GET /debug/trace/{id} returned status {}", resp.status);
    }
    let text = std::str::from_utf8(&resp.body).context("utf8 body")?;
    Json::parse(text).context("trace json")
}

/// Fetch the usage/saturation snapshot from `GET /debug/usage` (or
/// `GET /debug/usage/<tenant>` when `tenant` is given) — the HTTP
/// client behind `deltadq usage`.
pub fn fetch_usage(addr: &str, tenant: Option<&str>, timeout: Duration) -> Result<Json> {
    let path = match tenant {
        Some(t) => format!("/debug/usage/{t}"),
        None => "/debug/usage".to_string(),
    };
    let conn = TcpStream::connect(addr).context("connect")?;
    conn.set_read_timeout(Some(timeout)).context("set timeout")?;
    let mut w = conn.try_clone().context("clone stream")?;
    write!(w, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
        .context("send request")?;
    w.flush().context("flush request")?;
    let mut reader = BufReader::new(conn);
    let resp = read_response(&mut reader).context("response")?;
    if resp.status != 200 {
        bail!("GET {path} returned status {}", resp.status);
    }
    let text = std::str::from_utf8(&resp.body).context("utf8 body")?;
    Json::parse(text).context("usage json")
}

/// Everything one worker thread needs to fire its request.
struct RequestSpec {
    addr: String,
    stream: bool,
    timeout: Duration,
    /// Honor `Retry-After` (pause + retry) instead of terminal rejects.
    honor: bool,
    /// Shared tenant → next-fire-time map (honor mode).
    pauses: Arc<Mutex<HashMap<String, Instant>>>,
    arrival: Arrival,
}

/// Extra attempts after the first when honoring `Retry-After`.
const HONOR_RETRIES: usize = 2;

/// Execute one request and fold its measurements into a fresh report.
/// In honor mode a hinted 429/503 pauses the tenant and re-fires the
/// request after the pause, up to [`HONOR_RETRIES`] times.
fn one_request(spec: &RequestSpec) -> LoadReport {
    let mut report = LoadReport::default();
    let tenant = spec.arrival.tenant.clone();
    let attempts = if spec.honor { 1 + HONOR_RETRIES } else { 1 };
    let mut was_deferred = false;
    for attempt in 0..attempts {
        if spec.honor {
            // wait out any standing pause for this tenant before firing
            loop {
                let until = spec.pauses.lock().unwrap().get(&tenant).copied();
                match until {
                    Some(t) if t > Instant::now() => {
                        was_deferred = true;
                        std::thread::sleep(t.saturating_duration_since(Instant::now()));
                    }
                    _ => break,
                }
            }
        }
        match try_request(spec, &mut report) {
            Ok(()) => break,
            Err(RequestError::Status { code, retry_after_s }) => {
                let hinted = code == 429 || code == 503;
                if spec.honor && hinted && attempt < attempts - 1 {
                    if let Some(secs) = retry_after_s {
                        let until = Instant::now() + Duration::from_secs(secs.max(1));
                        let mut pauses = spec.pauses.lock().unwrap();
                        let slot = pauses.entry(tenant.clone()).or_insert(until);
                        if *slot < until {
                            *slot = until;
                        }
                        drop(pauses);
                        report.retried += 1;
                        report.backoff.entry(tenant.clone()).or_default().retried += 1;
                        continue;
                    }
                }
                // terminal rejection: count it by class
                if code == 429 {
                    report.rejected_429 += 1;
                } else {
                    report.http_errors += 1;
                }
                break;
            }
            Err(RequestError::Transport(_)) => {
                report.transport_errors += 1;
                break;
            }
        }
    }
    if was_deferred {
        report.deferred += 1;
        report.backoff.entry(tenant).or_default().deferred += 1;
    }
    report
}

enum RequestError {
    Status {
        code: u16,
        /// Parsed `Retry-After` header, when the response carried one.
        retry_after_s: Option<u64>,
    },
    Transport(anyhow::Error),
}

impl From<anyhow::Error> for RequestError {
    fn from(e: anyhow::Error) -> RequestError {
        RequestError::Transport(e)
    }
}

/// Parse a `Retry-After` header value (whole seconds only — the HTTP
/// date form is not emitted by this gateway).
fn parse_retry_after(value: Option<&str>) -> Option<u64> {
    value.and_then(|v| v.trim().parse::<u64>().ok())
}

/// Record a TTFT observation into the combined and class histograms.
fn record_ttft(report: &mut LoadReport, long: bool, seconds: f64) {
    report.ttft.record(seconds);
    if long {
        report.ttft_long.record(seconds);
    } else {
        report.ttft_short.record(seconds);
    }
}

fn try_request(spec: &RequestSpec, report: &mut LoadReport) -> Result<(), RequestError> {
    let RequestSpec { addr, stream, timeout, arrival } = spec;
    let (stream, timeout) = (*stream, *timeout);
    let mut body = Json::obj();
    body.set("tenant", arrival.tenant.as_str())
        .set("prompt", arrival.prompt.clone())
        .set("max_tokens", arrival.max_tokens as u64)
        .set("stream", stream);
    let body = body.to_string();

    let started = Instant::now();
    let conn = TcpStream::connect(addr.as_str()).context("connect")?;
    conn.set_read_timeout(Some(timeout)).context("set timeout")?;
    conn.set_nodelay(true).context("nodelay")?;
    let mut w = conn.try_clone().context("clone stream")?;
    write!(
        w,
        "POST /v1/completions HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .context("send request")?;
    w.flush().context("flush request")?;

    let mut reader = BufReader::new(conn);
    if stream {
        let head = read_response_head(&mut reader).context("response head")?;
        if head.status != 200 {
            // error bodies are fixed-length JSON even on the stream path
            return Err(RequestError::Status {
                code: head.status,
                retry_after_s: parse_retry_after(head.header("retry-after")),
            });
        }
        let mut chunks = ChunkReader::new();
        let mut last_token_at: Option<Instant> = None;
        // staged locally; folded into the report only if the whole
        // stream succeeds, so failed requests can't pollute the
        // histograms (report.ttft.count() == report.ok must hold)
        let mut ttft: Option<f64> = None;
        let mut gaps: Vec<f64> = Vec::new();
        let mut n_tokens = 0u64;
        let mut saw_done = false;
        let mut req_id: Option<u64> = None;
        while let Some(chunk) = chunks.next_chunk(&mut reader).context("read chunk")? {
            let Some(payload) = sse::payload_of(&chunk) else { continue };
            if payload == sse::DONE_SENTINEL {
                continue;
            }
            let event = Json::parse(&payload).context("frame json")?;
            if event.get("token").is_some() {
                let now = Instant::now();
                match last_token_at {
                    None => ttft = Some(now.duration_since(started).as_secs_f64()),
                    Some(prev) => gaps.push(now.duration_since(prev).as_secs_f64()),
                }
                last_token_at = Some(now);
                n_tokens += 1;
            } else if event.get("done").is_some() {
                if event.get("error").is_some() {
                    return Err(RequestError::Status { code: 500, retry_after_s: None });
                }
                req_id = event.get("id").and_then(Json::as_u64);
                saw_done = true;
            }
        }
        if !saw_done {
            return Err(RequestError::Transport(anyhow::anyhow!("stream ended without done")));
        }
        // a request that legitimately generated zero tokens (immediate
        // EOS) has its TTFT at stream end
        let v = ttft.unwrap_or_else(|| started.elapsed().as_secs_f64());
        record_ttft(report, arrival.long, v);
        for gap in gaps {
            report.inter_token.record(gap);
        }
        let total_s = started.elapsed().as_secs_f64();
        report.total.record(total_s);
        report.tokens += n_tokens;
        report.ok += 1;
        if let Some(id) = req_id {
            report.samples.push((id, total_s));
        }
    } else {
        let resp = read_response(&mut reader).context("response")?;
        if resp.status != 200 {
            return Err(RequestError::Status {
                code: resp.status,
                retry_after_s: parse_retry_after(resp.header("retry-after")),
            });
        }
        // no per-token frames here: TTFT collapses to head arrival
        record_ttft(report, arrival.long, started.elapsed().as_secs_f64());
        let text = std::str::from_utf8(&resp.body).context("utf8 body")?;
        let j = Json::parse(text).context("body json")?;
        let n = j
            .get("tokens")
            .and_then(Json::as_array)
            .map(|a| a.len())
            .ok_or_else(|| anyhow::anyhow!("response missing 'tokens'"))?;
        let total_s = started.elapsed().as_secs_f64();
        report.total.record(total_s);
        report.tokens += n as u64;
        report.ok += 1;
        if let Some(id) = j.get("id").and_then(Json::as_u64) {
            report.samples.push((id, total_s));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_merge_accumulates() {
        let mut a = LoadReport { ok: 2, tokens: 10, ..Default::default() };
        a.ttft.record(0.01);
        let mut b = LoadReport { ok: 1, rejected_429: 3, ..Default::default() };
        b.ttft.record(0.02);
        a.merge(&b);
        assert_eq!(a.ok, 3);
        assert_eq!(a.rejected_429, 3);
        assert_eq!(a.tokens, 10);
        assert_eq!(a.ttft.count(), 2);
        let j = a.to_json().to_string();
        assert!(j.contains("\"rejected_429\":3"), "{j}");
        assert!(j.contains("\"ttft_ms\""), "{j}");
    }

    #[test]
    fn ttft_splits_by_request_class() {
        let mut a = LoadReport::default();
        record_ttft(&mut a, false, 0.010);
        record_ttft(&mut a, true, 0.200);
        let mut b = LoadReport::default();
        record_ttft(&mut b, false, 0.020);
        a.merge(&b);
        assert_eq!(a.ttft.count(), 3, "combined histogram sees every request");
        assert_eq!(a.ttft_short.count(), 2);
        assert_eq!(a.ttft_long.count(), 1);
        assert!(a.ttft_long.mean() > a.ttft_short.mean());
        let j = a.to_json().to_string();
        assert!(j.contains("\"ttft_short_ms\""), "{j}");
        assert!(j.contains("\"ttft_long_ms\""), "{j}");
        let rendered = a.render();
        assert!(rendered.contains("ttft[short]"), "{rendered}");
        assert!(rendered.contains("ttft[long]"), "{rendered}");
    }

    #[test]
    fn slowest_orders_samples_across_merges() {
        let mut a = LoadReport::default();
        a.samples.push((1, 0.5));
        a.samples.push((2, 0.1));
        let mut b = LoadReport::default();
        b.samples.push((3, 0.9));
        a.merge(&b);
        assert_eq!(a.slowest(2), vec![(3, 0.9), (1, 0.5)]);
        assert_eq!(a.slowest(10).len(), 3, "n past the sample count clamps");
    }

    #[test]
    fn retry_after_parses_whole_seconds_only() {
        assert_eq!(parse_retry_after(Some("3")), Some(3));
        assert_eq!(parse_retry_after(Some(" 12 ")), Some(12));
        assert_eq!(parse_retry_after(Some("soon")), None);
        assert_eq!(parse_retry_after(None), None);
    }

    #[test]
    fn backoff_counters_merge_per_tenant() {
        let mut a = LoadReport { retried: 1, deferred: 2, ..Default::default() };
        a.backoff.insert("hot".into(), TenantBackoff { retried: 1, deferred: 2 });
        let mut b = LoadReport { retried: 3, deferred: 1, ..Default::default() };
        b.backoff.insert("hot".into(), TenantBackoff { retried: 2, deferred: 0 });
        b.backoff.insert("cool".into(), TenantBackoff { retried: 1, deferred: 1 });
        a.merge(&b);
        assert_eq!(a.retried, 4);
        assert_eq!(a.deferred, 3);
        assert_eq!(a.backoff["hot"].retried, 3);
        assert_eq!(a.backoff["hot"].deferred, 2);
        assert_eq!(a.backoff["cool"].retried, 1);
        let j = a.to_json().to_string();
        assert!(j.contains("\"retried\":4"), "{j}");
        assert!(j.contains("\"backoff\""), "{j}");
        let rendered = a.render();
        assert!(rendered.contains("honoring Retry-After"), "{rendered}");
    }

    #[test]
    fn run_rejects_bad_options() {
        let no_tenants =
            LoadgenOptions { tenants: Vec::new(), requests: 0, ..Default::default() };
        assert!(run(&no_tenants).is_err());
        let bad_rps = LoadgenOptions { rps: 0.0, requests: 0, ..Default::default() };
        assert!(run(&bad_rps).is_err());
    }
}
