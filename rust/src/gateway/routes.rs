//! Route handlers for the gateway: `POST /v1/completions` (batch and
//! SSE-streaming), `GET /metrics` (Prometheus text), `GET /healthz`
//! (the readiness report), `GET /debug/trace` (index of recent traced
//! requests), `GET /debug/trace/<id>` (one request's span tree),
//! `GET /debug/flight` (the flight recorder as Chrome Trace Event
//! Format), `GET /debug/quality[/<tenant>]` (shadow-audit and
//! per-layer compression-quality telemetry) and
//! `GET /debug/usage[/<tenant>]` (the per-tenant usage ledger +
//! saturation report) — plus the [`SubmitError`] → HTTP status mapping
//! that turns batcher backpressure into 429 + a load-derived
//! `Retry-After` and unknown tenants into 404.

use std::io::Write;
use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{Response, Server, StreamEvent, SubmitError, Tier};
use crate::gateway::http::{write_response, ChunkedWriter, HttpRequest};
use crate::gateway::sse;
use crate::sched::SchedStage;
use crate::usage::TenantTotals;
use crate::util::json::Json;
use crate::util::trace;

/// How long a connection worker waits on the coordinator before
/// answering 504 (the batcher has accepted the request, so this only
/// fires if the model is pathologically slow or a worker died).
pub const RESPONSE_TIMEOUT: Duration = Duration::from_secs(120);

/// Drive-thread heartbeat age past which `/healthz` reports the
/// scheduler wedged. The drive loop stamps its heartbeat every
/// iteration and every idle tick (a few milliseconds apart), so five
/// silent seconds mean the thread is stuck inside a backend call or
/// dead.
const SCHED_WEDGED_AFTER: Duration = Duration::from_secs(5);

const CT_JSON: &str = "application/json";
const CT_SSE: &str = "text/event-stream";
const CT_PROM: &str = "text/plain; version=0.0.4";

/// Dispatch one parsed request; returns whether to keep the
/// connection. `draining` forces `Connection: close` on the response —
/// the gateway is shutting down and will close after this exchange.
pub fn handle(
    server: &Server,
    req: &HttpRequest,
    w: &mut impl Write,
    draining: bool,
) -> Result<bool> {
    let keep = req.keep_alive() && !draining;
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/completions") => completions(server, req, w, keep),
        ("GET", "/healthz") => healthz(server, w, keep),
        ("GET", "/metrics") => {
            let body = render_prometheus(server);
            write_response(w, 200, CT_PROM, body.as_bytes(), keep, &[])?;
            Ok(keep)
        }
        ("GET", "/debug/flight") => {
            let body = trace::flight_json(None).to_string();
            write_response(w, 200, CT_JSON, body.as_bytes(), keep, &[])?;
            Ok(keep)
        }
        ("GET", "/debug/quality") => {
            // layer profiles are computed lazily on the audit thread:
            // the first scrape enqueues the work, later scrapes see it
            for t in server.tenants() {
                server.metrics.audit.request_layer_stats(&t);
            }
            let body = server.metrics.audit.quality_json(None).to_string();
            write_response(w, 200, CT_JSON, body.as_bytes(), keep, &[])?;
            Ok(keep)
        }
        ("GET", p) if p.starts_with("/debug/quality/") => {
            let tenant = &p["/debug/quality/".len()..];
            if server.tenants().iter().any(|t| t == tenant) {
                server.metrics.audit.request_layer_stats(tenant);
                let body = server.metrics.audit.quality_json(Some(tenant)).to_string();
                write_response(w, 200, CT_JSON, body.as_bytes(), keep, &[])?;
            } else {
                error_response(w, 404, &format!("unknown tenant '{tenant}'"), keep)?;
            }
            Ok(keep)
        }
        ("GET", "/debug/usage") => {
            let body = server.usage_json(None).unwrap_or_else(Json::obj).to_string();
            write_response(w, 200, CT_JSON, body.as_bytes(), keep, &[])?;
            Ok(keep)
        }
        ("GET", p) if p.starts_with("/debug/usage/") => {
            let tenant = &p["/debug/usage/".len()..];
            match server.usage_json(Some(tenant)) {
                Some(j) => {
                    write_response(w, 200, CT_JSON, j.to_string().as_bytes(), keep, &[])?;
                }
                None => error_response(w, 404, &format!("unknown tenant '{tenant}'"), keep)?,
            }
            Ok(keep)
        }
        ("GET", "/debug/trace") => {
            // bare index (no id): recent request roots, newest first
            let body = trace::recent_requests(64).to_string();
            write_response(w, 200, CT_JSON, body.as_bytes(), keep, &[])?;
            Ok(keep)
        }
        ("GET", p) if p.starts_with("/debug/trace/") => {
            let suffix = &p["/debug/trace/".len()..];
            match suffix.parse::<u64>().ok().and_then(trace::request_tree) {
                Some(tree) => {
                    write_response(w, 200, CT_JSON, tree.to_string().as_bytes(), keep, &[])?;
                }
                None => {
                    let msg = format!("no trace recorded for request '{suffix}'");
                    error_response(w, 404, &msg, keep)?;
                }
            }
            Ok(keep)
        }
        ("GET" | "POST", _) => {
            error_response(w, 404, &format!("no route for {} {}", req.method, req.path), keep)?;
            Ok(keep)
        }
        _ => {
            error_response(w, 405, &format!("method {} not allowed", req.method), keep)?;
            Ok(keep)
        }
    }
}

/// `GET /healthz`: a readiness report, not a bare 200. The JSON body
/// carries scheduler drive-thread liveness (age of its last iteration),
/// the quarantined-tenant count, and KV-pool state; the status is 503
/// `"degraded"` when the drive thread has gone silent past
/// [`SCHED_WEDGED_AFTER`] or every registered tenant is quarantined.
fn healthz(server: &Server, w: &mut impl Write, keep: bool) -> Result<bool> {
    let tenants = server.tenants().len();
    let quarantined = server.quarantined_count();
    let sched = server.sched_stats();
    let mut wedged = false;
    let sched_json = match &sched {
        Some(s) => {
            // heartbeat 0 = the loop hasn't published yet (it stamps on
            // its first iteration, microseconds after spawn) — treat as
            // healthy rather than flagging a server that just started
            let age_us = match s.last_heartbeat_us {
                0 => 0,
                hb => trace::now_us().saturating_sub(hb),
            };
            wedged = s.last_heartbeat_us != 0 && Duration::from_micros(age_us) > SCHED_WEDGED_AFTER;
            let mut j = Json::obj();
            j.set("active", true)
                .set("last_iteration_age_ms", age_us as f64 / 1e3)
                .set("running", s.running)
                .set("waiting", s.waiting)
                .set("kv_blocks_used", s.kv_blocks_used)
                .set("kv_blocks_free", s.kv_blocks_free)
                .set("kv_blocks_total", s.kv_blocks_total);
            j
        }
        None => Json::Null, // legacy worker pool: no drive thread to watch
    };
    let all_quarantined = tenants > 0 && quarantined >= tenants;
    let degraded = wedged || all_quarantined;
    let mut o = Json::obj();
    o.set("status", if degraded { "degraded" } else { "ok" })
        .set("tenants", tenants)
        .set("quarantined", quarantined)
        .set("sched", sched_json);
    let status = if degraded { 503 } else { 200 };
    write_response(w, status, CT_JSON, o.to_string().as_bytes(), keep, &[])?;
    Ok(keep)
}

/// `{"error": msg}` with the given status. A 429/503 carries the floor
/// `Retry-After: 1`; load-aware callers use
/// [`error_response_retry`] with the live-derived hint instead.
pub fn error_response(w: &mut impl Write, status: u16, msg: &str, keep: bool) -> Result<()> {
    error_response_retry(w, status, msg, keep, 1)
}

/// As [`error_response`] with an explicit `Retry-After` hint (whole
/// seconds, clamped ≥ 1) stamped on 429/503 responses — the
/// load-derived backoff from [`Server::retry_after_s`].
pub fn error_response_retry(
    w: &mut impl Write,
    status: u16,
    msg: &str,
    keep: bool,
    retry_after_s: u64,
) -> Result<()> {
    let mut o = Json::obj();
    o.set("error", msg);
    let secs = retry_after_s.max(1).to_string();
    let headers: [(&str, &str); 1] = [("Retry-After", secs.as_str())];
    let extra: &[(&str, &str)] = if status == 429 || status == 503 { &headers } else { &[] };
    write_response(w, status, CT_JSON, o.to_string().as_bytes(), keep, extra)
}

/// Answer a [`SubmitError`] with its mapped status. A quarantined
/// tenant's 503 carries the loader's probe interval as `Retry-After`;
/// backpressure 429s and shutdown 503s carry the saturation-derived
/// hint (the 1-second floor while the server has headroom, climbing
/// toward the configured ceiling as load approaches saturation).
fn submit_error_response(
    w: &mut impl Write,
    server: &Server,
    e: &SubmitError,
    keep: bool,
) -> Result<()> {
    let (status, msg) = submit_error_status(e);
    let hint = match e {
        SubmitError::Quarantined { retry_after_s, .. } => *retry_after_s,
        SubmitError::Backpressure { .. } | SubmitError::Closed => server.retry_after_s(),
        SubmitError::UnknownTenant(_) => 1,
    };
    error_response_retry(w, status, &msg, keep, hint)
}

/// The JSON body shared by the non-streaming response and the SSE
/// `done` frame.
pub fn response_json(resp: &Response) -> Json {
    let mut o = Json::obj();
    o.set("id", resp.id)
        .set("tenant", resp.tenant.as_str())
        .set("tokens", resp.tokens.clone())
        .set("n_tokens", resp.tokens.len())
        .set("served_hot", resp.served_hot)
        .set("queue_wait_ms", resp.queue_wait.as_secs_f64() * 1e3)
        .set("total_ms", resp.total.as_secs_f64() * 1e3);
    if let Some(e) = &resp.error {
        o.set("error", e.as_str());
    }
    o
}

/// Parsed body of `POST /v1/completions`.
struct CompletionParams {
    tenant: String,
    prompt: Vec<u32>,
    max_tokens: usize,
    stream: bool,
    /// Per-request deadline (optional `ttl_ms` body field); overrides
    /// the server-wide `request_ttl` default.
    ttl: Option<Duration>,
}

fn parse_params(body: &[u8]) -> Result<CompletionParams, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    let j = Json::parse(text).map_err(|e| format!("bad JSON body: {e}"))?;
    let tenant = j
        .get("tenant")
        .and_then(Json::as_str)
        .ok_or("missing string field 'tenant'")?
        .to_string();
    let prompt_field = j.get("prompt").ok_or("missing array field 'prompt' (token ids)")?;
    let items = prompt_field.as_array().ok_or("'prompt' must be an array of token ids")?;
    let mut prompt = Vec::with_capacity(items.len());
    for item in items {
        prompt.push(item.as_u64().ok_or("'prompt' entries must be non-negative integers")? as u32);
    }
    if prompt.is_empty() {
        return Err("'prompt' must not be empty".to_string());
    }
    let max_tokens = match j.get("max_tokens") {
        Some(v) => v.as_u64().ok_or("'max_tokens' must be a non-negative integer")? as usize,
        None => 16,
    };
    let stream = match j.get("stream") {
        Some(v) => v.as_bool().ok_or("'stream' must be a boolean")?,
        None => false,
    };
    let ttl = match j.get("ttl_ms") {
        Some(v) => {
            let ms = v.as_u64().ok_or("'ttl_ms' must be a positive integer")?;
            if ms == 0 {
                return Err("'ttl_ms' must be a positive integer".to_string());
            }
            Some(Duration::from_millis(ms))
        }
        None => None,
    };
    Ok(CompletionParams { tenant, prompt, max_tokens, stream, ttl })
}

fn submit_error_status(e: &SubmitError) -> (u16, String) {
    match e {
        SubmitError::Backpressure { tenant, depth } => (
            429,
            format!("tenant '{tenant}' queue full (depth {depth}); retry after backoff"),
        ),
        SubmitError::UnknownTenant(t) => (404, format!("unknown tenant '{t}'")),
        SubmitError::Quarantined { tenant, retry_after_s } => (
            503,
            format!("tenant '{tenant}' quarantined; retry after {retry_after_s}s"),
        ),
        SubmitError::Closed => (503, "server is shutting down".to_string()),
    }
}

fn completions(
    server: &Server,
    req: &HttpRequest,
    w: &mut impl Write,
    keep: bool,
) -> Result<bool> {
    let params = match parse_params(&req.body) {
        Ok(p) => p,
        Err(msg) => {
            error_response(w, 400, &msg, keep)?;
            return Ok(keep);
        }
    };
    // bound-check against the model before submission: an oversized
    // prompt or out-of-vocab token would panic a coordinator worker
    let (vocab_size, max_seq) = server.model_limits();
    if params.prompt.len() >= max_seq {
        let msg = format!("prompt of {} tokens exceeds max_seq {max_seq}", params.prompt.len());
        error_response(w, 400, &msg, keep)?;
        return Ok(keep);
    }
    if let Some(&bad) = params.prompt.iter().find(|&&t| t as usize >= vocab_size) {
        let msg = format!("prompt token {bad} outside the vocabulary (size {vocab_size})");
        error_response(w, 400, &msg, keep)?;
        return Ok(keep);
    }
    if params.stream {
        completions_stream(server, params, w, keep)
    } else {
        completions_batch(server, params, w, keep)
    }
}

fn completions_batch(
    server: &Server,
    params: CompletionParams,
    w: &mut impl Write,
    keep: bool,
) -> Result<bool> {
    let submitted = match params.ttl {
        Some(ttl) => {
            server.submit_with_ttl(&params.tenant, params.prompt, params.max_tokens, ttl)
        }
        None => server.submit(&params.tenant, params.prompt, params.max_tokens),
    };
    let rx = match submitted {
        Ok(rx) => rx,
        Err(e) => {
            submit_error_response(w, server, &e, keep)?;
            return Ok(keep);
        }
    };
    match rx.recv_timeout(RESPONSE_TIMEOUT) {
        Ok(resp) => {
            let status = if resp.error.is_some() { 500 } else { 200 };
            let body = response_json(&resp).to_string();
            write_response(w, status, CT_JSON, body.as_bytes(), keep, &[])?;
        }
        Err(RecvTimeoutError::Timeout) => {
            error_response(w, 504, "request accepted but not answered in time", keep)?;
        }
        Err(RecvTimeoutError::Disconnected) => {
            // tenant removed while queued — its queue (and our sender)
            // was dropped
            error_response(w, 404, &format!("tenant '{}' was removed", params.tenant), keep)?;
        }
    }
    Ok(keep)
}

fn completions_stream(
    server: &Server,
    params: CompletionParams,
    w: &mut impl Write,
    keep: bool,
) -> Result<bool> {
    let submitted = match params.ttl {
        Some(ttl) => {
            server.submit_stream_with_ttl(&params.tenant, params.prompt, params.max_tokens, ttl)
        }
        None => server.submit_stream(&params.tenant, params.prompt, params.max_tokens),
    };
    let rx = match submitted {
        Ok(rx) => rx,
        Err(e) => {
            // nothing streamed yet — a plain status response is still
            // possible (this is where the 429/503 + Retry-After surfaces)
            submit_error_response(w, server, &e, keep)?;
            return Ok(keep);
        }
    };
    let mut cw = ChunkedWriter::start(w, 200, CT_SSE, keep)?;
    let mut index = 0usize;
    loop {
        match rx.recv_timeout(RESPONSE_TIMEOUT) {
            Ok(StreamEvent::Token(token)) => {
                cw.chunk(&sse::token_frame(index, token))?;
                index += 1;
            }
            Ok(StreamEvent::Done(resp)) => {
                cw.chunk(&sse::done_frame(&resp))?;
                break;
            }
            Err(e) => {
                // headers are gone; the error has to ride the stream
                let reason = match e {
                    RecvTimeoutError::Timeout => "timed out waiting for the next token",
                    RecvTimeoutError::Disconnected => "tenant removed mid-stream",
                };
                // NB the reverse direction is handled upstream: when
                // the *client* disconnects, `cw.chunk` errors out of
                // this handler, dropping `rx` — the scheduler sees the
                // dead sink on its next token, cancels the sequence,
                // and frees its KV blocks and running slot.
                let mut o = Json::obj();
                o.set("error", reason).set("done", true);
                cw.chunk(&sse::frame(&o.to_string()))?;
                break;
            }
        }
    }
    cw.chunk(&sse::frame(sse::DONE_SENTINEL))?;
    cw.finish()?;
    Ok(keep)
}

/// Render the coordinator metrics in Prometheus text exposition format.
pub fn render_prometheus(server: &Server) -> String {
    use std::fmt::Write as _;
    use std::sync::atomic::Ordering;

    let render_start = std::time::Instant::now();
    let m = &server.metrics;
    let mut out = String::with_capacity(2048);
    let mut counter = |name: &str, help: &str, value: u64| {
        let _ = writeln!(out, "# HELP deltadq_{name} {help}");
        let _ = writeln!(out, "# TYPE deltadq_{name} counter");
        let _ = writeln!(out, "deltadq_{name} {value}");
    };
    counter(
        "requests_submitted_total",
        "Submission attempts (accepted + rejected).",
        m.requests_submitted.load(Ordering::Relaxed),
    );
    counter(
        "requests_completed_total",
        "Requests answered (including backend errors).",
        m.requests_completed.load(Ordering::Relaxed),
    );
    counter(
        "requests_rejected_total",
        "Submissions refused (backpressure / unknown tenant).",
        m.requests_rejected.load(Ordering::Relaxed),
    );
    counter(
        "tokens_generated_total",
        "Tokens decoded across all requests.",
        m.tokens_generated.load(Ordering::Relaxed),
    );
    counter(
        "batches_executed_total",
        "Tenant batches executed by the worker pool.",
        m.batches_executed.load(Ordering::Relaxed),
    );
    counter(
        "promotions_total",
        "Cold→Hot tenant promotions.",
        m.promotions.load(Ordering::Relaxed),
    );
    counter(
        "evictions_total",
        "Hot-cache evictions.",
        m.evictions.load(Ordering::Relaxed),
    );
    counter(
        "backend_errors_total",
        "Requests whose execution backend failed.",
        m.backend_errors.load(Ordering::Relaxed),
    );
    counter(
        "disk_loads_total",
        "Disk→Cold tenant hydrations from the delta store.",
        m.tiers.disk_loads.load(Ordering::Relaxed),
    );
    counter(
        "demotions_total",
        "Cold→Disk demotions under the delta budget.",
        m.tiers.demotions.load(Ordering::Relaxed),
    );
    counter(
        "store_bytes_read_total",
        "Bytes read from delta-store shards.",
        m.tiers.store_bytes_read.load(Ordering::Relaxed),
    );
    let sched = m.sched.stats();
    counter(
        "sched_preempted_total",
        "Sequences preempted back to the queue on KV-pool exhaustion.",
        sched.preempted_total,
    );
    counter(
        "sched_cancelled_total",
        "Sequences cancelled after their streaming client disconnected.",
        sched.cancelled_total,
    );
    counter(
        "load_retries_total",
        "Disk→Cold hydration attempts retried after a transient failure.",
        m.tiers.load_retries.load(Ordering::Relaxed),
    );
    counter(
        "decode_group_panics_total",
        "Decode groups whose backend call panicked (contained per group).",
        sched.decode_group_panics_total,
    );
    counter(
        "deadline_expired_total",
        "Requests answered with a deadline-exceeded error.",
        sched.deadline_expired_total,
    );
    let audit = &m.audit;
    counter(
        "audit_sampled_total",
        "Completed requests enqueued for shadow audit.",
        audit.sampled_total.load(Ordering::Relaxed),
    );
    counter(
        "audit_dropped_total",
        "Audit samples dropped (queue full or auditor stopped).",
        audit.dropped_total.load(Ordering::Relaxed),
    );
    counter(
        "audit_completed_total",
        "Shadow audits finished (reference re-run compared).",
        audit.completed_total.load(Ordering::Relaxed),
    );
    counter(
        "audit_warn_total",
        "Drift-window breaches of the agreement threshold.",
        audit.warn_total.load(Ordering::Relaxed),
    );
    counter(
        "audit_quarantined_total",
        "Tenants quarantined by the auditor in enforce mode.",
        audit.quarantined_total.load(Ordering::Relaxed),
    );
    counter(
        "audit_errors_total",
        "Shadow audits that failed to run (load/compare errors).",
        audit.errors_total.load(Ordering::Relaxed),
    );

    let mut gauge = |name: &str, help: &str, value: f64| {
        let _ = writeln!(out, "# HELP deltadq_{name} {help}");
        let _ = writeln!(out, "# TYPE deltadq_{name} gauge");
        let _ = writeln!(out, "deltadq_{name} {value}");
    };
    gauge(
        "queue_depth",
        "Requests currently queued across all tenants.",
        server.queued() as f64,
    );
    gauge(
        "queue_depth_limit",
        "Per-tenant queue capacity (submissions beyond it get 429).",
        server.queue_depth() as f64,
    );
    gauge(
        "sched_running_sequences",
        "Sequences holding a scheduler running slot.",
        sched.running as f64,
    );
    gauge(
        "sched_waiting_sequences",
        "Requests waiting for admission (queued + preempted).",
        sched.waiting as f64,
    );
    gauge(
        "tenant_quarantined",
        "Tenants currently quarantined after repeated hydration failures.",
        server.quarantined_count() as f64,
    );

    let _ = writeln!(out, "# HELP deltadq_kv_pool_blocks Paged KV-cache block pool occupancy.");
    let _ = writeln!(out, "# TYPE deltadq_kv_pool_blocks gauge");
    let _ = writeln!(out, "deltadq_kv_pool_blocks{{state=\"used\"}} {}", sched.kv_blocks_used);
    let _ = writeln!(out, "deltadq_kv_pool_blocks{{state=\"free\"}} {}", sched.kv_blocks_free);
    let _ = writeln!(
        out,
        "# HELP deltadq_kv_pool_blocks_total KV block pool capacity (the configured budget)."
    );
    let _ = writeln!(out, "# TYPE deltadq_kv_pool_blocks_total gauge");
    let _ = writeln!(out, "deltadq_kv_pool_blocks_total {}", sched.kv_blocks_total);

    let _ = writeln!(out, "# HELP deltadq_tenant_queue_depth Queued requests per tenant.");
    let _ = writeln!(out, "# TYPE deltadq_tenant_queue_depth gauge");
    for (tenant, depth) in server.tenant_queue_depths() {
        let label = tenant.replace('\\', "\\\\").replace('"', "\\\"");
        let _ = writeln!(out, "deltadq_tenant_queue_depth{{tenant=\"{label}\"}} {depth}");
    }

    let residency = server.tier_residency();
    let count_tier = |t: Tier| residency.iter().filter(|(_, tier, _)| *tier == t).count();
    let _ = writeln!(out, "# HELP deltadq_tenants Registered tenants by residency tier.");
    let _ = writeln!(out, "# TYPE deltadq_tenants gauge");
    for (label, tier) in [("hot", Tier::Hot), ("cold", Tier::Cold), ("disk", Tier::Disk)] {
        let _ = writeln!(out, "deltadq_tenants{{tier=\"{label}\"}} {}", count_tier(tier));
    }

    let latency = m.latency_histogram();
    let queue_wait = m.queue_wait_histogram();
    for (name, help, hist) in [
        ("request_latency_seconds", "End-to-end request latency.", &latency),
        ("queue_wait_seconds", "Queue wait before batch pickup.", &queue_wait),
    ] {
        let _ = writeln!(out, "# HELP deltadq_{name} {help}");
        let _ = writeln!(out, "# TYPE deltadq_{name} summary");
        for (q, p) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)] {
            let _ = writeln!(
                out,
                "deltadq_{name}{{quantile=\"{q}\"}} {}",
                hist.percentile(p)
            );
        }
        let _ = writeln!(out, "deltadq_{name}_sum {}", hist.sum());
        let _ = writeln!(out, "deltadq_{name}_count {}", hist.count());
    }

    // native histograms (aggregatable across shards, unlike the
    // summaries above): cumulative `le` buckets straight from the
    // log-bucket boundaries, only occupied buckets emitted
    let batch_exec = m.batch_exec_histogram();
    for (name, help, hist) in [
        ("request_latency_hist_seconds", "End-to-end request latency.", &latency),
        ("queue_wait_hist_seconds", "Queue wait before batch pickup.", &queue_wait),
        ("batch_exec_hist_seconds", "Per-iteration batch execution time.", &batch_exec),
    ] {
        let _ = writeln!(out, "# HELP deltadq_{name} {help}");
        let _ = writeln!(out, "# TYPE deltadq_{name} histogram");
        for (le, c) in hist.cumulative_buckets() {
            let _ = writeln!(out, "deltadq_{name}_bucket{{le=\"{le}\"}} {c}");
        }
        let _ = writeln!(out, "deltadq_{name}_bucket{{le=\"+Inf\"}} {}", hist.count());
        let _ = writeln!(out, "deltadq_{name}_sum {}", hist.sum());
        let _ = writeln!(out, "deltadq_{name}_count {}", hist.count());
    }

    // per-stage scheduler-iteration breakdown: one histogram family,
    // a `stage` label per iteration phase
    let _ = writeln!(
        out,
        "# HELP deltadq_sched_stage_seconds Scheduler iteration wall time by stage."
    );
    let _ = writeln!(out, "# TYPE deltadq_sched_stage_seconds histogram");
    for stage in SchedStage::ALL {
        let hist = m.sched.stage_histogram(stage);
        let s = stage.name();
        for (le, c) in hist.cumulative_buckets() {
            let _ = writeln!(
                out,
                "deltadq_sched_stage_seconds_bucket{{stage=\"{s}\",le=\"{le}\"}} {c}"
            );
        }
        let _ = writeln!(
            out,
            "deltadq_sched_stage_seconds_bucket{{stage=\"{s}\",le=\"+Inf\"}} {}",
            hist.count()
        );
        let _ = writeln!(out, "deltadq_sched_stage_seconds_sum{{stage=\"{s}\"}} {}", hist.sum());
        let _ = writeln!(
            out,
            "deltadq_sched_stage_seconds_count{{stage=\"{s}\"}} {}",
            hist.count()
        );
    }

    // quality telemetry: shadow-audit agreement/divergence per tenant,
    // reconstruction error + BIR variance per (tenant, layer)
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let summaries = m.audit.tenant_summaries();
    if !summaries.is_empty() {
        let _ = writeln!(
            out,
            "# HELP deltadq_audit_token_agreement Windowed greedy token agreement vs the dense reference."
        );
        let _ = writeln!(out, "# TYPE deltadq_audit_token_agreement gauge");
        for (tenant, agreement, _, _, _) in &summaries {
            let t = esc(tenant);
            let _ = writeln!(out, "deltadq_audit_token_agreement{{tenant=\"{t}\"}} {agreement}");
        }
        let _ = writeln!(
            out,
            "# HELP deltadq_audit_logit_maxabs Max-abs final-position logit divergence of the latest shadow audit."
        );
        let _ = writeln!(out, "# TYPE deltadq_audit_logit_maxabs gauge");
        for (tenant, _, _, maxabs, _) in &summaries {
            let t = esc(tenant);
            let _ = writeln!(out, "deltadq_audit_logit_maxabs{{tenant=\"{t}\"}} {maxabs}");
        }
    }
    let layers = m.audit.layer_snapshot();
    if !layers.is_empty() {
        let _ = writeln!(
            out,
            "# HELP deltadq_layer_recon_error Relative reconstruction-norm error vs the manifest-recorded pre-quantization norm."
        );
        let _ = writeln!(out, "# TYPE deltadq_layer_recon_error gauge");
        for (tenant, stats) in &layers {
            let t = esc(tenant);
            for s in stats {
                let l = esc(&s.name);
                let _ = writeln!(
                    out,
                    "deltadq_layer_recon_error{{tenant=\"{t}\",layer=\"{l}\"}} {}",
                    s.recon_error
                );
            }
        }
        let _ = writeln!(
            out,
            "# HELP deltadq_bir_variance Variance of sampled balanced intermediate results (X*dW^T partials)."
        );
        let _ = writeln!(out, "# TYPE deltadq_bir_variance gauge");
        for (tenant, stats) in &layers {
            let t = esc(tenant);
            for s in stats {
                let l = esc(&s.name);
                let _ = writeln!(
                    out,
                    "deltadq_bir_variance{{tenant=\"{t}\",layer=\"{l}\"}} {}",
                    s.bir.variance
                );
            }
        }
    }

    // saturation + usage: per-axis load scores, the derived Retry-After
    // hint, and per-tenant attributed-resource counters capped at the
    // configured top-K (by attributed compute) with the remainder
    // folded into tenant="other" — bounded exposition cardinality no
    // matter how many tenants register
    let sat = server.saturation();
    let _ = writeln!(
        out,
        "# HELP deltadq_saturation Per-axis load score over the trailing window (0 idle, 1 saturated)."
    );
    let _ = writeln!(out, "# TYPE deltadq_saturation gauge");
    for (axis, v) in sat.axes() {
        let _ = writeln!(out, "deltadq_saturation{{axis=\"{axis}\"}} {v}");
    }
    let _ = writeln!(out, "deltadq_saturation{{axis=\"combined\"}} {}", sat.combined);
    let _ = writeln!(
        out,
        "# HELP deltadq_retry_after_seconds Load-derived Retry-After hint stamped on 429/503 responses."
    );
    let _ = writeln!(out, "# TYPE deltadq_retry_after_seconds gauge");
    let _ = writeln!(out, "deltadq_retry_after_seconds {}", sat.retry_after_s);

    let (mut usage_rows, usage_other) = m.usage.export();
    if let Some(rest) = usage_other {
        usage_rows.push(("other".to_string(), rest));
    }
    if !usage_rows.is_empty() {
        type Get = fn(&TenantTotals) -> f64;
        let families: [(&str, &str, Get); 6] = [
            (
                "tenant_compute_seconds_total",
                "Execution wall time attributed to this tenant.",
                |t| t.compute_us as f64 / 1e6,
            ),
            (
                "tenant_kv_block_seconds_total",
                "KV-cache block-seconds held by this tenant's sequences.",
                |t| t.kv_block_us as f64 / 1e6,
            ),
            (
                "tenant_queue_wait_seconds_total",
                "Admission queue wait accumulated by this tenant.",
                |t| t.queue_wait_us as f64 / 1e6,
            ),
            (
                "tenant_requests_total",
                "Submissions per tenant (accepted + rejected).",
                |t| t.requests as f64,
            ),
            (
                "tenant_store_bytes_read_total",
                "Delta-store shard bytes read hydrating this tenant.",
                |t| t.store_bytes_read as f64,
            ),
            (
                "tenant_hydrations_total",
                "Disk→Cold hydrations performed for this tenant.",
                |t| t.hydrations as f64,
            ),
        ];
        for (name, help, get) in families {
            let _ = writeln!(out, "# HELP deltadq_{name} {help}");
            let _ = writeln!(out, "# TYPE deltadq_{name} counter");
            for (tenant, totals) in &usage_rows {
                let t = esc(tenant);
                let _ = writeln!(out, "deltadq_{name}{{tenant=\"{t}\"}} {}", get(totals));
            }
        }
        let _ = writeln!(
            out,
            "# HELP deltadq_tenant_tokens_total Tokens per tenant by direction (prompt in, generated out)."
        );
        let _ = writeln!(out, "# TYPE deltadq_tenant_tokens_total counter");
        for (tenant, totals) in &usage_rows {
            let t = esc(tenant);
            let _ = writeln!(
                out,
                "deltadq_tenant_tokens_total{{tenant=\"{t}\",dir=\"in\"}} {}",
                totals.tokens_in
            );
            let _ = writeln!(
                out,
                "deltadq_tenant_tokens_total{{tenant=\"{t}\",dir=\"out\"}} {}",
                totals.tokens_out
            );
        }
        let _ = writeln!(
            out,
            "# HELP deltadq_tenant_rejected_total Rejected submissions per tenant by HTTP status."
        );
        let _ = writeln!(out, "# TYPE deltadq_tenant_rejected_total counter");
        for (tenant, totals) in &usage_rows {
            let t = esc(tenant);
            let _ = writeln!(
                out,
                "deltadq_tenant_rejected_total{{tenant=\"{t}\",status=\"429\"}} {}",
                totals.rejected_429
            );
            let _ = writeln!(
                out,
                "deltadq_tenant_rejected_total{{tenant=\"{t}\",status=\"503\"}} {}",
                totals.rejected_503
            );
        }
    }

    let _ = writeln!(out, "# HELP deltadq_build_info Build metadata (value is always 1).");
    let _ = writeln!(out, "# TYPE deltadq_build_info gauge");
    let _ = writeln!(
        out,
        "deltadq_build_info{{version=\"{}\",git_sha=\"{}\",features=\"{}\"}} 1",
        env!("CARGO_PKG_VERSION"),
        option_env!("DELTADQ_GIT_SHA").unwrap_or("unknown"),
        if cfg!(feature = "pjrt") { "pjrt" } else { "default" },
    );

    // written last so it covers the whole render, including itself
    let _ = writeln!(
        out,
        "# HELP deltadq_metrics_render_seconds Wall time spent rendering this exposition."
    );
    let _ = writeln!(out, "# TYPE deltadq_metrics_render_seconds gauge");
    let _ = writeln!(
        out,
        "deltadq_metrics_render_seconds {}",
        render_start.elapsed().as_secs_f64()
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_parse_and_validate() {
        let p = parse_params(
            br#"{"tenant":"math","prompt":[1,2,3],"max_tokens":4,"stream":true}"#,
        )
        .unwrap();
        assert_eq!(p.tenant, "math");
        assert_eq!(p.prompt, vec![1, 2, 3]);
        assert_eq!(p.max_tokens, 4);
        assert!(p.stream);

        let defaults = parse_params(br#"{"tenant":"t","prompt":[7]}"#).unwrap();
        assert_eq!(defaults.max_tokens, 16);
        assert!(!defaults.stream);

        assert!(parse_params(b"not json").is_err());
        assert!(parse_params(br#"{"prompt":[1]}"#).unwrap_err().contains("tenant"));
        assert!(parse_params(br#"{"tenant":"t"}"#).unwrap_err().contains("prompt"));
        assert!(parse_params(br#"{"tenant":"t","prompt":[]}"#).is_err());
        assert!(parse_params(br#"{"tenant":"t","prompt":[-1]}"#).is_err());
        assert!(parse_params(br#"{"tenant":"t","prompt":[1.5]}"#).is_err());
    }

    #[test]
    fn submit_errors_map_to_statuses() {
        let (s, msg) = submit_error_status(&SubmitError::Backpressure {
            tenant: "a".into(),
            depth: 4,
        });
        assert_eq!(s, 429);
        assert!(msg.contains("queue full"));
        let (s, _) = submit_error_status(&SubmitError::UnknownTenant("g".into()));
        assert_eq!(s, 404);
        let (s, _) = submit_error_status(&SubmitError::Closed);
        assert_eq!(s, 503);
        let (s, msg) = submit_error_status(&SubmitError::Quarantined {
            tenant: "q".into(),
            retry_after_s: 2,
        });
        assert_eq!(s, 503);
        assert!(msg.contains("quarantined"));
        assert!(msg.contains("2s"));
    }

    #[test]
    fn ttl_ms_parses_and_validates() {
        let p = parse_params(br#"{"tenant":"t","prompt":[1],"ttl_ms":250}"#).unwrap();
        assert_eq!(p.ttl, Some(Duration::from_millis(250)));
        let none = parse_params(br#"{"tenant":"t","prompt":[1]}"#).unwrap();
        assert_eq!(none.ttl, None);
        assert!(parse_params(br#"{"tenant":"t","prompt":[1],"ttl_ms":0}"#).is_err());
        assert!(parse_params(br#"{"tenant":"t","prompt":[1],"ttl_ms":"soon"}"#).is_err());
    }

    #[test]
    fn response_json_carries_tokens_and_error() {
        let resp = Response {
            id: 7,
            tenant: "math".into(),
            tokens: vec![5, 6],
            queue_wait: Duration::from_millis(2),
            total: Duration::from_millis(9),
            served_hot: true,
            error: None,
        };
        let j = response_json(&resp);
        let text = j.to_string();
        assert!(text.contains("\"tokens\":[5,6]"), "{text}");
        assert!(text.contains("\"served_hot\":true"), "{text}");
        assert!(!text.contains("\"error\""), "{text}");
    }
}
