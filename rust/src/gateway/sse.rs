//! Server-sent-events framing for `POST /v1/completions` with
//! `"stream": true`.
//!
//! Wire format (each frame is one chunked-transfer chunk, flushed as
//! soon as the token decodes):
//!
//! ```text
//! data: {"index":0,"token":17}\n\n
//! data: {"index":1,"token":4}\n\n
//! data: {"done":true,"id":9,"tenant":"math","tokens":[17,4],...}\n\n
//! data: [DONE]\n\n
//! ```
//!
//! Every `data:` payload except the final sentinel is a JSON object
//! built with [`crate::util::json::Json`]; a request that fails after
//! streaming began carries an `"error"` key on its `done` frame.

use crate::coordinator::Response;
use crate::util::json::Json;

/// Terminal sentinel frame (mirrors the OpenAI streaming convention).
pub const DONE_SENTINEL: &str = "[DONE]";

/// Encode one payload as an SSE frame.
pub fn frame(payload: &str) -> Vec<u8> {
    format!("data: {payload}\n\n").into_bytes()
}

/// Frame for one decoded token.
pub fn token_frame(index: usize, token: u32) -> Vec<u8> {
    let mut o = Json::obj();
    o.set("index", index).set("token", token);
    frame(&o.to_string())
}

/// Terminal `done` frame carrying the full response summary (same
/// fields as the non-streaming response body, plus `"done": true`).
pub fn done_frame(resp: &Response) -> Vec<u8> {
    let mut o = super::routes::response_json(resp);
    o.set("done", true);
    frame(&o.to_string())
}

/// Split a complete SSE body into its `data:` payloads (client side —
/// loadgen and the integration tests).
pub fn parse_payloads(body: &str) -> Vec<String> {
    body.split("\n\n")
        .filter_map(|block| block.trim_start().strip_prefix("data:"))
        .map(|p| p.trim().to_string())
        .collect()
}

/// Extract the `data:` payload from a single frame, if `buf` holds one.
pub fn payload_of(frame: &[u8]) -> Option<String> {
    let text = std::str::from_utf8(frame).ok()?;
    text.trim_end_matches('\n').trim_start().strip_prefix("data:").map(|p| p.trim().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_frames_roundtrip() {
        let f = token_frame(3, 42);
        let payload = payload_of(&f).unwrap();
        let j = Json::parse(&payload).unwrap();
        assert_eq!(j.get("index").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("token").unwrap().as_u64(), Some(42));
    }

    #[test]
    fn parse_payloads_splits_frames() {
        let body = "data: {\"a\":1}\n\ndata: {\"b\":2}\n\ndata: [DONE]\n\n";
        let got = parse_payloads(body);
        assert_eq!(got, vec!["{\"a\":1}", "{\"b\":2}", DONE_SENTINEL]);
    }
}
