//! Minimal HTTP/1.1 wire handling over `std::net` — just enough for
//! the gateway's three routes and the loadgen client: request-line +
//! header parsing, `Content-Length` bodies, fixed and chunked response
//! writing, and a client-side response parser (used by the load
//! generator and the integration tests).
//!
//! Deliberately not a general HTTP implementation: no multipart, no
//! compression, no trailers, no request pipelining. Unsupported
//! constructs fail fast with a 4xx instead of being half-handled.

use std::io::{BufRead, Read, Write};

use anyhow::{bail, Context, Result};

/// Largest accepted request body (a prompt of ~100k tokens as JSON).
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Largest accepted request-line + header block.
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    /// Request method verbatim ("GET", "POST", ...).
    pub method: String,
    /// Request target as sent (path + optional query string).
    pub path: String,
    /// "HTTP/1.1" or "HTTP/1.0".
    pub version: String,
    /// Header names lower-cased; values trimmed.
    pub headers: Vec<(String, String)>,
    /// Raw request body (empty when no `content-length` was sent).
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the connection should stay open after this exchange.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(str::to_ascii_lowercase) {
            Some(c) if c.contains("close") => false,
            Some(c) if c.contains("keep-alive") => true,
            // HTTP/1.1 defaults to persistent, 1.0 to close
            _ => self.version == "HTTP/1.1",
        }
    }
}

/// Read one request off the connection. `Ok(None)` means the peer
/// closed cleanly before sending another request (normal keep-alive
/// termination); malformed input is an error the caller answers with
/// a 400.
pub fn read_request(reader: &mut impl BufRead) -> Result<Option<HttpRequest>> {
    let Some(request_line) = read_line(reader, MAX_HEAD_BYTES)? else {
        return Ok(None);
    };
    if request_line.is_empty() {
        bail!("empty request line");
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.0").to_string();
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        bail!("unsupported version '{version}'");
    }

    let mut headers = Vec::new();
    let mut head_bytes = request_line.len();
    loop {
        let line = read_line(reader, MAX_HEAD_BYTES)?.context("eof inside headers")?;
        if line.is_empty() {
            break;
        }
        head_bytes += line.len();
        if head_bytes > MAX_HEAD_BYTES {
            bail!("header block too large");
        }
        let (name, value) = line.split_once(':').with_context(|| format!("bad header '{line}'"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut req = HttpRequest { method, path, version, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        bail!("chunked request bodies are not supported");
    }
    if let Some(len) = req.header("content-length") {
        let len: usize = len.parse().with_context(|| format!("bad content-length '{len}'"))?;
        if len > MAX_BODY_BYTES {
            bail!("body of {len} bytes exceeds the {MAX_BODY_BYTES} limit");
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).context("short body")?;
        req.body = body;
    }
    Ok(Some(req))
}

/// Read one CRLF (or bare-LF) terminated line, without the terminator.
/// `Ok(None)` on immediate EOF.
fn read_line(reader: &mut impl BufRead, limit: usize) -> Result<Option<String>> {
    let mut buf = Vec::new();
    let n = reader
        .by_ref()
        .take(limit as u64 + 2)
        .read_until(b'\n', &mut buf)
        .context("read line")?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
    } else if buf.len() > limit {
        bail!("line exceeds {limit} bytes");
    }
    Ok(Some(String::from_utf8(buf).context("non-utf8 header data")?))
}

/// Standard reason phrase for the status codes the gateway emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response. `extra_headers` are emitted
/// verbatim (e.g. `("Retry-After", "1")`).
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra_headers: &[(&str, &str)],
) -> Result<()> {
    write!(w, "HTTP/1.1 {status} {}\r\n", reason(status))?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    write!(w, "Connection: {}\r\n", if keep_alive { "keep-alive" } else { "close" })?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Chunked-transfer body writer for streaming responses. Callers write
/// the header block via [`start`], then any number of chunks, then
/// [`finish`] for the zero-length terminator.
///
/// [`start`]: ChunkedWriter::start
/// [`finish`]: ChunkedWriter::finish
pub struct ChunkedWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkedWriter<W> {
    /// Write the response head announcing a chunked body.
    pub fn start(
        mut w: W,
        status: u16,
        content_type: &str,
        keep_alive: bool,
    ) -> Result<ChunkedWriter<W>> {
        write!(w, "HTTP/1.1 {status} {}\r\n", reason(status))?;
        write!(w, "Content-Type: {content_type}\r\n")?;
        w.write_all(b"Transfer-Encoding: chunked\r\n")?;
        w.write_all(b"Cache-Control: no-store\r\n")?;
        write!(w, "Connection: {}\r\n", if keep_alive { "keep-alive" } else { "close" })?;
        w.write_all(b"\r\n")?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// Write one chunk (flushed immediately — each streamed token must
    /// hit the wire without waiting for the next).
    pub fn chunk(&mut self, data: &[u8]) -> Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the body
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()?;
        Ok(())
    }

    /// Terminate the chunked body.
    pub fn finish(mut self) -> Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()?;
        Ok(())
    }
}

/// One parsed client-side HTTP response.
#[derive(Debug)]
pub struct HttpResponse {
    /// Numeric status code from the status line.
    pub status: u16,
    /// Header names lower-cased; values trimmed.
    pub headers: Vec<(String, String)>,
    /// Response body (filled by [`read_response`]; empty from
    /// [`read_response_head`]).
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// Client side: read one full response (status line, headers, body —
/// fixed-length, chunked, or read-to-EOF). Used by loadgen's
/// non-streaming path and the integration tests; the streaming path
/// uses [`read_response_head`] + [`ChunkReader`] to timestamp frames.
pub fn read_response(reader: &mut impl BufRead) -> Result<HttpResponse> {
    let mut resp = read_response_head(reader)?;
    if resp.header("transfer-encoding").map(str::to_ascii_lowercase).as_deref() == Some("chunked")
    {
        let mut chunks = ChunkReader::new();
        while let Some(chunk) = chunks.next_chunk(reader)? {
            resp.body.extend_from_slice(&chunk);
        }
    } else if let Some(len) = resp.header("content-length") {
        let len: usize = len.parse().with_context(|| format!("bad content-length '{len}'"))?;
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).context("short response body")?;
        resp.body = body;
    } else {
        reader.read_to_end(&mut resp.body)?;
    }
    Ok(resp)
}

/// Client side: status line + headers only (body left to the caller).
pub fn read_response_head(reader: &mut impl BufRead) -> Result<HttpResponse> {
    let status_line = read_line(reader, MAX_HEAD_BYTES)?.context("eof before status line")?;
    let mut parts = status_line.split_whitespace();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("bad status line '{status_line}'");
    }
    let status: u16 = parts.next().context("missing status")?.parse()?;
    let mut headers = Vec::new();
    loop {
        let line = read_line(reader, MAX_HEAD_BYTES)?.context("eof inside headers")?;
        if line.is_empty() {
            break;
        }
        let (name, value) = line.split_once(':').with_context(|| format!("bad header '{line}'"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    Ok(HttpResponse { status, headers, body: Vec::new() })
}

/// Client side: incremental chunked-body reader. `next_chunk` blocks
/// until one whole chunk arrives — which for the gateway's SSE stream
/// means "one flushed event" — so callers can timestamp arrivals.
#[derive(Default)]
pub struct ChunkReader {
    done: bool,
}

impl ChunkReader {
    /// Fresh reader positioned before the first chunk.
    pub fn new() -> ChunkReader {
        ChunkReader::default()
    }

    /// `Ok(None)` once the terminating zero-length chunk is consumed.
    pub fn next_chunk(&mut self, reader: &mut impl BufRead) -> Result<Option<Vec<u8>>> {
        if self.done {
            return Ok(None);
        }
        let size_line = read_line(reader, 64)?.context("eof inside chunked body")?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .with_context(|| format!("bad chunk size '{size_line}'"))?;
        if size == 0 {
            // consume the trailing CRLF after the last-chunk marker
            let _ = read_line(reader, MAX_HEAD_BYTES)?;
            self.done = true;
            return Ok(None);
        }
        if size > MAX_BODY_BYTES {
            bail!("chunk of {size} bytes exceeds the {MAX_BODY_BYTES} limit");
        }
        let mut data = vec![0u8; size];
        reader.read_exact(&mut data).context("short chunk")?;
        let crlf = read_line(reader, 8)?.context("missing chunk terminator")?;
        if !crlf.is_empty() {
            bail!("chunk not CRLF-terminated");
        }
        Ok(Some(data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let req = read_request(&mut BufReader::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"), "case-insensitive");
        assert_eq!(req.body, b"abcd");
        assert!(req.keep_alive(), "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn clean_eof_is_none_and_garbage_is_error() {
        assert!(read_request(&mut BufReader::new(&b""[..])).unwrap().is_none());
        assert!(read_request(&mut BufReader::new(&b"not http\r\n\r\n"[..])).is_err());
        let oversized =
            format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(read_request(&mut BufReader::new(oversized.as_bytes())).is_err());
    }

    #[test]
    fn connection_close_overrides_version_default() {
        let raw = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let req = read_request(&mut BufReader::new(&raw[..])).unwrap().unwrap();
        assert!(!req.keep_alive());
        let raw10 = b"GET / HTTP/1.0\r\n\r\n";
        let req10 = read_request(&mut BufReader::new(&raw10[..])).unwrap().unwrap();
        assert!(!req10.keep_alive(), "HTTP/1.0 defaults to close");
    }

    #[test]
    fn response_roundtrip_fixed() {
        let mut wire = Vec::new();
        write_response(&mut wire, 429, "application/json", b"{}", false, &[("Retry-After", "1")])
            .unwrap();
        let resp = read_response(&mut BufReader::new(&wire[..])).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.body, b"{}");
    }

    #[test]
    fn response_roundtrip_chunked() {
        let mut wire = Vec::new();
        let mut cw = ChunkedWriter::start(&mut wire, 200, "text/event-stream", true).unwrap();
        cw.chunk(b"data: 1\n\n").unwrap();
        cw.chunk(b"data: 2\n\n").unwrap();
        cw.finish().unwrap();
        // incremental reader sees each flushed chunk separately
        let mut r = BufReader::new(&wire[..]);
        let head = read_response_head(&mut r).unwrap();
        assert_eq!(head.status, 200);
        assert_eq!(head.header("transfer-encoding"), Some("chunked"));
        let mut chunks = ChunkReader::new();
        assert_eq!(chunks.next_chunk(&mut r).unwrap().unwrap(), b"data: 1\n\n");
        assert_eq!(chunks.next_chunk(&mut r).unwrap().unwrap(), b"data: 2\n\n");
        assert!(chunks.next_chunk(&mut r).unwrap().is_none());
        // and the one-shot reader reassembles the full body
        let resp = read_response(&mut BufReader::new(&wire[..])).unwrap();
        assert_eq!(resp.body, b"data: 1\n\ndata: 2\n\n");
    }
}
