//! Step 1 of the pipeline (paper Fig. 2): **Split Weight** —
//! `ΔW_i = W_i − W_b` for every compressible tensor.

use std::collections::BTreeMap;

use crate::model::weights::ModelWeights;
use crate::tensor::Matrix;

/// Extract per-tensor deltas between a fine-tuned model and its base.
/// Only the linear-layer tensors (`config.delta_tensor_names()`) are
/// extracted; embeddings and norms ride with the base (the paper
/// compresses the Linear deltas).
pub fn extract_deltas(base: &ModelWeights, finetuned: &ModelWeights) -> BTreeMap<String, Matrix> {
    assert_eq!(base.config, finetuned.config, "mismatched configs");
    let mut deltas = BTreeMap::new();
    for name in base.config.delta_tensor_names() {
        let d = finetuned.get(&name).sub(base.get(&name));
        deltas.insert(name, d);
    }
    deltas
}

/// Summary of how large the deltas are relative to the base — the
/// precondition for the whole method (`‖ΔW‖ ≪ ‖W‖`, DESIGN.md §2).
#[derive(Debug, Clone)]
pub struct DeltaNormReport {
    /// Per-tensor (‖ΔW‖_F, ‖W_b‖_F).
    pub per_tensor: Vec<(String, f64, f64)>,
}

impl DeltaNormReport {
    /// Frobenius norms of each delta tensor and its base counterpart.
    pub fn compute(base: &ModelWeights, deltas: &BTreeMap<String, Matrix>) -> DeltaNormReport {
        let per_tensor = deltas
            .iter()
            .map(|(name, d)| {
                (
                    name.clone(),
                    d.frobenius_norm() as f64,
                    base.get(name).frobenius_norm() as f64,
                )
            })
            .collect();
        DeltaNormReport { per_tensor }
    }

    /// Mean of per-tensor ‖Δ‖/‖W‖ ratios.
    pub fn mean_relative_norm(&self) -> f64 {
        if self.per_tensor.is_empty() {
            return 0.0;
        }
        self.per_tensor.iter().map(|(_, d, b)| d / b.max(1e-12)).sum::<f64>()
            / self.per_tensor.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::tensor::Pcg64;

    #[test]
    fn extract_then_apply_roundtrips() {
        let mut rng = Pcg64::seeded(1);
        let base = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let mut ft = base.clone();
        // perturb a couple of tensors like fine-tuning would
        ft.get_mut("layers.1.attn.wq").add_scaled(&Matrix::full(64, 64, 0.01), 1.0);
        ft.get_mut("layers.1.mlp.up").add_scaled(&Matrix::full(128, 64, -0.02), 1.0);
        let deltas = extract_deltas(&base, &ft);
        assert_eq!(deltas.len(), base.config.n_layers * 7);
        let rebuilt = base.apply_deltas(&deltas);
        for (name, tensor) in ft.iter() {
            assert!(rebuilt.get(name).allclose(tensor, 1e-6, 0.0), "{name}");
        }
    }

    #[test]
    fn untouched_tensors_have_zero_delta() {
        let mut rng = Pcg64::seeded(2);
        let base = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let ft = base.clone();
        let deltas = extract_deltas(&base, &ft);
        for (name, d) in &deltas {
            assert_eq!(d.count_nonzeros(), 0, "{name}");
        }
    }

    #[test]
    fn norm_report_reflects_scale() {
        let mut rng = Pcg64::seeded(3);
        let base = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let mut ft = base.clone();
        for name in base.config.delta_tensor_names() {
            let shape = ft.get(&name).shape();
            let mut rng2 = Pcg64::seeded(4);
            // deltas at 1% of init std
            ft.get_mut(&name)
                .add_assign(&Matrix::randn(shape.0, shape.1, 0.0002, &mut rng2));
        }
        let deltas = extract_deltas(&base, &ft);
        let report = DeltaNormReport::compute(&base, &deltas);
        let rel = report.mean_relative_norm();
        assert!(rel > 0.0 && rel < 0.05, "relative norm {rel}");
    }
}
