//! Delta management (S7): extraction (`ΔW = W_ft − W_b`), the `.ddq`
//! on-disk format for compressed delta sets, and the per-tenant
//! registry with Hot/Cold residency and LRU dense-cache eviction.

pub mod extract;
pub mod format;
pub mod registry;

pub use extract::{extract_deltas, DeltaNormReport};
pub use format::{load_delta_set, save_delta_set, DeltaSet};
pub use registry::{DeltaRegistry, Residency, TenantEntry};
