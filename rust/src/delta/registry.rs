//! In-memory registry of per-tenant compressed delta sets (S7).
//!
//! The serving coordinator keys tenants by id; each tenant owns one
//! [`DeltaSet`] plus residency state. The registry enforces a byte
//! budget with LRU eviction of *reconstruction caches* (the compressed
//! deltas themselves are small and always resident — that is the
//! paper's deployment story; what competes for memory is the densified
//! `W_b + Δ` fast path).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::delta::format::DeltaSet;
use crate::model::weights::ModelWeights;
use crate::store::DeltaStore;

/// Residency of a tenant's dense reconstruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// Compressed only; every request pays the separate-computation path.
    Cold,
    /// Dense `W_b + Δ` materialized and cached; requests use one matmul.
    Hot,
}

/// One tenant's registered model delta.
#[derive(Debug)]
pub struct TenantEntry {
    /// Owning tenant's identifier.
    pub tenant_id: String,
    /// The tenant's compressed deltas (always resident).
    pub deltas: DeltaSet,
    /// Densified weights, present iff `Hot`.
    pub dense_cache: Option<ModelWeights>,
    /// Monotone counter of last use (LRU clock).
    pub last_used: u64,
    /// Requests this tenant has served since registration.
    pub requests_served: u64,
}

impl TenantEntry {
    /// Compressed resident bytes.
    pub fn compressed_bytes(&self) -> u64 {
        self.deltas.storage_bits() / 8
    }

    /// Dense-cache resident bytes (0 when cold).
    pub fn cache_bytes(&self) -> u64 {
        self.dense_cache
            .as_ref()
            .map(|w| w.resident_bytes())
            .unwrap_or(0)
    }

    /// Current residency tier (Hot iff the dense cache is present).
    pub fn residency(&self) -> Residency {
        if self.dense_cache.is_some() {
            Residency::Hot
        } else {
            Residency::Cold
        }
    }
}

/// Tenant registry with an optional dense-cache byte budget.
#[derive(Debug)]
pub struct DeltaRegistry {
    tenants: BTreeMap<String, TenantEntry>,
    clock: u64,
    /// Max bytes of dense caches (None = unbounded).
    cache_budget: Option<u64>,
}

impl DeltaRegistry {
    /// Empty registry; `cache_budget` caps dense-cache bytes (None = unbounded).
    pub fn new(cache_budget: Option<u64>) -> DeltaRegistry {
        DeltaRegistry { tenants: BTreeMap::new(), clock: 0, cache_budget }
    }

    /// Register (or replace) a tenant's compressed deltas.
    pub fn register(&mut self, tenant_id: &str, deltas: DeltaSet) {
        self.clock += 1;
        self.tenants.insert(
            tenant_id.to_string(),
            TenantEntry {
                tenant_id: tenant_id.to_string(),
                deltas,
                dense_cache: None,
                last_used: self.clock,
                requests_served: 0,
            },
        );
    }

    /// Remove a tenant entirely; returns whether it existed.
    pub fn unregister(&mut self, tenant_id: &str) -> bool {
        self.tenants.remove(tenant_id).is_some()
    }

    /// Look up a tenant's entry without touching the LRU clock.
    pub fn get(&self, tenant_id: &str) -> Option<&TenantEntry> {
        self.tenants.get(tenant_id)
    }

    /// Touch a tenant for a request: bumps LRU clock and counters.
    pub fn touch(&mut self, tenant_id: &str) -> bool {
        self.clock += 1;
        let clock = self.clock;
        match self.tenants.get_mut(tenant_id) {
            Some(e) => {
                e.last_used = clock;
                e.requests_served += 1;
                true
            }
            None => false,
        }
    }

    /// Registered tenant ids, sorted.
    pub fn tenant_ids(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    /// Number of registered tenants.
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// Whether no tenants are registered.
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Total compressed bytes across tenants.
    pub fn compressed_bytes(&self) -> u64 {
        self.tenants.values().map(|e| e.compressed_bytes()).sum()
    }

    /// Total dense-cache bytes across tenants.
    pub fn cache_bytes(&self) -> u64 {
        self.tenants.values().map(|e| e.cache_bytes()).sum()
    }

    /// Promote a tenant to Hot by materializing `W_b + Δ`, evicting LRU
    /// dense caches if the budget would be exceeded. Returns the evicted
    /// tenant ids.
    pub fn promote(&mut self, tenant_id: &str, base: &ModelWeights) -> Vec<String> {
        let mut evicted = Vec::new();
        let Some(entry) = self.tenants.get(tenant_id) else {
            return evicted;
        };
        if entry.dense_cache.is_some() {
            return evicted;
        }
        // Materialize dense weights: base + delta per tensor.
        let mut dense = base.clone();
        for (name, delta) in &self.tenants[tenant_id].deltas.tensors {
            delta.add_to_dense(dense.get_mut(name), 1.0);
        }
        let new_bytes = dense.resident_bytes();
        if let Some(budget) = self.cache_budget {
            // LRU-evict other hot tenants until the new cache fits.
            while self.cache_bytes() + new_bytes > budget {
                let victim = self
                    .tenants
                    .values()
                    .filter(|e| e.dense_cache.is_some() && e.tenant_id != tenant_id)
                    .min_by_key(|e| e.last_used)
                    .map(|e| e.tenant_id.clone());
                match victim {
                    Some(v) => {
                        self.tenants.get_mut(&v).unwrap().dense_cache = None;
                        evicted.push(v);
                    }
                    None => break, // nothing left to evict
                }
            }
            if new_bytes > budget {
                // cannot ever fit; stay cold
                return evicted;
            }
        }
        self.tenants.get_mut(tenant_id).unwrap().dense_cache = Some(dense);
        evicted
    }

    /// Demote a tenant to Cold (drop its dense cache).
    pub fn demote(&mut self, tenant_id: &str) {
        if let Some(e) = self.tenants.get_mut(tenant_id) {
            e.dense_cache = None;
        }
    }

    /// Persist every registered tenant into an on-disk [`DeltaStore`]
    /// (the offline half of the push workflow: compress → register →
    /// persist). Returns the total payload bytes written.
    pub fn persist_all(&self, store: &DeltaStore) -> Result<u64> {
        let mut total = 0u64;
        for entry in self.tenants.values() {
            total += store.push(&entry.tenant_id, &entry.deltas)?;
        }
        Ok(total)
    }

    /// Register a tenant by hydrating it from a store (Cold residency).
    pub fn register_from_store(&mut self, store: &DeltaStore, tenant_id: &str) -> Result<()> {
        let set = store.load(tenant_id)?;
        self.register(tenant_id, set);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::model::ModelConfig;
    use crate::tensor::{Matrix, Pcg64};

    fn base() -> ModelWeights {
        let mut rng = Pcg64::seeded(1);
        ModelWeights::init(ModelConfig::tiny(), &mut rng)
    }

    fn delta_set(seed: u64) -> DeltaSet {
        let mut rng = Pcg64::seeded(seed);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(4.0, Some(16)));
        let mut set = DeltaSet::new(&dq.name(), 4.0);
        let c = ModelConfig::tiny();
        for name in c.delta_tensor_names() {
            let (r, cc) = if name.contains("mlp.gate") || name.contains("mlp.up") {
                (c.ffn_hidden, c.hidden)
            } else if name.contains("mlp.down") {
                (c.hidden, c.ffn_hidden)
            } else {
                (c.hidden, c.hidden)
            };
            let d = Matrix::randn(r, cc, 0.002, &mut rng);
            set.tensors
                .insert(name.clone(), dq.compress(&d, &LayerContext::data_free(0, &name), &mut rng));
        }
        set
    }

    #[test]
    fn register_and_touch() {
        let mut reg = DeltaRegistry::new(None);
        reg.register("math", delta_set(2));
        assert_eq!(reg.len(), 1);
        assert!(reg.touch("math"));
        assert!(!reg.touch("nope"));
        assert_eq!(reg.get("math").unwrap().requests_served, 1);
    }

    #[test]
    fn promote_materializes_base_plus_delta() {
        let b = base();
        let mut reg = DeltaRegistry::new(None);
        reg.register("t", delta_set(3));
        reg.promote("t", &b);
        let entry = reg.get("t").unwrap();
        assert_eq!(entry.residency(), Residency::Hot);
        let dense = entry.dense_cache.as_ref().unwrap();
        // the cached weights differ from base exactly by the delta
        let name = "layers.0.attn.wq";
        let want = {
            let mut w = b.get(name).clone();
            entry.deltas.tensors[name].add_to_dense(&mut w, 1.0);
            w
        };
        assert!(dense.get(name).allclose(&want, 1e-6, 0.0));
    }

    #[test]
    fn budget_evicts_lru() {
        let b = base();
        let one_cache = b.resident_bytes();
        // room for exactly two dense caches
        let mut reg = DeltaRegistry::new(Some(2 * one_cache + 1024));
        reg.register("a", delta_set(4));
        reg.register("b", delta_set(5));
        reg.register("c", delta_set(6));
        assert!(reg.promote("a", &b).is_empty());
        assert!(reg.promote("b", &b).is_empty());
        // touch a so b becomes LRU
        reg.touch("a");
        let evicted = reg.promote("c", &b);
        assert_eq!(evicted, vec!["b".to_string()]);
        assert_eq!(reg.get("b").unwrap().residency(), Residency::Cold);
        assert_eq!(reg.get("a").unwrap().residency(), Residency::Hot);
        assert_eq!(reg.get("c").unwrap().residency(), Residency::Hot);
    }

    #[test]
    fn compressed_far_smaller_than_cache() {
        let b = base();
        let mut reg = DeltaRegistry::new(None);
        reg.register("t", delta_set(7));
        reg.promote("t", &b);
        let e = reg.get("t").unwrap();
        // the whole point: compressed deltas ≪ densified model
        assert!(e.compressed_bytes() * 2 < e.cache_bytes());
    }

    #[test]
    fn demote_frees_cache() {
        let b = base();
        let mut reg = DeltaRegistry::new(None);
        reg.register("t", delta_set(8));
        reg.promote("t", &b);
        assert!(reg.cache_bytes() > 0);
        reg.demote("t");
        assert_eq!(reg.cache_bytes(), 0);
    }

    #[test]
    fn unregister_removes() {
        let mut reg = DeltaRegistry::new(None);
        reg.register("t", delta_set(9));
        assert!(reg.unregister("t"));
        assert!(!reg.unregister("t"));
        assert!(reg.is_empty());
    }

    #[test]
    fn persist_and_rehydrate_through_store() {
        let root = std::env::temp_dir()
            .join("deltadq-test-registry-store")
            .join(format!("{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let store = DeltaStore::open_or_create(&root).unwrap();
        let mut reg = DeltaRegistry::new(None);
        reg.register("a", delta_set(20));
        reg.register("b", delta_set(21));
        let written = reg.persist_all(&store).unwrap();
        assert!(written > 0);
        assert_eq!(store.tenant_count(), 2);

        let mut fresh = DeltaRegistry::new(None);
        fresh.register_from_store(&store, "a").unwrap();
        assert!(fresh.register_from_store(&store, "ghost").is_err());
        let orig = reg.get("a").unwrap();
        let back = fresh.get("a").unwrap();
        assert_eq!(back.deltas.nnz(), orig.deltas.nnz());
        for (name, t) in &orig.deltas.tensors {
            assert_eq!(back.deltas.tensors[name].to_dense(), t.to_dense(), "{name}");
        }
    }
}
