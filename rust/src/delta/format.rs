//! On-disk format for compressed delta sets — the `.ddq` file.
//!
//! One file holds every compressed tensor of one fine-tuned model
//! (tenant), plus metadata: method name, nominal ratio, and the original
//! model scale. The coordinator memory-maps nothing fancy — files are
//! small by construction (that is the point of the paper).
//!
//! Layout (little-endian):
//!
//! ```text
//! magic    b"DDQD"
//! version  u32 (=2; v1 files — no trailer — remain readable)
//! method   str16        (length-prefixed utf-8, u16 length)
//! ratio    f64          nominal compression ratio
//! count    u32          number of tensors
//! tensor*:
//!   name   str16
//!   kind   u8           0 = Sparse CSR fp32, 1 = Quantized decomposed
//!   Sparse:    rows u32 | cols u32 | nnz u32 | offsets u32[rows+1]
//!              | cols u32[nnz] | values f32[nnz]
//!   Quantized: rows u32 | cols u32 | k u32 | m u32 | scale f32 | zero i32
//!              | per part: nnz u32 | offsets u32[rows+1] | cols u32[nnz]
//!                | words u64: n_words u32 then u64[n_words]
//! norms    (v3+)        count u32, then per entry: name str16 | f64 —
//!                       pre-quantization Frobenius norm of each delta
//!                       tensor, the audit subsystem's reconstruction-
//!                       error reference
//! crc32    u32 (v2+)    CRC-32 of every preceding byte — truncated or
//!                       bit-flipped files fail loudly at load time
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::compress::CompressedDelta;
use crate::quant::separate::{DecomposedDelta, QuantPart};
use crate::quant::uniform::QuantParams;
use crate::sparse::bitpack::PackedCodes;
use crate::sparse::csr::CsrMatrix;
use crate::util::crc32::crc32;

const MAGIC: &[u8; 4] = b"DDQD";
/// Current write version. v2 appends the trailing CRC-32; v3 inserts the
/// pre-quantization norms table between the body and the trailer.
const VERSION: u32 = 3;
/// Oldest version still readable (pre-checksum files).
const MIN_VERSION: u32 = 1;

/// A named set of compressed deltas plus provenance metadata.
#[derive(Debug, Clone)]
pub struct DeltaSet {
    /// Compression method that produced the set (e.g. "deltadq").
    pub method: String,
    /// Ratio the method was configured for (target, not measured).
    pub nominal_ratio: f64,
    /// Compressed delta per tensor name.
    pub tensors: BTreeMap<String, CompressedDelta>,
    /// Pre-quantization Frobenius norm per tensor name, recorded at
    /// compression time (empty for sets from pre-v3 files). The audit
    /// subsystem scores per-layer reconstruction error against these.
    pub norms: BTreeMap<String, f64>,
}

impl DeltaSet {
    /// Empty set tagged with its producing method and target ratio.
    pub fn new(method: &str, nominal_ratio: f64) -> DeltaSet {
        DeltaSet {
            method: method.to_string(),
            nominal_ratio,
            tensors: BTreeMap::new(),
            norms: BTreeMap::new(),
        }
    }

    /// Total measured storage (bits) across tensors.
    pub fn storage_bits(&self) -> u64 {
        self.tensors.values().map(|t| t.storage_bits()).sum()
    }

    /// Total stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.tensors.values().map(|t| t.nnz()).sum()
    }

    /// Total delta elements (dense count).
    pub fn total_elems(&self) -> u64 {
        self.tensors
            .values()
            .map(|t| {
                let (r, c) = t.shape();
                (r * c) as u64
            })
            .sum()
    }

    /// Measured storage compression ratio vs dense fp16.
    pub fn measured_ratio(&self) -> f64 {
        crate::compress::ratio::storage_ratio(self.total_elems(), self.storage_bits())
    }
}

// ---------------------------------------------------------------- write

fn w_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_str16(w: &mut impl Write, s: &str) -> Result<()> {
    let b = s.as_bytes();
    if b.len() > u16::MAX as usize {
        bail!("string too long");
    }
    w.write_all(&(b.len() as u16).to_le_bytes())?;
    w.write_all(b)?;
    Ok(())
}

fn w_u32_slice(w: &mut impl Write, xs: &[u32]) -> Result<()> {
    let bytes: Vec<u8> = xs.iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

fn w_f32_slice(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let bytes: Vec<u8> = xs.iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

fn write_csr(w: &mut impl Write, csr: &CsrMatrix) -> Result<()> {
    w_u32(w, csr.rows() as u32)?;
    w_u32(w, csr.cols() as u32)?;
    w_u32(w, csr.nnz() as u32)?;
    w_u32_slice(w, csr.row_offsets())?;
    w_u32_slice(w, csr.col_indices())?;
    w_f32_slice(w, csr.values())?;
    Ok(())
}

fn write_quantized(w: &mut impl Write, d: &DecomposedDelta) -> Result<()> {
    w_u32(w, d.rows() as u32)?;
    w_u32(w, d.cols() as u32)?;
    w_u32(w, d.params.bits)?;
    w_u32(w, d.m)?;
    w.write_all(&d.params.scale.to_le_bytes())?;
    w.write_all(&d.params.zero_point.to_le_bytes())?;
    for part in &d.parts {
        w_u32(w, part.nnz() as u32)?;
        w_u32_slice(w, &part.row_offsets)?;
        w_u32_slice(w, &part.col_indices)?;
        match &part.codes {
            Some(codes) => {
                w_u32(w, codes.words().len() as u32)?;
                let bytes: Vec<u8> =
                    codes.words().iter().flat_map(|v| v.to_le_bytes()).collect();
                w.write_all(&bytes)?;
            }
            None => w_u32(w, 0)?,
        }
    }
    Ok(())
}

/// One tensor record (kind byte + payload) — the unit the delta store
/// pages in lazily; identical bytes inside a `.ddq` file and a store
/// shard.
pub(crate) fn write_tensor(w: &mut impl Write, tensor: &CompressedDelta) -> Result<()> {
    match tensor {
        CompressedDelta::Sparse(csr) => {
            w.write_all(&[0u8])?;
            write_csr(w, csr)?;
        }
        CompressedDelta::Quantized(d) => {
            w.write_all(&[1u8])?;
            write_quantized(w, d)?;
        }
        CompressedDelta::Dense(_) => {
            bail!("dense deltas are not serializable (ablation-only)")
        }
    }
    Ok(())
}

/// Serialize the body shared by every version: metadata + named tensors.
fn write_set_body(w: &mut impl Write, set: &DeltaSet) -> Result<()> {
    w_str16(w, &set.method)?;
    w.write_all(&set.nominal_ratio.to_le_bytes())?;
    w_u32(w, set.tensors.len() as u32)?;
    for (name, tensor) in &set.tensors {
        w_str16(w, name)?;
        write_tensor(w, tensor)?;
    }
    Ok(())
}

/// Save a delta set to a `.ddq` file (current version, with the norms
/// table and the trailing CRC-32).
pub fn save_delta_set(path: &Path, set: &DeltaSet) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    w_u32(&mut buf, VERSION)?;
    write_set_body(&mut buf, set)?;
    // v3: pre-quantization norms table (kept out of write_set_body so v1
    // body bytes stay exactly reproducible for compat tests and shards)
    w_u32(&mut buf, set.norms.len() as u32)?;
    for (name, norm) in &set.norms {
        w_str16(&mut buf, name)?;
        buf.extend_from_slice(&norm.to_le_bytes());
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    std::fs::write(path, &buf).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

// ----------------------------------------------------------------- read

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_i32(r: &mut impl Read) -> Result<i32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(i32::from_le_bytes(b))
}

fn r_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

fn r_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn r_str16(r: &mut impl Read) -> Result<String> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    let len = u16::from_le_bytes(b) as usize;
    let mut s = vec![0u8; len];
    r.read_exact(&mut s)?;
    Ok(String::from_utf8(s).context("utf-8")?)
}

fn r_u32_vec(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

fn r_f32_vec(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

/// Upper bounds on header fields read from a `.ddq` file. Far above any
/// real model tensor; they exist so corrupt headers fail with an error
/// instead of attempting a multi-GiB allocation.
const MAX_TENSOR_DIM: usize = 1 << 24;
const MAX_TENSOR_NNZ: usize = 1 << 26;

fn check_tensor_header(rows: usize, cols: usize, nnz: usize) -> Result<()> {
    if rows > MAX_TENSOR_DIM || cols > MAX_TENSOR_DIM {
        bail!("corrupt tensor header: {rows}x{cols} exceeds the dimension cap");
    }
    if nnz > MAX_TENSOR_NNZ {
        bail!("corrupt tensor header: nnz {nnz} exceeds the nnz cap");
    }
    if nnz as u64 > rows as u64 * cols as u64 {
        bail!("corrupt tensor header: nnz {nnz} > rows*cols = {}", rows as u64 * cols as u64);
    }
    Ok(())
}

fn read_csr(r: &mut impl Read) -> Result<CsrMatrix> {
    let rows = r_u32(r)? as usize;
    let cols = r_u32(r)? as usize;
    let nnz = r_u32(r)? as usize;
    check_tensor_header(rows, cols, nnz)?;
    let offsets = r_u32_vec(r, rows + 1)?;
    let col_indices = r_u32_vec(r, nnz)?;
    let values = r_f32_vec(r, nnz)?;
    CsrMatrix::from_parts(rows, cols, offsets, col_indices, values)
        .context("corrupt CSR tensor")
}

fn read_quantized(r: &mut impl Read) -> Result<DecomposedDelta> {
    let rows = r_u32(r)? as usize;
    let cols = r_u32(r)? as usize;
    check_tensor_header(rows, cols, 0)?;
    let bits = r_u32(r)?;
    let m = r_u32(r)?;
    if !(1..=16).contains(&bits) {
        bail!("corrupt quantized tensor: bit width {bits}");
    }
    if m == 0 || !m.is_power_of_two() || m > (1u32 << bits) {
        bail!("corrupt quantized tensor: m={m} for k={bits}");
    }
    let scale = r_f32(r)?;
    let zero_point = r_i32(r)?;
    let params = QuantParams { scale, zero_point, bits };
    let part_bits = bits - m.ilog2();
    let mut parts = Vec::with_capacity(m as usize);
    for j in 0..m {
        let nnz = r_u32(r)? as usize;
        check_tensor_header(rows, cols, nnz)?;
        let row_offsets = r_u32_vec(r, rows + 1)?;
        let col_indices = r_u32_vec(r, nnz)?;
        let n_words = r_u32(r)? as usize;
        let codes = if part_bits == 0 {
            if n_words != 0 {
                bail!("zero-width part with code words");
            }
            None
        } else {
            let expect_words = (nnz as u64 * part_bits as u64).div_ceil(64) as usize;
            if n_words != expect_words {
                bail!(
                    "corrupt quantized tensor: part {j} has {n_words} code words, \
                     expected {expect_words}"
                );
            }
            let mut bytes = vec![0u8; n_words * 8];
            r.read_exact(&mut bytes)?;
            let words: Vec<u64> = bytes
                .chunks_exact(8)
                .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
                .collect();
            Some(PackedCodes::from_words(part_bits, nnz, words))
        };
        parts.push(QuantPart { row_offsets, col_indices, codes, part_index: j });
    }
    DecomposedDelta::from_parts(rows, cols, params, m, parts)
        .context("corrupt quantized tensor")
}

/// One tensor record (kind byte + payload) — inverse of
/// [`write_tensor`], shared with the delta store's paged reads.
pub(crate) fn read_tensor(r: &mut impl Read) -> Result<CompressedDelta> {
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    match kind[0] {
        0 => Ok(CompressedDelta::Sparse(read_csr(r)?)),
        1 => Ok(CompressedDelta::Quantized(read_quantized(r)?)),
        k => bail!("unknown tensor kind {k}"),
    }
}

/// Parse the version-independent body: metadata + named tensors.
fn read_set_body(r: &mut impl Read) -> Result<DeltaSet> {
    let method = r_str16(r)?;
    let nominal_ratio = r_f64(r)?;
    let count = r_u32(r)? as usize;
    let mut set = DeltaSet::new(&method, nominal_ratio);
    for _ in 0..count {
        let name = r_str16(r)?;
        let tensor = read_tensor(r)?;
        set.tensors.insert(name, tensor);
    }
    Ok(set)
}

/// Load a `.ddq` file (v1 = no trailer, v2 = trailing CRC-32 verified
/// before any tensor payload is trusted).
///
/// The whole file is buffered deliberately: verify-before-decode needs
/// every byte hashed before the first tensor is parsed, and `.ddq`
/// artifacts are small by construction (that is the paper's point).
pub fn load_delta_set(path: &Path) -> Result<DeltaSet> {
    let buf = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
    if buf.len() < 8 || &buf[..4] != MAGIC {
        bail!("{path:?}: bad magic (expected DDQD)");
    }
    let version = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    if !(MIN_VERSION..=VERSION).contains(&version) {
        bail!("{path:?}: unsupported version {version}");
    }
    let body = if version >= 2 {
        // verify the trailer before parsing: a truncated or bit-flipped
        // tail must fail here with a clear message, not decode garbage
        if buf.len() < 12 {
            bail!("{path:?}: checksum failure — file truncated");
        }
        let split = buf.len() - 4;
        let mut tail = &buf[split..];
        let stored = r_u32(&mut tail)?;
        let actual = crc32(&buf[..split]);
        if stored != actual {
            bail!(
                "{path:?}: checksum failure — stored crc32 {stored:#010x}, \
                 computed {actual:#010x} (file truncated or corrupt)"
            );
        }
        &buf[8..split]
    } else {
        &buf[8..]
    };
    let mut r: &[u8] = body;
    let mut set = read_set_body(&mut r).with_context(|| format!("parse {path:?}"))?;
    if version >= 3 {
        let count = r_u32(&mut r).with_context(|| format!("parse norms table in {path:?}"))?;
        for _ in 0..count {
            let name = r_str16(&mut r)?;
            let norm = r_f64(&mut r)?;
            set.norms.insert(name, norm);
        }
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::tensor::{Matrix, Pcg64};

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("deltadq-test-format");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_set(quant: Option<(u32, u32)>) -> DeltaSet {
        let mut rng = Pcg64::seeded(1);
        let dq = DeltaDq::new(DeltaDqConfig { alpha: 4.0, group_size: Some(8), quant });
        let mut set = DeltaSet::new(&dq.name(), dq.nominal_ratio());
        for i in 0..3 {
            let d = Matrix::randn(16, 32, 0.01, &mut rng);
            let name = format!("layers.{i}.attn.wq");
            let c = dq.compress(&d, &LayerContext::data_free(i, &name), &mut rng);
            set.tensors.insert(name, c);
        }
        set
    }

    #[test]
    fn sparse_roundtrip_exact() {
        let set = sample_set(None);
        let path = tmpfile("sparse.ddq");
        save_delta_set(&path, &set).unwrap();
        let loaded = load_delta_set(&path).unwrap();
        assert_eq!(loaded.method, set.method);
        assert_eq!(loaded.nominal_ratio, set.nominal_ratio);
        assert_eq!(loaded.tensors.len(), 3);
        for (name, t) in &set.tensors {
            assert_eq!(loaded.tensors[name].to_dense(), t.to_dense(), "{name}");
        }
    }

    #[test]
    fn quantized_roundtrip_exact() {
        for (k, m) in [(8u32, 1u32), (8, 4), (4, 8), (2, 4)] {
            let set = sample_set(Some((k, m)));
            let path = tmpfile(&format!("quant-{k}-{m}.ddq"));
            save_delta_set(&path, &set).unwrap();
            let loaded = load_delta_set(&path).unwrap();
            for (name, t) in &set.tensors {
                assert_eq!(loaded.tensors[name].to_dense(), t.to_dense(), "k={k} m={m} {name}");
            }
        }
    }

    #[test]
    fn measured_ratio_reported() {
        let set = sample_set(Some((8, 1)));
        // 4x dropout + 8-bit codes + 16-bit idx ≈ storage ratio near
        // 16*2048 / (512*(8+16) + overhead) ≳ 2
        let ratio = set.measured_ratio();
        assert!(ratio > 2.0 && ratio < 16.0, "ratio {ratio}");
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("garbage.ddq");
        std::fs::write(&path, b"not a ddq file at all").unwrap();
        assert!(load_delta_set(&path).is_err());
    }

    /// A structurally valid file whose CSR payload is internally
    /// inconsistent must fail with an error — in release builds too.
    #[test]
    fn rejects_corrupt_csr_payload() {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, 1).unwrap(); // v1: no trailer, payload guards engage
        w_str16(&mut buf, "DeltaDQ").unwrap();
        buf.extend_from_slice(&4.0f64.to_le_bytes());
        w_u32(&mut buf, 1).unwrap(); // one tensor
        w_str16(&mut buf, "layers.0.attn.wq").unwrap();
        buf.push(0u8); // kind: sparse CSR
        w_u32(&mut buf, 2).unwrap(); // rows
        w_u32(&mut buf, 3).unwrap(); // cols
        w_u32(&mut buf, 2).unwrap(); // nnz
        w_u32_slice(&mut buf, &[0, 2, 1]).unwrap(); // non-monotone offsets...
        w_u32_slice(&mut buf, &[0, 1]).unwrap(); // col indices
        w_f32_slice(&mut buf, &[1.0, 2.0]).unwrap(); // values
        let path = tmpfile("corrupt-csr.ddq");
        std::fs::write(&path, &buf).unwrap();
        let err = load_delta_set(&path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
    }

    /// Absurd header dimensions must error before any buffer is sized
    /// from them (no multi-GiB allocation attempt on corrupt files).
    #[test]
    fn rejects_absurd_header_without_allocating() {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, 1).unwrap(); // v1: no trailer, payload guards engage
        w_str16(&mut buf, "DeltaDQ").unwrap();
        buf.extend_from_slice(&4.0f64.to_le_bytes());
        w_u32(&mut buf, 1).unwrap();
        w_str16(&mut buf, "x").unwrap();
        buf.push(0u8); // kind: sparse CSR
        w_u32(&mut buf, u32::MAX).unwrap(); // rows: absurd
        w_u32(&mut buf, 3).unwrap();
        w_u32(&mut buf, 1).unwrap();
        let path = tmpfile("absurd.ddq");
        std::fs::write(&path, &buf).unwrap();
        assert!(load_delta_set(&path).is_err());

        // plausible dims but absurd nnz must be caught by the nnz cap
        // (rows*cols alone would admit it)
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, 1).unwrap(); // v1: no trailer, payload guards engage
        w_str16(&mut buf, "DeltaDQ").unwrap();
        buf.extend_from_slice(&4.0f64.to_le_bytes());
        w_u32(&mut buf, 1).unwrap();
        w_str16(&mut buf, "x").unwrap();
        buf.push(0u8);
        w_u32(&mut buf, 1 << 23).unwrap(); // rows: under the dim cap
        w_u32(&mut buf, 1 << 23).unwrap(); // cols: under the dim cap
        w_u32(&mut buf, u32::MAX).unwrap(); // nnz: ~17 GiB of values
        let path = tmpfile("absurd-nnz.ddq");
        std::fs::write(&path, &buf).unwrap();
        assert!(load_delta_set(&path).is_err());
    }

    /// Same for the quantized payload: an invalid (k, m) pair errors
    /// instead of panicking on bit arithmetic.
    #[test]
    fn rejects_corrupt_quantized_header() {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, 1).unwrap(); // v1: no trailer, payload guards engage
        w_str16(&mut buf, "DeltaDQ").unwrap();
        buf.extend_from_slice(&64.0f64.to_le_bytes());
        w_u32(&mut buf, 1).unwrap();
        w_str16(&mut buf, "layers.0.attn.wq").unwrap();
        buf.push(1u8); // kind: quantized
        w_u32(&mut buf, 2).unwrap(); // rows
        w_u32(&mut buf, 3).unwrap(); // cols
        w_u32(&mut buf, 4).unwrap(); // k = 4
        w_u32(&mut buf, 32).unwrap(); // m = 32 > 2^k — invalid
        let path = tmpfile("corrupt-quant.ddq");
        std::fs::write(&path, &buf).unwrap();
        assert!(load_delta_set(&path).is_err());
    }

    #[test]
    fn dense_is_not_serializable() {
        let mut set = DeltaSet::new("ablation", 1.0);
        set.tensors
            .insert("x".into(), CompressedDelta::Dense(Matrix::zeros(2, 2)));
        let path = tmpfile("dense.ddq");
        assert!(save_delta_set(&path, &set).is_err());
    }

    /// The v3 norms table round-trips exactly; v2 files (checksum but
    /// no norms table) still load with empty norms.
    #[test]
    fn norms_table_roundtrips_and_v2_files_load() {
        let mut set = sample_set(Some((8, 4)));
        for (i, name) in set.tensors.keys().cloned().enumerate() {
            set.norms.insert(name, (i + 1) as f64 * 0.37);
        }
        let path = tmpfile("norms.ddq");
        save_delta_set(&path, &set).unwrap();
        let loaded = load_delta_set(&path).unwrap();
        assert_eq!(loaded.norms, set.norms);

        // a v2 file: body + CRC trailer, no norms table
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, 2).unwrap();
        write_set_body(&mut buf, &set).unwrap();
        let crc = crc32(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        let path = tmpfile("v2-compat.ddq");
        std::fs::write(&path, &buf).unwrap();
        let loaded = load_delta_set(&path).unwrap();
        assert_eq!(loaded.method, set.method);
        assert!(loaded.norms.is_empty());
        assert_eq!(loaded.tensors.len(), set.tensors.len());
    }

    /// v1 files (written before the checksum trailer) must stay
    /// readable byte-for-byte.
    #[test]
    fn v1_file_without_trailer_still_loads() {
        let set = sample_set(Some((8, 4)));
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, 1).unwrap(); // the pre-checksum version
        write_set_body(&mut buf, &set).unwrap();
        let path = tmpfile("v1-compat.ddq");
        std::fs::write(&path, &buf).unwrap();
        let loaded = load_delta_set(&path).unwrap();
        assert_eq!(loaded.method, set.method);
        for (name, t) in &set.tensors {
            assert_eq!(loaded.tensors[name].to_dense(), t.to_dense(), "{name}");
        }
    }

    /// Truncation round-trip: chopping any tail off a v2 file must fail
    /// the checksum with a clear error, never decode a partial set.
    #[test]
    fn truncated_tail_fails_checksum() {
        let set = sample_set(None);
        let path = tmpfile("truncate.ddq");
        save_delta_set(&path, &set).unwrap();
        let full = std::fs::read(&path).unwrap();
        assert!(load_delta_set(&path).is_ok(), "pristine file loads");
        for chop in [1usize, 4, 17, full.len() / 2] {
            std::fs::write(&path, &full[..full.len() - chop]).unwrap();
            let err = load_delta_set(&path).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("checksum") || msg.contains("truncated"),
                "chop {chop}: {msg}"
            );
        }
    }

    /// A bit flip anywhere in the payload fails the checksum.
    #[test]
    fn bit_flip_fails_checksum() {
        let set = sample_set(Some((4, 2)));
        let path = tmpfile("bitflip.ddq");
        save_delta_set(&path, &set).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_delta_set(&path).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }
}
