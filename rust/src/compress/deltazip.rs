//! DELTAZIP baseline (Yao & Klimovic 2023): SparseGPT-style
//! second-order sparsification of the delta weight, optionally fused
//! with GPTQ-style quantization — the "sparsity + quantization"
//! comparator of Tables 1–3.
//!
//! Per layer, with calibration inputs `X` and damped Hessian
//! `H = XᵀX + λI`:
//!
//! * columns are processed left-to-right in blocks; within each block a
//!   per-row mask prunes the `1 − 1/α` fraction with the smallest
//!   saliency `w_j² / [H⁻¹]_{jj}²` (SparseGPT's criterion);
//! * every pruned (or quantized) weight's error is compensated by the
//!   OBS update `w_{j+1:} −= (w_j − ŵ_j)/[H⁻¹]_{jj} · [H⁻¹]_{j,j+1:}`.
//!
//! When no calibration data is provided the Hessian degenerates to `I`
//! and the method reduces to per-block magnitude pruning — tests cover
//! both paths.

use crate::compress::{CompressedDelta, Compressor, LayerContext};
use crate::quant::uniform::QuantParams;
use crate::sparse::csr::CsrMatrix;
use crate::tensor::{Matrix, Pcg64};
use crate::util::linalg::{damped_gram, spd_inverse};

/// DELTAZIP configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeltaZipConfig {
    /// Sparsification ratio α (keep 1/α of the elements).
    pub alpha: f64,
    /// Column block size for mask selection + error propagation.
    pub block_size: usize,
    /// Optional GPTQ-style quantization bit width for surviving weights
    /// (group size = `block_size`). The paper's 16× DELTAZIP row is 4×
    /// sparsity + 4-bit quantization.
    pub quant_bits: Option<u32>,
    /// Relative Hessian damping λ (SparseGPT uses 0.01).
    pub damping: f32,
}

impl DeltaZipConfig {
    /// Pure sparsification at ratio `alpha` (no quantization).
    pub fn sparsify_only(alpha: f64) -> DeltaZipConfig {
        DeltaZipConfig { alpha, block_size: 128, quant_bits: None, damping: 0.01 }
    }

    /// Sparsify at `alpha` then quantize survivors to `bits` bits.
    pub fn with_quant(alpha: f64, bits: u32) -> DeltaZipConfig {
        DeltaZipConfig { alpha, block_size: 128, quant_bits: Some(bits), damping: 0.01 }
    }

    /// Canonical operating point for a target total ratio, mirroring the
    /// paper's DELTAZIP rows: ≤8× pure sparsity; 16× = 4×sparse +
    /// 4-bit; 32× = 8×sparse + 4-bit; beyond = deeper sparsity + 4-bit.
    pub fn for_total_ratio(total: f64) -> DeltaZipConfig {
        if total <= 8.0 {
            DeltaZipConfig::sparsify_only(total)
        } else {
            // total = alpha * 16/4 => alpha = total/4
            DeltaZipConfig::with_quant(total / 4.0, 4)
        }
    }
}

/// The DELTAZIP compressor.
#[derive(Debug, Clone, Copy)]
pub struct DeltaZip {
    /// Operating point (ratio, block size, quantization, damping).
    pub config: DeltaZipConfig,
}

impl DeltaZip {
    /// DELTAZIP at the given operating point.
    pub fn new(config: DeltaZipConfig) -> DeltaZip {
        DeltaZip { config }
    }
}

impl Compressor for DeltaZip {
    fn name(&self) -> String {
        "DELTAZIP".to_string()
    }

    fn nominal_ratio(&self) -> f64 {
        match self.config.quant_bits {
            None => self.config.alpha,
            Some(bits) => self.config.alpha * 16.0 / bits as f64,
        }
    }

    fn compress(
        &self,
        delta: &Matrix,
        ctx: &LayerContext<'_>,
        _rng: &mut Pcg64,
    ) -> CompressedDelta {
        let h_in = delta.cols();
        // Hessian inverse from calibration data (identity fallback).
        let hinv = match ctx.calibration {
            Some(x) => {
                assert_eq!(x.cols(), h_in, "calibration width");
                let h = damped_gram(x, self.config.damping);
                spd_inverse(&h).unwrap_or_else(|| Matrix::eye(h_in))
            }
            None => Matrix::eye(h_in),
        };
        let diag: Vec<f32> = (0..h_in).map(|j| hinv.get(j, j).max(1e-12)).collect();

        let mut out = delta.clone();
        let bs = self.config.block_size.min(h_in).max(1);
        let mut scores: Vec<(f32, usize)> = Vec::with_capacity(bs);
        let mut prune = vec![false; h_in];

        for r in 0..out.rows() {
            // Working copy of the row; OBS updates mutate it in place.
            let mut start = 0usize;
            while start < h_in {
                let end = (start + bs).min(h_in);
                let len = end - start;
                // 1. saliency-based mask for this block
                scores.clear();
                for j in start..end {
                    let w = out.get(r, j);
                    let s = (w * w) / (diag[j] * diag[j]);
                    scores.push((s, j));
                }
                let n_prune = len - crate::dropout::keep_count(len, self.config.alpha);
                scores.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                for p in prune[start..end].iter_mut() {
                    *p = false;
                }
                for &(_, j) in scores.iter().take(n_prune) {
                    prune[j] = true;
                }
                // 2. quant params for this block's survivors (GPTQ group)
                let qp = self.config.quant_bits.map(|bits| {
                    let survivors: Vec<f32> = (start..end)
                        .filter(|&j| !prune[j])
                        .map(|j| out.get(r, j))
                        .collect();
                    QuantParams::fit(&survivors, bits)
                });
                // 3. column-by-column prune/quantize + error compensation
                for j in start..end {
                    let w = out.get(r, j);
                    let w_hat = if prune[j] {
                        0.0
                    } else if let Some(qp) = &qp {
                        qp.dequantize(qp.quantize(w))
                    } else {
                        w
                    };
                    let err = w - w_hat;
                    out.set(r, j, w_hat);
                    if err != 0.0 {
                        let e = err / diag[j];
                        // propagate into all later columns of the row
                        let hrow = hinv.row(j);
                        let orow = out.row_mut(r);
                        for jj in (j + 1)..h_in {
                            orow[jj] -= e * hrow[jj];
                        }
                    }
                }
                start = end;
            }
        }
        CompressedDelta::Sparse(CsrMatrix::from_dense(&out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::randn(rows, cols, 0.02, &mut rng)
    }

    fn calib(t: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::randn(t, cols, 1.0, &mut rng)
    }

    #[test]
    fn hits_target_density() {
        let d = delta(8, 64, 1);
        let x = calib(32, 64, 2);
        let dz = DeltaZip::new(DeltaZipConfig::sparsify_only(4.0));
        let mut rng = Pcg64::seeded(3);
        let ctx = LayerContext { layer_index: 0, name: "t", calibration: Some(&x) };
        let c = dz.compress(&d, &ctx, &mut rng);
        let density = c.nnz() as f64 / d.len() as f64;
        assert!((density - 0.25).abs() < 0.02, "density {density}");
    }

    /// Correlated calibration inputs — i.i.d. Gaussian X gives H ≈ σ²I,
    /// which collapses OBS to magnitude pruning. Real activations are
    /// strongly correlated; we mimic that with a low-rank mixing matrix.
    fn correlated_calib(t: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let z = Matrix::randn(t, cols / 4, 1.0, &mut rng);
        let mix = Matrix::randn(cols, cols / 4, 1.0, &mut rng);
        let noise = Matrix::randn(t, cols, 0.1, &mut rng);
        z.matmul_nt(&mix).add(&noise)
    }

    #[test]
    fn obs_compensation_beats_plain_magnitude_on_layer_loss() {
        // The whole point of second-order pruning: ‖XΔᵀ − XΔ̂ᵀ‖² is lower
        // than magnitude pruning at the same density.
        let d = delta(16, 48, 4);
        let x = correlated_calib(64, 48, 5);
        let ctx = LayerContext { layer_index: 0, name: "t", calibration: Some(&x) };
        let mut rng = Pcg64::seeded(6);
        let dz =
            DeltaZip::new(DeltaZipConfig { block_size: 16, ..DeltaZipConfig::sparsify_only(4.0) });
        let zip = dz.compress(&d, &ctx, &mut rng).to_dense();
        let mag = crate::compress::Magnitude::new(4.0)
            .compress(&d, &ctx, &mut rng)
            .to_dense();
        let ref_out = x.matmul_nt(&d);
        let zip_err = ref_out.sq_distance(&x.matmul_nt(&zip));
        let mag_err = ref_out.sq_distance(&x.matmul_nt(&mag));
        assert!(zip_err < mag_err, "zip {zip_err} vs mag {mag_err}");
    }

    #[test]
    fn identity_hessian_fallback_prunes_by_magnitude_per_block() {
        let d = Matrix::from_vec(1, 4, vec![0.1, -0.9, 0.2, 0.8]);
        let dz = DeltaZip::new(DeltaZipConfig {
            alpha: 2.0,
            block_size: 4,
            quant_bits: None,
            damping: 0.01,
        });
        let mut rng = Pcg64::seeded(7);
        let c = dz.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
        let dense = c.to_dense();
        assert_eq!(dense.get(0, 1), -0.9);
        // with identity Hessian there is no compensation, small ones go
        assert_eq!(dense.get(0, 0), 0.0);
        assert_eq!(dense.get(0, 2), 0.0);
    }

    #[test]
    fn quantized_variant_limits_distinct_levels() {
        let d = delta(4, 32, 8);
        let x = calib(16, 32, 9);
        let ctx = LayerContext { layer_index: 0, name: "t", calibration: Some(&x) };
        let dz = DeltaZip::new(DeltaZipConfig {
            alpha: 2.0,
            block_size: 32,
            quant_bits: Some(4),
            damping: 0.01,
        });
        let mut rng = Pcg64::seeded(10);
        let c = dz.compress(&d, &ctx, &mut rng);
        // each row-block has ≤ 2^4 distinct surviving values
        let dense = c.to_dense();
        for row in dense.rows_iter() {
            let mut vals: Vec<u32> =
                row.iter().filter(|v| **v != 0.0).map(|v| v.to_bits()).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 16, "row has {} distinct levels", vals.len());
        }
    }

    #[test]
    fn nominal_ratio_accounts_quant() {
        assert_eq!(DeltaZip::new(DeltaZipConfig::sparsify_only(8.0)).nominal_ratio(), 8.0);
        assert_eq!(DeltaZip::new(DeltaZipConfig::with_quant(4.0, 4)).nominal_ratio(), 16.0);
        let c = DeltaZipConfig::for_total_ratio(128.0);
        assert_eq!(c.alpha, 32.0);
        assert_eq!(c.quant_bits, Some(4));
    }
}
