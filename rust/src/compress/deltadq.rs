//! The DeltaDQ pipeline (paper §3.3–§3.4, Fig. 2):
//!
//! 1. *(upstream)* Split Weight — `ΔW = W_ft − W_b` ([`crate::delta`]).
//! 2. **Group-wise Dropout** — exact-count dropout within groups of
//!    `h_g` along each row, survivors rescaled ×α.
//! 3. **Separate Quantization** *(optional, for ultra-high ratios)* —
//!    per-tensor k-bit uniform quantization, decomposed into m parts of
//!    `k − log₂ m` bits each.
//! 4. *(downstream)* Deployment — [`crate::coordinator`] serves the
//!    compressed deltas with separate computation.

use crate::compress::{CompressedDelta, Compressor, LayerContext};
use crate::dropout::{dropout, DropoutKind};
use crate::quant::separate::DecomposedDelta;
use crate::sparse::csr::CsrMatrix;
use crate::tensor::{Matrix, Pcg64};

/// Configuration of one DeltaDQ run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeltaDqConfig {
    /// Sparsification ratio α₁ (keep 1/α₁ of the delta elements).
    pub alpha: f64,
    /// Group size `h_g` for Group-wise Dropout. `None` = row-wise
    /// (i.e. `h_g = h_in`). Normally chosen by [`crate::search`].
    pub group_size: Option<usize>,
    /// Separate Quantization `(k, m)`: quantize to `k` bits, decompose
    /// into `m` parts (`None` = no quantization; values stay fp16).
    pub quant: Option<(u32, u32)>,
}

impl DeltaDqConfig {
    /// Dropout-only configuration (paper's 2×/4×/8× rows).
    pub fn dropout_only(alpha: f64, group_size: Option<usize>) -> DeltaDqConfig {
        DeltaDqConfig { alpha, group_size, quant: None }
    }

    /// Full pipeline with Separate Quantization.
    pub fn with_quant(alpha: f64, group_size: Option<usize>, k: u32, m: u32) -> DeltaDqConfig {
        DeltaDqConfig { alpha, group_size, quant: Some((k, m)) }
    }

    /// The paper's named operating points for a target total ratio
    /// (§4.2): 2×–8× use dropout only; 16× = 8× dropout + 8-bit m=1;
    /// 32× = 16× dropout + 8-bit; 64× = 8× + (k=4,m=4) 2-bit parts ≈
    /// paper's m=4 row; 128× = 8× + (k=4,m=8) 1-bit parts; 256× = 16× +
    /// (k=4,m=8); 512× = 32× + (k=4,m=8).
    pub fn for_total_ratio(total: f64, group_size: Option<usize>) -> DeltaDqConfig {
        match total as u64 {
            0..=1 => DeltaDqConfig::dropout_only(1.0, group_size),
            2 => DeltaDqConfig::dropout_only(2.0, group_size),
            4 => DeltaDqConfig::dropout_only(4.0, group_size),
            8 => DeltaDqConfig::dropout_only(8.0, group_size),
            16 => DeltaDqConfig::with_quant(8.0, group_size, 8, 1),
            32 => DeltaDqConfig::with_quant(16.0, group_size, 8, 1),
            64 => DeltaDqConfig::with_quant(8.0, group_size, 4, 4),
            128 => DeltaDqConfig::with_quant(8.0, group_size, 4, 8),
            256 => DeltaDqConfig::with_quant(16.0, group_size, 4, 8),
            512 => DeltaDqConfig::with_quant(32.0, group_size, 4, 8),
            other => panic!("no canonical DeltaDQ operating point for {other}x"),
        }
    }
}

/// The DeltaDQ compressor.
#[derive(Debug, Clone)]
pub struct DeltaDq {
    /// Operating point (dropout ratio, group size, quantization widths).
    pub config: DeltaDqConfig,
}

impl DeltaDq {
    /// DeltaDQ at the given operating point.
    pub fn new(config: DeltaDqConfig) -> DeltaDq {
        DeltaDq { config }
    }

    /// Stage 2 only: the sparse delta after Group-wise Dropout.
    pub fn sparsify(&self, delta: &Matrix, rng: &mut Pcg64) -> CsrMatrix {
        let kind = match self.config.group_size {
            Some(g) => DropoutKind::GroupWise { group_size: g },
            None => DropoutKind::RowWise,
        };
        let result = dropout(delta, self.config.alpha, kind, rng);
        CsrMatrix::from_dense(&result.matrix)
    }
}

impl Compressor for DeltaDq {
    fn name(&self) -> String {
        match self.config.quant {
            Some((_, m)) if m > 1 => format!("DeltaDQ(m={m})"),
            Some(_) => "DeltaDQ(m=1)".to_string(),
            None => "DeltaDQ".to_string(),
        }
    }

    fn nominal_ratio(&self) -> f64 {
        crate::compress::ratio::nominal_ratio(self.config.alpha, self.config.quant)
    }

    fn compress(
        &self,
        delta: &Matrix,
        _ctx: &LayerContext<'_>,
        rng: &mut Pcg64,
    ) -> CompressedDelta {
        let sparse = self.sparsify(delta, rng);
        match self.config.quant {
            None => CompressedDelta::Sparse(sparse),
            Some((k, m)) => {
                CompressedDelta::Quantized(DecomposedDelta::compress(&sparse, k, m))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ratio::nominal_ratio;

    fn delta(seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::randn(16, 64, 0.02, &mut rng)
    }

    #[test]
    fn dropout_only_density_matches_alpha() {
        let d = delta(1);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(4.0, Some(16)));
        let mut rng = Pcg64::seeded(2);
        let c = dq.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
        assert_eq!(c.nnz(), 16 * 64 / 4);
        assert!(matches!(c, CompressedDelta::Sparse(_)));
    }

    #[test]
    fn quantized_pipeline_produces_decomposed() {
        let d = delta(3);
        let dq = DeltaDq::new(DeltaDqConfig::with_quant(8.0, Some(8), 4, 8));
        let mut rng = Pcg64::seeded(4);
        let c = dq.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
        match &c {
            CompressedDelta::Quantized(q) => {
                assert_eq!(q.part_bits(), 1, "4-bit quant over 8 parts → 1-bit");
                assert_eq!(q.nnz(), 16 * 64 / 8);
            }
            other => panic!("expected quantized, got {other:?}"),
        }
        assert_eq!(dq.nominal_ratio(), 128.0);
    }

    #[test]
    fn reconstruction_error_grows_with_alpha() {
        let d = delta(5);
        let mut errs = Vec::new();
        for alpha in [2.0, 4.0, 8.0] {
            let dq = DeltaDq::new(DeltaDqConfig::dropout_only(alpha, Some(16)));
            let mut rng = Pcg64::seeded(6);
            let c = dq.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
            errs.push(d.sq_distance(&c.to_dense()));
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }

    #[test]
    fn canonical_operating_points_hit_ratio() {
        for total in [2.0f64, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0] {
            let cfg = DeltaDqConfig::for_total_ratio(total, None);
            let got = nominal_ratio(cfg.alpha, cfg.quant);
            assert_eq!(got, total, "config {cfg:?}");
        }
    }

    #[test]
    fn names_follow_paper_convention() {
        assert_eq!(DeltaDq::new(DeltaDqConfig::dropout_only(4.0, None)).name(), "DeltaDQ");
        assert_eq!(
            DeltaDq::new(DeltaDqConfig::with_quant(8.0, None, 8, 1)).name(),
            "DeltaDQ(m=1)"
        );
        assert_eq!(
            DeltaDq::new(DeltaDqConfig::with_quant(8.0, None, 4, 8)).name(),
            "DeltaDQ(m=8)"
        );
    }

    #[test]
    #[should_panic]
    fn unknown_operating_point_panics() {
        let _ = DeltaDqConfig::for_total_ratio(96.0, None);
    }
}
