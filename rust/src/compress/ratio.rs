//! Compression-ratio accounting.
//!
//! The paper quotes ratios **on the delta weight** against an fp16 dense
//! baseline (16 bits/element). Two views are reported:
//!
//! * **nominal ratio** — the paper's headline number: the sparsification
//!   ratio `α` times the quantization gain `16/(k − log₂ m)` (§3.4).
//! * **storage ratio** — measured bits: dense fp16 cost divided by the
//!   actual CSR/bit-packed footprint including indices, offsets and
//!   quantization parameters (what Figure 7's memory axis shows).

/// Bits to store a dense fp16 tensor of `elems` elements.
pub fn dense_fp16_bits(elems: u64) -> u64 {
    elems * 16
}

/// Nominal combined ratio `α · 16/(k − log₂ m)` (paper §3.4). With no
/// quantization the second factor is 1 (values stay fp16).
pub fn nominal_ratio(alpha: f64, quant: Option<(u32, u32)>) -> f64 {
    match quant {
        None => alpha,
        Some((k, m)) => {
            assert!(m.is_power_of_two() && m <= (1 << k));
            let final_bits = k - m.ilog2();
            if final_bits == 0 {
                // The "-" rows of Tables 2–3: every part stores a single
                // value; treat as the limit (ratio dominated by indices).
                f64::INFINITY
            } else {
                alpha * 16.0 / final_bits as f64
            }
        }
    }
}

/// Measured storage ratio: dense fp16 bits / actual compressed bits.
pub fn storage_ratio(elems: u64, compressed_bits: u64) -> f64 {
    if compressed_bits == 0 {
        return f64::INFINITY;
    }
    dense_fp16_bits(elems) as f64 / compressed_bits as f64
}

/// Aggregate accounting across layers of a model.
#[derive(Debug, Clone, Default)]
pub struct RatioReport {
    /// Dense fp16 bits the deltas would occupy uncompressed.
    pub dense_bits: u64,
    /// Measured compressed bits.
    pub compressed_bits: u64,
    /// Total dense elements across layers.
    pub total_elems: u64,
    /// Total surviving non-zeros across layers.
    pub total_nnz: u64,
}

impl RatioReport {
    /// Accumulate one layer's element/nnz/bit counts.
    pub fn add_layer(&mut self, elems: u64, nnz: u64, compressed_bits: u64) {
        self.dense_bits += dense_fp16_bits(elems);
        self.compressed_bits += compressed_bits;
        self.total_elems += elems;
        self.total_nnz += nnz;
    }

    /// Measured storage ratio over all layers.
    pub fn storage_ratio(&self) -> f64 {
        storage_ratio(self.total_elems, self.compressed_bits)
    }

    /// Measured density (nnz / elems).
    pub fn density(&self) -> f64 {
        if self.total_elems == 0 {
            0.0
        } else {
            self.total_nnz as f64 / self.total_elems as f64
        }
    }

    /// Compressed footprint in mebibytes.
    pub fn compressed_mib(&self) -> f64 {
        self.compressed_bits as f64 / 8.0 / 1024.0 / 1024.0
    }

    /// Dense fp16 footprint in mebibytes.
    pub fn dense_mib(&self) -> f64 {
        self.dense_bits as f64 / 8.0 / 1024.0 / 1024.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_ratio_paper_configs() {
        // Table 1 @16x: dropout 8x + 8-bit m=1 quant -> 8 * 16/8 = 16
        assert_eq!(nominal_ratio(8.0, Some((8, 1))), 16.0);
        // §4.2: 128x on 7B = dropout 8x + (k=4, m=8) -> 1-bit parts
        assert_eq!(nominal_ratio(8.0, Some((4, 8))), 128.0);
        // §4.2: 512x on 70B = dropout 32x + (k=4, m=8)
        assert_eq!(nominal_ratio(32.0, Some((4, 8))), 512.0);
        // dropout-only rows
        assert_eq!(nominal_ratio(4.0, None), 4.0);
        // the "-" extreme: m = 2^k
        assert!(nominal_ratio(8.0, Some((4, 16))).is_infinite());
    }

    #[test]
    fn storage_ratio_basics() {
        assert_eq!(storage_ratio(100, 1600), 1.0);
        assert_eq!(storage_ratio(100, 800), 2.0);
        assert!(storage_ratio(100, 0).is_infinite());
    }

    #[test]
    fn report_aggregates() {
        let mut r = RatioReport::default();
        r.add_layer(1000, 250, 250 * 32);
        r.add_layer(1000, 250, 250 * 32);
        assert_eq!(r.density(), 0.25);
        assert_eq!(r.storage_ratio(), 2.0);
        assert!((r.dense_mib() - 2000.0 * 16.0 / 8.0 / 1024.0 / 1024.0).abs() < 1e-12);
    }
}
