//! Delta-compression framework (S5): the [`Compressor`] trait, the
//! compressed-delta representation shared by all methods, and the four
//! pipelines the paper evaluates — [`deltadq::DeltaDq`] plus the
//! [`magnitude::Magnitude`], [`dare::Dare`], and [`deltazip::DeltaZip`]
//! baselines (Table 1–3).

pub mod dare;
pub mod deltadq;
pub mod deltazip;
pub mod magnitude;
pub mod pipeline;
pub mod ratio;

pub use dare::Dare;
pub use deltadq::{DeltaDq, DeltaDqConfig};
pub use deltazip::{DeltaZip, DeltaZipConfig};
pub use magnitude::Magnitude;
pub use ratio::RatioReport;

use crate::quant::separate::DecomposedDelta;
use crate::sparse::csr::CsrMatrix;
use crate::tensor::{Matrix, Pcg64};

/// Densification telemetry: a process-wide count of every dense-`Δ`
/// materialization from a compressed delta. The fused Cold serving path
/// guarantees it never densifies — integration tests pin that guarantee
/// by asserting this counter stays flat across a served request stream.
pub mod densify {
    use std::sync::atomic::{AtomicU64, Ordering};

    static EVENTS: AtomicU64 = AtomicU64::new(0);

    pub(crate) fn record() {
        EVENTS.fetch_add(1, Ordering::Relaxed);
    }

    /// Total dense-`Δ` materializations since process start.
    pub fn events() -> u64 {
        EVENTS.load(Ordering::Relaxed)
    }
}

/// A compressed per-layer delta weight, ready for storage or the
/// separate-computation serving path.
#[derive(Debug, Clone)]
pub enum CompressedDelta {
    /// Sparse fp16-valued delta (dropout / magnitude output).
    Sparse(CsrMatrix),
    /// Sparse + Separate-Quantized delta (DeltaDQ with quantization, or
    /// DELTAZIP's sparse+quant output represented post-hoc).
    Quantized(DecomposedDelta),
    /// Dense fake-quantized delta (no sparsity — not produced by any of
    /// the paper's methods at α>1, but used by ablations).
    Dense(Matrix),
}

impl CompressedDelta {
    /// Reconstruct the (approximate) dense delta.
    pub fn to_dense(&self) -> Matrix {
        densify::record();
        match self {
            CompressedDelta::Sparse(csr) => csr.to_dense(),
            CompressedDelta::Quantized(d) => d.to_dense(),
            CompressedDelta::Dense(m) => m.clone(),
        }
    }

    /// Accumulate `scale · Δ` into a dense weight buffer (Hot-promotion
    /// path — counted by [`densify`]).
    pub fn add_to_dense(&self, out: &mut Matrix, scale: f32) {
        densify::record();
        match self {
            CompressedDelta::Sparse(csr) => csr.add_to_dense(out, scale),
            CompressedDelta::Quantized(d) => d.add_to_dense(out, scale),
            CompressedDelta::Dense(m) => out.add_scaled(m, scale),
        }
    }

    /// Delta-path matmul `X·Δᵀ` without densifying.
    pub fn matmul_nt_from_dense(&self, x: &Matrix) -> Matrix {
        match self {
            CompressedDelta::Sparse(csr) => csr.matmul_nt_from_dense(x),
            CompressedDelta::Quantized(d) => d.matmul_nt_from_dense(x),
            CompressedDelta::Dense(m) => x.matmul_nt(m),
        }
    }

    /// (rows, cols).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            CompressedDelta::Sparse(csr) => csr.shape(),
            CompressedDelta::Quantized(d) => d.shape(),
            CompressedDelta::Dense(m) => m.shape(),
        }
    }

    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        match self {
            CompressedDelta::Sparse(csr) => csr.nnz(),
            CompressedDelta::Quantized(d) => d.nnz(),
            CompressedDelta::Dense(m) => m.count_nonzeros(),
        }
    }

    /// Measured storage cost in bits (paper accounting; DESIGN.md §7).
    pub fn storage_bits(&self) -> u64 {
        match self {
            // fp16 values + 16-bit column indices + 32-bit row offsets
            CompressedDelta::Sparse(csr) => csr.storage_bits(16, 16, 32),
            CompressedDelta::Quantized(d) => d.storage_bits(),
            CompressedDelta::Dense(m) => m.len() as u64 * 16,
        }
    }
}

/// Per-layer context available to a compressor.
pub struct LayerContext<'a> {
    /// Layer index (0-based) within the model.
    pub layer_index: usize,
    /// Human-readable tensor name ("layers.3.attn.wq" etc.).
    pub name: &'a str,
    /// Calibration inputs `X` for this tensor (t × h_in) — required by
    /// second-order methods (DELTAZIP); ignored by data-free methods.
    pub calibration: Option<&'a Matrix>,
}

impl<'a> LayerContext<'a> {
    /// A data-free context (no calibration inputs).
    pub fn data_free(layer_index: usize, name: &'a str) -> LayerContext<'a> {
        LayerContext { layer_index, name, calibration: None }
    }
}

/// A delta-weight compression method (one of the paper's four).
pub trait Compressor {
    /// Display name used in tables ("DeltaDQ", "DARE", …).
    fn name(&self) -> String;

    /// Nominal compression ratio (the paper's α·16/(k−log₂m) headline).
    fn nominal_ratio(&self) -> f64;

    /// Compress one layer's delta weight.
    fn compress(
        &self,
        delta: &Matrix,
        ctx: &LayerContext<'_>,
        rng: &mut Pcg64,
    ) -> CompressedDelta;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn compressed_delta_dense_passthrough() {
        let mut rng = Pcg64::seeded(1);
        let m = Matrix::randn(4, 6, 0.1, &mut rng);
        let c = CompressedDelta::Dense(m.clone());
        assert_eq!(c.to_dense(), m);
        assert_eq!(c.shape(), (4, 6));
        assert_eq!(c.storage_bits(), 24 * 16);
    }

    #[test]
    fn sparse_variant_storage_counts_csr() {
        let m = Matrix::from_vec(2, 4, vec![0.0, 1.0, 0.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        let c = CompressedDelta::Sparse(CsrMatrix::from_dense(&m));
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.storage_bits(), 3 * 32 + 3 * 32);
    }
}
