//! DARE baseline (Yu et al. 2023, "Language Models are Super Mario"):
//! global i.i.d. Bernoulli dropout on the delta weight with drop rate
//! `p = 1 − 1/α`, then rescale the survivors by `1/(1−p) = α`.
//!
//! DARE differs from DeltaDQ's Group-wise Dropout only in mask
//! granularity: it draws one global Bernoulli mask, so the per-row /
//! per-group survivor counts fluctuate — exactly the variance the
//! paper's row/group-exact masks remove (§3.3).

use crate::compress::{CompressedDelta, Compressor, LayerContext};
use crate::dropout::{dropout, DropoutKind};
use crate::sparse::csr::CsrMatrix;
use crate::tensor::{Matrix, Pcg64};

/// The DARE compressor at ratio α.
#[derive(Debug, Clone, Copy)]
pub struct Dare {
    /// Target compression ratio (keep probability = 1/α).
    pub alpha: f64,
}

impl Dare {
    /// DARE at ratio `alpha` (≥ 1).
    pub fn new(alpha: f64) -> Dare {
        assert!(alpha >= 1.0);
        Dare { alpha }
    }
}

impl Compressor for Dare {
    fn name(&self) -> String {
        "DARE".to_string()
    }

    fn nominal_ratio(&self) -> f64 {
        self.alpha
    }

    fn compress(
        &self,
        delta: &Matrix,
        _ctx: &LayerContext<'_>,
        rng: &mut Pcg64,
    ) -> CompressedDelta {
        let r = dropout(delta, self.alpha, DropoutKind::Global, rng);
        CompressedDelta::Sparse(CsrMatrix::from_dense(&r.matrix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_near_nominal() {
        let mut rng0 = Pcg64::seeded(1);
        let d = Matrix::randn(64, 64, 0.02, &mut rng0);
        let dare = Dare::new(8.0);
        let mut rng = Pcg64::seeded(2);
        let c = dare.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
        let density = c.nnz() as f64 / d.len() as f64;
        assert!((density - 0.125).abs() < 0.02, "density {density}");
    }

    #[test]
    fn survivors_rescaled() {
        let d = Matrix::full(16, 16, 1.0);
        let dare = Dare::new(4.0);
        let mut rng = Pcg64::seeded(3);
        let dense = dare.compress(&d, &LayerContext::data_free(0, "t"), &mut rng).to_dense();
        for &v in dense.data() {
            assert!(v == 0.0 || (v - 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn per_row_counts_fluctuate_unlike_rowwise() {
        // The structural difference vs DeltaDQ: global masks give uneven
        // per-row survivor counts.
        let mut rng0 = Pcg64::seeded(4);
        let d = Matrix::randn(32, 128, 0.02, &mut rng0);
        let dare = Dare::new(4.0);
        let mut rng = Pcg64::seeded(5);
        let dense = dare.compress(&d, &LayerContext::data_free(0, "t"), &mut rng).to_dense();
        let counts: Vec<usize> =
            dense.rows_iter().map(|r| r.iter().filter(|v| **v != 0.0).count()).collect();
        let distinct: std::collections::HashSet<usize> = counts.iter().copied().collect();
        assert!(distinct.len() > 1, "global Bernoulli should vary per row: {counts:?}");
    }
}
