//! Magnitude pruning baseline (Han et al. 2015) applied to the delta
//! weight: keep the global top-`1/α` fraction of elements by |Δw|,
//! drop the rest. No rescaling (magnitude pruning is not an unbiased
//! estimator — it deliberately keeps the largest weights as-is).

use crate::compress::{CompressedDelta, Compressor, LayerContext};
use crate::sparse::csr::CsrMatrix;
use crate::tensor::{Matrix, Pcg64};

/// Global magnitude pruner at ratio α.
#[derive(Debug, Clone, Copy)]
pub struct Magnitude {
    /// Target compression ratio (keeps the top 1/α by |value|).
    pub alpha: f64,
}

impl Magnitude {
    /// Magnitude pruner at ratio `alpha` (≥ 1).
    pub fn new(alpha: f64) -> Magnitude {
        assert!(alpha >= 1.0);
        Magnitude { alpha }
    }

    /// The |value| threshold that keeps `keep` elements (k-th largest).
    fn threshold(delta: &Matrix, keep: usize) -> f32 {
        if keep == 0 {
            return f32::INFINITY;
        }
        if keep >= delta.len() {
            return 0.0;
        }
        let mut mags: Vec<f32> = delta.data().iter().map(|v| v.abs()).collect();
        // select_nth_unstable puts the (len-keep)-th smallest in place so
        // everything right of it is the top-`keep` set.
        let idx = mags.len() - keep;
        let (_, nth, _) = mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
        *nth
    }
}

impl Compressor for Magnitude {
    fn name(&self) -> String {
        "Magnitude".to_string()
    }

    fn nominal_ratio(&self) -> f64 {
        self.alpha
    }

    fn compress(
        &self,
        delta: &Matrix,
        _ctx: &LayerContext<'_>,
        _rng: &mut Pcg64,
    ) -> CompressedDelta {
        let keep = (delta.len() as f64 / self.alpha).round() as usize;
        let thresh = Self::threshold(delta, keep);
        let mut out = delta.clone();
        // Keep strictly-above-threshold, then fill remaining quota from
        // the elements exactly at the threshold (ties).
        let mut kept = 0usize;
        for v in out.data_mut() {
            if v.abs() > thresh {
                kept += 1;
            } else {
                *v = 0.0;
            }
        }
        if kept < keep && thresh.is_finite() {
            let mut quota = keep - kept;
            for (i, &orig) in delta.data().iter().enumerate() {
                if quota == 0 {
                    break;
                }
                if orig.abs() == thresh && orig != 0.0 {
                    out.data_mut()[i] = orig;
                    quota -= 1;
                }
            }
        }
        CompressedDelta::Sparse(CsrMatrix::from_dense(&out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest_magnitudes() {
        let d = Matrix::from_vec(2, 4, vec![0.1, -0.9, 0.2, 0.8, -0.05, 0.3, -0.7, 0.01]);
        let m = Magnitude::new(2.0);
        let mut rng = Pcg64::seeded(1);
        let c = m.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
        let dense = c.to_dense();
        // top-4 by |v|: -0.9, 0.8, -0.7, 0.3
        assert_eq!(dense.get(0, 1), -0.9);
        assert_eq!(dense.get(0, 3), 0.8);
        assert_eq!(dense.get(1, 2), -0.7);
        assert_eq!(dense.get(1, 1), 0.3);
        assert_eq!(c.nnz(), 4);
    }

    #[test]
    fn no_rescaling_applied() {
        let d = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let m = Magnitude::new(2.0);
        let mut rng = Pcg64::seeded(2);
        let dense = m.compress(&d, &LayerContext::data_free(0, "t"), &mut rng).to_dense();
        assert_eq!(dense.data(), &[0.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn exact_keep_count_with_ties() {
        let d = Matrix::full(2, 8, 0.5); // every |v| equal
        let m = Magnitude::new(4.0);
        let mut rng = Pcg64::seeded(3);
        let c = m.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
        assert_eq!(c.nnz(), 4, "ties must be broken to hit the quota");
    }

    #[test]
    fn alpha_one_keeps_all() {
        let mut rng0 = Pcg64::seeded(4);
        let d = Matrix::randn(4, 8, 1.0, &mut rng0);
        let m = Magnitude::new(1.0);
        let mut rng = Pcg64::seeded(5);
        let c = m.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
        assert!(c.to_dense().allclose(&d, 0.0, 0.0));
    }

    #[test]
    fn extreme_alpha_keeps_none_or_few() {
        let mut rng0 = Pcg64::seeded(6);
        let d = Matrix::randn(4, 8, 1.0, &mut rng0);
        let m = Magnitude::new(64.0);
        let mut rng = Pcg64::seeded(7);
        let c = m.compress(&d, &LayerContext::data_free(0, "t"), &mut rng);
        assert!(c.nnz() <= 1);
    }
}
