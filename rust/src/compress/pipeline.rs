//! Whole-model compression pipeline: run a [`Compressor`] over every
//! delta tensor of a fine-tuned model, with optional calibration-input
//! capture for second-order methods (DELTAZIP).

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::compress::{CompressedDelta, Compressor, LayerContext};
use crate::delta::format::DeltaSet;
use crate::eval::tasks::Sample;
use crate::model::forward::{forward, WeightSource};
use crate::model::weights::ModelWeights;
use crate::model::ModelConfig;
use crate::tensor::{Matrix, Pcg64};

/// A [`WeightSource`] wrapper that records the inputs fed to each
/// linear layer — calibration capture for SparseGPT-style methods.
pub struct RecordingSource<'a, S: WeightSource> {
    inner: &'a S,
    records: RefCell<BTreeMap<String, Vec<Matrix>>>,
    /// Cap on captured rows per tensor (keeps the Hessian cheap).
    max_rows: usize,
}

impl<'a, S: WeightSource> RecordingSource<'a, S> {
    /// Wrap `inner`, capturing at most `max_rows` input rows per tensor.
    pub fn new(inner: &'a S, max_rows: usize) -> RecordingSource<'a, S> {
        RecordingSource { inner, records: RefCell::new(BTreeMap::new()), max_rows }
    }

    /// Concatenate recorded inputs per tensor (rows capped).
    pub fn into_calibration(self) -> BTreeMap<String, Matrix> {
        let records = self.records.into_inner();
        let mut out = BTreeMap::new();
        for (name, chunks) in records {
            let cols = chunks[0].cols();
            let mut rows = 0usize;
            let mut data = Vec::new();
            'outer: for chunk in &chunks {
                for r in 0..chunk.rows() {
                    if rows >= self.max_rows {
                        break 'outer;
                    }
                    data.extend_from_slice(chunk.row(r));
                    rows += 1;
                }
            }
            out.insert(name, Matrix::from_vec(rows, cols, data));
        }
        out
    }
}

impl<'a, S: WeightSource> WeightSource for RecordingSource<'a, S> {
    fn config(&self) -> ModelConfig {
        self.inner.config()
    }

    fn dense(&self, name: &str) -> &Matrix {
        self.inner.dense(name)
    }

    fn linear(&self, name: &str, x: &Matrix) -> Matrix {
        let mut records = self.records.borrow_mut();
        let entry = records.entry(name.to_string()).or_default();
        let have: usize = entry.iter().map(|m| m.rows()).sum();
        if have < self.max_rows {
            entry.push(x.clone());
        }
        drop(records);
        self.inner.linear(name, x)
    }
}

/// Run forward passes over `samples` against the *fine-tuned* weights
/// and capture per-tensor linear inputs (DELTAZIP calibrates against
/// the model being compressed).
pub fn capture_calibration(
    weights: &ModelWeights,
    samples: &[Sample],
    max_rows: usize,
) -> BTreeMap<String, Matrix> {
    let rec = RecordingSource::new(weights, max_rows);
    for s in samples {
        let seq = s.full_sequence();
        let _ = forward(&rec, &seq[..seq.len() - 1]);
    }
    rec.into_calibration()
}

/// Compress every delta tensor of a model with the given method.
///
/// `calibration` maps tensor name → captured inputs; pass an empty map
/// for data-free methods.
pub fn compress_model_deltas(
    deltas: &BTreeMap<String, Matrix>,
    method: &dyn Compressor,
    calibration: &BTreeMap<String, Matrix>,
    rng: &mut Pcg64,
) -> DeltaSet {
    let mut set = DeltaSet::new(&method.name(), method.nominal_ratio());
    for (idx, (name, delta)) in deltas.iter().enumerate() {
        let ctx = LayerContext {
            layer_index: layer_index_of(name),
            name,
            calibration: calibration.get(name),
        };
        let _ = idx;
        // pre-quantization norm: the audit subsystem's reconstruction-
        // error reference, persisted through .ddq v3 and the store
        set.norms.insert(name.clone(), delta.frobenius_norm() as f64);
        let compressed = method.compress(delta, &ctx, rng);
        set.tensors.insert(name.clone(), compressed);
    }
    set
}

/// Reconstruct full fine-tuned weights from base + compressed deltas
/// (the merged path; the serving path uses `DeltaView` instead).
pub fn reconstruct_weights(base: &ModelWeights, set: &DeltaSet) -> ModelWeights {
    let mut out = base.clone();
    for (name, delta) in &set.tensors {
        delta.add_to_dense(out.get_mut(name), 1.0);
    }
    out
}

/// Convert a `DeltaSet` to the per-tensor map a `DeltaView` needs.
pub fn delta_map(set: &DeltaSet) -> BTreeMap<String, CompressedDelta> {
    set.tensors.clone()
}

/// Parse the layer index out of "layers.<i>.…" (0 for globals).
pub fn layer_index_of(name: &str) -> usize {
    name.strip_prefix("layers.")
        .and_then(|rest| rest.split('.').next())
        .and_then(|i| i.parse().ok())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Dare, DeltaDq, DeltaDqConfig, DeltaZip, DeltaZipConfig};
    use crate::delta::extract::extract_deltas;
    use crate::eval::tasks::{gen_dataset, TaskKind};

    fn base_and_ft() -> (ModelWeights, ModelWeights) {
        let mut rng = Pcg64::seeded(1);
        let base = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let mut ft = base.clone();
        let mut rng2 = Pcg64::seeded(2);
        for name in base.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            ft.get_mut(&name).add_assign(&Matrix::randn(r, c, 0.001, &mut rng2));
        }
        (base, ft)
    }

    #[test]
    fn layer_index_parsing() {
        assert_eq!(layer_index_of("layers.3.attn.wq"), 3);
        assert_eq!(layer_index_of("layers.11.mlp.down"), 11);
        assert_eq!(layer_index_of("lm_head"), 0);
    }

    #[test]
    fn compress_all_tensors() {
        let (base, ft) = base_and_ft();
        let deltas = extract_deltas(&base, &ft);
        let mut rng = Pcg64::seeded(3);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(4.0, Some(16)));
        let set = compress_model_deltas(&deltas, &dq, &BTreeMap::new(), &mut rng);
        assert_eq!(set.tensors.len(), deltas.len());
        assert_eq!(set.method, "DeltaDQ");
        // density across the whole set ≈ 1/4
        let density = set.nnz() as f64 / set.total_elems() as f64;
        assert!((density - 0.25).abs() < 0.01, "density {density}");
    }

    #[test]
    fn reconstruct_approximates_finetuned() {
        let (base, ft) = base_and_ft();
        let deltas = extract_deltas(&base, &ft);
        let mut rng = Pcg64::seeded(4);
        // alpha = 1: lossless; reconstruction must equal the fine-tune
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(1.0, None));
        let set = compress_model_deltas(&deltas, &dq, &BTreeMap::new(), &mut rng);
        let rebuilt = reconstruct_weights(&base, &set);
        for (name, t) in ft.iter() {
            assert!(rebuilt.get(name).allclose(t, 1e-5, 1e-5), "{name}");
        }
    }

    #[test]
    fn calibration_capture_covers_all_linear_tensors() {
        let (_, ft) = base_and_ft();
        let data = gen_dataset(TaskKind::Math, 4, 5);
        let calib = capture_calibration(&ft, &data, 64);
        // 7 tensors per layer + lm_head
        let c = ft.config;
        for name in c.delta_tensor_names() {
            let x = calib.get(&name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(x.rows() > 0 && x.rows() <= 64);
            let expected_cols = ft.get(&name).cols();
            assert_eq!(x.cols(), expected_cols, "{name}");
        }
        assert!(calib.contains_key("lm_head"));
    }

    #[test]
    fn deltazip_consumes_calibration() {
        let (base, ft) = base_and_ft();
        let deltas = extract_deltas(&base, &ft);
        let data = gen_dataset(TaskKind::Math, 4, 6);
        let calib = capture_calibration(&ft, &data, 32);
        let mut rng = Pcg64::seeded(7);
        let dz = DeltaZip::new(DeltaZipConfig::sparsify_only(4.0));
        let set = compress_model_deltas(&deltas, &dz, &calib, &mut rng);
        let density = set.nnz() as f64 / set.total_elems() as f64;
        assert!((density - 0.25).abs() < 0.02, "density {density}");
    }

    #[test]
    fn dare_runs_data_free() {
        let (base, ft) = base_and_ft();
        let deltas = extract_deltas(&base, &ft);
        let mut rng = Pcg64::seeded(8);
        let set = compress_model_deltas(&deltas, &Dare::new(8.0), &BTreeMap::new(), &mut rng);
        let density = set.nnz() as f64 / set.total_elems() as f64;
        assert!((density - 0.125).abs() < 0.01, "density {density}");
    }
}
