//! Distribution analysis (S17) backing the paper's motivating figures.
//!
//! * Figure 4: per-output-element variance & min-max range of the
//!   matmul partial products, delta weight vs fine-tuned weight.
//! * Figure 6: the delta-weight value distribution before and after
//!   uniform quantization.

use std::collections::BTreeMap;

use crate::model::weights::ModelWeights;
use crate::quant::uniform::fake_quantize;
use crate::tensor::stats::{median, Histogram, IntermediateStats};
use crate::tensor::Matrix;

/// Fig. 4 comparison for one tensor: intermediate-result statistics of
/// the delta weight vs the full fine-tuned weight on the same inputs.
#[derive(Debug, Clone)]
pub struct BalancedResultReport {
    /// Tensor the statistics were computed for.
    pub tensor: String,
    /// Median partial-product variance, delta weight.
    pub delta_variance: f64,
    /// Median partial-product variance, fine-tuned weight.
    pub finetuned_variance: f64,
    /// Median partial-product min-max range, delta weight.
    pub delta_range: f64,
    /// Median partial-product min-max range, fine-tuned weight.
    pub finetuned_range: f64,
}

impl BalancedResultReport {
    /// Variance contrast (fine-tuned / delta); ≫ 1 is the phenomenon.
    pub fn variance_contrast(&self) -> f64 {
        self.finetuned_variance / self.delta_variance.max(1e-300)
    }

    /// Range contrast (fine-tuned / delta).
    pub fn range_contrast(&self) -> f64 {
        self.finetuned_range / self.delta_range.max(1e-300)
    }
}

/// Compute the Fig. 4 statistics for one tensor given calibration
/// inputs `x` (t × h_in), the base weight, and the delta.
pub fn balanced_intermediate_results(
    name: &str,
    x: &Matrix,
    base: &Matrix,
    delta: &Matrix,
    max_elems: usize,
) -> BalancedResultReport {
    let finetuned = base.add(delta);
    let d = IntermediateStats::compute(x, delta, max_elems);
    let f = IntermediateStats::compute(x, &finetuned, max_elems);
    BalancedResultReport {
        tensor: name.to_string(),
        delta_variance: d.median_variance(),
        finetuned_variance: f.median_variance(),
        delta_range: d.median_range(),
        finetuned_range: f.median_range(),
    }
}

/// Whole-model Fig. 4 sweep: one report per delta tensor with
/// calibration inputs available.
pub fn balanced_results_sweep(
    base: &ModelWeights,
    deltas: &BTreeMap<String, Matrix>,
    calibration: &BTreeMap<String, Matrix>,
    max_elems: usize,
) -> Vec<BalancedResultReport> {
    deltas
        .iter()
        .filter_map(|(name, delta)| {
            calibration.get(name).map(|x| {
                balanced_intermediate_results(name, x, base.get(name), delta, max_elems)
            })
        })
        .collect()
}

/// Fig. 6: delta-weight histogram before and after k-bit uniform
/// quantization (same bins for comparability).
#[derive(Debug, Clone)]
pub struct QuantDistributionReport {
    /// Value histogram of the raw delta.
    pub before: Histogram,
    /// Histogram after quantize→dequantize, same bins.
    pub after: Histogram,
    /// Quantization bit width.
    pub bits: u32,
    /// Quantization MSE.
    pub mse: f64,
}

/// Compute the Fig. 6 before/after histograms and quantization MSE.
pub fn quant_distribution(delta: &Matrix, bits: u32, bins: usize) -> QuantDistributionReport {
    let before = Histogram::of_matrix(delta, bins);
    let (quantized, _) = fake_quantize(delta, bits);
    let mut after = Histogram::new(before.lo, before.hi, bins);
    for &v in quantized.data() {
        after.add(v as f64);
    }
    let mse = delta.sq_distance(&quantized) / delta.len().max(1) as f64;
    QuantDistributionReport { before, after, bits, mse }
}

/// Median variance contrast across a sweep — the single number quoted
/// in EXPERIMENTS.md for Fig. 4.
pub fn median_contrast(reports: &[BalancedResultReport]) -> (f64, f64) {
    let v: Vec<f64> = reports.iter().map(|r| r.variance_contrast()).collect();
    let r: Vec<f64> = reports.iter().map(|r| r.range_contrast()).collect();
    (median(&v), median(&r))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn delta_shows_balanced_intermediate_results() {
        // Genuine setup: base ~ N(0, 0.02), delta ~ N(0, 0.002) (10x
        // smaller, like real fine-tuning deltas).
        let mut rng = Pcg64::seeded(1);
        let x = Matrix::randn(16, 64, 1.0, &mut rng);
        let base = Matrix::randn(32, 64, 0.02, &mut rng);
        let delta = Matrix::randn(32, 64, 0.002, &mut rng);
        let r = balanced_intermediate_results("t", &x, &base, &delta, 256);
        assert!(r.variance_contrast() > 10.0, "contrast {}", r.variance_contrast());
        assert!(r.range_contrast() > 3.0, "contrast {}", r.range_contrast());
    }

    #[test]
    fn quant_distribution_mse_shrinks_with_bits() {
        let mut rng = Pcg64::seeded(2);
        let delta = Matrix::randn(32, 32, 0.01, &mut rng);
        let r2 = quant_distribution(&delta, 2, 32);
        let r8 = quant_distribution(&delta, 8, 32);
        assert!(r8.mse < r2.mse / 100.0, "{} vs {}", r8.mse, r2.mse);
        assert_eq!(r2.before.total(), 32 * 32);
        assert_eq!(r2.after.total(), 32 * 32);
    }

    #[test]
    fn quantized_histogram_concentrates_mass() {
        // after k-bit quantization at most 2^k distinct values exist, so
        // at most 2^k bins are occupied
        let mut rng = Pcg64::seeded(3);
        let delta = Matrix::randn(64, 64, 0.01, &mut rng);
        let r = quant_distribution(&delta, 2, 64);
        let occupied = r.after.counts.iter().filter(|&&c| c > 0).count();
        assert!(occupied <= 4, "occupied {occupied}");
    }

    #[test]
    fn median_contrast_aggregates() {
        let reports = vec![
            BalancedResultReport {
                tensor: "a".into(),
                delta_variance: 1.0,
                finetuned_variance: 100.0,
                delta_range: 1.0,
                finetuned_range: 10.0,
            },
            BalancedResultReport {
                tensor: "b".into(),
                delta_variance: 1.0,
                finetuned_variance: 400.0,
                delta_range: 1.0,
                finetuned_range: 20.0,
            },
        ];
        let (v, r) = median_contrast(&reports);
        assert_eq!(v, 250.0);
        assert_eq!(r, 15.0);
    }
}
