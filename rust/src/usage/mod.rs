//! Per-tenant usage ledger + saturation engine (PR 10).
//!
//! Attributes every unit of work the serving stack performs to the
//! tenant that caused it — compute wall time (decode-group forwards,
//! prefill chunks, legacy batch execution), KV-block-seconds
//! (integrated block-pool occupancy per sequence), queue wait, store
//! bytes read / hydrations, tokens in/out, and request / 429 / 503
//! counts — in lock-light atomic counters ([`TenantUsage`]), and keeps
//! a ring of per-second snapshots so callers can read rolling
//! 1 s / 10 s / 60 s windows without a background thread.
//!
//! From the same windows the ledger derives a per-axis **saturation
//! score** in `[0, 1]` (KV-pool occupancy, admission-queue fill,
//! drive-loop duty cycle, audit/loader backlog) and a combined score
//! that the gateway turns into a bounded, load-derived `Retry-After`
//! hint on 429/503 responses ([`retry_after_from_score`]). The
//! scheduler's `publish()` feeds the ring every iteration (and every
//! idle tick), so the windows decay on their own once load drops; the
//! legacy worker loop feeds it from the read paths (`/metrics`,
//! `/debug/usage`, `/healthz`).
//!
//! Cardinality policy: `/metrics` exports per-tenant series for the
//! top-K tenants by attributed compute, aggregating the rest into one
//! `tenant="other"` sample per family ([`UsageLedger::export`]);
//! `GET /debug/usage` serves the unaggregated JSON.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Ring capacity in one-second slots: one more than the longest window
/// so a full 60 s delta always has its start snapshot resident.
const RING_SECONDS: u64 = 61;

/// The mid window (seconds) — what the saturation score smooths over.
const MID_WINDOW_S: u64 = 10;

/// Audit/loader backlog items that count as "fully backed up" (the
/// normalizer for the backlog saturation axis).
const BACKLOG_FULL: f64 = 32.0;

/// `[usage]` configuration (see `config::ServeConfig::usage_config`).
#[derive(Debug, Clone)]
pub struct UsageConfig {
    /// Ledger toggle (`[usage] enabled`, default true). Off = every
    /// attribution call is a relaxed load + branch, windows stay empty,
    /// and the `Retry-After` hint pins to the 1 s floor.
    pub enabled: bool,
    /// Per-tenant series exported on `/metrics` before aggregation
    /// into `tenant="other"` (`[usage] top_k`, default 8).
    pub top_k: usize,
    /// Upper bound of the derived `Retry-After` hint in seconds
    /// (`[usage] retry_max_s`, default 30; floor is always 1).
    pub retry_max_s: u64,
}

impl Default for UsageConfig {
    fn default() -> UsageConfig {
        UsageConfig { enabled: true, top_k: 8, retry_max_s: 30 }
    }
}

/// One tenant's attributed-resource counters. All monotonic totals,
/// updated with relaxed atomics from the hot paths; durations are
/// stored in integer microseconds.
#[derive(Debug, Default)]
pub struct TenantUsage {
    /// Attributed compute wall time (µs): decode-group wall split by
    /// group membership, prefill-chunk wall, legacy per-batch exec.
    pub compute_us: AtomicU64,
    /// Integrated KV occupancy (block-microseconds): Σ blocks × time
    /// held, accrued at step/respond/preempt/cancel boundaries.
    pub kv_block_us: AtomicU64,
    /// Queue wait from submission to first admission (µs).
    pub queue_wait_us: AtomicU64,
    /// Bytes read from the delta store hydrating this tenant.
    pub store_bytes_read: AtomicU64,
    /// Disk→Cold hydrations performed for this tenant.
    pub hydrations: AtomicU64,
    /// Prompt tokens accepted.
    pub tokens_in: AtomicU64,
    /// Tokens generated (including streams cancelled mid-generation).
    pub tokens_out: AtomicU64,
    /// Requests accepted for this tenant.
    pub requests: AtomicU64,
    /// Requests refused with 429 (queue backpressure).
    pub rejected_429: AtomicU64,
    /// Requests refused with 503 (quarantine / shutdown).
    pub rejected_503: AtomicU64,
}

impl TenantUsage {
    /// Attribute `wall` of compute to this tenant.
    pub fn add_compute(&self, wall: Duration) {
        self.compute_us.fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
    }

    /// Accrue `blocks` KV blocks held for `held`.
    pub fn add_kv_blocks(&self, blocks: u64, held: Duration) {
        self.kv_block_us.fetch_add(blocks * held.as_micros() as u64, Ordering::Relaxed);
    }

    /// Attribute one request's queue wait.
    pub fn add_queue_wait(&self, wait: Duration) {
        self.queue_wait_us.fetch_add(wait.as_micros() as u64, Ordering::Relaxed);
    }

    /// Plain-integer copy of every counter (consistent enough for
    /// reporting; each field is read with one relaxed load).
    pub fn totals(&self) -> TenantTotals {
        TenantTotals {
            compute_us: self.compute_us.load(Ordering::Relaxed),
            kv_block_us: self.kv_block_us.load(Ordering::Relaxed),
            queue_wait_us: self.queue_wait_us.load(Ordering::Relaxed),
            store_bytes_read: self.store_bytes_read.load(Ordering::Relaxed),
            hydrations: self.hydrations.load(Ordering::Relaxed),
            tokens_in: self.tokens_in.load(Ordering::Relaxed),
            tokens_out: self.tokens_out.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            rejected_429: self.rejected_429.load(Ordering::Relaxed),
            rejected_503: self.rejected_503.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of one tenant's [`TenantUsage`] counters (or the sum of
/// several, for the `tenant="other"` aggregate).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantTotals {
    /// See [`TenantUsage::compute_us`].
    pub compute_us: u64,
    /// See [`TenantUsage::kv_block_us`].
    pub kv_block_us: u64,
    /// See [`TenantUsage::queue_wait_us`].
    pub queue_wait_us: u64,
    /// See [`TenantUsage::store_bytes_read`].
    pub store_bytes_read: u64,
    /// See [`TenantUsage::hydrations`].
    pub hydrations: u64,
    /// See [`TenantUsage::tokens_in`].
    pub tokens_in: u64,
    /// See [`TenantUsage::tokens_out`].
    pub tokens_out: u64,
    /// See [`TenantUsage::requests`].
    pub requests: u64,
    /// See [`TenantUsage::rejected_429`].
    pub rejected_429: u64,
    /// See [`TenantUsage::rejected_503`].
    pub rejected_503: u64,
}

impl TenantTotals {
    /// Fold another snapshot into this one (the `other` aggregation).
    pub fn absorb(&mut self, o: &TenantTotals) {
        self.compute_us += o.compute_us;
        self.kv_block_us += o.kv_block_us;
        self.queue_wait_us += o.queue_wait_us;
        self.store_bytes_read += o.store_bytes_read;
        self.hydrations += o.hydrations;
        self.tokens_in += o.tokens_in;
        self.tokens_out += o.tokens_out;
        self.requests += o.requests;
        self.rejected_429 += o.rejected_429;
        self.rejected_503 += o.rejected_503;
    }

    /// JSON object (durations converted to seconds).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("compute_s", self.compute_us as f64 / 1e6)
            .set("kv_block_s", self.kv_block_us as f64 / 1e6)
            .set("queue_wait_s", self.queue_wait_us as f64 / 1e6)
            .set("store_bytes_read", self.store_bytes_read)
            .set("hydrations", self.hydrations)
            .set("tokens_in", self.tokens_in)
            .set("tokens_out", self.tokens_out)
            .set("requests", self.requests)
            .set("rejected_429", self.rejected_429)
            .set("rejected_503", self.rejected_503);
        o
    }
}

/// Saturation scores per resource axis, each in `[0, 1]`, plus the
/// combined score (the max — any one saturated axis throttles) and the
/// `Retry-After` hint it implies.
#[derive(Debug, Clone, Copy)]
pub struct Saturation {
    /// KV-pool occupancy (used / total blocks), 10 s mean.
    pub kv: f64,
    /// Admission-queue fill (queued / aggregate queue capacity), 10 s
    /// mean.
    pub queue: f64,
    /// Drive-loop duty cycle: attributed exec wall per wall-clock
    /// second over the 10 s window.
    pub duty: f64,
    /// Audit/loader backlog pressure (pending shadow audits,
    /// normalized), 10 s mean.
    pub backlog: f64,
    /// `max` of the axes.
    pub combined: f64,
    /// Bounded load-derived `Retry-After` hint (seconds, ≥ 1).
    pub retry_after_s: u64,
}

impl Saturation {
    /// The per-axis scores with their `/metrics` label values.
    pub fn axes(&self) -> [(&'static str, f64); 4] {
        [("kv", self.kv), ("queue", self.queue), ("duty", self.duty), ("backlog", self.backlog)]
    }

    /// JSON object (the `/debug/usage` `"saturation"` field).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("kv", self.kv)
            .set("queue", self.queue)
            .set("duty", self.duty)
            .set("backlog", self.backlog)
            .set("combined", self.combined)
            .set("retry_after_s", self.retry_after_s);
        o
    }
}

/// Normalize an audit/loader backlog (pending items) into the `[0, 1]`
/// backlog-axis gauge fed to [`UsageLedger::tick`].
pub fn backlog_frac(pending: u64) -> f64 {
    (pending as f64 / BACKLOG_FULL).clamp(0.0, 1.0)
}

/// Map a combined saturation score to a bounded `Retry-After` hint:
/// at or below 0.5 the hint stays at the 1 s floor; above it the hint
/// grows linearly to `max_s` at full saturation.
pub fn retry_after_from_score(score: f64, max_s: u64) -> u64 {
    let max_s = max_s.max(1);
    let score = if score.is_finite() { score.clamp(0.0, 1.0) } else { 0.0 };
    let excess = (score - 0.5).max(0.0) / 0.5;
    let hint = 1.0 + excess * (max_s - 1) as f64;
    (hint.round() as u64).clamp(1, max_s)
}

/// Running mean of a gauge within one ring slot.
#[derive(Debug, Clone, Copy, Default)]
struct GaugeAvg {
    sum: f64,
    n: u64,
}

impl GaugeAvg {
    fn record(&mut self, v: f64) {
        self.sum += v;
        self.n += 1;
    }

    fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// One second of ledger history: gauge means observed within the
/// second plus cumulative-counter snapshots as of the latest tick in
/// it (so window deltas are `latest − snapshot[window start]`).
#[derive(Debug, Clone, Default)]
struct Slot {
    /// Absolute second (since ledger start) this slot holds.
    second: u64,
    valid: bool,
    kv: GaugeAvg,
    queue: GaugeAvg,
    backlog: GaugeAvg,
    /// Cumulative global exec wall (µs) snapshot.
    exec_us: u64,
    /// Cumulative per-tenant `(compute_us, tokens_out)` snapshots.
    tenants: HashMap<String, (u64, u64)>,
}

/// The per-second snapshot ring. `last_second` is the slot the most
/// recent tick landed in.
#[derive(Debug)]
struct Ring {
    slots: Vec<Slot>,
    last_second: u64,
}

impl Default for Ring {
    fn default() -> Ring {
        Ring { slots: vec![Slot::default(); RING_SECONDS as usize], last_second: 0 }
    }
}

impl Ring {
    fn slot_mut(&mut self, second: u64) -> &mut Slot {
        &mut self.slots[(second % RING_SECONDS) as usize]
    }

    fn slot(&self, second: u64) -> &Slot {
        &self.slots[(second % RING_SECONDS) as usize]
    }

    /// Valid slots within the trailing `window` seconds, oldest first.
    fn window(&self, window: u64) -> Vec<&Slot> {
        let from = self.last_second.saturating_sub(window.saturating_sub(1).min(RING_SECONDS - 1));
        (from..=self.last_second)
            .map(|s| self.slot(s))
            .filter(|slot| slot.valid && slot.second + window > self.last_second)
            .collect()
    }
}

/// The coordinator-wide usage ledger: per-tenant attributed counters,
/// the global exec-wall counter, and the per-second snapshot ring the
/// saturation engine reads. Lives inside
/// [`crate::coordinator::Metrics`]; one per server.
#[derive(Debug)]
pub struct UsageLedger {
    enabled: AtomicBool,
    top_k: AtomicU64,
    retry_max_s: AtomicU64,
    /// Monotonic base of the ring's second counter.
    started: Instant,
    /// Global attributed exec wall (µs): per-step exec wall on the
    /// scheduler path, per-batch wall on the legacy path. The
    /// conservation property checks Σ per-tenant compute against this.
    exec_us: AtomicU64,
    tenants: Mutex<HashMap<String, Arc<TenantUsage>>>,
    ring: Mutex<Ring>,
}

impl Default for UsageLedger {
    fn default() -> UsageLedger {
        UsageLedger {
            enabled: AtomicBool::new(true),
            top_k: AtomicU64::new(8),
            retry_max_s: AtomicU64::new(30),
            started: Instant::now(),
            exec_us: AtomicU64::new(0),
            tenants: Mutex::new(HashMap::new()),
            ring: Mutex::new(Ring::default()),
        }
    }
}

impl UsageLedger {
    /// Apply the `[usage]` config (done once at server construction).
    pub fn configure(&self, cfg: &UsageConfig) {
        self.enabled.store(cfg.enabled, Ordering::Relaxed);
        self.top_k.store(cfg.top_k.max(1) as u64, Ordering::Relaxed);
        self.retry_max_s.store(cfg.retry_max_s.max(1), Ordering::Relaxed);
    }

    /// Whether attribution is on.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// The configured `Retry-After` upper bound in seconds.
    pub fn retry_max_s(&self) -> u64 {
        self.retry_max_s.load(Ordering::Relaxed)
    }

    /// The tenant's counter block, created on first touch. `None` when
    /// the ledger is disabled — callers skip attribution entirely, so
    /// the disabled hot path pays one relaxed load per call site.
    pub fn tenant(&self, name: &str) -> Option<Arc<TenantUsage>> {
        if !self.enabled() {
            return None;
        }
        let mut map = self.tenants.lock().unwrap();
        Some(map.entry(name.to_string()).or_default().clone())
    }

    /// Add `wall` to the global exec-wall counter (the conservation
    /// denominator and the duty-cycle numerator).
    pub fn add_exec_wall(&self, wall: Duration) {
        if self.enabled() {
            self.exec_us.fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
        }
    }

    /// Total attributed exec wall in microseconds.
    pub fn exec_wall_us(&self) -> u64 {
        self.exec_us.load(Ordering::Relaxed)
    }

    /// Σ per-tenant attributed compute ÷ global exec wall, or `None`
    /// before any exec wall has been recorded. ≈ 1.0 when attribution
    /// conserves (the `bench --name usage` / `tests/usage_serving.rs`
    /// property).
    pub fn conservation_ratio(&self) -> Option<f64> {
        let exec = self.exec_wall_us();
        if exec == 0 {
            return None;
        }
        let attributed: u64 = self
            .tenants
            .lock()
            .unwrap()
            .values()
            .map(|t| t.compute_us.load(Ordering::Relaxed))
            .sum();
        Some(attributed as f64 / exec as f64)
    }

    /// Feed the snapshot ring one observation of the instantaneous
    /// gauges (each in `[0, 1]`), rolling it to the current second.
    /// Called by the scheduler's `publish()` every iteration / idle
    /// tick, and by the read paths so the window decays even under the
    /// legacy worker loop.
    pub fn tick(&self, kv_frac: f64, queue_frac: f64, backlog_frac: f64) {
        if !self.enabled() {
            return;
        }
        let clamp = |v: f64| if v.is_finite() { v.clamp(0.0, 1.0) } else { 0.0 };
        let now_s = self.started.elapsed().as_secs();
        let exec_total = self.exec_us.load(Ordering::Relaxed);
        let mut ring = self.ring.lock().unwrap();
        let rolled = now_s > ring.last_second || !ring.slot(now_s).valid;
        if now_s > ring.last_second {
            if now_s - ring.last_second >= RING_SECONDS {
                // idle longer than the ring remembers: restart clean
                for slot in &mut ring.slots {
                    *slot = Slot::default();
                }
            } else {
                // carry cumulative snapshots through skipped seconds
                // (no activity) with zero-gauge slots, so window means
                // decay while window deltas stay correct
                let prev = ring.slot(ring.last_second).clone();
                for s in (ring.last_second + 1)..now_s {
                    *ring.slot_mut(s) = Slot {
                        second: s,
                        valid: prev.valid,
                        exec_us: prev.exec_us,
                        tenants: prev.tenants.clone(),
                        ..Slot::default()
                    };
                }
            }
            ring.last_second = now_s;
        }
        if rolled {
            // per-tenant cumulative snapshots are taken only at second
            // boundaries — within a second, ticks touch atomics and one
            // gauge record, nothing that allocates
            let snaps: HashMap<String, (u64, u64)> = {
                let map = self.tenants.lock().unwrap();
                map.iter()
                    .map(|(name, t)| {
                        let c = t.compute_us.load(Ordering::Relaxed);
                        let tok = t.tokens_out.load(Ordering::Relaxed);
                        (name.clone(), (c, tok))
                    })
                    .collect()
            };
            *ring.slot_mut(now_s) =
                Slot { second: now_s, valid: true, tenants: snaps, ..Slot::default() };
        }
        let slot = ring.slot_mut(now_s);
        slot.exec_us = exec_total;
        slot.kv.record(clamp(kv_frac));
        slot.queue.record(clamp(queue_frac));
        slot.backlog.record(clamp(backlog_frac));
    }

    /// Derive the saturation scores from the trailing 10 s window.
    /// Callers should [`UsageLedger::tick`] first so the window
    /// includes the present.
    pub fn saturation(&self) -> Saturation {
        if !self.enabled() {
            return Saturation {
                kv: 0.0,
                queue: 0.0,
                duty: 0.0,
                backlog: 0.0,
                combined: 0.0,
                retry_after_s: 1,
            };
        }
        let ring = self.ring.lock().unwrap();
        let window = ring.window(MID_WINDOW_S);
        let axis_mean = |pick: &dyn Fn(&Slot) -> GaugeAvg| -> f64 {
            if window.is_empty() {
                return 0.0;
            }
            window.iter().map(|s| pick(s).mean()).sum::<f64>() / window.len() as f64
        };
        let kv = axis_mean(&|s: &Slot| s.kv);
        let queue = axis_mean(&|s: &Slot| s.queue);
        let backlog = axis_mean(&|s: &Slot| s.backlog);
        let duty = match (window.first(), window.last()) {
            (Some(first), Some(last)) if last.second > first.second => {
                let span_us = (last.second - first.second) as f64 * 1e6;
                ((last.exec_us.saturating_sub(first.exec_us)) as f64 / span_us).clamp(0.0, 1.0)
            }
            _ => 0.0,
        };
        drop(ring);
        let combined = kv.max(queue).max(duty).max(backlog);
        let retry_after_s = retry_after_from_score(combined, self.retry_max_s());
        Saturation { kv, queue, duty, backlog, combined, retry_after_s }
    }

    /// Per-tenant rate over the trailing `window` seconds:
    /// `(compute seconds per second, tokens per second)` derived from
    /// the ring's cumulative snapshots. Zero when the window has no
    /// span yet.
    fn window_rates(&self, window: u64) -> HashMap<String, (f64, f64)> {
        let ring = self.ring.lock().unwrap();
        let slots = ring.window(window);
        let (Some(first), Some(last)) = (slots.first(), slots.last()) else {
            return HashMap::new();
        };
        if last.second <= first.second {
            return HashMap::new();
        }
        let span_s = (last.second - first.second) as f64;
        last.tenants
            .iter()
            .map(|(name, &(compute, tokens))| {
                let (c0, t0) = first.tenants.get(name).copied().unwrap_or((0, 0));
                let compute_rate = compute.saturating_sub(c0) as f64 / 1e6 / span_s;
                let token_rate = tokens.saturating_sub(t0) as f64 / span_s;
                (name.clone(), (compute_rate, token_rate))
            })
            .collect()
    }

    /// The `/metrics` cardinality-capped view: the top-K tenants by
    /// attributed compute (ties broken by name), plus the aggregate of
    /// everyone else as `tenant="other"` when any were cut.
    pub fn export(&self) -> (Vec<(String, TenantTotals)>, Option<TenantTotals>) {
        let k = self.top_k.load(Ordering::Relaxed) as usize;
        let mut all: Vec<(String, TenantTotals)> = {
            let map = self.tenants.lock().unwrap();
            map.iter().map(|(name, t)| (name.clone(), t.totals())).collect()
        };
        all.sort_by(|a, b| b.1.compute_us.cmp(&a.1.compute_us).then_with(|| a.0.cmp(&b.0)));
        if all.len() <= k {
            return (all, None);
        }
        let rest = all.split_off(k);
        let mut other = TenantTotals::default();
        for (_, t) in &rest {
            other.absorb(t);
        }
        (all, Some(other))
    }

    /// One tenant's totals, if it has any attributed usage.
    pub fn totals(&self, tenant: &str) -> Option<TenantTotals> {
        self.tenants.lock().unwrap().get(tenant).map(|t| t.totals())
    }

    /// The `GET /debug/usage` JSON: saturation plus every tenant's
    /// totals and windowed rates. With `tenant` set, the single-tenant
    /// view (`None` when that tenant has no attributed usage).
    pub fn snapshot_json(&self, tenant: Option<&str>) -> Option<Json> {
        let sat = self.saturation();
        let rates_1 = self.window_rates(1);
        let rates_10 = self.window_rates(MID_WINDOW_S);
        let rates_60 = self.window_rates(60);
        let tenant_json = |name: &str, totals: &TenantTotals| -> Json {
            let mut rates = Json::obj();
            for (label, map) in [("1s", &rates_1), ("10s", &rates_10), ("60s", &rates_60)] {
                let (compute, tokens) = map.get(name).copied().unwrap_or((0.0, 0.0));
                let mut w = Json::obj();
                w.set("compute_s_per_s", compute).set("tokens_per_s", tokens);
                rates.set(label, w);
            }
            let mut o = Json::obj();
            o.set("totals", totals.to_json()).set("rates", rates);
            o
        };
        if let Some(name) = tenant {
            let totals = self.totals(name)?;
            let mut o = Json::obj();
            o.set("tenant", name)
                .set("enabled", self.enabled())
                .set("saturation", sat.to_json());
            let detail = tenant_json(name, &totals);
            if let Some(obj) = detail.as_object() {
                for (k, v) in obj {
                    o.set(k, v.clone());
                }
            }
            return Some(o);
        }
        let mut tenants: Vec<(String, TenantTotals)> = {
            let map = self.tenants.lock().unwrap();
            map.iter().map(|(name, t)| (name.clone(), t.totals())).collect()
        };
        tenants.sort_by(|a, b| b.1.compute_us.cmp(&a.1.compute_us).then_with(|| a.0.cmp(&b.0)));
        let mut by_tenant = Json::obj();
        for (name, totals) in &tenants {
            by_tenant.set(name, tenant_json(name, totals));
        }
        let mut o = Json::obj();
        o.set("enabled", self.enabled())
            .set("saturation", sat.to_json())
            .set("exec_wall_s", self.exec_wall_us() as f64 / 1e6)
            .set("tenants", by_tenant);
        Some(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_after_floor_ceiling_and_monotone() {
        assert_eq!(retry_after_from_score(0.0, 30), 1);
        assert_eq!(retry_after_from_score(0.5, 30), 1);
        assert_eq!(retry_after_from_score(1.0, 30), 30);
        assert_eq!(retry_after_from_score(2.0, 30), 30, "clamps above 1.0");
        assert_eq!(retry_after_from_score(f64::NAN, 30), 1);
        let mut last = 0;
        for i in 0..=20 {
            let hint = retry_after_from_score(i as f64 / 20.0, 30);
            assert!(hint >= last, "hint grows with score");
            last = hint;
        }
        assert_eq!(retry_after_from_score(1.0, 0), 1, "max_s floors at 1");
    }

    #[test]
    fn disabled_ledger_skips_attribution() {
        let ledger = UsageLedger::default();
        ledger.configure(&UsageConfig { enabled: false, ..UsageConfig::default() });
        assert!(ledger.tenant("math").is_none());
        ledger.add_exec_wall(Duration::from_millis(5));
        assert_eq!(ledger.exec_wall_us(), 0);
        assert_eq!(ledger.saturation().retry_after_s, 1);
    }

    #[test]
    fn counters_accumulate_and_conserve() {
        let ledger = UsageLedger::default();
        let a = ledger.tenant("a").unwrap();
        let b = ledger.tenant("b").unwrap();
        a.add_compute(Duration::from_millis(30));
        b.add_compute(Duration::from_millis(10));
        a.add_kv_blocks(4, Duration::from_millis(100));
        a.add_queue_wait(Duration::from_millis(2));
        a.tokens_out.fetch_add(7, Ordering::Relaxed);
        ledger.add_exec_wall(Duration::from_millis(40));
        let ratio = ledger.conservation_ratio().unwrap();
        assert!((ratio - 1.0).abs() < 0.01, "attributed ≈ global: {ratio}");
        let totals = ledger.totals("a").unwrap();
        assert_eq!(totals.kv_block_us, 400_000);
        assert_eq!(totals.tokens_out, 7);
        assert!(ledger.totals("missing").is_none());
    }

    #[test]
    fn export_caps_cardinality_with_other() {
        let ledger = UsageLedger::default();
        ledger.configure(&UsageConfig { top_k: 2, ..UsageConfig::default() });
        for (name, ms) in [("hot", 30u64), ("warm", 20), ("cool", 5), ("cold", 1)] {
            ledger.tenant(name).unwrap().add_compute(Duration::from_millis(ms));
        }
        let (top, other) = ledger.export();
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].0, "hot");
        assert_eq!(top[1].0, "warm");
        let other = other.expect("two tenants were cut");
        assert_eq!(other.compute_us, 6_000);
        // under the cap: no "other" sample at all
        ledger.configure(&UsageConfig { top_k: 8, ..UsageConfig::default() });
        let (top, other) = ledger.export();
        assert_eq!(top.len(), 4);
        assert!(other.is_none());
    }

    #[test]
    fn saturation_tracks_gauges_and_derives_retry() {
        let ledger = UsageLedger::default();
        ledger.tick(0.0, 0.0, 0.0);
        let calm = ledger.saturation();
        assert!(calm.combined < 0.01);
        assert_eq!(calm.retry_after_s, 1);
        for _ in 0..8 {
            ledger.tick(0.2, 1.0, 0.1);
        }
        let hot = ledger.saturation();
        assert!(hot.queue > 0.5, "queue axis dominates: {hot:?}");
        assert_eq!(hot.combined, hot.kv.max(hot.queue).max(hot.duty).max(hot.backlog));
        assert!(hot.retry_after_s > 1, "saturated score lifts the hint: {hot:?}");
        assert!(hot.retry_after_s <= 30);
    }

    #[test]
    fn snapshot_json_shapes() {
        let ledger = UsageLedger::default();
        let t = ledger.tenant("math").unwrap();
        t.add_compute(Duration::from_millis(12));
        t.requests.fetch_add(3, Ordering::Relaxed);
        ledger.tick(0.1, 0.2, 0.0);
        let all = ledger.snapshot_json(None).unwrap().to_string();
        assert!(all.contains("\"saturation\""), "{all}");
        assert!(all.contains("\"math\""), "{all}");
        assert!(all.contains("\"retry_after_s\""), "{all}");
        assert!(all.contains("\"rates\""), "{all}");
        let one = ledger.snapshot_json(Some("math")).unwrap().to_string();
        assert!(one.contains("\"tenant\":\"math\""), "{one}");
        assert!(one.contains("\"requests\":3"), "{one}");
        assert!(ledger.snapshot_json(Some("nope")).is_none());
    }
}
