//! `deltadq` — the launcher (S12).
//!
//! Subcommands:
//!
//! * `gen-data`   — generate the synthetic task datasets (`.dqt`)
//! * `compress`   — compress a fine-tuned model's delta (`.ddq` out)
//! * `eval`       — task accuracy of base / fine-tuned / compressed
//! * `search`     — group-size search (direct vs proxy)
//! * `serve`      — multi-tenant serving coordinator
//! * `push`       — register a `.ddq` artifact into a delta store
//! * `gc`         — sweep a delta store (and optionally remove tenants)
//! * `ls`         — list a delta store's tenants
//! * `audit`      — offline shadow audit of a stored tenant (quality)
//! * `usage`      — per-tenant usage + saturation from a live gateway
//! * `bench`      — regenerate a paper table/figure (table1..4, fig4..8)
//!
//! CLI parsing is hand-rolled (the container vendors no clap); flags are
//! `--key value` pairs after the subcommand.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use deltadq::bench_harness;
use deltadq::compress::pipeline::{capture_calibration, compress_model_deltas};
use deltadq::compress::{Compressor, Dare, DeltaDq, DeltaDqConfig, DeltaZip, DeltaZipConfig, Magnitude};
use deltadq::config::{Config, ServeConfig};
use deltadq::coordinator;
use deltadq::delta::{extract_deltas, load_delta_set, save_delta_set};
use deltadq::eval::{evaluate_parallel, gen_dataset, save_dataset, TaskKind};
use deltadq::model::load_weights;
use deltadq::search::{search_direct, search_proxy};
use deltadq::store::DeltaStore;
use deltadq::tensor::Pcg64;
use deltadq::util::table::Table;

/// Minimal `--key value` flag map.
struct Args {
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let key = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{}'", argv[i]))?;
            let value = argv
                .get(i + 1)
                .with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key.to_string(), value.clone());
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
            None => Ok(default),
        }
    }

    fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v} (expected true|false)")),
            None => Ok(default),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "gen-models" => cmd_gen_models(&args),
        "compress" => cmd_compress(&args),
        "eval" => cmd_eval(&args),
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "push" => cmd_push(&args),
        "gc" => cmd_gc(&args),
        "ls" => cmd_ls(&args),
        "audit" => cmd_audit(&args),
        "usage" => cmd_usage(&args),
        "bench" => cmd_bench(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `deltadq help`)"),
    }
}

fn print_usage() {
    println!(
        "deltadq — ultra-high delta compression for fine-tuned LLMs\n\
         \n\
         USAGE: deltadq <command> [--flag value]...\n\
         \n\
         COMMANDS:\n\
           gen-data  --out DIR [--train N] [--eval N] [--seed S]\n\
           gen-models --out DIR [--scale tiny|small|base|large]\n\
                     [--tenants LIST] [--seed S] (synthesizes base.dqw\n\
                     + per-tenant fine-tune .dqw artifacts — randomly\n\
                     initialized, for serving smoke tests; real models\n\
                     come from python/compile/train.py)\n\
           compress  --base F.dqw --finetuned F.dqw --out F.ddq\n\
                     [--method deltadq|dare|magnitude|deltazip]\n\
                     [--ratio R] [--group-size G] [--bits K] [--parts M]\n\
                     [--data DIR]\n\
           eval      --base F.dqw [--delta F.ddq | --finetuned F.dqw]\n\
                     --data F.dqt [--threads N]\n\
           search    --base F.dqw --finetuned F.dqw --data F.dqt\n\
                     [--ratio R] [--method proxy|direct|both]\n\
           serve     [--config F.toml] [--models DIR] [--requests N]\n\
                     [--tenants LIST] [--rate R] [--backend native|pjrt]\n\
                     [--store DIR] (tiered serving out of a delta store)\n\
                     [--listen HOST:PORT] (HTTP gateway: POST\n\
                     /v1/completions with SSE streaming, GET /metrics,\n\
                     GET /healthz, GET /debug/trace/<id>, GET\n\
                     /debug/flight; port 0 = ephemeral, the bound\n\
                     address is printed; serves until killed)\n\
                     [--sched.kv_pool_mib M] [--sched.block_size B]\n\
                     [--sched.max_running N] [--sched.enabled B]\n\
                     [--sched.prefill_chunk P] (continuous-batching\n\
                     scheduler knobs; prefill_chunk bounds prompt\n\
                     positions cached per iteration, 0 = whole prompt)\n\
                     [--trace.enabled B] [--trace.ring_spans N]\n\
                     [--trace.flight_window_s S] (request-tracing /\n\
                     flight-recorder knobs; see docs/OBSERVABILITY.md)\n\
                     [--audit.enabled B] [--audit.sample_every N]\n\
                     [--audit.quarantine_below A] [--audit.enforce B]\n\
                     [--audit.window W] (online shadow-audit knobs;\n\
                     scrape GET /debug/quality[/<tenant>])\n\
                     [--usage.enabled B] [--usage.top_k K]\n\
                     [--usage.retry_max_s S] (per-tenant usage ledger +\n\
                     saturation knobs; 429/503 Retry-After hints derive\n\
                     from load; scrape GET /debug/usage[/<tenant>])\n\
           loadgen   --addr HOST:PORT [--requests N] [--rps R]\n\
                     [--tenants LIST] [--zipf S] [--prompt-len P]\n\
                     [--max-tokens M] [--long-frac F]\n\
                     [--long-max-tokens M] [--stream true|false]\n\
                     [--honor-retry-after true|false]\n\
                     [--seed S] [--out REPORT.json] [--trace-slowest N]\n\
                     (open-loop HTTP load: TTFT / inter-token / total\n\
                     latency histograms split short-vs-long, 429\n\
                     accounting; --honor-retry-after pauses a tenant's\n\
                     arrivals for the server's hinted interval and\n\
                     retries; --trace-slowest fetches and prints the\n\
                     server-side span tree of the N slowest requests)\n\
           push      --store DIR --tenant NAME --delta F.ddq\n\
           gc        --store DIR [--remove TENANT[,TENANT...]]\n\
                     [--dry-run true] (report orphans/bytes without\n\
                     deleting; removals print bytes per tenant)\n\
           ls        --store DIR\n\
           audit     --store DIR --tenant NAME [--models DIR]\n\
                     [--scale tiny|small|base|large] [--base F.dqw]\n\
                     [--prompts N] [--max-tokens M] [--json true]\n\
                     [--backend native|pjrt] [--fused-threads N]\n\
                     (offline shadow audit: decode through the fused\n\
                     serving path, re-score against a dense\n\
                     reconstruction of the store copy, and print the\n\
                     per-layer reconstruction-error / BIR table)\n\
           usage     --addr HOST:PORT [--tenant NAME] [--json true]\n\
                     (per-tenant resource totals + saturation axes from\n\
                     a running gateway's GET /debug/usage)\n\
           bench     --name table1|table2|table3|table4|fig4|fig5|fig6|\n\
                     fig7|fig8|ablations|serving|kernels|churn|gateway|\n\
                     decode|chaos|trace|audit|usage\n\
                     [--models DIR] [--out FILE] [--backend native|pjrt]\n\
                     [--fused-threads N] [--artifacts DIR]\n\
                     (kernels/churn/gateway/decode/chaos/trace/usage\n\
                     write BENCH_<name>.json; set DELTADQ_BENCH_QUICK=1\n\
                     for the CI-sized run)"
    );
}

// ------------------------------------------------------------ gen-data

fn cmd_gen_data(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.str_or("out", "artifacts/data"));
    std::fs::create_dir_all(&out)?;
    let n_train = args.usize_or("train", 20_000)?;
    let n_eval = args.usize_or("eval", 400)?;
    let seed = args.u64_or("seed", 20240701)?;
    for task in [TaskKind::Math, TaskKind::Code, TaskKind::Chat] {
        let train = gen_dataset(task, n_train, seed);
        let eval = gen_dataset(task, n_eval, seed ^ 0xEEEE);
        save_dataset(&out.join(format!("{}_train.dqt", task.name())), &train)?;
        save_dataset(&out.join(format!("{}_eval.dqt", task.name())), &eval)?;
        println!(
            "wrote {}_train.dqt ({n_train} samples) and {}_eval.dqt ({n_eval})",
            task.name(),
            task.name()
        );
    }
    Ok(())
}

// ---------------------------------------------------------- gen-models

/// Synthesize serving artifacts without the Python training pipeline:
/// a randomly initialized `base.dqw` plus one small-perturbation
/// fine-tune `.dqw` per tenant. Enough for the gateway/serving smoke
/// paths (`serve` compresses the delta on first load); accuracy-bearing
/// experiments still need the trained artifacts.
fn cmd_gen_models(args: &Args) -> Result<()> {
    use deltadq::model::{save_weights, ModelConfig, ModelWeights};

    let out = PathBuf::from(args.str_or("out", "artifacts/models"));
    let scale = args.str_or("scale", "tiny");
    let tenants = args.str_or("tenants", "math,code,chat");
    let seed = args.u64_or("seed", 7)?;
    let config = ModelConfig::preset(&scale)
        .with_context(|| format!("unknown scale '{scale}' (tiny|small|base|large)"))?;
    let dir = out.join(&scale);
    std::fs::create_dir_all(&dir)?;
    let mut rng = Pcg64::seeded(seed);
    let base = ModelWeights::init(config, &mut rng);
    save_weights(&dir.join("base.dqw"), &base)?;
    let mut n = 1usize;
    for tenant in tenants.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let mut ft = base.clone();
        for name in config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            let d = deltadq::tensor::Matrix::randn(r, c, 0.001, &mut rng);
            ft.get_mut(&name).add_assign(&d);
        }
        save_weights(&dir.join(format!("{tenant}.dqw")), &ft)?;
        n += 1;
    }
    println!("wrote {n} synthetic '{scale}' model(s) under {}", dir.display());
    Ok(())
}

// ------------------------------------------------------------ compress

fn cmd_compress(args: &Args) -> Result<()> {
    let base = load_weights(Path::new(
        args.get("base").context("--base required")?,
    ))?;
    let ft = load_weights(Path::new(
        args.get("finetuned").context("--finetuned required")?,
    ))?;
    let out = PathBuf::from(args.get("out").context("--out required")?);
    let method = args.str_or("method", "deltadq");
    let ratio = args.f64_or("ratio", 16.0)?;
    let seed = args.u64_or("seed", 42)?;

    let deltas = extract_deltas(&base, &ft);
    let mut rng = Pcg64::seeded(seed);

    let group_size = args.get("group-size").map(|v| v.parse()).transpose()?;
    let compressor: Box<dyn Compressor> = match method.as_str() {
        "deltadq" => {
            let cfg = match (args.get("bits"), args.get("parts")) {
                (Some(k), m) => DeltaDqConfig::with_quant(
                    args.f64_or("alpha", ratio)?,
                    group_size,
                    k.parse()?,
                    m.map(|v| v.parse()).transpose()?.unwrap_or(1),
                ),
                (None, _) => DeltaDqConfig::for_total_ratio(ratio, group_size),
            };
            Box::new(DeltaDq::new(cfg))
        }
        "dare" => Box::new(Dare::new(ratio)),
        "magnitude" => Box::new(Magnitude::new(ratio)),
        "deltazip" => Box::new(DeltaZip::new(DeltaZipConfig::for_total_ratio(ratio))),
        other => bail!("unknown method '{other}'"),
    };

    // calibration for second-order methods
    let calibration = if method == "deltazip" {
        let data_dir = PathBuf::from(args.str_or("data", "artifacts/data"));
        let samples = deltadq::eval::load_dataset(&data_dir.join("math_eval.dqt"))?;
        capture_calibration(&ft, &samples[..samples.len().min(16)], 256)
    } else {
        BTreeMap::new()
    };

    let set = compress_model_deltas(&deltas, compressor.as_ref(), &calibration, &mut rng);
    save_delta_set(&out, &set)?;
    println!(
        "compressed with {}: nominal {}x, measured storage {:.1}x, {} -> {} bytes",
        set.method,
        set.nominal_ratio,
        set.measured_ratio(),
        set.total_elems() * 2,
        set.storage_bits() / 8
    );
    Ok(())
}

// ---------------------------------------------------------------- eval

fn cmd_eval(args: &Args) -> Result<()> {
    let base = load_weights(Path::new(
        args.get("base").context("--base required")?,
    ))?;
    let data = deltadq::eval::load_dataset(Path::new(
        args.get("data").context("--data required")?,
    ))?;
    let threads = args.usize_or("threads", 4)?;
    let weights = match (args.get("delta"), args.get("finetuned")) {
        (Some(ddq), _) => {
            let set = load_delta_set(Path::new(ddq))?;
            deltadq::compress::pipeline::reconstruct_weights(&base, &set)
        }
        (None, Some(ft)) => load_weights(Path::new(ft))?,
        (None, None) => base.clone(),
    };
    let report = evaluate_parallel(&weights, &data, threads);
    println!(
        "accuracy: {:.2}% ({}/{})",
        report.percent(),
        report.correct,
        report.total
    );
    Ok(())
}

// -------------------------------------------------------------- search

fn cmd_search(args: &Args) -> Result<()> {
    let base = load_weights(Path::new(
        args.get("base").context("--base required")?,
    ))?;
    let ft = load_weights(Path::new(
        args.get("finetuned").context("--finetuned required")?,
    ))?;
    let data = deltadq::eval::load_dataset(Path::new(
        args.get("data").context("--data required")?,
    ))?;
    let ratio = args.f64_or("ratio", 8.0)?;
    let seed = args.u64_or("seed", 42)?;
    let method = args.str_or("method", "both");
    let deltas = extract_deltas(&base, &ft);
    if method == "proxy" || method == "both" {
        let r = search_proxy(&base, &deltas, ratio, &data, 0.01, seed);
        println!(
            "proxy:  h_g* = {} in {:.2}s  {:?}",
            r.best_group_size,
            r.elapsed.as_secs_f64(),
            r.candidates
        );
    }
    if method == "direct" || method == "both" {
        let r = search_direct(&base, &deltas, ratio, &data, seed);
        println!(
            "direct: h_g* = {} in {:.2}s  {:?}",
            r.best_group_size,
            r.elapsed.as_secs_f64(),
            r.candidates
        );
    }
    Ok(())
}

// --------------------------------------------------------------- serve

fn cmd_serve(args: &Args) -> Result<()> {
    let mut config = match args.get("config") {
        Some(path) => Config::load(Path::new(path))?,
        None => Config::default(),
    };
    let overrides: Vec<String> = args
        .flags
        .iter()
        .filter(|(k, _)| {
            k.starts_with("serve.")
                || k.starts_with("store.")
                || k.starts_with("sched.")
                || k.starts_with("trace.")
                || k.starts_with("audit.")
                || k.starts_with("usage.")
        })
        .map(|(k, v)| format!("{k}={v}"))
        .collect();
    config.apply_overrides(&overrides)?;
    let mut serve = ServeConfig::from_config(&config);
    if let Some(dir) = args.get("models") {
        serve.artifacts_dir = dir.to_string();
    }
    if let Some(backend) = args.get("backend") {
        serve.backend = backend.to_string();
    }
    if let Some(store) = args.get("store") {
        serve.store_path = Some(store.to_string());
    }
    if let Some(listen) = args.get("listen") {
        serve.listen_addr = Some(listen.to_string());
    }
    let tenants = args.str_or("tenants", "math,code,chat");
    if serve.listen_addr.is_some() {
        // network front-end: expose the coordinator over HTTP and serve
        // until killed (requests come from outside the process)
        return deltadq::gateway::run_serve(&serve, &tenants);
    }
    let requests = args.usize_or("requests", 64)?;
    let rate = args.f64_or("rate", 200.0)?;
    coordinator::run_demo_server(&serve, &tenants, requests, rate)
}

// ------------------------------------------------------------- loadgen

fn cmd_loadgen(args: &Args) -> Result<()> {
    let opts = deltadq::gateway::loadgen::LoadgenOptions {
        addr: args.get("addr").context("--addr HOST:PORT required")?.to_string(),
        tenants: args
            .str_or("tenants", "math,code,chat")
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        requests: args.usize_or("requests", 64)?,
        rps: args.f64_or("rps", 32.0)?,
        zipf_s: args.f64_or("zipf", 1.1)?,
        prompt_len: args.usize_or("prompt-len", 8)?,
        max_tokens: args.usize_or("max-tokens", 8)?,
        long_frac: args.f64_or("long-frac", 0.0)?,
        long_max_tokens: args.usize_or("long-max-tokens", 32)?,
        stream: args.bool_or("stream", true)?,
        honor_retry_after: args.bool_or("honor-retry-after", false)?,
        seed: args.u64_or("seed", 0x10AD)?,
        timeout: std::time::Duration::from_secs(args.u64_or("timeout-secs", 120)?),
    };
    let report = deltadq::gateway::loadgen::run(&opts)?;
    print!("{}", report.render());
    let slowest = args.usize_or("trace-slowest", 0)?;
    for (rank, (id, total_s)) in report.slowest(slowest).into_iter().enumerate() {
        match deltadq::gateway::loadgen::fetch_trace(&opts.addr, id, opts.timeout) {
            Ok(tree) => {
                println!("slowest #{}: request {id}, total {:.1}ms", rank + 1, total_s * 1e3);
                print!("{}", deltadq::util::trace::render_tree(&tree));
            }
            // traces are best-effort: the ring may have evicted an old
            // request's spans by the time the run ends
            Err(e) => println!("slowest #{}: request {id} trace unavailable: {e:#}", rank + 1),
        }
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json().to_string())?;
        println!("wrote {out}");
    }
    if report.transport_errors > 0 || report.http_errors > 0 {
        bail!(
            "{} transport / {} http errors during the run",
            report.transport_errors,
            report.http_errors
        );
    }
    Ok(())
}

// ------------------------------------------------------- delta store

fn cmd_push(args: &Args) -> Result<()> {
    let root = PathBuf::from(args.get("store").context("--store required")?);
    let tenant = args.get("tenant").context("--tenant required")?;
    let delta = args.get("delta").context("--delta required (a .ddq file)")?;
    let set = load_delta_set(Path::new(delta))?;
    let store = DeltaStore::open_or_create(&root)?;
    let bytes = store.push(tenant, &set)?;
    let info = store.tenant_info(tenant).expect("just pushed");
    println!(
        "pushed '{tenant}' ({}, nominal {:.0}x): {} tensors, {bytes} bytes in {} shard(s)",
        info.method,
        info.nominal_ratio,
        info.tensors.len(),
        info.shards.len()
    );
    Ok(())
}

fn cmd_gc(args: &Args) -> Result<()> {
    let root = PathBuf::from(args.get("store").context("--store required")?);
    let store = DeltaStore::open(&root)?;
    let dry_run = args.bool_or("dry-run", false)?;
    if let Some(list) = args.get("remove") {
        for tenant in list.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            // read the size before the manifest entry goes away, so the
            // per-tenant reclaimed bytes can be reported
            let bytes = store.tenant_info(tenant).map(|i| i.bytes).unwrap_or(0);
            if dry_run {
                if store.contains(tenant) {
                    println!("would remove '{tenant}' ({bytes} bytes)");
                } else {
                    println!("'{tenant}' is not in the store");
                }
            } else if store.remove(tenant)? {
                println!("removed '{tenant}' ({bytes} bytes reclaimed)");
            } else {
                println!("'{tenant}' was not in the store");
            }
        }
    }
    let report = if dry_run { store.gc_dry_run()? } else { store.gc()? };
    let verb = if dry_run { "gc --dry-run: would sweep" } else { "gc: swept" };
    println!(
        "{verb} {} orphan file(s), {} bytes; {} tenant(s), {} bytes live",
        report.files_removed,
        report.bytes_freed,
        store.tenant_count(),
        store.total_bytes()
    );
    Ok(())
}

fn cmd_ls(args: &Args) -> Result<()> {
    let root = PathBuf::from(args.get("store").context("--store required")?);
    let store = DeltaStore::open(&root)?;
    let mut t = Table::new(
        &format!("delta store at {}", root.display()),
        &["tenant", "id", "method", "ratio", "tensors", "shards", "bytes"],
    );
    for tenant in store.tenants() {
        let info = store.tenant_info(&tenant).expect("listed");
        t.add_row(vec![
            tenant,
            info.id.to_string(),
            info.method.clone(),
            format!("{:.0}x", info.nominal_ratio),
            info.tensors.len().to_string(),
            info.shards.len().to_string(),
            info.bytes.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("total: {} tenant(s), {} payload bytes", store.tenant_count(), store.total_bytes());
    Ok(())
}

// --------------------------------------------------------------- audit

/// Offline shadow audit of one tenant against a delta store: every
/// prompt is decoded through the fused serving path, then re-scored
/// against a dense reconstruction of the CRC-verified store copy — the
/// same comparison the online auditor samples at 1-in-N, run over a
/// fixed prompt set without standing up a server. Prints per-prompt
/// agreement/divergence plus the per-layer reconstruction-error / BIR
/// table (`--json true` emits the same data as one JSON object).
fn cmd_audit(args: &Args) -> Result<()> {
    use deltadq::audit::{layer_stat_json, layer_stats, shadow_compare};
    use deltadq::runtime::ThreadPool;
    use deltadq::util::json::Json;

    let tenant = args.get("tenant").context("--tenant required")?;
    let root = PathBuf::from(args.get("store").context("--store required")?);
    let models_dir = PathBuf::from(args.str_or("models", "artifacts/models"));
    let scale = args.str_or("scale", "tiny");
    let base_path = match args.get("base") {
        Some(p) => PathBuf::from(p),
        None => models_dir.join(&scale).join("base.dqw"),
    };
    let n_prompts = args.usize_or("prompts", 8)?.max(1);
    let max_tokens = args.usize_or("max-tokens", 8)?.max(1);
    let json_mode = args.bool_or("json", false)?;
    let seed = args.u64_or("seed", 0xA0D17)?;

    let base = load_weights(&base_path).with_context(|| format!("loading {base_path:?}"))?;
    let store = DeltaStore::open(&root)?;
    let set = store
        .load(tenant)
        .with_context(|| format!("loading tenant '{tenant}' from {}", root.display()))?;
    let serve = ServeConfig {
        backend: args.str_or("backend", "native"),
        fused_threads: args.usize_or("fused-threads", 1)?,
        ..ServeConfig::default()
    };
    let backend = deltadq::runtime::backend_from_name(&serve.backend, &serve)?;

    let task = TaskKind::parse(tenant).unwrap_or(TaskKind::Math);
    let samples = gen_dataset(task, n_prompts, seed);
    let mut reports = Vec::new();
    for s in &samples {
        let served = backend.generate(&base, Some(&set), &s.prompt, max_tokens, None)?;
        if served.is_empty() {
            continue;
        }
        let report = shadow_compare(backend.as_ref(), &base, &set, &set, &s.prompt, &served)?;
        reports.push((s.prompt.len(), report));
    }
    let fallback_pool = ThreadPool::serial();
    let pool = backend.exec_pool().unwrap_or(&fallback_pool);
    let layers = layer_stats(&base, &set, pool);

    let n = reports.len().max(1) as f64;
    let mean_agreement: f64 = reports.iter().map(|(_, r)| r.agreement).sum::<f64>() / n;
    let worst_agreement =
        reports.iter().map(|(_, r)| r.agreement).fold(f64::INFINITY, f64::min);
    let max_maxabs = reports.iter().map(|(_, r)| r.logit_maxabs).fold(0.0, f64::max);
    let max_kl = reports.iter().map(|(_, r)| r.logit_kl).fold(0.0, f64::max);

    if json_mode {
        let mut o = Json::obj();
        o.set("tenant", tenant)
            .set("method", set.method.as_str())
            .set("prompts", reports.len() as u64)
            .set("mean_agreement", mean_agreement)
            .set("worst_agreement", if reports.is_empty() { 1.0 } else { worst_agreement })
            .set("max_logit_maxabs", max_maxabs)
            .set("max_logit_kl", max_kl);
        let mut shadows = Vec::new();
        for (prompt_len, r) in &reports {
            let mut s = Json::obj();
            s.set("prompt_len", *prompt_len as u64)
                .set("tokens", r.tokens as u64)
                .set("agreement", r.agreement)
                .set("logit_maxabs", r.logit_maxabs)
                .set("logit_kl", r.logit_kl);
            shadows.push(s);
        }
        o.set("shadows", Json::Arr(shadows));
        o.set("layers", Json::Arr(layers.iter().map(layer_stat_json).collect()));
        println!("{}", o.to_pretty_string());
        return Ok(());
    }

    let mut t = Table::new(
        &format!("shadow audit: '{tenant}' ({}, {} prompt(s))", set.method, reports.len()),
        &["prompt_len", "tokens", "agreement", "logit_maxabs", "logit_kl"],
    );
    for (prompt_len, r) in &reports {
        t.add_row(vec![
            prompt_len.to_string(),
            r.tokens.to_string(),
            format!("{:.4}", r.agreement),
            format!("{:.3e}", r.logit_maxabs),
            format!("{:.3e}", r.logit_kl),
        ]);
    }
    print!("{}", t.render());
    println!(
        "summary: mean agreement {:.4}, worst {:.4}, max |dlogit| {:.3e}, max KL {:.3e}",
        mean_agreement,
        if reports.is_empty() { 1.0 } else { worst_agreement },
        max_maxabs,
        max_kl
    );

    let mut lt = Table::new(
        &format!("per-layer quality: '{tenant}'"),
        &["layer", "shape", "density", "bits/param", "recon_err", "bir_var", "bir_min", "bir_max"],
    );
    for l in &layers {
        lt.add_row(vec![
            l.name.clone(),
            format!("{}x{}", l.rows, l.cols),
            format!("{:.4}", l.density),
            format!("{:.2}", l.bits_per_param),
            format!("{:.3e}", l.recon_error),
            format!("{:.3e}", l.bir.variance),
            format!("{:.3e}", l.bir.min),
            format!("{:.3e}", l.bir.max),
        ]);
    }
    print!("{}", lt.render());
    Ok(())
}

// --------------------------------------------------------------- usage

/// Live usage snapshot from a running gateway: fetches
/// `GET /debug/usage[/<tenant>]` and renders per-tenant resource totals
/// plus the saturation axes behind the server's `Retry-After` hints
/// (`--json true` prints the raw endpoint JSON).
fn cmd_usage(args: &Args) -> Result<()> {
    use deltadq::util::json::Json;

    let addr = args.get("addr").context("--addr HOST:PORT required")?;
    let tenant = args.get("tenant");
    let timeout = std::time::Duration::from_secs(args.u64_or("timeout-secs", 10)?);
    let snap = deltadq::gateway::loadgen::fetch_usage(addr, tenant, timeout)?;
    if args.bool_or("json", false)? {
        println!("{}", snap.to_pretty_string());
        return Ok(());
    }
    let num = |j: &Json, key: &str| j.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    if let Some(sat) = snap.get("saturation") {
        println!(
            "saturation: kv {:.2}, queue {:.2}, duty {:.2}, backlog {:.2} -> combined {:.2} \
             (Retry-After hint {}s)",
            num(sat, "kv"),
            num(sat, "queue"),
            num(sat, "duty"),
            num(sat, "backlog"),
            num(sat, "combined"),
            sat.get("retry_after_s").and_then(Json::as_u64).unwrap_or(1),
        );
    }
    let mut t = Table::new(
        &format!("usage at {addr}"),
        &[
            "tenant",
            "compute_s",
            "kv_block_s",
            "queue_wait_s",
            "reqs",
            "tok_out",
            "429",
            "503",
            "tok/s_10s",
        ],
    );
    let mut add_row = |name: &str, detail: &Json| {
        let empty = Json::obj();
        let totals = detail.get("totals").unwrap_or(&empty);
        let tokens_10s = detail
            .get("rates")
            .and_then(|r| r.get("10s"))
            .map(|w| num(w, "tokens_per_s"))
            .unwrap_or(0.0);
        t.add_row(vec![
            name.to_string(),
            format!("{:.3}", num(totals, "compute_s")),
            format!("{:.3}", num(totals, "kv_block_s")),
            format!("{:.3}", num(totals, "queue_wait_s")),
            format!("{:.0}", num(totals, "requests")),
            format!("{:.0}", num(totals, "tokens_out")),
            format!("{:.0}", num(totals, "rejected_429")),
            format!("{:.0}", num(totals, "rejected_503")),
            format!("{:.1}", tokens_10s),
        ]);
    };
    match tenant {
        // the per-tenant endpoint flattens totals/rates into the root
        Some(name) => add_row(name, &snap),
        None => {
            if let Some(by_tenant) = snap.get("tenants").and_then(Json::as_object) {
                for (name, detail) in by_tenant {
                    add_row(name, detail);
                }
            }
        }
    }
    print!("{}", t.render());
    if tenant.is_none() {
        println!("attributed exec wall: {:.3}s", num(&snap, "exec_wall_s"));
    }
    Ok(())
}

// --------------------------------------------------------------- bench

fn cmd_bench(args: &Args) -> Result<()> {
    let name = args.get("name").context("--name required")?;
    let models_dir = PathBuf::from(args.str_or("models", "artifacts/models"));
    let data_dir = PathBuf::from(args.str_or("data", "artifacts/data"));
    let out = args.get("out").map(PathBuf::from);
    let serve = ServeConfig {
        backend: args.str_or("backend", "native"),
        fused_threads: args.usize_or("fused-threads", 1)?,
        // pjrt prefill artifacts live at the artifacts root, not under
        // --models (which points at the .dqw directory)
        artifacts_dir: args.str_or("artifacts", "artifacts"),
        ..ServeConfig::default()
    };
    let backend = deltadq::runtime::backend_from_name(&serve.backend, &serve)?;
    let report = bench_harness::run(name, &models_dir, &data_dir, &backend)?;
    match out {
        Some(path) => {
            std::fs::write(&path, &report)?;
            println!("wrote {path:?}");
        }
        None => println!("{report}"),
    }
    Ok(())
}
