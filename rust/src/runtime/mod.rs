//! Execution backends (S11): how a `(base, Δ)` pair turns tokens into
//! logits on the serving path.
//!
//! * [`NativeBackend`] — pure-Rust forward pass. Hot tenants run one
//!   dense matmul per linear layer; Cold tenants run the **fused sparse
//!   path** ([`fused`]): every linear layer evaluates `X·(W_b + ΔŴ)ᵀ`
//!   directly from the compressed CSR / decomposed representation with
//!   per-part on-the-fly dequantization (`s·(code + step·j − z)`,
//!   Eq. 12) — the dense `Δ` is never materialized.
//! * [`pjrt::PjrtBackend`] (`--features pjrt`) — executes the
//!   AOT-lowered HLO artifacts on a PJRT client (xla-rs). The default
//!   build carries no XLA dependency at all; the feature pulls in the
//!   in-tree `xla-stub` unless a real xla-rs build is substituted.
//!
//! The coordinator ([`crate::coordinator`]), the launcher's `serve
//! --backend` flag, and the bench harness all accept any
//! [`ExecutionBackend`].

pub mod fused;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod pool;

pub use fused::{fused_matmul_nt, fused_matmul_nt_sampled, matmul_nt_pooled, BirSink};
pub use native::{FusedDeltaView, NativeBackend};
pub use pool::{SharedSliceMut, ThreadPool};
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtBackend, PjrtRuntime};

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::ServeConfig;
use crate::delta::format::DeltaSet;
use crate::model::weights::ModelWeights;
use crate::sched::PagedKvCache;
use crate::tensor::Matrix;

/// One sequence's slot in a batched decode step ([`ExecutionBackend::decode_steps`]):
/// the token to feed, its absolute position, and the sequence's paged
/// KV cache. Lanes in one call share a tenant (one `(base, Δ)` pair)
/// but nothing else — each lane appends to and attends over its own
/// cache.
pub struct DecodeLane<'a> {
    /// Token fed at this lane's position.
    pub token: u32,
    /// Absolute position of `token` (the cache holds `0..pos`).
    pub pos: usize,
    /// The sequence's KV cache.
    pub cache: &'a mut PagedKvCache,
}

/// A pluggable execution engine for prefill and greedy decoding.
///
/// `delta = None` is the dense path (the base model, or a merged Hot
/// tenant's weights); `delta = Some(set)` is the separate-computation
/// Cold path over one tenant's compressed deltas.
pub trait ExecutionBackend: Send + Sync {
    /// Short display name ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Full-sequence prefill: logits for every position (`t × vocab`).
    fn prefill(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        tokens: &[u32],
    ) -> Result<Matrix>;

    /// Greedy decode of up to `max_new` tokens after `prompt`, stopping
    /// at `eos` if given. Returns only the generated tokens.
    fn generate(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        prompt: &[u32],
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>>;

    /// Streaming decode: `on_token` fires for every generated token in
    /// order, as soon as it is available. The returned vector must be
    /// exactly the sequence of `on_token` calls — the coordinator's
    /// token-streaming path relies on that equivalence.
    ///
    /// The default emits all tokens only once the full `generate` call
    /// finishes (correct, but with no intra-request latency benefit);
    /// backends that own a decode loop should override it to emit
    /// per-step.
    fn generate_stream(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        prompt: &[u32],
        max_new: usize,
        eos: Option<u32>,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<Vec<u32>> {
        let tokens = self.generate(base, delta, prompt, max_new, eos)?;
        for &t in &tokens {
            on_token(t);
        }
        Ok(tokens)
    }

    /// Whether this backend implements the iteration-level stepping API
    /// ([`prefill_step`](ExecutionBackend::prefill_step) /
    /// [`decode_step`](ExecutionBackend::decode_step)) that the
    /// continuous-batching scheduler drives. Backends that don't (pjrt
    /// runs fixed-shape AOT artifacts) are served by the legacy
    /// run-to-completion worker loop instead — the defaults below
    /// preserve exactly that contract.
    fn supports_stepping(&self) -> bool {
        false
    }

    /// Prime `cache` with `tokens` — the prompt, or after a preemption
    /// the prompt plus everything already generated — and return the
    /// last position's logits (`1 × vocab`).
    fn prefill_step(
        &self,
        _base: &ModelWeights,
        _delta: Option<&DeltaSet>,
        _tokens: &[u32],
        _cache: &mut PagedKvCache,
    ) -> Result<Matrix> {
        bail!("backend '{}' does not implement iteration-level stepping", self.name())
    }

    /// One decode step: feed `token` at absolute position `pos` (the
    /// cache holds positions `0..pos`) and return its logits
    /// (`1 × vocab`).
    fn decode_step(
        &self,
        _base: &ModelWeights,
        _delta: Option<&DeltaSet>,
        _token: u32,
        _pos: usize,
        _cache: &mut PagedKvCache,
    ) -> Result<Matrix> {
        bail!("backend '{}' does not implement iteration-level stepping", self.name())
    }

    /// One decode step for a whole tenant group: lane `i` of the result
    /// (`lanes.len() × vocab`) holds the logits [`decode_step`](ExecutionBackend::decode_step)
    /// would return for lane `i` alone — **bit-identical**, which is
    /// the contract the batched scheduler drive loop pins its oracle
    /// tests on.
    ///
    /// The default decodes lane-by-lane and stacks the rows (correct
    /// for every stepping backend, no speedup). Backends whose kernels
    /// are invariant to the activation row count should override it to
    /// issue one fused `t=k` matmul per layer — that is the whole
    /// batching win.
    fn decode_steps(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        lanes: &mut [DecodeLane<'_>],
    ) -> Result<Matrix> {
        let vocab = base.config.vocab_size;
        let mut out = Matrix::zeros(lanes.len(), vocab);
        for (i, lane) in lanes.iter_mut().enumerate() {
            let logits = self.decode_step(base, delta, lane.token, lane.pos, lane.cache)?;
            out.row_mut(i).copy_from_slice(logits.row(0));
        }
        Ok(out)
    }

    /// Cache one bounded chunk of a sequence's prefix: `tokens` are the
    /// positions starting at the cache's current length. Returns the
    /// chunk's last-position logits (`1 × vocab`) — only meaningful
    /// once the final chunk lands, matching what a single
    /// [`prefill_step`](ExecutionBackend::prefill_step) over the whole
    /// prefix returns.
    ///
    /// The default delegates to `prefill_step`, which already resumes
    /// at the cache's fill point; chunking a prefix across several
    /// calls must not change any cached bit.
    fn prefill_chunk(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        tokens: &[u32],
        cache: &mut PagedKvCache,
    ) -> Result<Matrix> {
        self.prefill_step(base, delta, tokens, cache)
    }

    /// The worker pool the scheduler may fan independent tenant groups
    /// over (`None` = execute groups sequentially on the drive thread).
    /// Nested use is safe for [`ThreadPool`]: a group task's own pooled
    /// matmuls run on the same pool without deadlock.
    fn exec_pool(&self) -> Option<&ThreadPool> {
        None
    }
}

/// Resolve a backend by name ("native" | "pjrt") against serve settings.
///
/// The native backend's persistent worker pool is constructed here,
/// once — every tenant, layer, and request served through the returned
/// backend shares it (`serve.fused_threads`; `0` = auto-detect).
///
/// "pjrt" fails fast with a clear message when the crate was built
/// without the `pjrt` feature.
pub fn backend_from_name(name: &str, serve: &ServeConfig) -> Result<Arc<dyn ExecutionBackend>> {
    match name {
        "native" => Ok(Arc::new(NativeBackend::new(serve.fused_threads))),
        "pjrt" => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Arc::new(pjrt::PjrtBackend::new(
                    std::path::Path::new(&serve.artifacts_dir),
                    &serve.model,
                    serve.pjrt_seq_len,
                )?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                bail!("backend 'pjrt' requires a build with `--features pjrt`")
            }
        }
        other => bail!("unknown backend '{other}' (expected 'native' or 'pjrt')"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_resolves_native() {
        let serve = ServeConfig::default();
        let b = backend_from_name("native", &serve).unwrap();
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn factory_rejects_unknown() {
        let serve = ServeConfig::default();
        let err = backend_from_name("tpu", &serve).unwrap_err();
        assert!(err.to_string().contains("unknown backend"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_is_a_clear_error() {
        let serve = ServeConfig::default();
        let err = backend_from_name("pjrt", &serve).unwrap_err();
        assert!(err.to_string().contains("--features pjrt"), "{err}");
    }
}
