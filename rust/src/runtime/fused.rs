//! The fused sparse serving kernel: `A = X·(W_b + ΔŴ)ᵀ` evaluated
//! directly from the compressed delta representation.
//!
//! The Cold serving path used to compute the base term and the delta
//! term as two separate matmuls plus an elementwise add. This kernel
//! fuses them: each output stripe accumulates the dense base product
//! (via the register-tiled panel kernel in [`crate::tensor::ops`]) and
//! the sparse delta contribution in one pass. Decomposed deltas (§3.4
//! Separate Quantization) are dequantized **per part, on the fly** —
//! `DQ = s·(code + step·j − z)` (Eq. 12), decoded once per weight row
//! into a per-worker scratch buffer, never materialized densely.
//!
//! Work is partitioned across weight rows `q` (output columns) and run
//! on the backend's persistent [`ThreadPool`]; each chunk writes its
//! disjoint column stripe of the preallocated output directly (no
//! per-worker block + `set_cols` assembly, no thread spawns).
//!
//! Delta accumulation streams `Xᵀ` (transposed once per call): delta
//! row `q`'s entries each touch one *contiguous* length-`t` column of
//! `X`, so the inner loop is a `t`-wide FMA instead of `t` scattered
//! gathers — the activation matrix is streamed once per row-block
//! rather than gathered per activation row.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::compress::CompressedDelta;
use crate::quant::separate::DecomposedDelta;
use crate::runtime::pool::{SharedSliceMut, ThreadPool};
use crate::sparse::CsrMatrix;
use crate::tensor::stats::{Accumulator, SampleStats};
use crate::tensor::{ops, Matrix};

/// Collector for sampled `X·ΔŴᵀ` intermediate columns (the paper's
/// Balanced-Intermediate-Results signal, Fig. 4) captured *inside* the
/// fused kernel as it runs.
///
/// The hot serving path never sees this type: [`fused_matmul_nt`]
/// threads `None` through the kernel internals, so the disabled cost is
/// a single branch per weight row (mirroring `util/trace.rs`'s
/// discipline). Audit probes call [`fused_matmul_nt_sampled`] instead.
///
/// Sampling is deterministic: weight row `q` is accepted iff
/// `q % every == 0` and fewer than `max_rows` such rows exist below it,
/// so the sampled set is a pure function of the shape — independent of
/// thread count and chunking. Decomposed deltas contribute per part;
/// the sink accumulates parts into one column per row (each row is
/// owned by exactly one chunk, so part order is sequential per worker
/// and the accumulation is bit-deterministic).
pub struct BirSink {
    every: usize,
    max_rows: usize,
    /// Sampled delta-contribution columns keyed by weight row `q`;
    /// each value has one entry per activation row `p`.
    rows: Mutex<BTreeMap<usize, Vec<f32>>>,
}

impl BirSink {
    /// Sink accepting every `every`-th weight row, up to `max_rows` rows.
    pub fn new(every: usize, max_rows: usize) -> BirSink {
        BirSink { every: every.max(1), max_rows, rows: Mutex::new(BTreeMap::new()) }
    }

    fn accepts(&self, q: usize) -> bool {
        q % self.every == 0 && q / self.every < self.max_rows
    }

    /// Register a zero column of width `t` for row `q` (delta rows with
    /// no stored entries still contribute a sample — of zeros).
    fn seed(&self, q: usize, t: usize) {
        if !self.accepts(q) {
            return;
        }
        self.rows.lock().unwrap().entry(q).or_insert_with(|| vec![0.0; t]);
    }

    /// Fold one computed delta-contribution column into row `q`
    /// (accumulates across decomposed parts).
    fn record(&self, q: usize, acc: &[f32]) {
        if !self.accepts(q) {
            return;
        }
        let mut rows = self.rows.lock().unwrap();
        let row = rows.entry(q).or_insert_with(|| vec![0.0; acc.len()]);
        for (r, &a) in row.iter_mut().zip(acc) {
            *r += a;
        }
    }

    /// Number of weight rows actually sampled.
    pub fn sampled_rows(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    /// The flattened sample stream in `(q asc, p asc)` order — the
    /// exact stream [`finalize`](BirSink::finalize) folds, exposed so
    /// tests can run the batch oracle over it.
    pub fn samples(&self) -> Vec<f32> {
        let rows = self.rows.lock().unwrap();
        let mut out = Vec::new();
        for row in rows.values() {
            out.extend_from_slice(row);
        }
        out
    }

    /// Streamed statistics over the sampled intermediates, folded
    /// online via [`Accumulator`] in `(q, p)` order — bitwise equal to
    /// [`SampleStats::from_slice`] over [`samples`](BirSink::samples)
    /// (identical Welford recurrence over the identical stream).
    pub fn finalize(&self) -> SampleStats {
        let rows = self.rows.lock().unwrap();
        let mut acc = Accumulator::new();
        for row in rows.values() {
            for &v in row {
                acc.add(v as f64);
            }
        }
        SampleStats {
            mean: acc.mean(),
            variance: acc.variance(),
            min: acc.min(),
            max: acc.max(),
        }
    }
}

thread_local! {
    /// Per-worker scratch: (decoded values, t-length column accumulator).
    /// Hoisted out of the per-weight-row loop — one allocation per pool
    /// worker for the life of the process, not one `Vec` per row.
    static SCRATCH: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Chunk the `[0, h_out)` weight-row range for the pool: ~4 chunks per
/// thread for load balance, panel-aligned, never below one panel.
fn stripe_width(h_out: usize, threads: usize) -> usize {
    if threads <= 1 {
        return h_out.max(1);
    }
    let target = h_out.div_ceil(threads * 4).max(ops::TILE_NR);
    // round up to a panel multiple so stripes don't split panels
    target.div_ceil(ops::TILE_NR) * ops::TILE_NR
}

/// Shared stripe driver: chunk `[0, h_out)` into panel-aligned column
/// stripes and run `f(q0, q1, shared)` over the pool, each chunk owning
/// its disjoint stripe of `out`. Every pooled kernel goes through this,
/// so the chunking/safety contract lives in one place.
fn run_striped(
    pool: &ThreadPool,
    h_out: usize,
    out: &mut Matrix,
    f: impl Fn(usize, usize, &SharedSliceMut<'_, f32>) + Sync,
) {
    let chunk = stripe_width(h_out, pool.threads());
    let n_chunks = h_out.div_ceil(chunk);
    let shared = SharedSliceMut::new(out.data_mut());
    pool.run(n_chunks, &|i| {
        let q0 = i * chunk;
        let q1 = (q0 + chunk).min(h_out);
        f(q0, q1, &shared);
    });
}

/// Fused `X·(W + Δ)ᵀ` (`X: t×h_in`, `W, Δ: h_out×h_in` → `t×h_out`)
/// without densifying `Δ`, parallelized over the persistent `pool`.
///
/// Results are bit-identical for any pool size: each output element is
/// an order-fixed sum computed entirely within one chunk, and chunk
/// boundaries never change summation order.
pub fn fused_matmul_nt(
    x: &Matrix,
    w: &Matrix,
    delta: &CompressedDelta,
    pool: &ThreadPool,
) -> Matrix {
    fused_matmul_nt_impl(x, w, delta, pool, None)
}

/// [`fused_matmul_nt`] with BIR sampling: identical output bits, plus
/// every accepted weight row's delta-contribution column is folded into
/// `sink`. Used by the audit subsystem's hydration probe — never by the
/// serving hot path.
pub fn fused_matmul_nt_sampled(
    x: &Matrix,
    w: &Matrix,
    delta: &CompressedDelta,
    pool: &ThreadPool,
    sink: &BirSink,
) -> Matrix {
    fused_matmul_nt_impl(x, w, delta, pool, Some(sink))
}

fn fused_matmul_nt_impl(
    x: &Matrix,
    w: &Matrix,
    delta: &CompressedDelta,
    pool: &ThreadPool,
    sink: Option<&BirSink>,
) -> Matrix {
    let (h_out, h_in) = w.shape();
    assert_eq!(x.cols(), h_in, "fused inner dims: x is {}x{}", x.rows(), x.cols());
    assert_eq!(delta.shape(), (h_out, h_in), "delta shape vs w {h_out}x{h_in}");
    let t = x.rows();
    let mut out = Matrix::zeros(t, h_out);
    if t == 0 || h_out == 0 {
        return out;
    }
    // Xᵀ is streamed by the sparse delta paths (t-contiguous columns);
    // the Dense arm never reads it, so skip the copy there.
    let xt = match delta {
        CompressedDelta::Dense(_) => None,
        _ => Some(x.transpose()),
    };
    run_striped(pool, h_out, &mut out, |q0, q1, shared| {
        // SAFETY: this chunk exclusively owns columns [q0, q1) of every
        // output row; chunks are pairwise disjoint.
        unsafe { ops::matmul_nt_block_raw(x, w, q0, q1, shared.as_ptr(), h_out, false) };
        // Seed accepted rows with zero columns so delta rows without
        // stored entries still contribute their (zero) samples.
        if let Some(s) = sink {
            for q in q0..q1 {
                s.seed(q, t);
            }
        }
        match (delta, &xt) {
            (CompressedDelta::Sparse(csr), Some(xt)) => {
                add_csr_rows(xt, csr, q0, q1, shared, h_out, sink)
            }
            (CompressedDelta::Quantized(d), Some(xt)) => {
                add_decomposed_rows(xt, d, q0, q1, shared, h_out, sink)
            }
            // Dense deltas reuse the blocked kernel in accumulate mode —
            // no scalar dot loop, no temporary. Sampling runs a separate
            // scalar pass (the blocked kernel has no per-row column).
            (CompressedDelta::Dense(m), _) => {
                unsafe { ops::matmul_nt_block_raw(x, m, q0, q1, shared.as_ptr(), h_out, true) };
                if let Some(s) = sink {
                    record_dense_rows(x, m, q0, q1, s);
                }
            }
            // xt is Some for every non-Dense delta by construction.
            _ => unreachable!("xt missing for sparse delta"),
        }
    });
    out
}

/// Dense `X·Wᵀ` over the persistent pool (the Hot / no-delta serving
/// path). Same stripe decomposition and kernels as the fused path, so
/// it is likewise bit-identical across pool sizes.
pub fn matmul_nt_pooled(x: &Matrix, w: &Matrix, pool: &ThreadPool) -> Matrix {
    assert_eq!(x.cols(), w.cols(), "inner dims");
    let t = x.rows();
    let h_out = w.rows();
    let mut out = Matrix::zeros(t, h_out);
    if t == 0 || h_out == 0 {
        return out;
    }
    run_striped(pool, h_out, &mut out, |q0, q1, shared| {
        // SAFETY: disjoint column stripes per chunk.
        unsafe { ops::matmul_nt_block_raw(x, w, q0, q1, shared.as_ptr(), h_out, false) };
    });
    out
}

/// Accumulate the CSR delta contribution for weight rows `[q0, q1)`
/// into the output stripe. `xt` is `Xᵀ` (`h_in × t`): entry `(q, c)`
/// contributes `v · xt[c][·]` to output column `q`, a contiguous
/// `t`-wide FMA per stored non-zero.
fn add_csr_rows(
    xt: &Matrix,
    csr: &CsrMatrix,
    q0: usize,
    q1: usize,
    out: &SharedSliceMut<'_, f32>,
    stride: usize,
    sink: Option<&BirSink>,
) {
    let t = xt.cols();
    SCRATCH.with(|s| {
        let (_, acc) = &mut *s.borrow_mut();
        acc.resize(t, 0.0);
        for q in q0..q1 {
            let (cols, vals) = csr.row_entries(q);
            if cols.is_empty() {
                continue;
            }
            acc.fill(0.0);
            for (&c, &v) in cols.iter().zip(vals) {
                let xcol = xt.row(c as usize);
                for (a, &xv) in acc.iter_mut().zip(xcol) {
                    *a += xv * v;
                }
            }
            if let Some(s) = sink {
                s.record(q, acc);
            }
            for (p, &a) in acc.iter().enumerate() {
                // SAFETY: column q lies in this chunk's stripe.
                unsafe { out.slice_mut(p * stride + q, 1)[0] += a };
            }
        }
    });
}

/// BIR sampling pass for the Dense delta arm: the blocked kernel never
/// materializes a per-row delta column, so accepted rows get a scalar
/// `t`-wide dot computed here (sequential over `h_in`, deterministic).
fn record_dense_rows(x: &Matrix, m: &Matrix, q0: usize, q1: usize, sink: &BirSink) {
    let t = x.rows();
    let mut acc = vec![0.0f32; t];
    for q in q0..q1 {
        if !sink.accepts(q) {
            continue;
        }
        let wr = m.row(q);
        for (p, a) in acc.iter_mut().enumerate() {
            let mut sum = 0.0f32;
            for (&xv, &wv) in x.row(p).iter().zip(wr) {
                sum += xv * wv;
            }
            *a = sum;
        }
        sink.record(q, &acc);
    }
}

/// Accumulate the decomposed-delta contribution for weight rows
/// `[q0, q1)`, dequantizing each part's entries on the fly. Codes are
/// decoded once per weight row into the worker's scratch buffer, then
/// applied with the same `t`-wide `Xᵀ` streaming as the CSR path.
fn add_decomposed_rows(
    xt: &Matrix,
    d: &DecomposedDelta,
    q0: usize,
    q1: usize,
    out: &SharedSliceMut<'_, f32>,
    stride: usize,
    sink: Option<&BirSink>,
) {
    let t = xt.cols();
    SCRATCH.with(|s| {
        let (vals, acc) = &mut *s.borrow_mut();
        acc.resize(t, 0.0);
        for part in &d.parts {
            for q in q0..q1 {
                let lo = part.row_offsets[q] as usize;
                let hi = part.row_offsets[q + 1] as usize;
                if lo == hi {
                    continue;
                }
                // decode once per weight row via the shared Eq. 12 formula
                vals.clear();
                vals.extend((lo..hi).map(|e| d.dequant_entry(part, e)));
                let cols = &part.col_indices[lo..hi];
                acc.fill(0.0);
                for (&c, v) in cols.iter().zip(vals.iter()) {
                    let xcol = xt.row(c as usize);
                    for (a, &xv) in acc.iter_mut().zip(xcol) {
                        *a += xv * v;
                    }
                }
                // per-part fold: the sink sums parts into one column
                // (this chunk owns q for every part, so order is fixed)
                if let Some(s) = sink {
                    s.record(q, acc);
                }
                for (p, &a) in acc.iter().enumerate() {
                    // SAFETY: column q lies in this chunk's stripe.
                    unsafe { out.slice_mut(p * stride + q, 1)[0] += a };
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn sparse_random(rows: usize, cols: usize, density: f64, rng: &mut Pcg64) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| {
            if rng.bernoulli(density) {
                rng.normal() * 0.02
            } else {
                0.0
            }
        })
    }

    #[test]
    fn fused_csr_matches_densified() {
        let mut rng = Pcg64::seeded(1);
        let w = Matrix::randn(17, 24, 0.02, &mut rng);
        let dm = sparse_random(17, 24, 0.2, &mut rng);
        let x = Matrix::randn(5, 24, 1.0, &mut rng);
        let delta = CompressedDelta::Sparse(CsrMatrix::from_dense(&dm));
        let want = x.matmul_nt(&w.add(&dm));
        for threads in [1usize, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let got = fused_matmul_nt(&x, &w, &delta, &pool);
            assert!(got.allclose(&want, 1e-5, 1e-5), "threads={threads}");
        }
    }

    #[test]
    fn fused_decomposed_matches_densified() {
        let mut rng = Pcg64::seeded(2);
        let w = Matrix::randn(19, 32, 0.02, &mut rng);
        let dm = sparse_random(19, 32, 0.25, &mut rng);
        let x = Matrix::randn(4, 32, 1.0, &mut rng);
        let csr = CsrMatrix::from_dense(&dm);
        for (k, m) in [(8u32, 1u32), (8, 4), (4, 8), (2, 4)] {
            let dec = DecomposedDelta::compress(&csr, k, m);
            let want = x.matmul_nt(&w.add(&dec.to_dense()));
            for threads in [1usize, 3] {
                let pool = ThreadPool::new(threads);
                let got =
                    fused_matmul_nt(&x, &w, &CompressedDelta::Quantized(dec.clone()), &pool);
                assert!(got.allclose(&want, 1e-5, 1e-5), "k={k} m={m} threads={threads}");
            }
        }
    }

    #[test]
    fn fused_dense_variant_matches() {
        let mut rng = Pcg64::seeded(3);
        let w = Matrix::randn(9, 16, 0.02, &mut rng);
        let dm = Matrix::randn(9, 16, 0.01, &mut rng);
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let pool = ThreadPool::new(2);
        let got = fused_matmul_nt(&x, &w, &CompressedDelta::Dense(dm.clone()), &pool);
        let want = x.matmul_nt(&w.add(&dm));
        assert!(got.allclose(&want, 1e-5, 1e-5));
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        // each output element is an order-fixed sum computed within one
        // chunk, so results are identical (not just close) across pool
        // sizes — including sizes that don't divide the row count
        let mut rng = Pcg64::seeded(4);
        let w = Matrix::randn(33, 40, 0.02, &mut rng);
        let dm = sparse_random(33, 40, 0.15, &mut rng);
        let x = Matrix::randn(7, 40, 1.0, &mut rng);
        let dec = DecomposedDelta::compress(&CsrMatrix::from_dense(&dm), 4, 4);
        let delta = CompressedDelta::Quantized(dec);
        let one = fused_matmul_nt(&x, &w, &delta, &ThreadPool::new(1));
        for threads in [2usize, 3, 5, 16] {
            let pool = ThreadPool::new(threads);
            assert_eq!(fused_matmul_nt(&x, &w, &delta, &pool), one, "threads={threads}");
        }
    }

    #[test]
    fn pooled_dense_matmul_is_bit_stable_and_correct() {
        let mut rng = Pcg64::seeded(6);
        for (t, h_in, h_out) in [(1usize, 48usize, 31usize), (8, 64, 29), (13, 37, 53)] {
            let x = Matrix::randn(t, h_in, 1.0, &mut rng);
            let w = Matrix::randn(h_out, h_in, 0.1, &mut rng);
            let serial = matmul_nt_pooled(&x, &w, &ThreadPool::new(1));
            assert!(serial.allclose(&x.matmul_nt_naive(&w), 1e-4, 1e-4));
            for threads in [2usize, 3, 7] {
                let pool = ThreadPool::new(threads);
                assert_eq!(matmul_nt_pooled(&x, &w, &pool), serial, "t={t} threads={threads}");
            }
        }
    }

    #[test]
    fn single_row_activation_decode_shape() {
        let mut rng = Pcg64::seeded(5);
        let w = Matrix::randn(12, 8, 0.02, &mut rng);
        let dm = sparse_random(12, 8, 0.4, &mut rng);
        let x = Matrix::randn(1, 8, 1.0, &mut rng);
        let delta = CompressedDelta::Sparse(CsrMatrix::from_dense(&dm));
        let pool = ThreadPool::new(4);
        let got = fused_matmul_nt(&x, &w, &delta, &pool);
        assert_eq!(got.shape(), (1, 12));
        assert!(got.allclose(&x.matmul_nt(&w.add(&dm)), 1e-5, 1e-5));
    }

    #[test]
    fn bir_sampling_does_not_change_output_bits() {
        // the sampled entry point must be a pure observer: same output
        // bits as the unsampled kernel for every delta representation
        let mut rng = Pcg64::seeded(11);
        let w = Matrix::randn(21, 24, 0.02, &mut rng);
        let dm = sparse_random(21, 24, 0.2, &mut rng);
        let x = Matrix::randn(6, 24, 1.0, &mut rng);
        let dec = DecomposedDelta::compress(&CsrMatrix::from_dense(&dm), 4, 4);
        let deltas = [
            CompressedDelta::Sparse(CsrMatrix::from_dense(&dm)),
            CompressedDelta::Quantized(dec),
            CompressedDelta::Dense(dm.clone()),
        ];
        let pool = ThreadPool::new(3);
        for delta in &deltas {
            let plain = fused_matmul_nt(&x, &w, delta, &pool);
            let sink = BirSink::new(1, 64);
            let sampled = fused_matmul_nt_sampled(&x, &w, delta, &pool, &sink);
            assert_eq!(plain, sampled);
            assert_eq!(sink.sampled_rows(), 21);
        }
    }

    #[test]
    fn bir_streamed_stats_bit_match_batch_oracle() {
        // the property the audit telemetry rests on: the online Welford
        // fold inside the kernel produces *bit-identical* statistics to
        // the batch oracle (`SampleStats::from_slice`) over the same
        // densified-intermediate samples, for every group config and
        // pool size — and the sample stream itself is thread-invariant
        let mut rng = Pcg64::seeded(12);
        let w = Matrix::randn(33, 40, 0.02, &mut rng);
        let dm = sparse_random(33, 40, 0.2, &mut rng);
        let x = Matrix::randn(7, 40, 1.0, &mut rng);
        let csr = CsrMatrix::from_dense(&dm);
        for (k, m) in [(8u32, 1u32), (8, 4), (4, 8), (2, 4)] {
            let dec = DecomposedDelta::compress(&csr, k, m);
            let delta = CompressedDelta::Quantized(dec);
            let mut reference: Option<Vec<u32>> = None;
            for threads in [1usize, 2, 3, 5, 16] {
                let pool = ThreadPool::new(threads);
                let sink = BirSink::new(2, 64);
                fused_matmul_nt_sampled(&x, &w, &delta, &pool, &sink);
                let samples = sink.samples();
                assert_eq!(samples.len(), 17 * 7, "k={k} m={m}"); // ceil(33/2) rows × t
                let bits: Vec<u32> = samples.iter().map(|v| v.to_bits()).collect();
                match &reference {
                    Some(r) => assert_eq!(&bits, r, "k={k} m={m} threads={threads}"),
                    None => reference = Some(bits),
                }
                let online = sink.finalize();
                let batch = SampleStats::from_slice(&samples);
                assert_eq!(online.mean.to_bits(), batch.mean.to_bits(), "mean k={k} m={m}");
                assert_eq!(
                    online.variance.to_bits(),
                    batch.variance.to_bits(),
                    "variance k={k} m={m}"
                );
                assert_eq!(online.min.to_bits(), batch.min.to_bits(), "min k={k} m={m}");
                assert_eq!(online.max.to_bits(), batch.max.to_bits(), "max k={k} m={m}");
            }
        }
    }

    #[test]
    fn bir_samples_match_densified_intermediate() {
        // sampled columns equal X·Δᵀ's columns for accepted rows — the
        // densified-intermediate ground truth, including all-zero rows
        let mut rng = Pcg64::seeded(13);
        let w = Matrix::randn(10, 12, 0.02, &mut rng);
        let mut dm = Matrix::zeros(10, 12);
        dm.set(0, 3, 0.5);
        dm.set(4, 1, -0.25);
        dm.set(4, 7, 0.75);
        // rows 2, 6, 8 stay empty → sampled as zero columns
        let x = Matrix::randn(3, 12, 1.0, &mut rng);
        let delta = CompressedDelta::Sparse(CsrMatrix::from_dense(&dm));
        let pool = ThreadPool::new(4);
        let sink = BirSink::new(2, 64);
        fused_matmul_nt_sampled(&x, &w, &delta, &pool, &sink);
        assert_eq!(sink.sampled_rows(), 5); // q ∈ {0, 2, 4, 6, 8}
        let want = x.matmul_nt_naive(&dm); // 3×10
        let samples = sink.samples();
        for (i, &q) in [0usize, 2, 4, 6, 8].iter().enumerate() {
            for p in 0..3 {
                let got = samples[i * 3 + p];
                let exp = want.get(p, q);
                assert!((got - exp).abs() < 1e-5, "q={q} p={p}: {got} vs {exp}");
            }
        }
        // dense arm produces the same intermediates via its scalar pass
        let dsink = BirSink::new(2, 64);
        fused_matmul_nt_sampled(&x, &w, &CompressedDelta::Dense(dm.clone()), &pool, &dsink);
        let dsamples = dsink.samples();
        assert_eq!(dsamples.len(), samples.len());
        for (a, b) in dsamples.iter().zip(&samples) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_delta_rows_and_empty_activation() {
        // rows of Δ with no entries contribute nothing; t=0 short-circuits
        let mut rng = Pcg64::seeded(7);
        let w = Matrix::randn(6, 10, 0.02, &mut rng);
        let mut dm = Matrix::zeros(6, 10);
        dm.set(2, 3, 0.5); // single populated delta row
        let delta = CompressedDelta::Sparse(CsrMatrix::from_dense(&dm));
        let pool = ThreadPool::new(3);
        let x = Matrix::randn(4, 10, 1.0, &mut rng);
        let got = fused_matmul_nt(&x, &w, &delta, &pool);
        assert!(got.allclose(&x.matmul_nt(&w.add(&dm)), 1e-5, 1e-5));
        let empty = fused_matmul_nt(&Matrix::zeros(0, 10), &w, &delta, &pool);
        assert_eq!(empty.shape(), (0, 6));
    }
}
