//! The fused sparse serving kernel: `A = X·(W_b + ΔŴ)ᵀ` evaluated
//! directly from the compressed delta representation.
//!
//! The Cold serving path used to compute the base term and the delta
//! term as two separate matmuls plus an elementwise add. This kernel
//! fuses them: each output element `A[p][q]` accumulates the dense base
//! dot product and the sparse delta contribution of weight row `q` in
//! one pass. Decomposed deltas (§3.4 Separate Quantization) are
//! dequantized **per part, on the fly** — `DQ = s·(code + step·j − z)`
//! (Eq. 12), decoded once per weight row, never materialized densely.
//!
//! Work is partitioned across output rows `q` (weight rows) and run on
//! scoped threads — each thread owns a disjoint column block of the
//! output, so no synchronization is needed beyond the final assembly.

use crate::compress::CompressedDelta;
use crate::quant::separate::DecomposedDelta;
use crate::sparse::CsrMatrix;
use crate::tensor::matrix::dot;
use crate::tensor::Matrix;

/// Fused `X·(W + Δ)ᵀ` (`X: t×h_in`, `W, Δ: h_out×h_in` → `t×h_out`)
/// without densifying `Δ`. `threads ≤ 1` runs single-threaded;
/// otherwise output rows are split across `std::thread::scope` workers.
pub fn fused_matmul_nt(x: &Matrix, w: &Matrix, delta: &CompressedDelta, threads: usize) -> Matrix {
    let (h_out, h_in) = w.shape();
    assert_eq!(x.cols(), h_in, "fused inner dims: x is {}x{}", x.rows(), x.cols());
    assert_eq!(delta.shape(), (h_out, h_in), "delta shape vs w {h_out}x{h_in}");
    let t = x.rows();
    let threads = threads.clamp(1, h_out.max(1));
    if threads == 1 || h_out < 2 * threads {
        let mut out = Matrix::zeros(t, h_out);
        fused_block(x, w, delta, 0, h_out, &mut out);
        return out;
    }
    let chunk = h_out.div_ceil(threads);
    let mut blocks: Vec<(usize, Matrix)> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .filter_map(|b| {
                let q0 = b * chunk;
                if q0 >= h_out {
                    return None;
                }
                let q1 = (q0 + chunk).min(h_out);
                Some(scope.spawn(move || {
                    let mut block = Matrix::zeros(t, q1 - q0);
                    fused_block(x, w, delta, q0, q1, &mut block);
                    (q0, block)
                }))
            })
            .collect();
        for h in handles {
            blocks.push(h.join().expect("fused worker panicked"));
        }
    });
    let mut out = Matrix::zeros(t, h_out);
    for (q0, block) in blocks {
        out.set_cols(q0, &block);
    }
    out
}

/// Fill `block` (t × (q1−q0)) with `X·(W + Δ)ᵀ` restricted to weight
/// rows `[q0, q1)`.
fn fused_block(
    x: &Matrix,
    w: &Matrix,
    delta: &CompressedDelta,
    q0: usize,
    q1: usize,
    block: &mut Matrix,
) {
    let t = x.rows();
    for q in q0..q1 {
        let wrow = w.row(q);
        for p in 0..t {
            block.set(p, q - q0, dot(x.row(p), wrow));
        }
    }
    match delta {
        CompressedDelta::Sparse(csr) => add_csr_rows(x, csr, q0, q1, block),
        CompressedDelta::Quantized(d) => add_decomposed_rows(x, d, q0, q1, block),
        CompressedDelta::Dense(m) => {
            for q in q0..q1 {
                let drow = m.row(q);
                for p in 0..t {
                    let v = block.get(p, q - q0) + dot(x.row(p), drow);
                    block.set(p, q - q0, v);
                }
            }
        }
    }
}

/// Accumulate the CSR delta contribution for weight rows `[q0, q1)`.
fn add_csr_rows(x: &Matrix, csr: &CsrMatrix, q0: usize, q1: usize, block: &mut Matrix) {
    let t = x.rows();
    for q in q0..q1 {
        let (cols, vals) = csr.row_entries(q);
        if cols.is_empty() {
            continue;
        }
        for p in 0..t {
            let xrow = x.row(p);
            let mut acc = 0.0f32;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += xrow[c as usize] * v;
            }
            let cur = block.get(p, q - q0);
            block.set(p, q - q0, cur + acc);
        }
    }
}

/// Accumulate the decomposed-delta contribution for weight rows
/// `[q0, q1)`, dequantizing each part's entries on the fly (codes are
/// decoded once per weight row, then reused across all `t` activation
/// rows).
fn add_decomposed_rows(x: &Matrix, d: &DecomposedDelta, q0: usize, q1: usize, block: &mut Matrix) {
    let t = x.rows();
    let mut vals: Vec<f32> = Vec::new();
    for part in &d.parts {
        for q in q0..q1 {
            let lo = part.row_offsets[q] as usize;
            let hi = part.row_offsets[q + 1] as usize;
            if lo == hi {
                continue;
            }
            // decode once per weight row via the shared Eq. 12 formula
            vals.clear();
            vals.extend((lo..hi).map(|e| d.dequant_entry(part, e)));
            let cols = &part.col_indices[lo..hi];
            for p in 0..t {
                let xrow = x.row(p);
                let mut acc = 0.0f32;
                for (&c, &v) in cols.iter().zip(&vals) {
                    acc += xrow[c as usize] * v;
                }
                let cur = block.get(p, q - q0);
                block.set(p, q - q0, cur + acc);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn sparse_random(rows: usize, cols: usize, density: f64, rng: &mut Pcg64) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| {
            if rng.bernoulli(density) {
                rng.normal() * 0.02
            } else {
                0.0
            }
        })
    }

    #[test]
    fn fused_csr_matches_densified() {
        let mut rng = Pcg64::seeded(1);
        let w = Matrix::randn(17, 24, 0.02, &mut rng);
        let dm = sparse_random(17, 24, 0.2, &mut rng);
        let x = Matrix::randn(5, 24, 1.0, &mut rng);
        let delta = CompressedDelta::Sparse(CsrMatrix::from_dense(&dm));
        let want = x.matmul_nt(&w.add(&dm));
        for threads in [1usize, 2, 4, 8] {
            let got = fused_matmul_nt(&x, &w, &delta, threads);
            assert!(got.allclose(&want, 1e-5, 1e-5), "threads={threads}");
        }
    }

    #[test]
    fn fused_decomposed_matches_densified() {
        let mut rng = Pcg64::seeded(2);
        let w = Matrix::randn(19, 32, 0.02, &mut rng);
        let dm = sparse_random(19, 32, 0.25, &mut rng);
        let x = Matrix::randn(4, 32, 1.0, &mut rng);
        let csr = CsrMatrix::from_dense(&dm);
        for (k, m) in [(8u32, 1u32), (8, 4), (4, 8), (2, 4)] {
            let dec = DecomposedDelta::compress(&csr, k, m);
            let want = x.matmul_nt(&w.add(&dec.to_dense()));
            for threads in [1usize, 3] {
                let got = fused_matmul_nt(&x, &w, &CompressedDelta::Quantized(dec.clone()), threads);
                assert!(got.allclose(&want, 1e-5, 1e-5), "k={k} m={m} threads={threads}");
            }
        }
    }

    #[test]
    fn fused_dense_variant_matches() {
        let mut rng = Pcg64::seeded(3);
        let w = Matrix::randn(9, 16, 0.02, &mut rng);
        let dm = Matrix::randn(9, 16, 0.01, &mut rng);
        let x = Matrix::randn(3, 16, 1.0, &mut rng);
        let got = fused_matmul_nt(&x, &w, &CompressedDelta::Dense(dm.clone()), 2);
        let want = x.matmul_nt(&w.add(&dm));
        assert!(got.allclose(&want, 1e-5, 1e-5));
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        // each output element is computed independently, so results are
        // identical (not just close) across thread counts
        let mut rng = Pcg64::seeded(4);
        let w = Matrix::randn(33, 40, 0.02, &mut rng);
        let dm = sparse_random(33, 40, 0.15, &mut rng);
        let x = Matrix::randn(7, 40, 1.0, &mut rng);
        let dec = DecomposedDelta::compress(&CsrMatrix::from_dense(&dm), 4, 4);
        let delta = CompressedDelta::Quantized(dec);
        let one = fused_matmul_nt(&x, &w, &delta, 1);
        for threads in [2usize, 3, 5, 16] {
            assert_eq!(fused_matmul_nt(&x, &w, &delta, threads), one, "threads={threads}");
        }
    }

    #[test]
    fn single_row_activation_decode_shape() {
        let mut rng = Pcg64::seeded(5);
        let w = Matrix::randn(12, 8, 0.02, &mut rng);
        let dm = sparse_random(12, 8, 0.4, &mut rng);
        let x = Matrix::randn(1, 8, 1.0, &mut rng);
        let delta = CompressedDelta::Sparse(CsrMatrix::from_dense(&dm));
        let got = fused_matmul_nt(&x, &w, &delta, 4);
        assert_eq!(got.shape(), (1, 12));
        assert!(got.allclose(&x.matmul_nt(&w.add(&dm)), 1e-5, 1e-5));
    }
}
