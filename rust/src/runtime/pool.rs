//! Persistent worker pool for the serving compute core.
//!
//! PR 1's kernels spawned fresh OS threads (`std::thread::scope`) on
//! every `fused_matmul_nt` call — dozens of spawns per forward pass,
//! thousands per request. This pool is constructed **once** per
//! [`crate::runtime::NativeBackend`] (and therefore once per
//! [`crate::coordinator::Server`]) and reused by every tenant, layer,
//! and request.
//!
//! Work model: [`ThreadPool::run`] takes a *chunk count* and a closure
//! over the chunk index. Chunks are claimed from a shared atomic
//! counter (self-balancing: a slow chunk doesn't stall the others), the
//! caller participates in execution, and `run` returns only after every
//! chunk has finished — which is what makes lending the pool a
//! non-`'static` closure sound (see the safety comment on [`TaskPtr`]).
//!
//! Determinism: *what* a chunk computes depends only on its index, so
//! results are bit-identical for any pool size or claim order (pinned
//! by `tests/tiled_matmul.rs`).

use std::marker::PhantomData;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased pointer to the borrowed task closure.
///
/// Safety: the pointer is only dereferenced while claiming chunks of a
/// job whose `finished` count is below `total`; `ThreadPool::run` does
/// not return until `finished == total`, so the borrow it was created
/// from is still live for every dereference. Workers that wake late see
/// the chunk counter exhausted and never touch the pointer again.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

/// One data-parallel job: `total` chunks, claimed via `next`.
struct Job {
    task: TaskPtr,
    next: AtomicUsize,
    finished: AtomicUsize,
    total: usize,
    panicked: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
}

impl Job {
    /// Claim and execute chunks until the counter is exhausted.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.total {
                return;
            }
            // SAFETY: `finished < total` here, so `run` is still blocked
            // and the closure it lent us is alive (see TaskPtr docs).
            let f = unsafe { &*self.task.0 };
            if std::panic::catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
                self.panicked.store(true, Ordering::Release);
            }
            if self.finished.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
                let mut flag = self.done.lock().unwrap();
                *flag = true;
                self.done_cv.notify_all();
            }
        }
    }
}

/// The job queue workers watch. Multiple jobs can be in flight at once
/// (server workers call `run` concurrently); workers help whichever
/// incomplete job was published first, so no caller silently degrades
/// to single-threaded while the pool idles on a newer job.
struct Queue {
    jobs: Vec<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    work_cv: Condvar,
}

/// Persistent, scoped-lifetime-safe worker pool.
///
/// `ThreadPool::new(n)` provides `n`-way parallelism: `n - 1` parked OS
/// threads plus the calling thread, which always participates (so a
/// 1-thread pool spawns nothing and runs inline). Concurrent `run`
/// calls from different threads are safe: each caller drives its own
/// job to completion even if the workers are busy elsewhere.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool with `threads`-way parallelism. `threads == 0` auto-detects
    /// from [`std::thread::available_parallelism`]; `threads == 1` runs
    /// everything inline on the caller.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = resolve_threads(threads);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: Vec::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("deltadq-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Serial pool (1-way; no threads spawned). Handy default.
    pub fn serial() -> ThreadPool {
        ThreadPool::new(1)
    }

    /// Parallelism of this pool (workers + the participating caller).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Execute `task(0..chunks)` across the pool, returning when every
    /// chunk has finished. Chunk-to-thread assignment is dynamic; the
    /// closure must derive all effects from the chunk index alone
    /// (disjoint writes via [`SharedSliceMut`]).
    pub fn run(&self, chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        if self.workers.is_empty() || chunks == 1 {
            for i in 0..chunks {
                task(i);
            }
            return;
        }
        // Erase the borrow's lifetime for the shared job record; the
        // completion wait below re-establishes that no dereference
        // outlives the borrow (see TaskPtr).
        #[allow(clippy::useless_transmute)] // changes only the lifetime
        let task_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(task)
        };
        let job = Arc::new(Job {
            task: TaskPtr(task_static as *const (dyn Fn(usize) + Sync)),
            next: AtomicUsize::new(0),
            finished: AtomicUsize::new(0),
            total: chunks,
            panicked: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        {
            let mut queue = self.shared.queue.lock().unwrap();
            queue.jobs.push(job.clone());
            self.shared.work_cv.notify_all();
        }
        job.work(); // the caller is a worker too
        let mut flag = job.done.lock().unwrap();
        while !*flag {
            flag = job.done_cv.wait(flag).unwrap();
        }
        drop(flag);
        // Unpublish the completed job so its (now dangling) task
        // pointer doesn't linger in the queue between calls.
        {
            let mut queue = self.shared.queue.lock().unwrap();
            queue.jobs.retain(|j| !Arc::ptr_eq(j, &job));
        }
        if job.panicked.load(Ordering::Acquire) {
            panic!("pool worker task panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut queue = self.shared.queue.lock().unwrap();
            queue.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("threads", &self.threads()).finish()
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut queue = shared.queue.lock().unwrap();
            loop {
                if queue.shutdown {
                    return;
                }
                // Drop jobs whose chunks are all claimed (they finish on
                // the threads already executing them), then help the
                // oldest still-open job — FIFO keeps every concurrent
                // caller's request parallel instead of only the newest.
                queue.jobs.retain(|j| j.next.load(Ordering::Relaxed) < j.total);
                if let Some(job) = queue.jobs.first().cloned() {
                    break job;
                }
                queue = shared.work_cv.wait(queue).unwrap();
            }
        };
        job.work();
    }
}

/// `0` → available parallelism, otherwise the requested count (min 1).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// A `&mut [T]` that can be handed to pool chunks, each writing a
/// disjoint range — the primitive that lets fused-kernel workers write
/// straight into column stripes of the preallocated output instead of
/// assembling per-worker blocks through `Matrix::set_cols`.
pub struct SharedSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedSliceMut<'_, T> {}
unsafe impl<T: Send> Sync for SharedSliceMut<'_, T> {}

impl<'a, T> SharedSliceMut<'a, T> {
    /// Wrap a mutable slice for disjoint-range concurrent writes.
    pub fn new(slice: &'a mut [T]) -> SharedSliceMut<'a, T> {
        SharedSliceMut { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// Length of the wrapped slice.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the wrapped slice is empty.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Raw base pointer (for kernels that compute their own offsets;
    /// the disjointness obligation is the same as [`Self::slice_mut`]).
    #[inline]
    pub fn as_ptr(&self) -> *mut T {
        self.ptr
    }

    /// Mutable view of `start..start + len`.
    ///
    /// # Safety
    /// Concurrent callers must access pairwise-disjoint ranges, and no
    /// other reference to this region may be live for the duration.
    #[inline]
    #[allow(clippy::mut_from_ref)] // disjointness is the caller contract
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = ThreadPool::new(4);
        for total in [0usize, 1, 3, 17, 100] {
            let hits: Vec<AtomicUsize> = (0..total).map(|_| AtomicUsize::new(0)).collect();
            pool.run(total, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i} of {total}");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_many_runs() {
        let pool = ThreadPool::new(3);
        let counter = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run(8, &|_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 1600);
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ThreadPool::serial();
        assert_eq!(pool.threads(), 1);
        let seen: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        pool.run(5, &|i| {
            seen[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(seen.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn disjoint_writes_land() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u32; 64];
        let shared = SharedSliceMut::new(&mut data);
        pool.run(8, &|i| {
            // SAFETY: chunk i owns the disjoint range [i*8, i*8+8).
            let s = unsafe { shared.slice_mut(i * 8, 8) };
            for (k, v) in s.iter_mut().enumerate() {
                *v = (i * 8 + k) as u32;
            }
        });
        for (k, v) in data.iter().enumerate() {
            assert_eq!(*v, k as u32);
        }
    }

    #[test]
    fn concurrent_runs_from_multiple_threads_complete() {
        let pool = Arc::new(ThreadPool::new(4));
        let total = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let pool = pool.clone();
                let total = total.clone();
                scope.spawn(move || {
                    for _ in 0..20 {
                        pool.run(16, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * 16);
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err());
        // the pool survives a panicked job
        let c = AtomicUsize::new(0);
        pool.run(4, &|_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn zero_resolves_to_hardware_parallelism() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        let pool = ThreadPool::new(0);
        assert!(pool.threads() >= 1);
    }
}
