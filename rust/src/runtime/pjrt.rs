//! PJRT runtime (feature `pjrt`): loads the AOT artifacts
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them on the CPU PJRT client from the serving hot path.
//! Python never runs at request time.
//!
//! Interchange is HLO **text** — xla_extension 0.5.1 rejects jax ≥ 0.5
//! serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The default build of this crate does not compile this module at all;
//! `--features pjrt` compiles it against the in-tree `xla-stub` (type
//! surface only — client construction errors at runtime) unless the
//! `xla` dependency points at a real xla-rs build.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::delta::format::DeltaSet;
use crate::model::weights::ModelWeights;
use crate::runtime::ExecutionBackend;
use crate::tensor::Matrix;

/// A PJRT CPU client plus a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    /// (path, executable) cache — compile once per artifact.
    cache: Mutex<Vec<(String, std::sync::Arc<xla::PjRtLoadedExecutable>)>>,
}

impl PjrtRuntime {
    /// Create a CPU-backed runtime.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtRuntime { client, cache: Mutex::new(Vec::new()) })
    }

    /// The PJRT client's platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(&self, path: &Path) -> Result<LoadedGraph> {
        let key = path.to_string_lossy().to_string();
        {
            let cache = self.cache.lock().unwrap();
            if let Some((_, exe)) = cache.iter().find(|(k, _)| *k == key) {
                return Ok(LoadedGraph { exe: exe.clone() });
            }
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf-8")?)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?,
        );
        self.cache.lock().unwrap().push((key, exe.clone()));
        Ok(LoadedGraph { exe })
    }
}

/// A compiled executable ready to run.
pub struct LoadedGraph {
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
}

impl LoadedGraph {
    /// Execute with positional literals; expects a 1-tuple result whose
    /// element is a rank-2 f32 array of `shape`.
    pub fn execute_to_matrix(
        &self,
        args: &[xla::Literal],
        shape: (usize, usize),
    ) -> Result<Matrix> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        let out = result.to_tuple1().context("unwrap result tuple")?;
        let values = out.to_vec::<f32>().context("result to f32 vec")?;
        anyhow::ensure!(
            values.len() == shape.0 * shape.1,
            "result has {} elements, expected {}x{}",
            values.len(),
            shape.0,
            shape.1
        );
        Ok(Matrix::from_vec(shape.0, shape.1, values))
    }
}

/// Build the literal for a token sequence padded to `seq_len`
/// (i32, PAD = 0 — matches the python-side fixed-shape lowering).
pub fn tokens_literal(tokens: &[u32], seq_len: usize) -> Result<xla::Literal> {
    anyhow::ensure!(tokens.len() <= seq_len, "{} tokens > seq_len {seq_len}", tokens.len());
    let mut padded = vec![0i32; seq_len];
    for (i, &t) in tokens.iter().enumerate() {
        padded[i] = t as i32;
    }
    Ok(xla::Literal::vec1(&padded))
}

/// Matrix → rank-2 f32 literal.
pub fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(m.data()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// Argument literals for the `base_prefill` graph: tokens then every
/// weight tensor in sorted-name order (the python/rust shared
/// convention — `aot.py::weight_specs`).
pub fn base_prefill_args(
    tokens: &[u32],
    seq_len: usize,
    weights: &ModelWeights,
) -> Result<Vec<xla::Literal>> {
    let mut args = vec![tokens_literal(tokens, seq_len)?];
    for (_, tensor) in weights.iter() {
        args.push(matrix_literal(tensor)?);
    }
    Ok(args)
}

/// Argument literals for the `delta_prefill` graph: tokens, weights
/// (sorted), then the densified delta tensors (sorted delta names).
pub fn delta_prefill_args(
    tokens: &[u32],
    seq_len: usize,
    weights: &ModelWeights,
    deltas: &BTreeMap<String, Matrix>,
) -> Result<Vec<xla::Literal>> {
    let mut args = base_prefill_args(tokens, seq_len, weights)?;
    for name in weights.config.delta_tensor_names_sorted() {
        let delta = deltas
            .get(&name)
            .with_context(|| format!("missing delta tensor '{name}'"))?;
        args.push(matrix_literal(delta)?);
    }
    Ok(args)
}

/// [`ExecutionBackend`] that executes the AOT prefill artifacts on PJRT.
///
/// Artifact naming convention (shared with `python/compile/aot.py`):
/// `{base|delta}_prefill_<preset>_t<seq>.hlo.txt` inside the artifacts
/// directory. The Cold path densifies the compressed deltas into
/// literals at call time — the no-densify guarantee belongs to
/// [`crate::runtime::NativeBackend`]'s fused path only.
pub struct PjrtBackend {
    runtime: PjrtRuntime,
    artifacts_dir: PathBuf,
    preset: String,
    seq_len: usize,
}

impl PjrtBackend {
    /// Backend over the AOT artifacts for `preset` under
    /// `artifacts_dir`, compiled for fixed sequence length `seq_len`.
    pub fn new(artifacts_dir: &Path, preset: &str, seq_len: usize) -> Result<PjrtBackend> {
        anyhow::ensure!(seq_len > 0, "pjrt seq_len must be positive");
        Ok(PjrtBackend {
            runtime: PjrtRuntime::cpu()?,
            artifacts_dir: artifacts_dir.to_path_buf(),
            preset: preset.to_string(),
            seq_len,
        })
    }

    fn artifact(&self, kind: &str) -> PathBuf {
        self.artifacts_dir
            .join(format!("{kind}_prefill_{}_t{}.hlo.txt", self.preset, self.seq_len))
    }

    /// Prefill against pre-densified deltas (so decode loops densify
    /// the set once, not once per generated token).
    fn prefill_dense(
        &self,
        base: &ModelWeights,
        dense: Option<&BTreeMap<String, Matrix>>,
        tokens: &[u32],
    ) -> Result<Matrix> {
        anyhow::ensure!(!tokens.is_empty(), "empty token sequence");
        anyhow::ensure!(
            tokens.len() <= self.seq_len,
            "{} tokens > artifact seq_len {}",
            tokens.len(),
            self.seq_len
        );
        let logits = match dense {
            None => {
                let graph = self.runtime.load(&self.artifact("base"))?;
                let args = base_prefill_args(tokens, self.seq_len, base)?;
                graph.execute_to_matrix(&args, (self.seq_len, base.config.vocab_size))?
            }
            Some(deltas) => {
                let graph = self.runtime.load(&self.artifact("delta"))?;
                let args = delta_prefill_args(tokens, self.seq_len, base, deltas)?;
                graph.execute_to_matrix(&args, (self.seq_len, base.config.vocab_size))?
            }
        };
        Ok(logits.take_rows(tokens.len()))
    }
}

/// Densify a compressed delta set into per-tensor matrices (the PJRT
/// graphs take dense delta literals; see the struct-level note).
fn densify_set(set: &DeltaSet) -> BTreeMap<String, Matrix> {
    set.tensors.iter().map(|(n, d)| (n.clone(), d.to_dense())).collect()
}

impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prefill(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        tokens: &[u32],
    ) -> Result<Matrix> {
        let dense = delta.map(densify_set);
        self.prefill_dense(base, dense.as_ref(), tokens)
    }

    fn generate(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        prompt: &[u32],
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        // No decode-step artifact exists: re-run the fixed-shape prefill
        // per generated token (correct, O(n²) — PJRT serves the
        // prefill-heavy path; native is the decode-heavy backend). The
        // delta set is densified once for the whole decode loop.
        let dense = delta.map(densify_set);
        let limit = self.seq_len.min(base.config.max_seq);
        let mut ctx = prompt.to_vec();
        let mut out = Vec::new();
        for _ in 0..max_new {
            if ctx.len() >= limit {
                break;
            }
            let logits = self.prefill_dense(base, dense.as_ref(), &ctx)?;
            let next = crate::tensor::ops::argmax_rows(&logits)[ctx.len() - 1];
            if Some(next) == eos {
                break;
            }
            out.push(next);
            ctx.push(next);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_literal_pads() {
        let lit = tokens_literal(&[5, 6], 4).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![5, 6, 0, 0]);
        assert!(tokens_literal(&[1, 2, 3], 2).is_err());
    }

    /// Full artifact round-trip — runs only when a real PJRT runtime is
    /// linked (the stub errors at client creation) AND `make artifacts`
    /// has produced the tiny prefill graph.
    #[test]
    fn base_prefill_artifact_matches_native_forward() {
        let art = std::path::Path::new("artifacts/base_prefill_tiny_t48.hlo.txt");
        let weights_path = std::path::Path::new("artifacts/models/tiny/base.dqw");
        if !art.exists() || !weights_path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = match PjrtRuntime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: no real PJRT runtime ({e:#})");
                return;
            }
        };
        let graph = rt.load(art).unwrap();
        let weights = crate::model::load_weights(weights_path).unwrap();
        let tokens = vec![1u32, 20, 4, 21, 3];
        let args = base_prefill_args(&tokens, 48, &weights).unwrap();
        let logits = graph
            .execute_to_matrix(&args, (48, weights.config.vocab_size))
            .unwrap();
        let native = crate::model::forward(&weights, &tokens);
        for (p, _) in tokens.iter().enumerate() {
            for c in 0..weights.config.vocab_size {
                let a = logits.get(p, c);
                let b = native.get(p, c);
                assert!((a - b).abs() < 2e-2, "pos {p} col {c}: {a} vs {b}");
            }
        }
    }
}
