//! The default pure-Rust [`ExecutionBackend`]: dense forward for the
//! base/Hot path, the fused sparse kernel for the Cold path.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::compress::CompressedDelta;
use crate::delta::format::DeltaSet;
use crate::model::forward::{
    forward, forward_step, forward_steps, generate, generate_with, prefill_into, StepLane,
    WeightSource,
};
use crate::model::weights::ModelWeights;
use crate::model::ModelConfig;
use crate::runtime::fused::{fused_matmul_nt, matmul_nt_pooled};
use crate::runtime::pool::ThreadPool;
use crate::runtime::{DecodeLane, ExecutionBackend};
use crate::sched::PagedKvCache;
use crate::tensor::Matrix;

/// Weight source that evaluates `X·(W_b + ΔŴ)ᵀ` per linear layer via
/// the fused sparse kernel — the Cold serving path with zero dense-`Δ`
/// materialization (contrast [`crate::model::forward::DeltaView`],
/// which runs base and delta as two separate matmuls).
pub struct FusedDeltaView<'a> {
    /// The shared base model.
    pub base: &'a ModelWeights,
    /// One tenant's compressed per-tensor deltas.
    pub deltas: &'a BTreeMap<String, CompressedDelta>,
    /// The backend's persistent worker pool — shared by every tenant,
    /// layer, and request (no per-call thread spawns).
    pub pool: &'a ThreadPool,
}

impl WeightSource for FusedDeltaView<'_> {
    fn config(&self) -> ModelConfig {
        self.base.config
    }

    fn dense(&self, name: &str) -> &Matrix {
        self.base.get(name)
    }

    fn linear(&self, name: &str, x: &Matrix) -> Matrix {
        let w = self.base.get(name);
        match self.deltas.get(name) {
            Some(delta) => fused_matmul_nt(x, w, delta, self.pool),
            None => matmul_nt_pooled(x, w, self.pool),
        }
    }
}

/// Pure-Rust execution backend over `model::forward` — always
/// available, no external dependencies. Owns the persistent worker
/// pool: constructed once (per [`crate::coordinator::Server`] in
/// serving) and reused for every request on the hot path.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    pool: Arc<ThreadPool>,
}

impl Default for NativeBackend {
    fn default() -> NativeBackend {
        NativeBackend::new(1)
    }
}

impl NativeBackend {
    /// `threads ≤ 1` runs the kernels inline on the calling worker;
    /// `0` auto-detects hardware parallelism.
    pub fn new(threads: usize) -> NativeBackend {
        NativeBackend { pool: Arc::new(ThreadPool::new(threads)) }
    }

    /// Share an existing pool (e.g. one pool across several backends).
    pub fn with_pool(pool: Arc<ThreadPool>) -> NativeBackend {
        NativeBackend { pool }
    }

    /// The backend's persistent worker pool.
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    fn view<'a>(&'a self, base: &'a ModelWeights, set: &'a DeltaSet) -> FusedDeltaView<'a> {
        FusedDeltaView { base, deltas: &set.tensors, pool: &self.pool }
    }
}

/// Dense weights routed through the pooled matmul — the Hot / no-delta
/// path. Bit-identical to the single-threaded forward for any pool
/// size (same stripe kernels as the fused path).
struct PooledWeights<'a> {
    weights: &'a ModelWeights,
    pool: &'a ThreadPool,
}

impl WeightSource for PooledWeights<'_> {
    fn config(&self) -> ModelConfig {
        self.weights.config
    }

    fn dense(&self, name: &str) -> &Matrix {
        self.weights.get(name)
    }

    fn linear(&self, name: &str, x: &Matrix) -> Matrix {
        matmul_nt_pooled(x, self.weights.get(name), self.pool)
    }
}

impl ExecutionBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn prefill(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        tokens: &[u32],
    ) -> Result<Matrix> {
        Ok(match delta {
            None => forward(&PooledWeights { weights: base, pool: &self.pool }, tokens),
            Some(set) => forward(&self.view(base, set), tokens),
        })
    }

    fn generate(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        prompt: &[u32],
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>> {
        Ok(match delta {
            None => {
                generate(&PooledWeights { weights: base, pool: &self.pool }, prompt, max_new, eos)
            }
            Some(set) => generate(&self.view(base, set), prompt, max_new, eos),
        })
    }

    fn generate_stream(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        prompt: &[u32],
        max_new: usize,
        eos: Option<u32>,
        on_token: &mut dyn FnMut(u32),
    ) -> Result<Vec<u32>> {
        // same decode loop as `generate` (bit-identical tokens), with
        // the observer firing per decode step instead of at the end
        Ok(match delta {
            None => generate_with(
                &PooledWeights { weights: base, pool: &self.pool },
                prompt,
                max_new,
                eos,
                on_token,
            ),
            Some(set) => generate_with(&self.view(base, set), prompt, max_new, eos, on_token),
        })
    }

    fn supports_stepping(&self) -> bool {
        true
    }

    fn prefill_step(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        tokens: &[u32],
        cache: &mut PagedKvCache,
    ) -> Result<Matrix> {
        // the same `forward_step` loop `generate_with` runs over the
        // prompt — only the cache layout differs, and `KvSlot` makes
        // that bit-invisible
        Ok(match delta {
            None => {
                prefill_into(&PooledWeights { weights: base, pool: &self.pool }, tokens, cache)
            }
            Some(set) => prefill_into(&self.view(base, set), tokens, cache),
        })
    }

    fn decode_step(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        token: u32,
        pos: usize,
        cache: &mut PagedKvCache,
    ) -> Result<Matrix> {
        Ok(match delta {
            None => forward_step(
                &PooledWeights { weights: base, pool: &self.pool },
                token,
                pos,
                cache,
            ),
            Some(set) => forward_step(&self.view(base, set), token, pos, cache),
        })
    }

    fn decode_steps(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        lanes: &mut [DecodeLane<'_>],
    ) -> Result<Matrix> {
        if lanes.is_empty() {
            return Ok(Matrix::zeros(0, base.config.vocab_size));
        }
        // stack the group into one t=k forward: every linear layer runs
        // as a single fused matmul over all lanes; row i carries the
        // exact bits of a lone decode_step for lane i (the tiled kernel
        // is invariant to the activation row count)
        let mut stacked: Vec<StepLane<'_, PagedKvCache>> = lanes
            .iter_mut()
            .map(|l| StepLane { token: l.token, pos: l.pos, cache: &mut *l.cache })
            .collect();
        Ok(match delta {
            None => {
                forward_steps(&PooledWeights { weights: base, pool: &self.pool }, &mut stacked)
            }
            Some(set) => forward_steps(&self.view(base, set), &mut stacked),
        })
    }

    fn exec_pool(&self) -> Option<&ThreadPool> {
        Some(&self.pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::tensor::Pcg64;

    fn base(seed: u64) -> ModelWeights {
        let mut rng = Pcg64::seeded(seed);
        ModelWeights::init(ModelConfig::tiny(), &mut rng)
    }

    fn delta_set(base: &ModelWeights, seed: u64, quant: Option<(u32, u32)>) -> DeltaSet {
        let mut rng = Pcg64::seeded(seed);
        let dq = DeltaDq::new(DeltaDqConfig { alpha: 4.0, group_size: Some(16), quant });
        let mut set = DeltaSet::new("DeltaDQ", 4.0);
        for name in base.config.delta_tensor_names() {
            let (r, c) = base.get(&name).shape();
            let d = Matrix::randn(r, c, 0.002, &mut rng);
            set.tensors
                .insert(name.clone(), dq.compress(&d, &LayerContext::data_free(0, &name), &mut rng));
        }
        set
    }

    #[test]
    fn dense_prefill_matches_forward() {
        let w = base(1);
        let b = NativeBackend::default();
        let tokens = [1u32, 20, 4, 21, 3];
        let logits = b.prefill(&w, None, &tokens).unwrap();
        assert_eq!(logits, forward(&w, &tokens));
    }

    #[test]
    fn empty_delta_set_is_identity() {
        let w = base(2);
        let set = DeltaSet::new("none", 1.0);
        let b = NativeBackend::new(2);
        let tokens = [3u32, 1, 4];
        let a = b.prefill(&w, None, &tokens).unwrap();
        let c = b.prefill(&w, Some(&set), &tokens).unwrap();
        assert!(a.allclose(&c, 1e-5, 1e-5));
    }

    #[test]
    fn cold_prefill_close_to_merged_forward() {
        let w = base(3);
        let set = delta_set(&w, 4, Some((4, 8)));
        // merge the *quantized* reconstruction so only summation order differs
        let mut merged = w.clone();
        for (name, d) in &set.tensors {
            let dense = d.to_dense();
            merged.get_mut(name).add_assign(&dense);
        }
        let b = NativeBackend::new(3);
        let tokens = [1u32, 20, 4, 21, 3, 7];
        let got = b.prefill(&w, Some(&set), &tokens).unwrap();
        let want = forward(&merged, &tokens);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn generate_stream_emits_exactly_the_batch_tokens() {
        let w = base(7);
        let set = delta_set(&w, 8, Some((8, 4)));
        let prompt = [1u32, 20, 4, 21, 3];
        let b = NativeBackend::default();
        let batch = b.generate(&w, Some(&set), &prompt, 6, None).unwrap();
        let mut streamed = Vec::new();
        let ret = b
            .generate_stream(&w, Some(&set), &prompt, 6, None, &mut |t| streamed.push(t))
            .unwrap();
        assert_eq!(streamed, batch, "per-token emission == batch decode");
        assert_eq!(ret, batch, "return value == emitted sequence");
    }

    #[test]
    fn stepping_api_matches_generate_bit_for_bit() {
        // hand-drive the scheduler's step API (prefill_step + one
        // decode_step per token over a paged cache) and compare against
        // the run-to-completion decode loop
        use crate::eval::tasks::vocab;
        use crate::sched::BlockPool;
        use crate::tensor::ops;

        let w = base(11);
        let set = delta_set(&w, 12, Some((4, 8)));
        let prompt = [1u32, 20, 4, 21, 3];
        let max_new = 6;
        let b = NativeBackend::default();
        let want = b.generate(&w, Some(&set), &prompt, max_new, Some(vocab::EOS)).unwrap();

        let pool = Arc::new(BlockPool::with_blocks(&w.config, 4, 16));
        let mut cache = PagedKvCache::new(pool);
        assert!(cache.grow(prompt.len()));
        let mut last = b.prefill_step(&w, Some(&set), &prompt, &mut cache).unwrap();
        let mut got = Vec::new();
        let mut pos = prompt.len();
        for _ in 0..max_new {
            if pos >= w.config.max_seq {
                break;
            }
            let next = ops::argmax_rows(&last)[0];
            if next == vocab::EOS {
                break;
            }
            got.push(next);
            assert!(cache.grow(pos + 1));
            last = b.decode_step(&w, Some(&set), next, pos, &mut cache).unwrap();
            pos += 1;
        }
        assert_eq!(got, want, "stepped decode == run-to-completion decode");
    }

    #[test]
    fn decode_steps_bit_match_decode_step_loop_across_lane_counts() {
        // The fused decode_steps entry point must return, in row i, the
        // exact bits a lone decode_step would produce for lane i — at
        // any lane count. Different prompts per lane, shared position.
        use crate::runtime::DecodeLane;
        use crate::sched::BlockPool;
        use crate::tensor::ops;

        let w = base(13);
        let set = delta_set(&w, 14, Some((4, 8)));
        let b = NativeBackend::default();
        let decode_steps = 4;

        for lanes_n in [1usize, 3, 8] {
            let prompts: Vec<Vec<u32>> =
                (0..lanes_n).map(|i| vec![1, 20 + i as u32, 4, 21 + i as u32, 3]).collect();
            let positions = prompts[0].len() + decode_steps + 1;
            let blocks = 2 * lanes_n * positions.div_ceil(4) + 2;
            let pool = Arc::new(BlockPool::with_blocks(&w.config, 4, blocks));

            let prefill = |caches: &mut Vec<PagedKvCache>, tokens: &mut Vec<u32>| {
                for prompt in &prompts {
                    let mut cache = PagedKvCache::new(pool.clone());
                    assert!(cache.grow(prompt.len()));
                    let logits = b.prefill_step(&w, Some(&set), prompt, &mut cache).unwrap();
                    tokens.push(ops::argmax_rows(&logits)[0]);
                    caches.push(cache);
                }
            };

            // Reference: one decode_step call per lane per iteration.
            let (mut caches, mut tokens) = (Vec::new(), Vec::new());
            prefill(&mut caches, &mut tokens);
            let mut ref_logits: Vec<Vec<Vec<f32>>> = vec![Vec::new(); lanes_n];
            for step in 0..decode_steps {
                let pos = prompts[0].len() + step;
                for (i, cache) in caches.iter_mut().enumerate() {
                    assert!(cache.grow(pos + 1));
                    let l = b.decode_step(&w, Some(&set), tokens[i], pos, cache).unwrap();
                    tokens[i] = ops::argmax_rows(&l)[0];
                    ref_logits[i].push(l.data().to_vec());
                }
            }
            let ref_tokens = tokens.clone();
            drop(caches); // return blocks before the batched pass

            // Batched: one decode_steps call over all lanes.
            let (mut caches, mut tokens) = (Vec::new(), Vec::new());
            prefill(&mut caches, &mut tokens);
            for step in 0..decode_steps {
                let pos = prompts[0].len() + step;
                for cache in caches.iter_mut() {
                    assert!(cache.grow(pos + 1));
                }
                let mut lanes: Vec<DecodeLane<'_>> = caches
                    .iter_mut()
                    .zip(tokens.iter())
                    .map(|(cache, &token)| DecodeLane { token, pos, cache })
                    .collect();
                let stacked = b.decode_steps(&w, Some(&set), &mut lanes).unwrap();
                tokens = ops::argmax_rows(&stacked);
                for i in 0..lanes_n {
                    assert_eq!(
                        stacked.row(i),
                        &ref_logits[i][step][..],
                        "{lanes_n} lanes, lane {i}, step {step}: batched logits diverged"
                    );
                }
            }
            assert_eq!(tokens, ref_tokens, "{lanes_n} lanes: final tokens diverged");
        }
    }

    #[test]
    fn generate_is_deterministic_across_threads() {
        let w = base(5);
        let set = delta_set(&w, 6, Some((8, 4)));
        let prompt = [1u32, 20, 4, 21, 3];
        let one = NativeBackend::new(1).generate(&w, Some(&set), &prompt, 6, None).unwrap();
        let four = NativeBackend::new(4).generate(&w, Some(&set), &prompt, 6, None).unwrap();
        assert_eq!(one, four);
        assert_eq!(one.len(), 6);
    }
}
