//! The default pure-Rust [`ExecutionBackend`]: dense forward for the
//! base/Hot path, the fused sparse kernel for the Cold path.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::compress::CompressedDelta;
use crate::delta::format::DeltaSet;
use crate::model::forward::{forward, generate, WeightSource};
use crate::model::weights::ModelWeights;
use crate::model::ModelConfig;
use crate::runtime::fused::fused_matmul_nt;
use crate::runtime::ExecutionBackend;
use crate::tensor::{ops, Matrix};

/// Weight source that evaluates `X·(W_b + ΔŴ)ᵀ` per linear layer via
/// the fused sparse kernel — the Cold serving path with zero dense-`Δ`
/// materialization (contrast [`crate::model::forward::DeltaView`],
/// which runs base and delta as two separate matmuls).
pub struct FusedDeltaView<'a> {
    pub base: &'a ModelWeights,
    pub deltas: &'a BTreeMap<String, CompressedDelta>,
    /// Row-parallelism of the fused kernel (1 = single-threaded).
    pub threads: usize,
}

impl WeightSource for FusedDeltaView<'_> {
    fn config(&self) -> ModelConfig {
        self.base.config
    }

    fn dense(&self, name: &str) -> &Matrix {
        self.base.get(name)
    }

    fn linear(&self, name: &str, x: &Matrix) -> Matrix {
        let w = self.base.get(name);
        match self.deltas.get(name) {
            Some(delta) => fused_matmul_nt(x, w, delta, self.threads),
            None if self.threads > 1 => ops::matmul_nt_parallel(x, w, self.threads),
            None => x.matmul_nt(w),
        }
    }
}

/// Pure-Rust execution backend over `model::forward` — always
/// available, no external dependencies.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    threads: usize,
}

impl Default for NativeBackend {
    fn default() -> NativeBackend {
        NativeBackend { threads: 1 }
    }
}

impl NativeBackend {
    /// `threads ≤ 1` disables row parallelism in the fused kernel.
    pub fn new(threads: usize) -> NativeBackend {
        NativeBackend { threads: threads.max(1) }
    }

    fn view<'a>(&self, base: &'a ModelWeights, set: &'a DeltaSet) -> FusedDeltaView<'a> {
        FusedDeltaView { base, deltas: &set.tensors, threads: self.threads }
    }
}

impl ExecutionBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn prefill(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        tokens: &[u32],
    ) -> Result<Matrix> {
        Ok(match delta {
            None => forward(base, tokens),
            Some(set) => forward(&self.view(base, set), tokens),
        })
    }

    fn generate(
        &self,
        base: &ModelWeights,
        delta: Option<&DeltaSet>,
        prompt: &[u32],
        max_new: usize,
        eos: Option<u32>,
    ) -> Result<Vec<u32>> {
        Ok(match delta {
            None => generate(base, prompt, max_new, eos),
            Some(set) => generate(&self.view(base, set), prompt, max_new, eos),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::tensor::Pcg64;

    fn base(seed: u64) -> ModelWeights {
        let mut rng = Pcg64::seeded(seed);
        ModelWeights::init(ModelConfig::tiny(), &mut rng)
    }

    fn delta_set(base: &ModelWeights, seed: u64, quant: Option<(u32, u32)>) -> DeltaSet {
        let mut rng = Pcg64::seeded(seed);
        let dq = DeltaDq::new(DeltaDqConfig { alpha: 4.0, group_size: Some(16), quant });
        let mut set = DeltaSet::new("DeltaDQ", 4.0);
        for name in base.config.delta_tensor_names() {
            let (r, c) = base.get(&name).shape();
            let d = Matrix::randn(r, c, 0.002, &mut rng);
            set.tensors
                .insert(name.clone(), dq.compress(&d, &LayerContext::data_free(0, &name), &mut rng));
        }
        set
    }

    #[test]
    fn dense_prefill_matches_forward() {
        let w = base(1);
        let b = NativeBackend::default();
        let tokens = [1u32, 20, 4, 21, 3];
        let logits = b.prefill(&w, None, &tokens).unwrap();
        assert_eq!(logits, forward(&w, &tokens));
    }

    #[test]
    fn empty_delta_set_is_identity() {
        let w = base(2);
        let set = DeltaSet::new("none", 1.0);
        let b = NativeBackend::new(2);
        let tokens = [3u32, 1, 4];
        let a = b.prefill(&w, None, &tokens).unwrap();
        let c = b.prefill(&w, Some(&set), &tokens).unwrap();
        assert!(a.allclose(&c, 1e-5, 1e-5));
    }

    #[test]
    fn cold_prefill_close_to_merged_forward() {
        let w = base(3);
        let set = delta_set(&w, 4, Some((4, 8)));
        // merge the *quantized* reconstruction so only summation order differs
        let mut merged = w.clone();
        for (name, d) in &set.tensors {
            let dense = d.to_dense();
            merged.get_mut(name).add_assign(&dense);
        }
        let b = NativeBackend::new(3);
        let tokens = [1u32, 20, 4, 21, 3, 7];
        let got = b.prefill(&w, Some(&set), &tokens).unwrap();
        let want = forward(&merged, &tokens);
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn generate_is_deterministic_across_threads() {
        let w = base(5);
        let set = delta_set(&w, 6, Some((8, 4)));
        let prompt = [1u32, 20, 4, 21, 3];
        let one = NativeBackend::new(1).generate(&w, Some(&set), &prompt, 6, None).unwrap();
        let four = NativeBackend::new(4).generate(&w, Some(&set), &prompt, 6, None).unwrap();
        assert_eq!(one, four);
        assert_eq!(one.len(), 6);
    }
}
