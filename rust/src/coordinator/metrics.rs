//! Serving metrics: lock-free counters plus latency accumulators,
//! snapshot-able as JSON for the demo server's periodic report.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::tenant::TierCounters;
use crate::tensor::stats::Accumulator;
use crate::util::json::Json;

/// Coordinator-wide metrics. Cheap to update from any worker thread.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub batches_executed: AtomicU64,
    pub promotions: AtomicU64,
    pub evictions: AtomicU64,
    /// Requests whose execution backend returned an error.
    pub backend_errors: AtomicU64,
    /// Storage-tier counters (`disk_loads` / `demotions` /
    /// `store_bytes_read`). Shared with the [`TenantStore`]'s loader
    /// thread when the server runs over a delta store, so the snapshot
    /// reports tier churn without a second source of truth.
    ///
    /// [`TenantStore`]: crate::coordinator::TenantStore
    pub tiers: Arc<TierCounters>,
    /// End-to-end request latency (seconds).
    latency: Mutex<Accumulator>,
    /// Queue wait before batch pickup (seconds).
    queue_wait: Mutex<Accumulator>,
    /// Per-batch execution time (seconds).
    batch_exec: Mutex<Accumulator>,
    /// p50/p99 need raw samples; bounded ring of recent latencies.
    recent_latencies: Mutex<Vec<f64>>,
}

const RECENT_CAP: usize = 4096;

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Metrics whose tier counters alias the tenant store's (tiered
    /// serving: the loader thread writes, the snapshot reads).
    pub fn with_tiers(tiers: Arc<TierCounters>) -> Metrics {
        Metrics { tiers, ..Metrics::default() }
    }

    pub fn observe_latency(&self, seconds: f64) {
        self.latency.lock().unwrap().add(seconds);
        let mut recent = self.recent_latencies.lock().unwrap();
        if recent.len() >= RECENT_CAP {
            let len = recent.len();
            recent.copy_within(len / 2.., 0);
            recent.truncate(len / 2);
        }
        recent.push(seconds);
    }

    pub fn observe_queue_wait(&self, seconds: f64) {
        self.queue_wait.lock().unwrap().add(seconds);
    }

    pub fn observe_batch_exec(&self, seconds: f64) {
        self.batch_exec.lock().unwrap().add(seconds);
    }

    pub fn mean_latency(&self) -> f64 {
        self.latency.lock().unwrap().mean()
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        let recent = self.recent_latencies.lock().unwrap();
        crate::tensor::stats::percentile(&recent, p)
    }

    /// JSON snapshot (stable key order).
    pub fn snapshot(&self) -> Json {
        let mut o = Json::obj();
        o.set("requests_submitted", self.requests_submitted.load(Ordering::Relaxed));
        o.set("requests_completed", self.requests_completed.load(Ordering::Relaxed));
        o.set("requests_rejected", self.requests_rejected.load(Ordering::Relaxed));
        o.set("tokens_generated", self.tokens_generated.load(Ordering::Relaxed));
        o.set("batches_executed", self.batches_executed.load(Ordering::Relaxed));
        o.set("promotions", self.promotions.load(Ordering::Relaxed));
        o.set("evictions", self.evictions.load(Ordering::Relaxed));
        o.set("backend_errors", self.backend_errors.load(Ordering::Relaxed));
        o.set("disk_loads", self.tiers.disk_loads.load(Ordering::Relaxed));
        o.set("demotions", self.tiers.demotions.load(Ordering::Relaxed));
        o.set("store_bytes_read", self.tiers.store_bytes_read.load(Ordering::Relaxed));
        o.set("latency_mean_s", self.mean_latency());
        o.set("latency_p50_s", self.latency_percentile(50.0));
        o.set("latency_p99_s", self.latency_percentile(99.0));
        o.set("queue_wait_mean_s", self.queue_wait.lock().unwrap().mean());
        o.set("batch_exec_mean_s", self.batch_exec.lock().unwrap().mean());
        let completed = self.requests_completed.load(Ordering::Relaxed);
        let batches = self.batches_executed.load(Ordering::Relaxed).max(1);
        o.set("mean_batch_size", completed as f64 / batches as f64);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency() {
        let m = Metrics::new();
        m.requests_submitted.fetch_add(3, Ordering::Relaxed);
        m.requests_completed.fetch_add(2, Ordering::Relaxed);
        m.observe_latency(0.1);
        m.observe_latency(0.3);
        assert!((m.mean_latency() - 0.2).abs() < 1e-12);
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"requests_submitted\":3"));
        assert!(snap.contains("\"requests_completed\":2"));
    }

    #[test]
    fn percentiles_from_recent() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_latency(i as f64);
        }
        assert!((m.latency_percentile(50.0) - 50.5).abs() < 1.0);
        assert!(m.latency_percentile(99.0) > 95.0);
    }

    #[test]
    fn tier_counters_shared_and_snapshotted() {
        let tiers = Arc::new(TierCounters::default());
        let m = Metrics::with_tiers(tiers.clone());
        // the store side writes through its own Arc...
        tiers.disk_loads.fetch_add(3, Ordering::Relaxed);
        tiers.demotions.fetch_add(2, Ordering::Relaxed);
        tiers.store_bytes_read.fetch_add(4096, Ordering::Relaxed);
        // ...and the metrics snapshot sees it
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"disk_loads\":3"), "{snap}");
        assert!(snap.contains("\"demotions\":2"), "{snap}");
        assert!(snap.contains("\"store_bytes_read\":4096"), "{snap}");
    }

    #[test]
    fn recent_ring_stays_bounded() {
        let m = Metrics::new();
        for i in 0..(RECENT_CAP * 3) {
            m.observe_latency(i as f64);
        }
        assert!(m.recent_latencies.lock().unwrap().len() <= RECENT_CAP);
    }
}
