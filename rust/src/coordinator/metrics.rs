//! Serving metrics: lock-free counters plus log-bucketed latency
//! histograms, snapshot-able as JSON for the demo server's periodic
//! report and rendered as Prometheus text by the gateway's `/metrics`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::tenant::TierCounters;
use crate::sched::SchedCounters;
use crate::util::hist::LatencyHistogram;
use crate::util::json::Json;

/// Coordinator-wide metrics. Cheap to update from any worker thread.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted by `submit`/`submit_stream`.
    pub requests_submitted: AtomicU64,
    /// Requests that produced a final `Response`.
    pub requests_completed: AtomicU64,
    /// Requests refused at admission (queue full → 429).
    pub requests_rejected: AtomicU64,
    /// Tokens generated across all requests.
    pub tokens_generated: AtomicU64,
    /// Tenant batches executed (legacy loop) or scheduler iterations
    /// that ran at least one sequence.
    pub batches_executed: AtomicU64,
    /// Cold→Hot tenant promotions.
    pub promotions: AtomicU64,
    /// Hot-tier evictions back to Cold.
    pub evictions: AtomicU64,
    /// Requests whose execution backend returned an error.
    pub backend_errors: AtomicU64,
    /// Storage-tier counters (`disk_loads` / `demotions` /
    /// `store_bytes_read`). Shared with the [`TenantStore`]'s loader
    /// thread when the server runs over a delta store, so the snapshot
    /// reports tier churn without a second source of truth.
    ///
    /// [`TenantStore`]: crate::coordinator::TenantStore
    pub tiers: Arc<TierCounters>,
    /// Continuous-batching scheduler gauges (running/waiting/preempted
    /// sequences, KV-pool occupancy, per-step batch occupancy). Written
    /// by the scheduler drive loop; all-zero under the legacy
    /// run-to-completion worker loop.
    pub sched: Arc<SchedCounters>,
    /// Compression-quality audit state ([`crate::audit::AuditHub`]):
    /// sampling counters, per-tenant shadow-audit windows, and cached
    /// per-layer quality stats. Completion paths call
    /// `audit.offer(..)`; the dedicated audit thread consumes.
    pub audit: Arc<crate::audit::AuditHub>,
    /// Per-tenant usage ledger + saturation engine
    /// ([`crate::usage::UsageLedger`]): attributed compute /
    /// KV-block-seconds / queue-wait / token / store-I/O counters with
    /// rolling windows. Written by the scheduler, the legacy worker
    /// loop, and the store's loader thread; read by `/metrics`,
    /// `/debug/usage`, and the gateway's `Retry-After` derivation.
    pub usage: Arc<crate::usage::UsageLedger>,
    /// End-to-end request latency (log-bucketed histogram; exact mean,
    /// percentiles to bucket precision over the *whole* history — the
    /// old bounded sample ring forgot everything but recent requests).
    latency: Mutex<LatencyHistogram>,
    /// Queue wait before batch pickup (seconds).
    queue_wait: Mutex<LatencyHistogram>,
    /// Per-batch execution time (seconds).
    batch_exec: Mutex<LatencyHistogram>,
}

impl Metrics {
    /// Fresh metrics with private tier counters.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Metrics whose tier counters alias the tenant store's (tiered
    /// serving: the loader thread writes, the snapshot reads).
    pub fn with_tiers(tiers: Arc<TierCounters>) -> Metrics {
        Metrics { tiers, ..Metrics::default() }
    }

    /// Record one request's end-to-end latency.
    pub fn observe_latency(&self, seconds: f64) {
        self.latency.lock().unwrap().record(seconds);
    }

    /// Record one request's queue wait before pickup.
    pub fn observe_queue_wait(&self, seconds: f64) {
        self.queue_wait.lock().unwrap().record(seconds);
    }

    /// Record one batch's execution time.
    pub fn observe_batch_exec(&self, seconds: f64) {
        self.batch_exec.lock().unwrap().record(seconds);
    }

    /// Mean end-to-end request latency in seconds.
    pub fn mean_latency(&self) -> f64 {
        self.latency.lock().unwrap().mean()
    }

    /// End-to-end latency percentile `p` (0–100) in seconds.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.lock().unwrap().percentile(p)
    }

    /// Copy of the end-to-end latency histogram (for merging/rendering
    /// outside the lock).
    pub fn latency_histogram(&self) -> LatencyHistogram {
        self.latency.lock().unwrap().clone()
    }

    /// Copy of the queue-wait histogram.
    pub fn queue_wait_histogram(&self) -> LatencyHistogram {
        self.queue_wait.lock().unwrap().clone()
    }

    /// Copy of the per-batch execution-time histogram.
    pub fn batch_exec_histogram(&self) -> LatencyHistogram {
        self.batch_exec.lock().unwrap().clone()
    }

    /// JSON snapshot (stable key order).
    pub fn snapshot(&self) -> Json {
        let mut o = Json::obj();
        o.set("requests_submitted", self.requests_submitted.load(Ordering::Relaxed));
        o.set("requests_completed", self.requests_completed.load(Ordering::Relaxed));
        o.set("requests_rejected", self.requests_rejected.load(Ordering::Relaxed));
        o.set("tokens_generated", self.tokens_generated.load(Ordering::Relaxed));
        o.set("batches_executed", self.batches_executed.load(Ordering::Relaxed));
        o.set("promotions", self.promotions.load(Ordering::Relaxed));
        o.set("evictions", self.evictions.load(Ordering::Relaxed));
        o.set("backend_errors", self.backend_errors.load(Ordering::Relaxed));
        o.set("disk_loads", self.tiers.disk_loads.load(Ordering::Relaxed));
        o.set("demotions", self.tiers.demotions.load(Ordering::Relaxed));
        o.set("store_bytes_read", self.tiers.store_bytes_read.load(Ordering::Relaxed));
        o.set("latency_mean_s", self.mean_latency());
        o.set("latency_p50_s", self.latency_percentile(50.0));
        o.set("latency_p95_s", self.latency_percentile(95.0));
        o.set("latency_p99_s", self.latency_percentile(99.0));
        o.set("queue_wait_mean_s", self.queue_wait.lock().unwrap().mean());
        o.set("queue_wait_p99_s", self.queue_wait.lock().unwrap().percentile(99.0));
        o.set("batch_exec_mean_s", self.batch_exec.lock().unwrap().mean());
        let sched = self.sched.stats();
        o.set("sched_running", sched.running);
        o.set("sched_waiting", sched.waiting);
        o.set("sched_preempted", sched.preempted_total);
        o.set("sched_cancelled", sched.cancelled_total);
        o.set("kv_blocks_used", sched.kv_blocks_used);
        o.set("kv_blocks_free", sched.kv_blocks_free);
        o.set("kv_blocks_total", sched.kv_blocks_total);
        o.set("step_occupancy_mean", self.sched.occupancy_histogram().mean());
        o.set("decode_groups_total", sched.decode_groups_total);
        o.set("decode_lanes_total", sched.decode_lanes_total);
        o.set("prefill_chunks_total", sched.prefill_chunks_total);
        o.set("decode_group_mean", self.sched.group_size_histogram().mean());
        let completed = self.requests_completed.load(Ordering::Relaxed);
        let batches = self.batches_executed.load(Ordering::Relaxed).max(1);
        o.set("mean_batch_size", completed as f64 / batches as f64);
        o.set("load_retries_total", self.tiers.load_retries.load(Ordering::Relaxed));
        o.set("decode_group_panics_total", sched.decode_group_panics_total);
        o.set("deadline_expired_total", sched.deadline_expired_total);
        o.set("audit_sampled_total", self.audit.sampled_total.load(Ordering::Relaxed));
        o.set("audit_dropped_total", self.audit.dropped_total.load(Ordering::Relaxed));
        o.set("audit_completed_total", self.audit.completed_total.load(Ordering::Relaxed));
        o.set("audit_warn_total", self.audit.warn_total.load(Ordering::Relaxed));
        o.set("audit_quarantined_total", self.audit.quarantined_total.load(Ordering::Relaxed));
        o.set("usage_exec_wall_s", self.usage.exec_wall_us() as f64 / 1e6);
        let sat = self.usage.saturation();
        o.set("saturation_combined", sat.combined);
        o.set("retry_after_s", sat.retry_after_s);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency() {
        let m = Metrics::new();
        m.requests_submitted.fetch_add(3, Ordering::Relaxed);
        m.requests_completed.fetch_add(2, Ordering::Relaxed);
        m.observe_latency(0.1);
        m.observe_latency(0.3);
        assert!((m.mean_latency() - 0.2).abs() < 1e-12);
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"requests_submitted\":3"));
        assert!(snap.contains("\"requests_completed\":2"));
    }

    #[test]
    fn percentiles_from_histogram() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_latency(i as f64);
        }
        // log-bucketed: percentiles accurate to ~±2.5% relative
        assert!((m.latency_percentile(50.0) - 50.0).abs() < 2.0);
        assert!(m.latency_percentile(99.0) > 95.0);
        assert!(m.latency_percentile(99.0) <= 100.0);
    }

    #[test]
    fn tier_counters_shared_and_snapshotted() {
        let tiers = Arc::new(TierCounters::default());
        let m = Metrics::with_tiers(tiers.clone());
        // the store side writes through its own Arc...
        tiers.disk_loads.fetch_add(3, Ordering::Relaxed);
        tiers.demotions.fetch_add(2, Ordering::Relaxed);
        tiers.store_bytes_read.fetch_add(4096, Ordering::Relaxed);
        // ...and the metrics snapshot sees it
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"disk_loads\":3"), "{snap}");
        assert!(snap.contains("\"demotions\":2"), "{snap}");
        assert!(snap.contains("\"store_bytes_read\":4096"), "{snap}");
    }

    #[test]
    fn snapshot_reports_batched_decode_counters() {
        let m = Metrics::new();
        m.sched.decode_groups_total.fetch_add(2, Ordering::Relaxed);
        m.sched.decode_lanes_total.fetch_add(7, Ordering::Relaxed);
        m.sched.prefill_chunks_total.fetch_add(3, Ordering::Relaxed);
        m.sched.observe_group(3);
        m.sched.observe_group(4);
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"decode_groups_total\":2"), "{snap}");
        assert!(snap.contains("\"decode_lanes_total\":7"), "{snap}");
        assert!(snap.contains("\"prefill_chunks_total\":3"), "{snap}");
        assert!(snap.contains("\"decode_group_mean\":3.5"), "{snap}");
    }

    #[test]
    fn histogram_remembers_full_history() {
        // the pre-histogram sample ring halved itself at capacity; the
        // histogram's percentiles cover every observation ever recorded
        let m = Metrics::new();
        for _ in 0..10_000 {
            m.observe_latency(1e-3);
        }
        m.observe_latency(10.0); // one slow outlier, early...
        for _ in 0..10_000 {
            m.observe_latency(1e-3);
        }
        let h = m.latency_histogram();
        assert_eq!(h.count(), 20_001);
        assert!((h.max() - 10.0).abs() < 1e-9, "outlier retained");
        assert!(m.latency_percentile(50.0) < 2e-3);
    }
}
