//! The serving coordinator: the continuous-batching scheduler (or, for
//! backends without the stepping API, the legacy run-to-completion
//! worker pool) executing tenant requests through a pluggable
//! [`ExecutionBackend`] — fused separate computation for Cold tenants,
//! dense caches for Hot ones.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, ReplySink, Request, Response, StreamEvent, SubmitError};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::tenant::{RetryPolicy, TenantStore, TenantView, Tier};
use crate::delta::format::DeltaSet;
use crate::eval::tasks::vocab;
use crate::model::weights::ModelWeights;
use crate::runtime::{ExecutionBackend, NativeBackend};
use crate::sched::{self, SchedOptions, SchedStats};
use crate::store::DeltaStore;
use crate::util::trace;

/// Server construction knobs (a subset of [`crate::config::ServeConfig`]
/// resolved to concrete values).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Max requests per tenant batch (legacy loop) and the default
    /// `max_running` for the scheduler.
    pub max_batch: usize,
    /// How long a batch is held open for same-tenant joiners.
    pub batch_window: Duration,
    /// Per-tenant queue bound (beyond → backpressure).
    pub queue_depth: usize,
    /// Worker threads for the legacy run-to-completion loop.
    pub workers: usize,
    /// Dense-cache byte budget (None = unbounded).
    pub cache_budget: Option<u64>,
    /// Resident compressed-delta byte budget for the Cold tier (None =
    /// unbounded). Only meaningful with an attached delta store — an
    /// in-memory tenant has nowhere to be demoted to.
    pub delta_budget: Option<u64>,
    /// Promote to Hot after this many served requests.
    pub promote_after: u64,
    /// Continuous-batching scheduler knobs. `Some` (the default) drives
    /// requests through per-decode-step scheduling whenever the backend
    /// supports stepping; `None` forces the legacy run-to-completion
    /// worker loop (also the automatic fallback for backends without
    /// the stepping API, e.g. pjrt). Streamed tokens are bit-identical
    /// either way.
    pub sched: Option<SchedOptions>,
    /// Default per-request deadline (TTL): a request not finished this
    /// long after submission is terminated with a "deadline exceeded"
    /// error frame and its KV blocks freed. `None` = no deadline unless
    /// the caller passes one per request.
    pub request_ttl: Option<Duration>,
    /// Disk→Cold hydration retry/backoff/quarantine policy (only
    /// meaningful with an attached delta store).
    pub retry: RetryPolicy,
    /// Compression-quality audit settings (`[audit]`): shadow-sampling
    /// rate, drift threshold, enforcement. Enabled by default at 1-in-64
    /// sampling with drift detection off (telemetry only).
    pub audit: crate::audit::AuditConfig,
    /// Per-tenant usage accounting + saturation settings (`[usage]`):
    /// ledger on/off, `/metrics` tenant cardinality cap, and the ceiling
    /// of the load-derived `Retry-After` hint.
    pub usage: crate::usage::UsageConfig,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            max_batch: 8,
            batch_window: Duration::from_micros(500),
            queue_depth: 256,
            workers: 4,
            cache_budget: None,
            delta_budget: None,
            promote_after: 8,
            sched: Some(SchedOptions::default()),
            request_ttl: None,
            retry: RetryPolicy::default(),
            audit: crate::audit::AuditConfig::default(),
            usage: crate::usage::UsageConfig::default(),
        }
    }
}

/// Process-global request id counter. Ids must be unique across every
/// `Server` in the process — they key the trace registry's span-tree
/// join, and two servers reusing an id would cross their traces.
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

/// Multi-tenant delta-serving coordinator.
pub struct Server {
    store: Arc<TenantStore>,
    batcher: Arc<Batcher>,
    /// Serving metrics, shared with whatever front-end drives this
    /// server (snapshot via [`Metrics::snapshot`]).
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    backend: Arc<dyn ExecutionBackend>,
    /// Whether the continuous-batching scheduler (vs the legacy
    /// run-to-completion worker pool) drives execution.
    sched_active: bool,
    /// Default per-request TTL applied when the caller passes none.
    request_ttl: Option<Duration>,
}

impl Server {
    /// Start the worker pool over a base model with the default
    /// [`NativeBackend`].
    pub fn start(base: Arc<ModelWeights>, options: ServerOptions) -> Server {
        Server::with_backend(base, options, Arc::new(NativeBackend::default()))
    }

    /// Start the worker pool over a base model with an explicit
    /// execution backend.
    pub fn with_backend(
        base: Arc<ModelWeights>,
        options: ServerOptions,
        backend: Arc<dyn ExecutionBackend>,
    ) -> Server {
        let store = Arc::new(TenantStore::new(
            base,
            options.cache_budget,
            options.promote_after,
        ));
        Server::over_store(store, options, backend)
    }

    /// Start the worker pool over an on-disk [`DeltaStore`]: every
    /// tenant in the store manifest is registered at Disk tier (zero
    /// RAM) and hydrated by the background loader on first request;
    /// `options.delta_budget` bounds the resident Cold tier.
    pub fn with_store(
        base: Arc<ModelWeights>,
        options: ServerOptions,
        backend: Arc<dyn ExecutionBackend>,
        delta_store: Arc<DeltaStore>,
    ) -> Result<Server> {
        let store = Arc::new(TenantStore::with_disk_retry(
            base,
            options.cache_budget,
            options.delta_budget,
            options.promote_after,
            delta_store.clone(),
            options.retry.clone(),
        ));
        let server = Server::over_store(store, options, backend);
        for tenant in delta_store.tenants() {
            server.store.register_disk(&tenant)?;
            server.batcher.add_tenant(&tenant);
        }
        Ok(server)
    }

    fn over_store(
        store: Arc<TenantStore>,
        options: ServerOptions,
        backend: Arc<dyn ExecutionBackend>,
    ) -> Server {
        let batcher = Arc::new(Batcher::new(
            options.max_batch,
            options.batch_window,
            options.queue_depth,
        ));
        let metrics = Arc::new(Metrics::with_tiers(store.tiers()));
        let mut workers = Vec::new();
        metrics.audit.configure(&options.audit);
        metrics.usage.configure(&options.usage);
        // let the loader thread attribute hydration I/O per tenant
        store.attach_usage(metrics.usage.clone());
        if options.audit.enabled {
            // shadow-audit consumer: low-priority, off the hot path.
            // Completion threads only ever try_send into the bounded
            // queue; everything expensive (dense reference
            // reconstruction, prefills, layer profiling) happens here.
            let (tx, rx) = mpsc::sync_channel(crate::audit::AUDIT_QUEUE_DEPTH);
            metrics.audit.connect(tx);
            let hub = metrics.audit.clone();
            let store = store.clone();
            let backend = backend.clone();
            let handle = std::thread::Builder::new()
                .name("deltadq-audit".to_string())
                .spawn(move || crate::audit::worker_loop(rx, hub, backend, store))
                .expect("spawn audit thread");
            workers.push(handle);
        }
        let sched_opts = match &options.sched {
            Some(opts) if backend.supports_stepping() => Some(opts.clone()),
            _ => None,
        };
        let sched_active = sched_opts.is_some();
        if let Some(opts) = sched_opts {
            // iteration-level scheduling: one drive thread assembles a
            // mixed-tenant step batch every decode step (intra-op
            // parallelism comes from the backend's compute pool)
            let max_running =
                if opts.max_running == 0 { options.max_batch.max(1) } else { opts.max_running };
            let store = store.clone();
            let batcher = batcher.clone();
            let metrics = metrics.clone();
            let backend = backend.clone();
            let handle = std::thread::Builder::new()
                .name("deltadq-sched".to_string())
                .spawn(move || {
                    sched::drive_loop(
                        &store,
                        &batcher,
                        &metrics,
                        backend.as_ref(),
                        &opts,
                        max_running,
                    );
                })
                .expect("spawn scheduler thread");
            workers.push(handle);
        } else {
            for _ in 0..options.workers.max(1) {
                let store = store.clone();
                let batcher = batcher.clone();
                let metrics = metrics.clone();
                let backend = backend.clone();
                workers.push(std::thread::spawn(move || {
                    worker_loop(&store, &batcher, &metrics, backend.as_ref());
                }));
            }
        }
        Server {
            store,
            batcher,
            metrics,
            workers,
            backend,
            sched_active,
            request_ttl: options.request_ttl,
        }
    }

    /// Name of the execution backend serving requests.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Register a tenant's compressed deltas (in memory; not demotable).
    pub fn register_tenant(&self, tenant: &str, deltas: DeltaSet) {
        self.store.register(tenant, deltas);
        self.batcher.add_tenant(tenant);
    }

    /// Hot registration against the delta store: persist + serve. The
    /// artifact I/O happens before the tenant becomes routable, so the
    /// worker loop never blocks on it.
    pub fn push_tenant(&self, tenant: &str, deltas: DeltaSet) -> Result<u64> {
        let bytes = self.store.push(tenant, deltas)?;
        self.batcher.add_tenant(tenant);
        Ok(bytes)
    }

    /// Hot removal: stop routing (queued requests see a disconnect),
    /// drop residency, delete the artifact.
    pub fn remove_tenant(&self, tenant: &str) -> Result<bool> {
        self.batcher.remove_tenant(tenant);
        self.store.remove(tenant)
    }

    /// Registered tenant names (any tier).
    pub fn tenants(&self) -> Vec<String> {
        self.store.tenants()
    }

    /// Submit a request; returns the (final-only) response receiver.
    pub fn submit(
        &self,
        tenant: &str,
        prompt: Vec<u32>,
        max_new: usize,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit_with_sink(tenant, prompt, max_new, None, ReplySink::Batch(tx))?;
        Ok(rx)
    }

    /// As [`Server::submit`] with an explicit per-request TTL that
    /// overrides the server-wide `request_ttl` default.
    pub fn submit_with_ttl(
        &self,
        tenant: &str,
        prompt: Vec<u32>,
        max_new: usize,
        ttl: Duration,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit_with_sink(tenant, prompt, max_new, Some(ttl), ReplySink::Batch(tx))?;
        Ok(rx)
    }

    /// Submit a streaming request: the receiver yields one
    /// [`StreamEvent::Token`] per decoded token as the worker decodes
    /// it, then [`StreamEvent::Done`] with the final [`Response`]. The
    /// token sequence is bit-identical to what [`Server::submit`] would
    /// return for the same tenant/prompt/limit.
    pub fn submit_stream(
        &self,
        tenant: &str,
        prompt: Vec<u32>,
        max_new: usize,
    ) -> Result<mpsc::Receiver<StreamEvent>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit_with_sink(tenant, prompt, max_new, None, ReplySink::Stream(tx))?;
        Ok(rx)
    }

    /// As [`Server::submit_stream`] with an explicit per-request TTL
    /// that overrides the server-wide `request_ttl` default.
    pub fn submit_stream_with_ttl(
        &self,
        tenant: &str,
        prompt: Vec<u32>,
        max_new: usize,
        ttl: Duration,
    ) -> Result<mpsc::Receiver<StreamEvent>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit_with_sink(tenant, prompt, max_new, Some(ttl), ReplySink::Stream(tx))?;
        Ok(rx)
    }

    fn submit_with_sink(
        &self,
        tenant: &str,
        prompt: Vec<u32>,
        max_new: usize,
        ttl: Option<Duration>,
        respond: ReplySink,
    ) -> Result<(), SubmitError> {
        // quarantined tenants are rejected at submission so request
        // threads never queue work behind (or re-trigger) a failing
        // hydration — clients get the retry-after hint instead
        if let Some(retry_after) = self.store.quarantined(tenant) {
            self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
            self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
            if let Some(u) = self.metrics.usage.tenant(tenant) {
                u.requests.fetch_add(1, Ordering::Relaxed);
                u.rejected_503.fetch_add(1, Ordering::Relaxed);
            }
            return Err(SubmitError::Quarantined {
                tenant: tenant.to_string(),
                retry_after_s: retry_after.as_secs().max(1),
            });
        }
        let id = NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed);
        let submitted = Instant::now();
        let prompt_len = prompt.len();
        let req = Request {
            id,
            tenant: tenant.to_string(),
            prompt,
            max_new,
            submitted,
            deadline: ttl.or(self.request_ttl).map(|t| submitted + t),
            respond,
        };
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        // root trace span: opened before the queue hand-off (a fast
        // request may complete — and close the root — before submit
        // returns) and closed by the reply sink's terminal send
        trace::begin_request(id, tenant, prompt_len, max_new, submitted);
        match self.batcher.submit(req) {
            Ok(()) => {
                if let Some(u) = self.metrics.usage.tenant(tenant) {
                    u.requests.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            }
            Err(e) => {
                trace::end_request(id, Some("rejected at submission"));
                self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                // attribute the rejection — but never mint a ledger entry
                // for a tenant that doesn't exist (unbounded cardinality)
                match &e {
                    SubmitError::Backpressure { .. } => {
                        if let Some(u) = self.metrics.usage.tenant(tenant) {
                            u.requests.fetch_add(1, Ordering::Relaxed);
                            u.rejected_429.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    SubmitError::Quarantined { .. } | SubmitError::Closed => {
                        if let Some(u) = self.metrics.usage.tenant(tenant) {
                            u.requests.fetch_add(1, Ordering::Relaxed);
                            u.rejected_503.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    SubmitError::UnknownTenant(_) => {}
                }
                Err(e)
            }
        }
    }

    /// Total queued requests across all tenant queues (a backpressure
    /// gauge for the metrics endpoint).
    pub fn queued(&self) -> usize {
        self.batcher.queued()
    }

    /// `(vocab_size, max_seq)` of the base model — the bounds the
    /// gateway validates prompts against before submission (an
    /// out-of-range token or over-length sequence would otherwise
    /// panic a worker mid-batch).
    pub fn model_limits(&self) -> (usize, usize) {
        let c = self.store.base().config;
        (c.vocab_size, c.max_seq)
    }

    /// The per-tenant queue-depth limit requests bounce off (HTTP 429).
    pub fn queue_depth(&self) -> usize {
        self.batcher.queue_depth
    }

    /// Queued requests per tenant (the `/metrics` per-tenant gauge).
    pub fn tenant_queue_depths(&self) -> Vec<(String, usize)> {
        self.batcher.queue_depths()
    }

    /// Live scheduler gauges — `None` when the legacy
    /// run-to-completion worker pool drives execution.
    pub fn sched_stats(&self) -> Option<SchedStats> {
        self.sched_active.then(|| self.metrics.sched.stats())
    }

    /// Current saturation estimate. Feeds the instantaneous gauges (KV
    /// occupancy, queue fill, audit backlog) into the usage ledger's
    /// rolling window and reads the per-axis + combined scores back —
    /// so it stays fresh even under the legacy worker loop, which has
    /// no drive thread ticking the ledger.
    pub fn saturation(&self) -> crate::usage::Saturation {
        let sched = self.metrics.sched.stats();
        let kv_frac = if sched.kv_blocks_total > 0 {
            sched.kv_blocks_used as f64 / sched.kv_blocks_total as f64
        } else {
            0.0
        };
        let queue_frac =
            self.batcher.queued() as f64 / self.batcher.queue_capacity().max(1) as f64;
        let sampled = self.metrics.audit.sampled_total.load(Ordering::Relaxed);
        let done = self
            .metrics
            .audit
            .dropped_total
            .load(Ordering::Relaxed)
            .saturating_add(self.metrics.audit.completed_total.load(Ordering::Relaxed));
        let pending = sampled.saturating_sub(done);
        self.metrics.usage.tick(kv_frac, queue_frac, crate::usage::backlog_frac(pending));
        self.metrics.usage.saturation()
    }

    /// The load-derived `Retry-After` hint, in whole seconds (≥ 1):
    /// the floor while the server has headroom, climbing toward the
    /// configured ceiling as saturation approaches 1.0. The gateway
    /// stamps this on 429 and queue-full 503 responses.
    pub fn retry_after_s(&self) -> u64 {
        self.saturation().retry_after_s
    }

    /// JSON usage report for `/debug/usage` (all tenants) or
    /// `/debug/usage/<tenant>`. `None` for an unknown tenant.
    pub fn usage_json(&self, tenant: Option<&str>) -> Option<crate::util::json::Json> {
        // refresh the saturation window first so the embedded scores
        // reflect the live gauges, not the last scheduler tick
        let _ = self.saturation();
        if let Some(t) = tenant {
            // a registered-but-idle tenant reports zeros, not 404
            if self.store.contains(t) {
                let _ = self.metrics.usage.tenant(t);
            }
        }
        self.metrics.usage.snapshot_json(tenant)
    }

    /// Number of quarantined tenants (the `deltadq_tenant_quarantined`
    /// metrics gauge).
    pub fn quarantined_count(&self) -> usize {
        self.store.quarantined_count()
    }

    /// If `tenant` is quarantined, the suggested client retry interval.
    pub fn quarantined(&self, tenant: &str) -> Option<Duration> {
        self.store.quarantined(tenant)
    }

    /// Residency snapshot (tenant, hot?, requests served).
    pub fn residency(&self) -> Vec<(String, bool, u64)> {
        self.store.snapshot()
    }

    /// Three-tier residency snapshot (tenant, tier, requests served).
    pub fn tier_residency(&self) -> Vec<(String, Tier, u64)> {
        self.store.tier_snapshot()
    }

    /// Drain queues and stop workers.
    pub fn shutdown(mut self) {
        self.batcher.close();
        // drop the audit channel's sender so the audit thread's recv
        // hangs up once queued jobs drain (it is joined with the rest)
        self.metrics.audit.disconnect();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// The legacy run-to-completion worker loop: pop a whole tenant batch,
/// run every request in it to completion, repeat. Still the execution
/// path for backends without the stepping API (pjrt) and the baseline
/// the `decode` bench compares the scheduler against.
fn worker_loop(
    store: &TenantStore,
    batcher: &Batcher,
    metrics: &Metrics,
    backend: &dyn ExecutionBackend,
) {
    while let Some((tenant, batch)) = batcher.next_batch() {
        let exec_start = Instant::now();
        let usage = metrics.usage.tenant(&tenant);
        let Some(acquired) = store.acquire(&tenant, batch.len() as u64) else {
            // tenant vanished or its hydration failed — answer the batch
            // with an error instead of leaving callers to time out
            for req in batch {
                metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
                req.respond.send_done(Response {
                    id: req.id,
                    tenant: tenant.clone(),
                    tokens: Vec::new(),
                    queue_wait: exec_start.duration_since(req.submitted),
                    total: req.submitted.elapsed(),
                    served_hot: false,
                    error: Some(format!("tenant '{tenant}' unavailable")),
                });
            }
            continue;
        };
        if acquired.promoted {
            metrics.promotions.fetch_add(1, Ordering::Relaxed);
        }
        metrics.evictions.fetch_add(acquired.evicted as u64, Ordering::Relaxed);
        let served_hot = matches!(acquired.view, TenantView::Hot(_));
        for req in batch {
            // deadline check before execution (the legacy loop cannot
            // interrupt a running generation, so expiry is only
            // enforced between requests here — the scheduler path
            // enforces it per iteration)
            if req.deadline.is_some_and(|d| Instant::now() >= d) {
                metrics.sched.deadline_expired_total.fetch_add(1, Ordering::Relaxed);
                metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
                req.respond.send_done(Response {
                    id: req.id,
                    tenant: tenant.clone(),
                    tokens: Vec::new(),
                    queue_wait: exec_start.duration_since(req.submitted),
                    total: req.submitted.elapsed(),
                    served_hot: false,
                    error: Some("deadline exceeded".to_string()),
                });
                continue;
            }
            let queue_wait = exec_start.duration_since(req.submitted);
            metrics.observe_queue_wait(queue_wait.as_secs_f64());
            if let Some(u) = &usage {
                u.add_queue_wait(queue_wait);
                u.tokens_in.fetch_add(req.prompt.len() as u64, Ordering::Relaxed);
            }
            // tokens flow to streaming sinks as they decode (batch
            // sinks ignore them); the decode loop is the same either
            // way, so streamed tokens are bit-identical to batch ones
            let sink = &req.respond;
            let mut on_token = |t: u32| {
                sink.send_token(t);
            };
            let result = match &acquired.view {
                // Hot: merged dense weights, no delta term.
                TenantView::Hot(weights) => backend.generate_stream(
                    weights.as_ref(),
                    None,
                    &req.prompt,
                    req.max_new,
                    Some(vocab::EOS),
                    &mut on_token,
                ),
                // Cold: separate computation over the compressed deltas
                // (the native backend's fused sparse path).
                TenantView::Cold(deltas) => backend.generate_stream(
                    store.base().as_ref(),
                    Some(deltas.as_ref()),
                    &req.prompt,
                    req.max_new,
                    Some(vocab::EOS),
                    &mut on_token,
                ),
            };
            let (tokens, error) = match result {
                Ok(tokens) => (tokens, None),
                Err(e) => {
                    metrics.backend_errors.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "backend '{}' failed for tenant '{tenant}' request {}: {e:#}",
                        backend.name(),
                        req.id
                    );
                    (Vec::new(), Some(format!("{e:#}")))
                }
            };
            metrics.tokens_generated.fetch_add(tokens.len() as u64, Ordering::Relaxed);
            metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
            if let Some(u) = &usage {
                u.tokens_out.fetch_add(tokens.len() as u64, Ordering::Relaxed);
            }
            // shadow-audit sampling: one atomic bump; clones only the
            // sampled 1-in-N request
            if error.is_none() {
                metrics.audit.offer(&tenant, &req.prompt, &tokens);
            }
            let total = req.submitted.elapsed();
            metrics.observe_latency(total.as_secs_f64());
            req.respond.send_done(Response {
                id: req.id,
                tenant: tenant.clone(),
                tokens,
                queue_wait,
                total,
                served_hot,
                error,
            });
        }
        let batch_wall = exec_start.elapsed();
        metrics.observe_batch_exec(batch_wall.as_secs_f64());
        metrics.batches_executed.fetch_add(1, Ordering::Relaxed);
        // whole-batch attribution: one tenant per legacy batch, and the
        // batch wall also accrues the global exec denominator so the
        // conservation invariant (Σ tenant compute ≈ exec wall) holds
        // on this path too — per worker thread, resource-seconds
        metrics.usage.add_exec_wall(batch_wall);
        if let Some(u) = &usage {
            u.add_compute(batch_wall);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::model::ModelConfig;
    use crate::tensor::{Matrix, Pcg64};

    fn base() -> Arc<ModelWeights> {
        let mut rng = Pcg64::seeded(1);
        Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng))
    }

    fn delta_set(seed: u64) -> DeltaSet {
        let mut rng = Pcg64::seeded(seed);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(8.0, Some(16)));
        let c = ModelConfig::tiny();
        let mut set = DeltaSet::new("DeltaDQ", 8.0);
        for name in c.delta_tensor_names() {
            let shape = if name.contains("mlp.gate") || name.contains("mlp.up") {
                (c.ffn_hidden, c.hidden)
            } else if name.contains("mlp.down") {
                (c.hidden, c.ffn_hidden)
            } else {
                (c.hidden, c.hidden)
            };
            let d = Matrix::randn(shape.0, shape.1, 0.002, &mut rng);
            set.tensors
                .insert(name.clone(), dq.compress(&d, &LayerContext::data_free(0, &name), &mut rng));
        }
        set
    }

    #[test]
    fn serves_requests_end_to_end() {
        let server = Server::start(base(), ServerOptions {
            workers: 2,
            batch_window: Duration::from_millis(1),
            ..Default::default()
        });
        server.register_tenant("math", delta_set(2));
        let mut rxs = Vec::new();
        for _ in 0..8 {
            rxs.push(server.submit("math", vec![1, 20, 4, 21, 3], 4).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.tokens.len() <= 4);
            assert_eq!(resp.tenant, "math");
        }
        assert_eq!(server.metrics.requests_completed.load(Ordering::Relaxed), 8);
        server.shutdown();
    }

    #[test]
    fn streamed_tokens_match_final_response() {
        let server = Server::start(base(), ServerOptions {
            workers: 1,
            batch_window: Duration::from_millis(0),
            ..Default::default()
        });
        server.register_tenant("t", delta_set(5));
        let prompt = vec![1u32, 20, 4, 21, 3];
        let batch = server
            .submit("t", prompt.clone(), 6)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        let rx = server.submit_stream("t", prompt, 6).unwrap();
        let mut streamed = Vec::new();
        let mut done = None;
        loop {
            match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
                StreamEvent::Token(t) => streamed.push(t),
                StreamEvent::Done(resp) => {
                    done = Some(resp);
                    break;
                }
            }
        }
        let done = done.unwrap();
        assert_eq!(streamed, done.tokens, "events concatenate to the final response");
        assert_eq!(streamed, batch.tokens, "streamed == batch-submitted tokens");
        assert!(done.error.is_none());
        server.shutdown();
    }

    #[test]
    fn scheduler_and_legacy_loop_stream_identical_tokens() {
        // the pinned core contract of the scheduler redesign: identical
        // single requests produce bit-identical streamed tokens on the
        // iteration-level path and the run-to-completion path
        let b = base();
        let set = delta_set(7);
        let prompt = vec![1u32, 20, 4, 21, 3];
        let collect = |server: &Server| -> Vec<u32> {
            let rx = server.submit_stream("t", prompt.clone(), 6).unwrap();
            let mut tokens = Vec::new();
            loop {
                match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
                    StreamEvent::Token(t) => tokens.push(t),
                    StreamEvent::Done(resp) => {
                        assert!(resp.error.is_none(), "{:?}", resp.error);
                        assert_eq!(resp.tokens, tokens);
                        return tokens;
                    }
                }
            }
        };

        let sched_server = Server::start(b.clone(), ServerOptions::default());
        assert!(sched_server.sched_stats().is_some(), "scheduler drives by default");
        sched_server.register_tenant("t", set.clone());
        let stepped = collect(&sched_server);
        let stats = sched_server.sched_stats().unwrap();
        assert!(stats.kv_blocks_total > 0);
        sched_server.shutdown();

        let legacy_server = Server::start(b, ServerOptions { sched: None, ..Default::default() });
        assert!(legacy_server.sched_stats().is_none());
        legacy_server.register_tenant("t", set);
        let legacy = collect(&legacy_server);
        legacy_server.shutdown();

        assert_eq!(stepped, legacy, "scheduler == run-to-completion, bit for bit");
    }

    #[test]
    fn scheduler_frees_all_kv_blocks_when_done() {
        let server = Server::start(base(), ServerOptions {
            batch_window: Duration::from_millis(0),
            ..Default::default()
        });
        server.register_tenant("t", delta_set(8));
        let mut rxs = Vec::new();
        for _ in 0..6 {
            rxs.push(server.submit("t", vec![1, 20, 4, 21, 3], 4).unwrap());
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(30)).unwrap();
        }
        // the drive loop publishes gauges on its next idle tick
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let stats = server.sched_stats().unwrap();
            if stats.kv_blocks_used == 0 && stats.running == 0 {
                assert_eq!(stats.kv_blocks_free, stats.kv_blocks_total);
                break;
            }
            assert!(Instant::now() < deadline, "kv blocks leaked: {stats:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        server.shutdown();
    }

    #[test]
    fn unknown_tenant_rejected_and_counted() {
        let server = Server::start(base(), ServerOptions::default());
        assert!(server.submit("ghost", vec![1], 2).is_err());
        assert_eq!(server.metrics.requests_rejected.load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    #[test]
    fn promotion_happens_under_load() {
        let server = Server::start(base(), ServerOptions {
            promote_after: 4,
            workers: 1,
            batch_window: Duration::from_millis(1),
            ..Default::default()
        });
        server.register_tenant("t", delta_set(3));
        let mut rxs = Vec::new();
        for _ in 0..12 {
            rxs.push(server.submit("t", vec![1, 20, 4, 21, 3], 2).unwrap());
        }
        let responses: Vec<Response> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap())
            .collect();
        assert!(responses.iter().any(|r| r.served_hot), "later requests hot");
        assert!(server.metrics.promotions.load(Ordering::Relaxed) >= 1);
        let residency = server.residency();
        assert!(residency.iter().any(|(_, hot, _)| *hot));
        server.shutdown();
    }

    #[test]
    fn hot_and_cold_agree_on_output() {
        // the same prompt must decode identically via separate
        // computation and via the dense cache (determinism check)
        let b = base();
        let set = delta_set(4);
        let prompt = vec![1u32, 20, 4, 21, 3];

        let cold_server = Server::start(b.clone(), ServerOptions {
            promote_after: u64::MAX, // never promote
            workers: 1,
            batch_window: Duration::from_millis(0),
            ..Default::default()
        });
        cold_server.register_tenant("t", set.clone());
        let cold = cold_server
            .submit("t", prompt.clone(), 6)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        assert!(!cold.served_hot);
        cold_server.shutdown();

        let hot_server = Server::start(b, ServerOptions {
            promote_after: 1,
            workers: 1,
            batch_window: Duration::from_millis(0),
            ..Default::default()
        });
        hot_server.register_tenant("t", set);
        let hot = hot_server
            .submit("t", prompt, 6)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        assert!(hot.served_hot);
        hot_server.shutdown();

        assert_eq!(cold.tokens, hot.tokens, "separate computation == merged");
    }

    #[test]
    fn explicit_backend_matches_default_bit_for_bit() {
        // every fused output element is computed independently, so the
        // row-parallel backend must reproduce the default exactly
        let b = base();
        let set = delta_set(9);
        let prompt = vec![1u32, 20, 4, 21, 3];
        let opts = ServerOptions {
            promote_after: u64::MAX,
            workers: 1,
            batch_window: Duration::from_millis(0),
            ..Default::default()
        };
        let default_server = Server::start(b.clone(), opts.clone());
        assert_eq!(default_server.backend_name(), "native");
        default_server.register_tenant("t", set.clone());
        let d = default_server
            .submit("t", prompt.clone(), 6)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        default_server.shutdown();

        let threaded_server = Server::with_backend(
            b,
            opts,
            Arc::new(crate::runtime::NativeBackend::new(3)),
        );
        threaded_server.register_tenant("t", set);
        let t = threaded_server
            .submit("t", prompt, 6)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        threaded_server.shutdown();
        assert_eq!(d.tokens, t.tokens);
    }

    #[test]
    fn multi_tenant_isolation() {
        // different tenants produce different outputs for the same prompt
        let server = Server::start(base(), ServerOptions {
            workers: 2,
            batch_window: Duration::from_millis(0),
            ..Default::default()
        });
        server.register_tenant("a", delta_set(10));
        server.register_tenant("b", delta_set(11));
        let prompt = vec![1u32, 30, 4, 40, 3];
        let ra = server
            .submit("a", prompt.clone(), 8)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        let rb = server
            .submit("b", prompt, 8)
            .unwrap()
            .recv_timeout(Duration::from_secs(30))
            .unwrap();
        // deltas differ; outputs will almost surely differ
        assert_ne!(ra.tokens, rb.tokens);
        server.shutdown();
    }
}
