//! Serving-side tenant store: per-tenant compressed deltas with
//! Hot/Cold residency, Arc-shared so worker threads execute without
//! holding the store lock, and an LRU dense-cache budget.
//!
//! (The library-level [`crate::delta::registry::DeltaRegistry`] is the
//! offline-facing registry; this store is the same idea optimized for
//! concurrent serving.)

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::delta::format::DeltaSet;
use crate::model::weights::ModelWeights;

/// Execution view handed to a worker: everything needed to run one
/// tenant's requests without any store locks.
#[derive(Clone)]
pub enum TenantView {
    /// Dense `W_b + Δ` cache — one matmul per linear layer.
    Hot(Arc<ModelWeights>),
    /// Compressed deltas — separate computation per linear layer.
    Cold(Arc<DeltaSet>),
}

struct TenantSlot {
    deltas: Arc<DeltaSet>,
    dense: Option<Arc<ModelWeights>>,
    last_used: u64,
    requests: u64,
}

/// Thread-safe tenant store with promotion policy and byte budget.
pub struct TenantStore {
    base: Arc<ModelWeights>,
    slots: Mutex<BTreeMap<String, TenantSlot>>,
    clock: AtomicU64,
    /// Dense-cache byte budget (None = unbounded).
    cache_budget: Option<u64>,
    /// Promote a tenant to Hot once it has served this many requests.
    pub promote_after: u64,
}

/// Outcome of an acquire: the view plus whether a promotion/evictions
/// happened (for metrics).
pub struct Acquired {
    pub view: TenantView,
    pub promoted: bool,
    pub evicted: usize,
}

impl TenantStore {
    pub fn new(
        base: Arc<ModelWeights>,
        cache_budget: Option<u64>,
        promote_after: u64,
    ) -> TenantStore {
        TenantStore {
            base,
            slots: Mutex::new(BTreeMap::new()),
            clock: AtomicU64::new(0),
            cache_budget,
            promote_after,
        }
    }

    pub fn base(&self) -> &Arc<ModelWeights> {
        &self.base
    }

    pub fn register(&self, tenant: &str, deltas: DeltaSet) {
        let clock = self.clock.fetch_add(1, Ordering::Relaxed);
        self.slots.lock().unwrap().insert(
            tenant.to_string(),
            TenantSlot { deltas: Arc::new(deltas), dense: None, last_used: clock, requests: 0 },
        );
    }

    pub fn tenants(&self) -> Vec<String> {
        self.slots.lock().unwrap().keys().cloned().collect()
    }

    pub fn contains(&self, tenant: &str) -> bool {
        self.slots.lock().unwrap().contains_key(tenant)
    }

    /// Total dense-cache bytes (under lock).
    fn cache_bytes_locked(slots: &BTreeMap<String, TenantSlot>) -> u64 {
        slots
            .values()
            .filter_map(|s| s.dense.as_ref())
            .map(|w| w.param_count() as u64 * 4)
            .sum()
    }

    /// Acquire an execution view for `batch_size` requests, applying the
    /// promotion policy. Returns `None` for unknown tenants.
    pub fn acquire(&self, tenant: &str, batch_size: u64) -> Option<Acquired> {
        let clock = self.clock.fetch_add(1, Ordering::Relaxed);
        let mut slots = self.slots.lock().unwrap();
        // policy decision under lock (cheap), materialization outside
        let slot = slots.get_mut(tenant)?;
        slot.last_used = clock;
        slot.requests += batch_size;
        if let Some(dense) = &slot.dense {
            return Some(Acquired { view: TenantView::Hot(dense.clone()), promoted: false, evicted: 0 });
        }
        let should_promote = slot.requests >= self.promote_after;
        let deltas = slot.deltas.clone();
        if !should_promote {
            return Some(Acquired { view: TenantView::Cold(deltas), promoted: false, evicted: 0 });
        }
        drop(slots);

        // Materialize W_b + Δ outside the lock (the expensive part).
        let mut dense = (*self.base).clone();
        for (name, delta) in &deltas.tensors {
            delta.add_to_dense(dense.get_mut(name), 1.0);
        }
        let dense = Arc::new(dense);
        let new_bytes = dense.param_count() as u64 * 4;

        let mut slots = self.slots.lock().unwrap();
        let mut evicted = 0usize;
        if let Some(budget) = self.cache_budget {
            if new_bytes > budget {
                // can never fit: stay cold
                return Some(Acquired { view: TenantView::Cold(deltas), promoted: false, evicted });
            }
            while Self::cache_bytes_locked(&slots) + new_bytes > budget {
                let victim = slots
                    .iter()
                    .filter(|(id, s)| s.dense.is_some() && id.as_str() != tenant)
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(id, _)| id.clone());
                match victim {
                    Some(v) => {
                        slots.get_mut(&v).unwrap().dense = None;
                        evicted += 1;
                    }
                    None => break,
                }
            }
        }
        if let Some(slot) = slots.get_mut(tenant) {
            slot.dense = Some(dense.clone());
        }
        Some(Acquired { view: TenantView::Hot(dense), promoted: true, evicted })
    }

    /// Residency snapshot for reporting: (tenant, hot?, requests).
    pub fn snapshot(&self) -> Vec<(String, bool, u64)> {
        self.slots
            .lock()
            .unwrap()
            .iter()
            .map(|(id, s)| (id.clone(), s.dense.is_some(), s.requests))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::model::ModelConfig;
    use crate::tensor::{Matrix, Pcg64};

    fn base() -> Arc<ModelWeights> {
        let mut rng = Pcg64::seeded(1);
        Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng))
    }

    fn deltas(seed: u64) -> DeltaSet {
        let mut rng = Pcg64::seeded(seed);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(8.0, Some(16)));
        let c = ModelConfig::tiny();
        let mut set = DeltaSet::new("DeltaDQ", 8.0);
        for name in c.delta_tensor_names() {
            let shape = if name.contains("mlp.gate") || name.contains("mlp.up") {
                (c.ffn_hidden, c.hidden)
            } else if name.contains("mlp.down") {
                (c.hidden, c.ffn_hidden)
            } else {
                (c.hidden, c.hidden)
            };
            let d = Matrix::randn(shape.0, shape.1, 0.002, &mut rng);
            set.tensors
                .insert(name.clone(), dq.compress(&d, &LayerContext::data_free(0, &name), &mut rng));
        }
        set
    }

    #[test]
    fn cold_until_promote_threshold() {
        let store = TenantStore::new(base(), None, 4);
        store.register("t", deltas(2));
        let a = store.acquire("t", 1).unwrap();
        assert!(matches!(a.view, TenantView::Cold(_)));
        let a = store.acquire("t", 2).unwrap();
        assert!(matches!(a.view, TenantView::Cold(_)));
        // cumulative 3 + 1 >= 4 → promote
        let a = store.acquire("t", 1).unwrap();
        assert!(a.promoted);
        assert!(matches!(a.view, TenantView::Hot(_)));
        // stays hot
        let a = store.acquire("t", 1).unwrap();
        assert!(!a.promoted);
        assert!(matches!(a.view, TenantView::Hot(_)));
    }

    #[test]
    fn unknown_tenant_is_none() {
        let store = TenantStore::new(base(), None, 1);
        assert!(store.acquire("nope", 1).is_none());
    }

    #[test]
    fn budget_evicts_lru_hot_tenant() {
        let b = base();
        let one = b.param_count() as u64 * 4;
        let store = TenantStore::new(b, Some(one + 1024), 1);
        store.register("a", deltas(3));
        store.register("b", deltas(4));
        let r = store.acquire("a", 1).unwrap();
        assert!(r.promoted);
        let r = store.acquire("b", 1).unwrap();
        assert!(r.promoted);
        assert_eq!(r.evicted, 1, "budget fits one cache; a must be evicted");
        let snap = store.snapshot();
        let hot: Vec<&str> = snap.iter().filter(|(_, h, _)| *h).map(|(id, _, _)| id.as_str()).collect();
        assert_eq!(hot, vec!["b"]);
    }

    #[test]
    fn hot_view_equals_base_plus_delta() {
        let b = base();
        let store = TenantStore::new(b.clone(), None, 1);
        let set = deltas(5);
        let name = "layers.0.attn.wq";
        let mut want = b.get(name).clone();
        set.tensors[name].add_to_dense(&mut want, 1.0);
        store.register("t", set);
        let a = store.acquire("t", 1).unwrap();
        match a.view {
            TenantView::Hot(w) => assert!(w.get(name).allclose(&want, 1e-6, 0.0)),
            TenantView::Cold(_) => panic!("expected hot"),
        }
    }

    #[test]
    fn concurrent_acquires_are_safe() {
        let store = Arc::new(TenantStore::new(base(), None, 8));
        store.register("t", deltas(6));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let store = store.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        let a = store.acquire("t", 1).unwrap();
                        match a.view {
                            TenantView::Hot(w) => assert!(w.param_count() > 0),
                            TenantView::Cold(d) => assert!(d.nnz() > 0),
                        }
                    }
                });
            }
        });
        let snap = store.snapshot();
        assert_eq!(snap[0].2, 80);
    }
}
