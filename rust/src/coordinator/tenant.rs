//! Serving-side tenant store: three-tier residency over an optional
//! on-disk [`DeltaStore`].
//!
//! ```text
//!   Disk  — manifest entry only; zero RAM           (store tier)
//!   Cold  — compressed DeltaSet resident            (delta_budget)
//!   Hot   — dense W_b+Δ cache materialized          (cache_budget)
//! ```
//!
//! Disk→Cold hydration is performed by one background loader thread: a
//! worker that acquires a Disk tenant enqueues a hydration request and
//! blocks on a condvar *for that tenant only* — other workers keep
//! serving resident tenants, and registration/removal (`push`, store
//! `gc`) do their file I/O outside the slot lock so they never stall
//! the worker loop. Cold→Disk demotion happens inside the loader under
//! `delta_budget` (LRU, only tenants with a disk copy); Hot→Cold
//! eviction stays on the promotion path under `cache_budget` (LRU).
//!
//! (The library-level [`crate::delta::registry::DeltaRegistry`] is the
//! offline-facing registry; this store is the same idea optimized for
//! concurrent serving.)

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::delta::format::DeltaSet;
use crate::model::weights::ModelWeights;
use crate::store::DeltaStore;

/// Residency tier of one tenant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Manifest entry only — hydrated on first request.
    Disk,
    /// Compressed deltas resident; requests run separate computation.
    Cold,
    /// Dense `W_b + Δ` cache resident; requests run one matmul.
    Hot,
}

/// Tier-transition counters, shared between the tenant store (writer)
/// and [`crate::coordinator::Metrics`] (reader) so the metrics snapshot
/// reports storage behavior without a second source of truth.
#[derive(Debug, Default)]
pub struct TierCounters {
    /// Disk→Cold hydrations performed by the loader thread.
    pub disk_loads: AtomicU64,
    /// Cold→Disk demotions under `delta_budget`.
    pub demotions: AtomicU64,
    /// Shard payload bytes read from the store.
    pub store_bytes_read: AtomicU64,
    /// Hydration load attempts retried after a failure (in-cycle
    /// backoff retries on the loader thread).
    pub load_retries: AtomicU64,
}

/// Disk→Cold load-failure containment policy: bounded in-cycle retries
/// with exponential backoff, a cooldown between failed cycles so
/// request threads can never hot-loop a dead artifact, and a per-tenant
/// quarantine (probed by the loader thread, not request threads) once
/// failures persist.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Re-attempts after the first failed load within one hydration
    /// cycle (exponential backoff between attempts).
    pub load_retries: u32,
    /// Backoff before the first in-cycle retry; doubles per retry, and
    /// seeds the between-cycle cooldown (doubling per failed cycle).
    pub backoff: Duration,
    /// Consecutive failed hydration cycles before the tenant is
    /// quarantined.
    pub quarantine_after: u32,
    /// How often the loader thread probes quarantined tenants (also the
    /// `Retry-After` hint surfaced to clients).
    pub probe_interval: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            load_retries: 2,
            backoff: Duration::from_millis(50),
            quarantine_after: 3,
            probe_interval: Duration::from_secs(2),
        }
    }
}

/// Execution view handed to a worker: everything needed to run one
/// tenant's requests without any store locks.
#[derive(Clone)]
pub enum TenantView {
    /// Dense `W_b + Δ` cache — one matmul per linear layer.
    Hot(Arc<ModelWeights>),
    /// Compressed deltas — separate computation per linear layer.
    Cold(Arc<DeltaSet>),
}

/// Per-slot load-failure containment state (guarded by the slots
/// lock). This replaces the old consumed-by-one-waiter `failed` flag,
/// which made a dead artifact immediately retriable by every next
/// request — a hot retry storm from request threads.
#[derive(Debug, Default)]
struct SlotHealth {
    /// Consecutive failed hydration cycles (one cycle = a loader
    /// attempt including its bounded in-cycle retries). Reset to 0 by
    /// any successful load or a fresh `push`/`register`.
    fail_cycles: u32,
    /// Quarantined: request threads never trigger loads; only the
    /// loader thread's background probe retries, and clients see
    /// 503 + `Retry-After` at the gateway.
    quarantined: bool,
    /// Cooldown gate: no new hydration cycle may start before this.
    retry_at: Option<Instant>,
}

impl SlotHealth {
    fn in_cooldown(&self, now: Instant) -> bool {
        self.retry_at.is_some_and(|t| t > now)
    }
}

struct TenantSlot {
    /// `None` = Disk tier (hydrated on demand; requires `on_disk`).
    deltas: Option<Arc<DeltaSet>>,
    dense: Option<Arc<ModelWeights>>,
    /// The store holds a copy — demotable, and hydratable after demotion.
    on_disk: bool,
    /// A hydration request is queued or in flight.
    loading: bool,
    /// Load-failure containment state (backoff cooldown + quarantine).
    health: SlotHealth,
    last_used: u64,
    requests: u64,
}

impl TenantSlot {
    fn tier(&self) -> Tier {
        if self.dense.is_some() {
            Tier::Hot
        } else if self.deltas.is_some() {
            Tier::Cold
        } else {
            Tier::Disk
        }
    }

    /// Compressed resident bytes (0 while on Disk).
    fn cold_bytes(&self) -> u64 {
        self.deltas.as_ref().map(|d| d.storage_bits() / 8).unwrap_or(0)
    }
}

enum LoaderMsg {
    Hydrate(String),
    Shutdown,
}

struct Shared {
    base: Arc<ModelWeights>,
    slots: Mutex<BTreeMap<String, TenantSlot>>,
    /// Signals slot-state changes (hydration done/failed, removal).
    cv: Condvar,
    clock: AtomicU64,
    /// Dense-cache byte budget (None = unbounded).
    cache_budget: Option<u64>,
    /// Resident compressed-delta byte budget (None = unbounded).
    delta_budget: Option<u64>,
    /// Promote a tenant to Hot once it has served this many requests.
    promote_after: u64,
    store: Option<Arc<DeltaStore>>,
    tiers: Arc<TierCounters>,
    /// Hydration retry/backoff/quarantine policy.
    retry: RetryPolicy,
    /// Per-tenant usage ledger, attached by the server after
    /// construction ([`TenantStore::attach_usage`]) so the loader thread
    /// can attribute hydration I/O to the tenant that caused it.
    usage: Mutex<Option<Arc<crate::usage::UsageLedger>>>,
}

/// Thread-safe tenant store with tiered residency and byte budgets.
pub struct TenantStore {
    shared: Arc<Shared>,
    loader_tx: Option<Mutex<mpsc::Sender<LoaderMsg>>>,
    loader_handle: Mutex<Option<JoinHandle<()>>>,
}

/// Outcome of an acquire: the view plus whether a promotion/evictions
/// happened (for metrics) and whether the caller waited on hydration.
pub struct Acquired {
    /// The execution view (Hot dense weights or Cold compressed deltas).
    pub view: TenantView,
    /// Whether this acquire promoted the tenant Cold→Hot.
    pub promoted: bool,
    /// Hot entries evicted to make room for a promotion.
    pub evicted: usize,
    /// This acquire found the tenant on Disk and waited for the loader.
    pub hydrated: bool,
}

/// Result of a non-blocking residency probe ([`TenantStore::poke`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Poke {
    /// Resident (Cold or Hot): `acquire` will not wait on hydration.
    Ready,
    /// On Disk with a hydration queued/in flight — check back later.
    Pending,
    /// Unknown tenant, or a failed hydration cooling down — requests
    /// answer unavailable *without* re-arming the loader; the cooldown
    /// (not the next request) decides when hydration is retried.
    Missing,
    /// Quarantined after repeated failed hydration cycles: only the
    /// loader thread's background probe retries; the gateway answers
    /// 503 + `Retry-After`.
    Quarantined,
}

impl TenantStore {
    /// In-memory store (no disk tier): every registered tenant is at
    /// least Cold-resident forever.
    pub fn new(
        base: Arc<ModelWeights>,
        cache_budget: Option<u64>,
        promote_after: u64,
    ) -> TenantStore {
        TenantStore::build(base, cache_budget, None, promote_after, None, RetryPolicy::default())
    }

    /// Tiered store over an on-disk [`DeltaStore`]: tenants hydrate
    /// Disk→Cold on first request (background loader thread) and demote
    /// Cold→Disk under `delta_budget`.
    pub fn with_disk(
        base: Arc<ModelWeights>,
        cache_budget: Option<u64>,
        delta_budget: Option<u64>,
        promote_after: u64,
        store: Arc<DeltaStore>,
    ) -> TenantStore {
        TenantStore::build(
            base,
            cache_budget,
            delta_budget,
            promote_after,
            Some(store),
            RetryPolicy::default(),
        )
    }

    /// As [`with_disk`](TenantStore::with_disk) with an explicit
    /// hydration retry/backoff/quarantine policy.
    pub fn with_disk_retry(
        base: Arc<ModelWeights>,
        cache_budget: Option<u64>,
        delta_budget: Option<u64>,
        promote_after: u64,
        store: Arc<DeltaStore>,
        retry: RetryPolicy,
    ) -> TenantStore {
        TenantStore::build(base, cache_budget, delta_budget, promote_after, Some(store), retry)
    }

    fn build(
        base: Arc<ModelWeights>,
        cache_budget: Option<u64>,
        delta_budget: Option<u64>,
        promote_after: u64,
        store: Option<Arc<DeltaStore>>,
        retry: RetryPolicy,
    ) -> TenantStore {
        let shared = Arc::new(Shared {
            base,
            slots: Mutex::new(BTreeMap::new()),
            cv: Condvar::new(),
            clock: AtomicU64::new(0),
            cache_budget,
            delta_budget,
            promote_after,
            store,
            tiers: Arc::new(TierCounters::default()),
            retry,
            usage: Mutex::new(None),
        });
        let (loader_tx, loader_handle) = match &shared.store {
            Some(_) => {
                let (tx, rx) = mpsc::channel();
                let shared2 = shared.clone();
                let handle = std::thread::Builder::new()
                    .name("deltastore-loader".to_string())
                    .spawn(move || loader_loop(&shared2, &rx))
                    .expect("spawn loader thread");
                (Some(Mutex::new(tx)), Some(handle))
            }
            None => (None, None),
        };
        TenantStore { shared, loader_tx, loader_handle: Mutex::new(loader_handle) }
    }

    /// The shared base model every tenant's delta applies to.
    pub fn base(&self) -> &Arc<ModelWeights> {
        &self.shared.base
    }

    /// The disk tier, if one is attached.
    pub fn store(&self) -> Option<&Arc<DeltaStore>> {
        self.shared.store.as_ref()
    }

    /// Tier-transition counters (shared with the metrics snapshot).
    pub fn tiers(&self) -> Arc<TierCounters> {
        self.shared.tiers.clone()
    }

    /// Attach the per-tenant usage ledger so the loader thread
    /// attributes hydration I/O (`store_bytes_read`, `hydrations`) to
    /// the tenant that caused it. Called once by the server at startup.
    pub fn attach_usage(&self, ledger: Arc<crate::usage::UsageLedger>) {
        *self.shared.usage.lock().unwrap() = Some(ledger);
    }

    /// Register (or replace) a tenant's compressed deltas in memory
    /// (Cold, never demotable to Disk — there is no disk copy).
    pub fn register(&self, tenant: &str, deltas: DeltaSet) {
        let clock = self.shared.clock.fetch_add(1, Ordering::Relaxed);
        let mut slots = self.shared.slots.lock().unwrap();
        slots.insert(
            tenant.to_string(),
            TenantSlot {
                deltas: Some(Arc::new(deltas)),
                dense: None,
                on_disk: false,
                loading: false,
                health: SlotHealth::default(),
                last_used: clock,
                requests: 0,
            },
        );
        drop(slots);
        self.shared.cv.notify_all();
    }

    /// Register a tenant that already lives in the store, without
    /// loading anything (Disk tier: manifest entry only).
    pub fn register_disk(&self, tenant: &str) -> Result<()> {
        let store = self.shared.store.as_ref().context("no delta store attached")?;
        if !store.contains(tenant) {
            bail!("tenant '{tenant}' is not in the store at {:?}", store.root());
        }
        let clock = self.shared.clock.fetch_add(1, Ordering::Relaxed);
        let mut slots = self.shared.slots.lock().unwrap();
        slots.insert(
            tenant.to_string(),
            TenantSlot {
                deltas: None,
                dense: None,
                on_disk: true,
                loading: false,
                health: SlotHealth::default(),
                last_used: clock,
                requests: 0,
            },
        );
        drop(slots);
        self.shared.cv.notify_all();
        Ok(())
    }

    /// Hot registration: persist the deltas to the store (file I/O —
    /// done before any slot lock is taken, so workers never stall),
    /// then register Cold-resident and demotable. Returns payload bytes
    /// written.
    pub fn push(&self, tenant: &str, deltas: DeltaSet) -> Result<u64> {
        let store = self.shared.store.as_ref().context("no delta store attached")?;
        let bytes = store.push(tenant, &deltas)?;
        let clock = self.shared.clock.fetch_add(1, Ordering::Relaxed);
        let mut slots = self.shared.slots.lock().unwrap();
        slots.insert(
            tenant.to_string(),
            TenantSlot {
                deltas: Some(Arc::new(deltas)),
                dense: None,
                on_disk: true,
                loading: false,
                health: SlotHealth::default(),
                last_used: clock,
                requests: 0,
            },
        );
        enforce_delta_budget(&self.shared, &mut slots, tenant);
        drop(slots);
        self.shared.cv.notify_all();
        Ok(bytes)
    }

    /// Hot removal: drop the slot (waiters wake and see it gone), then
    /// delete the on-disk artifact. Returns whether the tenant existed.
    pub fn remove(&self, tenant: &str) -> Result<bool> {
        let existed = {
            let mut slots = self.shared.slots.lock().unwrap();
            slots.remove(tenant).is_some()
        };
        self.shared.cv.notify_all();
        let on_store = match &self.shared.store {
            Some(store) => store.remove(tenant)?,
            None => false,
        };
        Ok(existed || on_store)
    }

    /// Registered tenant names, sorted.
    pub fn tenants(&self) -> Vec<String> {
        self.shared.slots.lock().unwrap().keys().cloned().collect()
    }

    /// Whether `tenant` is registered (any tier).
    pub fn contains(&self, tenant: &str) -> bool {
        self.shared.slots.lock().unwrap().contains_key(tenant)
    }

    /// Resident compressed bytes across Cold/Hot tenants.
    pub fn cold_bytes(&self) -> u64 {
        let slots = self.shared.slots.lock().unwrap();
        slots.values().map(|s| s.cold_bytes()).sum()
    }

    /// Total dense-cache bytes (under lock).
    fn cache_bytes_locked(slots: &BTreeMap<String, TenantSlot>) -> u64 {
        slots
            .values()
            .filter_map(|s| s.dense.as_ref())
            .map(|w| w.resident_bytes())
            .sum()
    }

    fn send_loader(&self, msg: LoaderMsg) -> Option<()> {
        let tx = self.loader_tx.as_ref()?;
        tx.lock().unwrap().send(msg).ok()
    }

    /// Non-blocking residency probe for iteration-level admission:
    /// reports whether [`acquire`](TenantStore::acquire) would return
    /// without waiting, kicking off the background hydration when the
    /// tenant is on Disk. The scheduler's single drive thread keeps
    /// decoding running sequences while a `Pending` tenant hydrates on
    /// the loader thread, instead of parking on the hydration condvar.
    pub fn poke(&self, tenant: &str) -> Poke {
        let mut slots = self.shared.slots.lock().unwrap();
        let Some(slot) = slots.get_mut(tenant) else {
            return Poke::Missing;
        };
        if slot.dense.is_some() || slot.deltas.is_some() {
            return Poke::Ready;
        }
        if slot.health.quarantined {
            return Poke::Quarantined;
        }
        if !slot.on_disk {
            return Poke::Missing; // unreachable: memory slots always hold deltas
        }
        if slot.loading {
            return Poke::Pending;
        }
        if slot.health.in_cooldown(Instant::now()) {
            // Failed recently: answer unavailable *without* re-arming the
            // loader. The cooldown expiring — not request pressure —
            // decides when the next hydration cycle starts.
            return Poke::Missing;
        }
        slot.loading = true;
        if self.send_loader(LoaderMsg::Hydrate(tenant.to_string())).is_none() {
            slot.loading = false;
            return Poke::Missing; // loader gone (shutdown)
        }
        Poke::Pending
    }

    /// Acquire an execution view for `batch_size` requests, applying
    /// the hydration + promotion policies. Returns `None` for unknown
    /// tenants and for tenants whose hydration failed (retried by the
    /// loader after the backoff cooldown, or by the background probe
    /// once quarantined — never by request threads).
    pub fn acquire(&self, tenant: &str, batch_size: u64) -> Option<Acquired> {
        let clock = self.shared.clock.fetch_add(1, Ordering::Relaxed);
        let mut slots = self.shared.slots.lock().unwrap();
        {
            let slot = slots.get_mut(tenant)?;
            slot.last_used = clock;
            slot.requests += batch_size;
        }
        let mut hydrated = false;
        let (deltas, should_promote) = loop {
            let slot = slots.get_mut(tenant)?;
            if let Some(dense) = &slot.dense {
                let view = TenantView::Hot(dense.clone());
                return Some(Acquired { view, promoted: false, evicted: 0, hydrated });
            }
            if let Some(deltas) = &slot.deltas {
                break (deltas.clone(), slot.requests >= self.shared.promote_after);
            }
            // Disk tier: queue a hydration (once) and wait for the
            // loader; other workers keep serving resident tenants. A
            // failed cycle parks the slot in cooldown (or quarantine),
            // so every waiter — and every subsequent request until the
            // cooldown expires — answers unavailable instead of
            // re-arming the loader in a hot retry storm.
            if slot.health.quarantined || slot.health.in_cooldown(Instant::now()) {
                return None; // hydration failing; error already logged
            }
            if !slot.loading {
                if !slot.on_disk {
                    return None; // unreachable: memory slots always hold deltas
                }
                slot.loading = true;
                if self.send_loader(LoaderMsg::Hydrate(tenant.to_string())).is_none() {
                    slot.loading = false;
                    return None; // loader gone (shutdown)
                }
            }
            hydrated = true;
            slots = self.shared.cv.wait(slots).unwrap();
        };
        if !should_promote {
            drop(slots);
            let view = TenantView::Cold(deltas);
            return Some(Acquired { view, promoted: false, evicted: 0, hydrated });
        }
        drop(slots);

        // Materialize W_b + Δ outside the lock (the expensive part).
        let mut dense = (*self.shared.base).clone();
        for (name, delta) in &deltas.tensors {
            delta.add_to_dense(dense.get_mut(name), 1.0);
        }
        let dense = Arc::new(dense);
        let new_bytes = dense.resident_bytes();

        let mut slots = self.shared.slots.lock().unwrap();
        let mut evicted = 0usize;
        if let Some(budget) = self.shared.cache_budget {
            if new_bytes > budget {
                // can never fit: stay cold
                let view = TenantView::Cold(deltas);
                return Some(Acquired { view, promoted: false, evicted, hydrated });
            }
            while Self::cache_bytes_locked(&slots) + new_bytes > budget {
                let victim = slots
                    .iter()
                    .filter(|(id, s)| s.dense.is_some() && id.as_str() != tenant)
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(id, _)| id.clone());
                match victim {
                    Some(v) => {
                        slots.get_mut(&v).unwrap().dense = None;
                        evicted += 1;
                    }
                    None => break,
                }
            }
        }
        if let Some(slot) = slots.get_mut(tenant) {
            slot.dense = Some(dense.clone());
        }
        Some(Acquired { view: TenantView::Hot(dense), promoted: true, evicted, hydrated })
    }

    /// Number of quarantined tenants (the `deltadq_tenant_quarantined`
    /// metrics gauge).
    pub fn quarantined_count(&self) -> usize {
        self.shared.slots.lock().unwrap().values().filter(|s| s.health.quarantined).count()
    }

    /// If `tenant` is quarantined, the suggested client retry interval
    /// (the background probe period, surfaced as `Retry-After`).
    pub fn quarantined(&self, tenant: &str) -> Option<Duration> {
        let slots = self.shared.slots.lock().unwrap();
        slots.get(tenant).filter(|s| s.health.quarantined).map(|_| self.shared.retry.probe_interval)
    }

    /// The tenant's resident compressed delta set, if any (Cold or Hot
    /// with deltas still resident). The audit thread reads this to
    /// shadow-compare what is actually serving; `None` for Disk tier.
    pub fn resident_deltas(&self, tenant: &str) -> Option<Arc<DeltaSet>> {
        self.shared.slots.lock().unwrap().get(tenant).and_then(|s| s.deltas.clone())
    }

    /// Route `tenant` into the quarantine lifecycle from outside the
    /// loader (the audit subsystem's drift enforcement). Drops resident
    /// deltas and dense cache so the background probe re-hydrates a
    /// fresh copy from the store — which is also why only tenants with
    /// a disk copy are quarantinable this way (no heal path otherwise).
    /// Returns whether the quarantine was applied.
    pub fn quarantine(&self, tenant: &str) -> bool {
        let mut slots = self.shared.slots.lock().unwrap();
        let Some(slot) = slots.get_mut(tenant) else {
            return false;
        };
        if !slot.on_disk {
            return false;
        }
        slot.deltas = None;
        slot.dense = None;
        slot.health.fail_cycles = self.shared.retry.quarantine_after;
        slot.health.quarantined = true;
        slot.health.retry_at = Some(Instant::now() + self.shared.retry.probe_interval);
        drop(slots);
        self.shared.cv.notify_all();
        true
    }

    /// Residency snapshot for reporting: (tenant, hot?, requests).
    pub fn snapshot(&self) -> Vec<(String, bool, u64)> {
        self.shared
            .slots
            .lock()
            .unwrap()
            .iter()
            .map(|(id, s)| (id.clone(), s.dense.is_some(), s.requests))
            .collect()
    }

    /// Three-tier residency snapshot: (tenant, tier, requests).
    pub fn tier_snapshot(&self) -> Vec<(String, Tier, u64)> {
        self.shared
            .slots
            .lock()
            .unwrap()
            .iter()
            .map(|(id, s)| (id.clone(), s.tier(), s.requests))
            .collect()
    }
}

impl Drop for TenantStore {
    fn drop(&mut self) {
        let _ = self.send_loader(LoaderMsg::Shutdown);
        if let Some(handle) = self.loader_handle.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

/// Demote LRU Cold tenants to Disk until the resident compressed bytes
/// fit `delta_budget`. Only tenants with a disk copy are demotable, and
/// `protect` (the tenant that triggered enforcement) is never demoted.
fn enforce_delta_budget(
    shared: &Shared,
    slots: &mut BTreeMap<String, TenantSlot>,
    protect: &str,
) {
    let Some(budget) = shared.delta_budget else {
        return;
    };
    // one O(tenants) sum up front, then subtract per victim — this runs
    // under the slots lock, so it must not rescan on every demotion
    let mut resident: u64 = slots.values().map(|s| s.cold_bytes()).sum();
    while resident > budget {
        let victim = slots
            .iter()
            .filter(|(id, s)| s.deltas.is_some() && s.on_disk && id.as_str() != protect)
            .min_by_key(|(_, s)| s.last_used)
            .map(|(id, _)| id.clone());
        match victim {
            Some(v) => {
                let slot = slots.get_mut(&v).unwrap();
                resident -= slot.cold_bytes();
                slot.deltas = None;
                shared.tiers.demotions.fetch_add(1, Ordering::Relaxed);
            }
            None => return, // nothing demotable left
        }
    }
}

/// The background loader/evictor: hydrates Disk→Cold on request (with
/// bounded in-cycle retries), applies `delta_budget` demotion after
/// each hydration, and — between messages — probes quarantined tenants
/// every `retry.probe_interval`. All file I/O happens with no slot
/// lock held.
fn loader_loop(shared: &Shared, rx: &mpsc::Receiver<LoaderMsg>) {
    let Some(store) = shared.store.as_ref() else {
        return; // never spawned without a store
    };
    loop {
        let tenant = match rx.recv_timeout(shared.retry.probe_interval) {
            Ok(LoaderMsg::Shutdown) => return,
            Ok(LoaderMsg::Hydrate(t)) => t,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                probe_quarantined(shared, store);
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        hydrate_one(shared, store, &tenant);
    }
}

/// One hydration cycle for `tenant`: bounded retries with exponential
/// backoff around the store load, then install-or-contain under the
/// slots lock. Runs on the loader thread only (hydration requests and
/// quarantine probes both funnel here).
fn hydrate_one(shared: &Shared, store: &DeltaStore, tenant: &str) {
    let needed = {
        let slots = shared.slots.lock().unwrap();
        matches!(slots.get(tenant), Some(s) if s.deltas.is_none() && s.dense.is_none())
    };
    if !needed {
        // slot vanished or was re-registered resident meanwhile
        let mut slots = shared.slots.lock().unwrap();
        if let Some(slot) = slots.get_mut(tenant) {
            slot.loading = false;
        }
        drop(slots);
        shared.cv.notify_all();
        return;
    }
    let disk_bytes = store.tenant_info(tenant).map(|r| r.bytes).unwrap_or(0);
    let loaded = {
        // tenant-scoped trace span: joins the span tree of every
        // request that overlaps this Disk→Cold hydration
        let mut span = crate::util::trace::span("tenant.hydrate");
        span.set_tenant(tenant);
        span.attr_u64("disk_bytes", disk_bytes);
        let loaded = load_with_retries(shared, store, tenant); // file I/O — no lock held
        span.attr_u64("ok", loaded.is_ok() as u64);
        loaded
    };
    let mut slots = shared.slots.lock().unwrap();
    // install only into a slot that still wants THIS hydration: a
    // concurrent push() may have replaced the slot with a fresh
    // resident artifact (loading = false), which must neither be
    // clobbered with the stale load nor marked failed by it.
    match (slots.get_mut(tenant), loaded) {
        (Some(slot), Ok(set)) if slot.loading && slot.deltas.is_none() => {
            // chaos hook: install a silently corrupted resident set
            // (256×-scaled densified deltas) while the store copy stays
            // pristine — the shadow audit must catch the divergence
            let set = if crate::util::failpoint::hit("tenant.corrupt_resident").is_err() {
                corrupt_delta_set(set)
            } else {
                set
            };
            slot.deltas = Some(Arc::new(set));
            slot.loading = false;
            slot.health = SlotHealth::default(); // served again: forgiven
            shared.tiers.disk_loads.fetch_add(1, Ordering::Relaxed);
            shared.tiers.store_bytes_read.fetch_add(disk_bytes, Ordering::Relaxed);
            let ledger = shared.usage.lock().unwrap().clone();
            if let Some(u) = ledger.and_then(|l| l.tenant(tenant)) {
                u.store_bytes_read.fetch_add(disk_bytes, Ordering::Relaxed);
                u.hydrations.fetch_add(1, Ordering::Relaxed);
            }
            enforce_delta_budget(shared, &mut slots, tenant);
        }
        (Some(slot), Err(e)) if slot.loading && slot.deltas.is_none() => {
            slot.loading = false;
            slot.health.fail_cycles += 1;
            if slot.health.fail_cycles >= shared.retry.quarantine_after {
                slot.health.quarantined = true;
                slot.health.retry_at = Some(Instant::now() + shared.retry.probe_interval);
                eprintln!(
                    "delta store: quarantining tenant '{tenant}' after {} failed \
                     hydration cycles: {e:#}",
                    slot.health.fail_cycles
                );
            } else {
                // between-cycle cooldown, doubling per failed cycle
                let factor = 2u32.saturating_pow(slot.health.fail_cycles.min(10));
                slot.health.retry_at = Some(Instant::now() + shared.retry.backoff * factor);
                eprintln!("delta store: hydrating tenant '{tenant}' failed: {e:#}");
            }
        }
        (Some(slot), _) => {
            slot.loading = false; // superseded by a racing register/push
        }
        (None, _) => {} // removed while loading
    }
    drop(slots);
    shared.cv.notify_all();
}

/// `store.load` wrapped in the in-cycle retry policy: up to
/// `retry.load_retries` re-attempts with doubling backoff, each retry
/// counted in [`TierCounters::load_retries`]. The `tenant.hydrate`
/// failpoint guards every attempt so chaos runs can inject transient
/// (retryable) and persistent (quarantining) load failures.
fn load_with_retries(shared: &Shared, store: &DeltaStore, tenant: &str) -> Result<DeltaSet> {
    let attempt =
        || crate::util::failpoint::hit("tenant.hydrate").and_then(|()| store.load(tenant));
    let mut last = match attempt() {
        Ok(set) => return Ok(set),
        Err(e) => e,
    };
    let mut backoff = shared.retry.backoff;
    for _ in 0..shared.retry.load_retries {
        std::thread::sleep(backoff);
        backoff *= 2;
        shared.tiers.load_retries.fetch_add(1, Ordering::Relaxed);
        match attempt() {
            Ok(set) => return Ok(set),
            Err(e) => last = e,
        }
    }
    Err(last)
}

/// The `tenant.corrupt_resident` chaos transform: every tensor becomes
/// a 256×-scaled dense copy — structurally valid (serving keeps
/// working), numerically wrong (shadow audits diverge). The scale is
/// deliberately overwhelming so the corrupted weights dominate the
/// model and greedy tokens are guaranteed to drift off the dense
/// reference. Mirrors a resident-memory bit-rot / bad-dequant class of
/// failure the store's CRCs cannot see.
fn corrupt_delta_set(mut set: DeltaSet) -> DeltaSet {
    for t in set.tensors.values_mut() {
        *t = crate::compress::CompressedDelta::Dense(t.to_dense().scaled(256.0));
    }
    set
}

/// Retry quarantined tenants from the loader thread — never from
/// request threads. Each tenant whose `retry_at` has passed gets one
/// fresh hydration cycle; success clears the quarantine, failure
/// re-arms `retry_at` for the next probe.
fn probe_quarantined(shared: &Shared, store: &DeltaStore) {
    let now = Instant::now();
    let due: Vec<String> = {
        let mut slots = shared.slots.lock().unwrap();
        let due: Vec<String> = slots
            .iter()
            .filter(|(_, s)| {
                s.health.quarantined
                    && !s.loading
                    && s.deltas.is_none()
                    && s.dense.is_none()
                    && !s.health.in_cooldown(now)
            })
            .map(|(id, _)| id.clone())
            .collect();
        for id in &due {
            slots.get_mut(id).expect("key from this map").loading = true;
        }
        due
    };
    for tenant in due {
        hydrate_one(shared, store, &tenant);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::model::ModelConfig;
    use crate::tensor::{Matrix, Pcg64};

    fn base() -> Arc<ModelWeights> {
        let mut rng = Pcg64::seeded(1);
        Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng))
    }

    fn deltas(seed: u64) -> DeltaSet {
        let mut rng = Pcg64::seeded(seed);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(8.0, Some(16)));
        let c = ModelConfig::tiny();
        let mut set = DeltaSet::new("DeltaDQ", 8.0);
        for name in c.delta_tensor_names() {
            let shape = if name.contains("mlp.gate") || name.contains("mlp.up") {
                (c.ffn_hidden, c.hidden)
            } else if name.contains("mlp.down") {
                (c.hidden, c.ffn_hidden)
            } else {
                (c.hidden, c.hidden)
            };
            let d = Matrix::randn(shape.0, shape.1, 0.002, &mut rng);
            set.tensors
                .insert(name.clone(), dq.compress(&d, &LayerContext::data_free(0, &name), &mut rng));
        }
        set
    }

    fn tmp_store(name: &str) -> Arc<DeltaStore> {
        let dir = std::env::temp_dir()
            .join("deltadq-test-tenantstore")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Arc::new(DeltaStore::open_or_create(&dir).unwrap())
    }

    #[test]
    fn cold_until_promote_threshold() {
        let store = TenantStore::new(base(), None, 4);
        store.register("t", deltas(2));
        let a = store.acquire("t", 1).unwrap();
        assert!(matches!(a.view, TenantView::Cold(_)));
        let a = store.acquire("t", 2).unwrap();
        assert!(matches!(a.view, TenantView::Cold(_)));
        // cumulative 3 + 1 >= 4 → promote
        let a = store.acquire("t", 1).unwrap();
        assert!(a.promoted);
        assert!(matches!(a.view, TenantView::Hot(_)));
        // stays hot
        let a = store.acquire("t", 1).unwrap();
        assert!(!a.promoted);
        assert!(matches!(a.view, TenantView::Hot(_)));
    }

    #[test]
    fn unknown_tenant_is_none() {
        let store = TenantStore::new(base(), None, 1);
        assert!(store.acquire("nope", 1).is_none());
    }

    #[test]
    fn budget_evicts_lru_hot_tenant() {
        let b = base();
        let one = b.resident_bytes();
        let store = TenantStore::new(b, Some(one + 1024), 1);
        store.register("a", deltas(3));
        store.register("b", deltas(4));
        let r = store.acquire("a", 1).unwrap();
        assert!(r.promoted);
        let r = store.acquire("b", 1).unwrap();
        assert!(r.promoted);
        assert_eq!(r.evicted, 1, "budget fits one cache; a must be evicted");
        let snap = store.snapshot();
        let hot: Vec<&str> = snap.iter().filter(|(_, h, _)| *h).map(|(id, _, _)| id.as_str()).collect();
        assert_eq!(hot, vec!["b"]);
    }

    /// Eviction *order* under pressure: the least-recently-used Hot
    /// tenant goes first, every time — not just "something was evicted".
    #[test]
    fn cache_budget_evicts_in_lru_order() {
        let b = base();
        let one = b.resident_bytes();
        // room for exactly two dense caches
        let store = TenantStore::new(b, Some(2 * one + 1024), 1);
        for (t, seed) in [("a", 5u64), ("b", 6), ("c", 7)] {
            store.register(t, deltas(seed));
        }
        assert_eq!(store.acquire("a", 1).unwrap().evicted, 0);
        assert_eq!(store.acquire("b", 1).unwrap().evicted, 0);
        // touch a → b becomes LRU → promoting c must evict b, not a
        store.acquire("a", 1).unwrap();
        let r = store.acquire("c", 1).unwrap();
        assert!(r.promoted);
        assert_eq!(r.evicted, 1);
        let hot_set = |store: &TenantStore| -> Vec<String> {
            let mut v: Vec<String> = store
                .snapshot()
                .into_iter()
                .filter(|(_, h, _)| *h)
                .map(|(id, _, _)| id)
                .collect();
            v.sort();
            v
        };
        assert_eq!(hot_set(&store), vec!["a".to_string(), "c".to_string()]);
        // now a is LRU (c was promoted after a's touch) → b's return
        // must evict a specifically
        let r = store.acquire("b", 1).unwrap();
        assert!(r.promoted);
        assert_eq!(r.evicted, 1);
        assert_eq!(hot_set(&store), vec!["b".to_string(), "c".to_string()]);
    }

    #[test]
    fn hot_view_equals_base_plus_delta() {
        let b = base();
        let store = TenantStore::new(b.clone(), None, 1);
        let set = deltas(5);
        let name = "layers.0.attn.wq";
        let mut want = b.get(name).clone();
        set.tensors[name].add_to_dense(&mut want, 1.0);
        store.register("t", set);
        let a = store.acquire("t", 1).unwrap();
        match a.view {
            TenantView::Hot(w) => assert!(w.get(name).allclose(&want, 1e-6, 0.0)),
            TenantView::Cold(_) => panic!("expected hot"),
        }
    }

    #[test]
    fn concurrent_acquires_are_safe() {
        let store = Arc::new(TenantStore::new(base(), None, 8));
        store.register("t", deltas(6));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let store = store.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        let a = store.acquire("t", 1).unwrap();
                        match a.view {
                            TenantView::Hot(w) => assert!(w.param_count() > 0),
                            TenantView::Cold(d) => assert!(d.nnz() > 0),
                        }
                    }
                });
            }
        });
        let snap = store.snapshot();
        assert_eq!(snap[0].2, 80);
    }

    #[test]
    fn poke_probes_residency_without_blocking() {
        let disk = tmp_store("poke");
        let store = TenantStore::with_disk(base(), None, None, u64::MAX, disk.clone());
        disk.push("t", &deltas(30)).unwrap();
        store.register_disk("t").unwrap();
        assert_eq!(store.poke("ghost"), Poke::Missing);
        // first probe kicks the loader; repeated probes don't re-enqueue
        let mut first = store.poke("t");
        assert_ne!(first, Poke::Missing);
        // loader hydrates in the background; Pending resolves to Ready
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while first == Poke::Pending {
            assert!(std::time::Instant::now() < deadline, "hydration never finished");
            std::thread::sleep(std::time::Duration::from_millis(2));
            first = store.poke("t");
        }
        assert_eq!(first, Poke::Ready);
        // now acquire is wait-free (already resident) and counts one load
        let a = store.acquire("t", 1).unwrap();
        assert!(matches!(a.view, TenantView::Cold(_)));
        assert_eq!(store.tiers().disk_loads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disk_tenant_hydrates_on_first_acquire() {
        let disk = tmp_store("hydrate");
        let store = TenantStore::with_disk(base(), None, None, u64::MAX, disk.clone());
        let set = deltas(7);
        disk.push("t", &set).unwrap();
        store.register_disk("t").unwrap();
        assert_eq!(store.tier_snapshot()[0].1, Tier::Disk);
        assert_eq!(store.cold_bytes(), 0);

        let a = store.acquire("t", 1).unwrap();
        assert!(a.hydrated, "first acquire pays the disk load");
        match &a.view {
            TenantView::Cold(d) => assert_eq!(d.nnz(), set.nnz()),
            TenantView::Hot(_) => panic!("promote_after = MAX"),
        }
        assert_eq!(store.tier_snapshot()[0].1, Tier::Cold);
        let t = store.tiers();
        assert_eq!(t.disk_loads.load(Ordering::Relaxed), 1);
        assert!(t.store_bytes_read.load(Ordering::Relaxed) > 0);

        // second acquire is resident — no further disk traffic
        let a = store.acquire("t", 1).unwrap();
        assert!(!a.hydrated);
        assert_eq!(t.disk_loads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn delta_budget_demotes_lru_to_disk() {
        let disk = tmp_store("demote");
        let sets: Vec<DeltaSet> = (0..3).map(|i| deltas(10 + i)).collect();
        let one = sets[0].storage_bits() / 8;
        // budget fits ~one resident tenant (sets are all the same shape)
        let store =
            TenantStore::with_disk(base(), None, Some(one + one / 2), u64::MAX, disk.clone());
        for (i, set) in sets.iter().enumerate() {
            disk.push(&format!("t{i}"), set).unwrap();
            store.register_disk(&format!("t{i}")).unwrap();
        }
        for i in 0..3 {
            let a = store.acquire(&format!("t{i}"), 1).unwrap();
            assert!(a.hydrated, "t{i} starts on disk");
        }
        let t = store.tiers();
        assert_eq!(t.disk_loads.load(Ordering::Relaxed), 3);
        assert!(t.demotions.load(Ordering::Relaxed) >= 2, "older tenants demoted");
        let resident: Vec<(String, Tier, u64)> = store
            .tier_snapshot()
            .into_iter()
            .filter(|(_, tier, _)| *tier != Tier::Disk)
            .collect();
        assert_eq!(resident.len(), 1, "budget admits one resident: {resident:?}");
        assert_eq!(resident[0].0, "t2", "LRU demoted first, newest stays");

        // a demoted tenant re-hydrates on demand (churn)
        let a = store.acquire("t0", 1).unwrap();
        assert!(a.hydrated);
        assert_eq!(t.disk_loads.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn push_is_resident_and_demotable_and_remove_wakes_waiters() {
        let disk = tmp_store("push");
        let store = TenantStore::with_disk(base(), None, None, u64::MAX, disk.clone());
        let bytes = store.push("t", deltas(20)).unwrap();
        assert!(bytes > 0);
        assert!(disk.contains("t"), "push persisted the artifact");
        assert_eq!(store.tier_snapshot()[0].1, Tier::Cold, "push registers resident");
        let a = store.acquire("t", 1).unwrap();
        assert!(!a.hydrated, "already resident — no disk wait");
        assert!(store.remove("t").unwrap());
        assert!(!disk.contains("t"));
        assert!(store.acquire("t", 1).is_none());
        assert!(!store.remove("t").unwrap());
    }

    #[test]
    fn failed_hydration_surfaces_as_unavailable() {
        let disk = tmp_store("fail");
        let store = TenantStore::with_disk(base(), None, None, u64::MAX, disk.clone());
        disk.push("t", &deltas(21)).unwrap();
        store.register_disk("t").unwrap();
        // destroy the artifact behind the manifest's back
        let info = disk.tenant_info("t").unwrap();
        for rel in &info.shards {
            std::fs::remove_file(disk.root().join(rel)).unwrap();
        }
        assert!(store.acquire("t", 1).is_none(), "hydration failure → unavailable");
        // the slot survives; a later push makes the tenant servable again
        store.push("t", deltas(21)).unwrap();
        assert!(store.acquire("t", 1).is_some());
    }

    /// Full containment lifecycle: failed cycles → cooldown (requests
    /// do NOT re-arm the loader) → quarantine → background probe heals
    /// the tenant once the artifact is restored.
    #[test]
    fn repeated_failures_quarantine_and_probe_heals() {
        let disk = tmp_store("quarantine");
        let retry = RetryPolicy {
            load_retries: 0,
            backoff: Duration::from_millis(100),
            quarantine_after: 2,
            probe_interval: Duration::from_millis(50),
        };
        let store = TenantStore::with_disk_retry(base(), None, None, u64::MAX, disk.clone(), retry);
        disk.push("t", &deltas(22)).unwrap();
        store.register_disk("t").unwrap();
        // destroy the artifact behind the manifest's back, keeping the
        // bytes around so the probe can heal it later
        let info = disk.tenant_info("t").unwrap();
        let saved: Vec<(std::path::PathBuf, Vec<u8>)> = info
            .shards
            .iter()
            .map(|rel| {
                let path = disk.root().join(rel);
                let bytes = std::fs::read(&path).unwrap();
                std::fs::remove_file(&path).unwrap();
                (path, bytes)
            })
            .collect();

        // cycle 1: fails → cooldown; waiters answer unavailable
        assert!(store.acquire("t", 1).is_none());
        assert_eq!(store.poke("t"), Poke::Missing, "cooldown: poke must not re-arm the loader");
        assert_eq!(store.quarantined_count(), 0);

        // cycle 2 (after cooldown): fails → quarantined
        let deadline = Instant::now() + Duration::from_secs(30);
        while store.quarantined_count() == 0 {
            assert!(Instant::now() < deadline, "never quarantined");
            let _ = store.acquire("t", 1); // None until quarantine engages
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(store.poke("t"), Poke::Quarantined);
        assert!(store.quarantined("t").is_some(), "retry-after hint exposed");
        assert!(store.acquire("t", 1).is_none(), "quarantined: no request-thread loads");

        // restore the artifact; the loader's probe — not a request —
        // brings the tenant back
        for (path, bytes) in &saved {
            std::fs::write(path, bytes).unwrap();
        }
        while store.poke("t") != Poke::Ready {
            assert!(Instant::now() < deadline, "probe never healed the tenant");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(store.quarantined_count(), 0);
        assert!(store.acquire("t", 1).is_some(), "serves again after the probe clears it");
        assert!(
            store.tiers().load_retries.load(Ordering::Relaxed) == 0,
            "load_retries counts in-cycle retries only (policy had none)"
        );
    }
}
