//! L3 serving coordinator (S10): multi-tenant request routing, dynamic
//! batching, Disk/Cold/Hot tenant residency over the delta store, and
//! the demo-server driver used by `deltadq serve`.
//!
//! Architecture (vLLM-router-like, adapted to delta serving):
//!
//! ```text
//!   submit() ─▶ Batcher (per-tenant FIFO queues, bounded)
//!                 │  oldest-head-first admission, FCFS across tenants
//!                 ▼
//!   scheduler ──▶ TenantStore.acquire()  (Hot dense cache | Cold
//!   drive loop  │  compressed deltas → separate computation |
//!   (sched::)   │  Disk → loader thread hydrates from DeltaStore)
//!                 ▼
//!   per-decode-step mixed-tenant batches over the paged KV block
//!   pool (admission control + preemption) ─▶ token stream / final
//!   Response channel, Metrics
//! ```
//!
//! Backends without the stepping API (pjrt), or servers built with
//! `ServerOptions { sched: None, .. }`, fall back to the legacy
//! run-to-completion worker pool — same tokens, bit for bit.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod tenant;

pub use batcher::{Batcher, ReplySink, Request, Response, StreamEvent, SubmitError};
pub use metrics::Metrics;
pub use server::{Server, ServerOptions};
pub use tenant::{Poke, RetryPolicy, TenantStore, TenantView, Tier, TierCounters};

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::delta::format::load_delta_set;
use crate::eval::tasks::{gen_dataset, TaskKind};
use crate::model::load_weights;
use crate::store::DeltaStore;
use crate::tensor::Pcg64;

/// Load a server from artifacts (`base.dqw` + `<tenant>.ddq` per
/// tenant); tenants without a `.ddq` fall back to an on-the-fly
/// DeltaDQ compression of their `.dqw` fine-tune if present. The
/// execution backend is resolved from `serve.backend`
/// ("native" | "pjrt").
///
/// With `[store] path` configured, the server runs tiered: every tenant
/// already in the store starts at Disk (manifest entry only, hydrated
/// on first request, resident set bounded by `delta_budget_mib`), and
/// requested tenants *not* yet in the store are compressed/loaded once
/// and pushed — so the next launch serves them straight from the store.
pub fn load_server(serve: &ServeConfig, tenants: &[String]) -> Result<Server> {
    if let Some(spec) = &serve.failpoints {
        // config-armed fault injection ([`crate::util::failpoint`]) —
        // same grammar as the DELTADQ_FAILPOINTS env var
        crate::util::failpoint::arm(spec)?;
    }
    // flight-recorder knobs ([trace]) apply process-wide before the
    // first request can open a span
    crate::util::trace::set_enabled(serve.trace_enabled);
    crate::util::trace::configure(serve.trace_ring_spans);
    crate::util::trace::set_flight_window(serve.trace_flight_window_s);
    let dir = Path::new(&serve.artifacts_dir);
    let scale_dir = dir.join(&serve.model);
    let base_path = scale_dir.join("base.dqw");
    let base = Arc::new(
        load_weights(&base_path).with_context(|| format!("loading {base_path:?}"))?,
    );
    let options = ServerOptions {
        max_batch: serve.max_batch,
        batch_window: Duration::from_micros(serve.batch_window_us),
        queue_depth: serve.queue_depth,
        workers: serve.workers,
        cache_budget: if serve.cache_budget_mib == 0 {
            None
        } else {
            Some(serve.cache_budget_mib * 1024 * 1024)
        },
        delta_budget: if serve.delta_budget_mib == 0 {
            None
        } else {
            Some(serve.delta_budget_mib * 1024 * 1024)
        },
        promote_after: 8,
        sched: if serve.sched_enabled {
            Some(crate::sched::SchedOptions {
                kv_pool_bytes: serve.sched_kv_pool_mib.max(1) * 1024 * 1024,
                block_size: serve.sched_block_size,
                max_running: serve.sched_max_running,
                prefill_chunk: serve.sched_prefill_chunk,
                step_exec: Default::default(),
            })
        } else {
            None
        },
        request_ttl: if serve.request_ttl_ms == 0 {
            None
        } else {
            Some(Duration::from_millis(serve.request_ttl_ms))
        },
        retry: RetryPolicy {
            load_retries: serve.load_retries as u32,
            backoff: Duration::from_millis(serve.load_backoff_ms),
            quarantine_after: (serve.quarantine_after as u32).max(1),
            probe_interval: Duration::from_millis(serve.probe_interval_ms.max(1)),
        },
        audit: serve.audit_config(),
        usage: serve.usage_config(),
    };
    let backend = crate::runtime::backend_from_name(&serve.backend, serve)?;
    let delta_store = match &serve.store_path {
        Some(path) => Some(Arc::new(DeltaStore::open_or_create(Path::new(path))?)),
        None => None,
    };
    let server = match &delta_store {
        Some(store) => Server::with_store(base.clone(), options, backend, store.clone())?,
        None => Server::with_backend(base.clone(), options, backend),
    };
    for tenant in tenants {
        if server.tenants().iter().any(|t| t == tenant) {
            continue; // already registered from the store manifest
        }
        let ddq = scale_dir.join(format!("{tenant}.ddq"));
        let set = if ddq.exists() {
            load_delta_set(&ddq)?
        } else {
            // compress on the fly from the fine-tuned weights
            let dqw = scale_dir.join(format!("{tenant}.dqw"));
            let ft = load_weights(&dqw)
                .with_context(|| format!("tenant '{tenant}': no .ddq and no {dqw:?}"))?;
            let deltas = crate::delta::extract_deltas(&base, &ft);
            let dq = crate::compress::DeltaDq::new(
                crate::compress::DeltaDqConfig::with_quant(8.0, Some(16), 8, 1),
            );
            let mut rng = Pcg64::seeded(7);
            crate::compress::pipeline::compress_model_deltas(
                &deltas,
                &dq,
                &Default::default(),
                &mut rng,
            )
        };
        if delta_store.is_some() {
            server.push_tenant(tenant, set)?;
        } else {
            server.register_tenant(tenant, set);
        }
    }
    Ok(server)
}

/// `deltadq serve`: drive the coordinator with a Poisson open-loop
/// request stream across tenants and print a throughput/latency report.
pub fn run_demo_server(
    serve: &ServeConfig,
    tenants_csv: &str,
    total_requests: usize,
    rate_per_sec: f64,
) -> Result<()> {
    let tenants: Vec<String> = tenants_csv.split(',').map(|s| s.trim().to_string()).collect();
    let server = load_server(serve, &tenants)?;
    println!(
        "serving {} tenants on '{}' preset via '{}' backend: {:?}",
        tenants.len(),
        serve.model,
        server.backend_name(),
        server.tenants()
    );

    let mut rng = Pcg64::seeded(99);
    let prompts: Vec<(String, Vec<u32>)> = {
        let mut v = Vec::new();
        for tenant in &tenants {
            let task = TaskKind::parse(tenant).unwrap_or(TaskKind::Math);
            for s in gen_dataset(task, total_requests / tenants.len() + 1, 5) {
                v.push((tenant.clone(), s.prompt));
            }
        }
        v
    };

    let start = Instant::now();
    let mut receivers = Vec::new();
    for i in 0..total_requests {
        let (tenant, prompt) = &prompts[i % prompts.len()];
        // open-loop Poisson arrivals
        let dt = rng.exponential(rate_per_sec);
        std::thread::sleep(Duration::from_secs_f64(dt.min(0.05)));
        match server.submit(tenant, prompt.clone(), 8) {
            Ok(rx) => receivers.push(rx),
            Err(e) => eprintln!("rejected: {e}"),
        }
    }
    let mut hot = 0usize;
    for rx in receivers {
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)) {
            if resp.served_hot {
                hot += 1;
            }
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    let m = &server.metrics;
    let completed = m.requests_completed.load(std::sync::atomic::Ordering::Relaxed);
    println!("--- serving report ---");
    println!("requests: {completed} completed, {hot} served hot");
    println!("throughput: {:.1} req/s", completed as f64 / elapsed);
    println!(
        "latency: mean {:.2}ms p50 {:.2}ms p99 {:.2}ms",
        m.mean_latency() * 1e3,
        m.latency_percentile(50.0) * 1e3,
        m.latency_percentile(99.0) * 1e3
    );
    println!("residency: {:?}", server.tier_residency());
    println!("metrics: {}", m.snapshot().to_string());
    server.shutdown();
    Ok(())
}
