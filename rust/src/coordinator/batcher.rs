//! Request types and the per-tenant dynamic batcher.
//!
//! Requests are routed into per-tenant FIFO queues (bounded →
//! backpressure). Workers pull *tenant batches*: the batcher picks the
//! tenant with the oldest head-of-line request (FIFO-fair across
//! tenants, like vLLM's FCFS default), then holds the batch open for up
//! to `batch_window` to let more same-tenant requests join — batching
//! is per tenant because the whole point of the deployment scheme is
//! that each tenant shares one (base, Δ) weight pair.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One generation request.
#[derive(Debug)]
pub struct Request {
    /// Server-assigned request id (monotonic).
    pub id: u64,
    /// Tenant the request is addressed to.
    pub tenant: String,
    /// Prompt token ids.
    pub prompt: Vec<u32>,
    /// Max tokens to generate.
    pub max_new: usize,
    /// When the request entered the queue.
    pub submitted: Instant,
    /// Absolute deadline (TTL resolved at submission): past this
    /// instant the request is terminated with a "deadline exceeded"
    /// error frame — at admission, or mid-decode with its KV blocks
    /// freed. `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Channel(s) the worker answers on — final-only or per-token.
    pub respond: ReplySink,
}

/// How a request wants to be answered: one final [`Response`], or a
/// live token stream followed by the final response. Dropping the sink
/// (e.g. `remove_tenant` dropping a queue) closes the receiver either
/// way, so waiting callers observe a disconnect, never a hang.
#[derive(Debug)]
pub enum ReplySink {
    /// Final-only responder — the original `submit()` contract.
    Batch(mpsc::Sender<Response>),
    /// Per-token streaming responder (`submit_stream()`): one
    /// [`StreamEvent::Token`] per decoded token as it decodes, then
    /// exactly one [`StreamEvent::Done`] carrying the same final
    /// [`Response`] the batch path would have produced.
    Stream(mpsc::Sender<StreamEvent>),
}

impl ReplySink {
    /// Emit one decoded token (no-op on the batch sink). Returns
    /// whether the receiver is still listening: `false` means a
    /// streaming client vanished — the iteration-level scheduler uses
    /// that to cancel the sequence and free its KV blocks, while the
    /// legacy run-to-completion loop ignores it (generation runs to
    /// completion so batch accounting stays identical).
    pub fn send_token(&self, token: u32) -> bool {
        match self {
            ReplySink::Stream(tx) => tx.send(StreamEvent::Token(token)).is_ok(),
            ReplySink::Batch(_) => true,
        }
    }

    /// Deliver the final response on either sink flavor. Also closes
    /// the request's root trace span *before* the send, so by the time
    /// the caller observes the response its span tree is fully
    /// assembled and queryable at `/debug/trace/<id>`.
    pub fn send_done(&self, response: Response) {
        crate::util::trace::end_request(response.id, response.error.as_deref());
        match self {
            ReplySink::Batch(tx) => {
                let _ = tx.send(response);
            }
            ReplySink::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(response));
            }
        }
    }
}

/// One event on a streaming response channel.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// The next generated token, emitted the moment it decodes.
    Token(u32),
    /// Terminal event: the full [`Response`] (its `tokens` equal the
    /// concatenation of every preceding `Token` event).
    Done(Response),
}

/// One generation response.
#[derive(Debug, Clone)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// The tenant that served it.
    pub tenant: String,
    /// Generated token ids.
    pub tokens: Vec<u32>,
    /// Time spent queued before pickup.
    pub queue_wait: Duration,
    /// Submission-to-completion wall time.
    pub total: Duration,
    /// Whether the tenant was Hot (dense cache) when executed.
    pub served_hot: bool,
    /// Execution-backend failure, if any (`tokens` is empty then —
    /// distinguishable from a legitimate immediate-EOS generation).
    pub error: Option<String>,
}

/// Submission failure modes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Per-tenant queue full — caller should back off.
    Backpressure { tenant: String, depth: usize },
    /// Tenant not registered.
    UnknownTenant(String),
    /// Tenant quarantined after repeated hydration failures; retried by
    /// the loader's background probe. Clients should retry after
    /// `retry_after_s` (the gateway maps this to 503 + `Retry-After`).
    Quarantined {
        /// The quarantined tenant.
        tenant: String,
        /// Suggested client retry interval, in whole seconds (≥ 1).
        retry_after_s: u64,
    },
    /// Batcher shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure { tenant, depth } => {
                write!(f, "tenant '{tenant}' queue full (depth {depth})")
            }
            SubmitError::UnknownTenant(t) => write!(f, "unknown tenant '{t}'"),
            SubmitError::Quarantined { tenant, retry_after_s } => {
                write!(f, "tenant '{tenant}' quarantined (retry after {retry_after_s}s)")
            }
            SubmitError::Closed => write!(f, "batcher closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Inner {
    queues: BTreeMap<String, VecDeque<Request>>,
    closed: bool,
}

/// Per-tenant dynamic batcher.
pub struct Batcher {
    inner: Mutex<Inner>,
    cv: Condvar,
    /// Max requests per tenant batch.
    pub max_batch: usize,
    /// How long a batch is held open for same-tenant joiners.
    pub batch_window: Duration,
    /// Per-tenant queue bound (beyond → backpressure).
    pub queue_depth: usize,
}

impl Batcher {
    /// Batcher with the given batch size, window, and queue bound
    /// (each clamped to at least 1 where zero makes no sense).
    pub fn new(max_batch: usize, batch_window: Duration, queue_depth: usize) -> Batcher {
        Batcher {
            inner: Mutex::new(Inner { queues: BTreeMap::new(), closed: false }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
            batch_window,
            queue_depth: queue_depth.max(1),
        }
    }

    /// Declare a tenant (creates its queue).
    pub fn add_tenant(&self, tenant: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.queues.entry(tenant.to_string()).or_default();
    }

    /// Drop a tenant's queue. Queued requests are dropped with it —
    /// their response senders close, so waiting callers see a
    /// disconnect immediately instead of a timeout. Later submissions
    /// get `UnknownTenant`.
    pub fn remove_tenant(&self, tenant: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.queues.remove(tenant);
        drop(inner);
        self.cv.notify_all();
    }

    /// Enqueue a request.
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(SubmitError::Closed);
        }
        let Some(q) = inner.queues.get_mut(&req.tenant) else {
            return Err(SubmitError::UnknownTenant(req.tenant.clone()));
        };
        if q.len() >= self.queue_depth {
            return Err(SubmitError::Backpressure {
                tenant: req.tenant.clone(),
                depth: self.queue_depth,
            });
        }
        q.push_back(req);
        drop(inner);
        self.cv.notify_all();
        Ok(())
    }

    /// Total queued requests (all tenants).
    pub fn queued(&self) -> usize {
        self.inner.lock().unwrap().queues.values().map(|q| q.len()).sum()
    }

    /// Aggregate admission capacity: `queue_depth` × the number of
    /// registered tenant queues (at least one, so `queued() / capacity`
    /// is a well-defined fill fraction even before tenants register).
    /// The saturation engine's queue axis.
    pub fn queue_capacity(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        self.queue_depth * inner.queues.len().max(1)
    }

    /// Queue depth per tenant (the `/metrics` per-tenant gauge).
    pub fn queue_depths(&self) -> Vec<(String, usize)> {
        let inner = self.inner.lock().unwrap();
        inner.queues.iter().map(|(t, q)| (t.clone(), q.len())).collect()
    }

    /// Submission time of the oldest head-of-line request across all
    /// tenant queues (the scheduler's FCFS admission probe).
    pub fn oldest_submitted(&self) -> Option<Instant> {
        let inner = self.inner.lock().unwrap();
        inner.queues.values().filter_map(|q| q.front().map(|r| r.submitted)).min()
    }

    /// Pop the single oldest head-of-line request across tenants —
    /// iteration-level admission (no batch window: the scheduler admits
    /// whenever a slot and KV blocks are free).
    pub fn pop_oldest(&self) -> Option<Request> {
        let mut inner = self.inner.lock().unwrap();
        let tenant = inner
            .queues
            .iter()
            .filter_map(|(t, q)| q.front().map(|r| (t.clone(), r.submitted)))
            .min_by_key(|(_, at)| *at)?
            .0;
        inner.queues.get_mut(&tenant).unwrap().pop_front()
    }

    /// Put a request back at the *front* of its tenant queue (the
    /// scheduler's head-of-line wait when the KV pool can't fit it
    /// yet). Returns false — dropping the request, which disconnects
    /// its caller — if the tenant was removed meanwhile. May hold the
    /// queue one past `queue_depth` transiently; `submit` still bounds
    /// what callers can add.
    pub fn requeue_front(&self, req: Request) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.queues.get_mut(&req.tenant) {
            Some(q) => {
                q.push_front(req);
                true
            }
            None => false,
        }
    }

    /// Park until a request is queued, the batcher closes, or `timeout`
    /// elapses. Returns `false` only when the batcher is closed *and*
    /// every queue is drained — the scheduler's exit condition.
    pub fn wait_for_work(&self, timeout: Duration) -> bool {
        let inner = self.inner.lock().unwrap();
        if inner.queues.values().any(|q| !q.is_empty()) {
            return true;
        }
        if inner.closed {
            return false;
        }
        let (inner, _timeout) = self.cv.wait_timeout(inner, timeout).unwrap();
        !(inner.closed && inner.queues.values().all(|q| q.is_empty()))
    }

    /// Pull the next tenant batch. Blocks until work arrives or the
    /// batcher closes (then returns `None` once all queues drain).
    pub fn next_batch(&self) -> Option<(String, Vec<Request>)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            // pick the tenant whose head request is oldest
            let pick = inner
                .queues
                .iter()
                .filter_map(|(t, q)| q.front().map(|r| (t.clone(), r.submitted)))
                .min_by_key(|(_, at)| *at);
            match pick {
                Some((tenant, head_at)) => {
                    let q_len = inner.queues[&tenant].len();
                    let age = head_at.elapsed();
                    if q_len < self.max_batch && age < self.batch_window {
                        // hold the batch open for stragglers
                        let wait = self.batch_window - age;
                        let (guard, _timeout) = self.cv.wait_timeout(inner, wait).unwrap();
                        inner = guard;
                        continue;
                    }
                    let q = inner.queues.get_mut(&tenant).unwrap();
                    let n = q.len().min(self.max_batch);
                    let batch: Vec<Request> = q.drain(..n).collect();
                    return Some((tenant, batch));
                }
                None if inner.closed => return None,
                None => {
                    inner = self.cv.wait(inner).unwrap();
                }
            }
        }
    }

    /// Shut down: wake all workers; `next_batch` drains then returns None.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(tenant: &str, id: u64) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                tenant: tenant.into(),
                prompt: vec![1, 2, 3],
                max_new: 4,
                submitted: Instant::now(),
                deadline: None,
                respond: ReplySink::Batch(tx),
            },
            rx,
        )
    }

    #[test]
    fn batches_same_tenant_together() {
        let b = Batcher::new(4, Duration::from_millis(5), 16);
        b.add_tenant("a");
        for i in 0..4 {
            let (r, _rx) = req("a", i);
            b.submit(r).unwrap();
        }
        let (tenant, batch) = b.next_batch().unwrap();
        assert_eq!(tenant, "a");
        assert_eq!(batch.len(), 4);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn window_flushes_partial_batches() {
        let b = Batcher::new(8, Duration::from_millis(10), 16);
        b.add_tenant("a");
        let (r, _rx) = req("a", 0);
        b.submit(r).unwrap();
        let t0 = Instant::now();
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(8), "waited the window");
    }

    #[test]
    fn oldest_head_wins_across_tenants() {
        let b = Batcher::new(4, Duration::from_millis(0), 16);
        b.add_tenant("a");
        b.add_tenant("z");
        let (r1, _rx1) = req("z", 1);
        b.submit(r1).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let (r2, _rx2) = req("a", 2);
        b.submit(r2).unwrap();
        let (tenant, _) = b.next_batch().unwrap();
        assert_eq!(tenant, "z", "z submitted first");
        let (tenant, _) = b.next_batch().unwrap();
        assert_eq!(tenant, "a");
    }

    #[test]
    fn backpressure_on_full_queue() {
        let b = Batcher::new(4, Duration::from_millis(1), 2);
        b.add_tenant("a");
        let (r1, _x1) = req("a", 1);
        let (r2, _x2) = req("a", 2);
        let (r3, _x3) = req("a", 3);
        b.submit(r1).unwrap();
        b.submit(r2).unwrap();
        match b.submit(r3) {
            Err(SubmitError::Backpressure { depth, .. }) => assert_eq!(depth, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn full_queue_tenant_does_not_starve_others() {
        // one tenant floods its queue to the depth limit and refills it
        // the instant a batch drains; a quiet tenant's single request
        // must still be served promptly. Oldest-head-first guarantees
        // it: after the flood's standing head drains, the quiet head is
        // the oldest request in the system.
        let depth = 4;
        let b = Batcher::new(2, Duration::from_millis(0), depth);
        b.add_tenant("flood");
        b.add_tenant("quiet");
        let mut next_id = 0u64;
        let mut rxs = Vec::new(); // keep senders' receivers alive
        let mut fill = |b: &Batcher, rxs: &mut Vec<mpsc::Receiver<Response>>| loop {
            next_id += 1;
            let (r, rx) = req("flood", next_id);
            match b.submit(r) {
                Ok(()) => rxs.push(rx),
                Err(SubmitError::Backpressure { .. }) => break,
                Err(e) => panic!("{e}"),
            }
        };
        fill(&b, &mut rxs);
        std::thread::sleep(Duration::from_millis(2));
        let (rq, _rxq) = req("quiet", 1000);
        b.submit(rq).unwrap();

        let mut quiet_after = None;
        for batch_no in 0..8 {
            let (tenant, batch) = b.next_batch().unwrap();
            if tenant == "quiet" {
                assert_eq!(batch[0].id, 1000);
                quiet_after = Some(batch_no);
                break;
            }
            // sustained overload: top the flood queue back up to depth
            fill(&b, &mut rxs);
        }
        let quiet_after = quiet_after.expect("quiet tenant starved under flood");
        // the flood requests already queued ahead of the quiet one are
        // legitimately older (depth 4 / max_batch 2 → two batches);
        // everything the flood refills afterwards is younger, so the
        // quiet head must be picked the moment the backlog drains.
        assert!(
            quiet_after <= 2,
            "quiet served at batch {quiet_after}, expected right after the standing backlog"
        );
    }

    #[test]
    fn unknown_tenant_rejected() {
        let b = Batcher::new(4, Duration::from_millis(1), 4);
        let (r, _rx) = req("ghost", 1);
        assert_eq!(b.submit(r).unwrap_err(), SubmitError::UnknownTenant("ghost".into()));
    }

    #[test]
    fn remove_tenant_rejects_and_disconnects() {
        let b = Batcher::new(4, Duration::from_millis(50), 16);
        b.add_tenant("a");
        let (r, rx) = req("a", 1);
        b.submit(r).unwrap();
        b.remove_tenant("a");
        // queued request's sender dropped with the queue → disconnect
        assert!(matches!(rx.try_recv(), Err(mpsc::TryRecvError::Disconnected)));
        // later submissions are unknown, not silently queued
        let (r2, _rx2) = req("a", 2);
        assert_eq!(b.submit(r2).unwrap_err(), SubmitError::UnknownTenant("a".into()));
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn close_drains_then_stops() {
        let b = Batcher::new(4, Duration::from_millis(0), 16);
        b.add_tenant("a");
        let (r, _rx) = req("a", 1);
        b.submit(r).unwrap();
        b.close();
        assert!(b.next_batch().is_some(), "queued work still served");
        assert!(b.next_batch().is_none(), "then shutdown");
        // submissions after close fail
        let (r2, _rx2) = req("a", 2);
        assert_eq!(b.submit(r2).unwrap_err(), SubmitError::Closed);
    }

    #[test]
    fn pop_oldest_is_fcfs_across_tenants_and_requeue_restores_head() {
        let b = Batcher::new(4, Duration::from_millis(0), 16);
        b.add_tenant("a");
        b.add_tenant("z");
        let (r1, _rx1) = req("z", 1);
        b.submit(r1).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        let (r2, _rx2) = req("a", 2);
        b.submit(r2).unwrap();
        assert!(b.oldest_submitted().is_some());
        let first = b.pop_oldest().unwrap();
        assert_eq!(first.id, 1, "z submitted first");
        // head-of-line wait: put it back, it must come out first again
        assert!(b.requeue_front(first));
        assert_eq!(b.pop_oldest().unwrap().id, 1);
        assert_eq!(b.pop_oldest().unwrap().id, 2);
        assert!(b.pop_oldest().is_none());
        assert!(b.oldest_submitted().is_none());
        // requeue into a removed tenant drops the request
        b.remove_tenant("a");
        let (r3, rx3) = req("a", 3);
        assert!(!b.requeue_front(r3));
        assert!(matches!(rx3.try_recv(), Err(mpsc::TryRecvError::Disconnected)));
    }

    #[test]
    fn queue_depths_per_tenant() {
        let b = Batcher::new(4, Duration::from_millis(0), 16);
        b.add_tenant("a");
        b.add_tenant("b");
        let (r1, _rx1) = req("a", 1);
        let (r2, _rx2) = req("a", 2);
        b.submit(r1).unwrap();
        b.submit(r2).unwrap();
        let depths = b.queue_depths();
        assert_eq!(depths, vec![("a".to_string(), 2), ("b".to_string(), 0)]);
    }

    #[test]
    fn queue_capacity_scales_with_tenants() {
        let b = Batcher::new(4, Duration::from_millis(0), 16);
        assert_eq!(b.queue_capacity(), 16, "no tenants yet: one nominal queue");
        b.add_tenant("a");
        b.add_tenant("b");
        assert_eq!(b.queue_capacity(), 32);
        b.remove_tenant("b");
        assert_eq!(b.queue_capacity(), 16);
    }

    #[test]
    fn wait_for_work_reports_close_and_drain() {
        let b = Batcher::new(4, Duration::from_millis(0), 16);
        b.add_tenant("a");
        // empty + open: times out but stays alive
        assert!(b.wait_for_work(Duration::from_millis(1)));
        let (r, _rx) = req("a", 1);
        b.submit(r).unwrap();
        b.close();
        assert!(b.wait_for_work(Duration::from_millis(1)), "queued work still served");
        b.pop_oldest().unwrap();
        assert!(!b.wait_for_work(Duration::from_millis(1)), "closed and drained");
    }

    #[test]
    fn blocking_worker_wakes_on_submit() {
        let b = std::sync::Arc::new(Batcher::new(2, Duration::from_millis(0), 8));
        b.add_tenant("a");
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(10));
        let (r, _rx) = req("a", 7);
        b.submit(r).unwrap();
        let got = h.join().unwrap();
        assert_eq!(got.unwrap().1[0].id, 7);
    }
}
