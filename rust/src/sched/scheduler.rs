//! The iteration-level scheduler drive loop: per-decode-step batching
//! with FCFS admission, KV-pool admission control, and preemption of
//! the youngest sequence when the pool runs dry.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, Request, Response};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::tenant::{Poke, TenantStore, TenantView};
use crate::eval::tasks::vocab;
use crate::runtime::ExecutionBackend;
use crate::sched::block::{BlockPool, PagedKvCache};
use crate::sched::SchedOptions;
use crate::tensor::ops;
use crate::tensor::Matrix;

/// How long the drive loop parks when it has nothing running and
/// nothing queued (also the gauge refresh cadence while idle).
const IDLE_WAIT: Duration = Duration::from_millis(5);

/// Where a running slot is within its lifecycle after a step.
enum SeqState {
    Active,
    /// Answered (normally or with an error); blocks already freed.
    Done,
    /// Pushed back to the waiting set; blocks freed, resumes by
    /// re-prefilling prompt + generated.
    Preempted,
    /// Stream receiver vanished mid-generation; blocks freed.
    Cancelled,
}

/// One admitted sequence: the request plus everything needed to decode
/// it one step at a time.
struct Sequence {
    req: Request,
    view: TenantView,
    served_hot: bool,
    cache: PagedKvCache,
    generated: Vec<u32>,
    /// `None` → needs (re)prefill; `Some` → ready for a decode slot.
    last_logits: Option<Matrix>,
    /// Wait from submission to first admission (reported queue_wait).
    queue_wait: Duration,
    /// Monotonic admission stamp — the preemption victim is the
    /// sequence with the largest (youngest) stamp.
    admission: u64,
    state: SeqState,
}

impl Sequence {
    /// Tokens that must be cached before the next decode: prompt plus
    /// everything generated so far.
    fn prefix_len(&self) -> usize {
        self.req.prompt.len() + self.generated.len()
    }
}

/// The plan for one scheduler iteration: which running slots run a
/// prefill and which run a single decode step. Mixed tenants share one
/// step batch — that is the whole point.
pub struct StepBatch {
    pub prefill: Vec<usize>,
    pub decode: Vec<usize>,
}

impl StepBatch {
    /// Sequences touched by this step.
    pub fn occupancy(&self) -> usize {
        self.prefill.len() + self.decode.len()
    }
}

/// Drive the coordinator with iteration-level scheduling until the
/// batcher closes and drains. Spawned by `Server` in place of the
/// run-to-completion worker pool when the backend supports stepping.
pub fn drive_loop(
    store: &TenantStore,
    batcher: &Batcher,
    metrics: &Metrics,
    backend: &dyn ExecutionBackend,
    opts: &SchedOptions,
    max_running: usize,
) {
    let pool =
        Arc::new(BlockPool::new(&store.base().config, opts.kv_pool_bytes, opts.block_size));
    metrics.sched.kv_blocks_total.store(pool.total_blocks() as u64, Ordering::Relaxed);
    let mut sched = Scheduler {
        store,
        batcher,
        metrics,
        backend,
        pool,
        max_running: max_running.max(1),
        running: Vec::new(),
        preempted: VecDeque::new(),
        admissions: 0,
        hydration_blocked: false,
    };
    loop {
        sched.admit();
        sched.publish();
        if sched.running.is_empty() {
            if !batcher.wait_for_work(IDLE_WAIT) && sched.preempted.is_empty() {
                sched.publish();
                return; // closed and fully drained
            }
            if sched.hydration_blocked {
                // the queue head is waiting on a background hydration,
                // so wait_for_work returns immediately (the queue is
                // non-empty) — park instead of spinning the probe
                std::thread::sleep(IDLE_WAIT);
            }
            continue;
        }
        sched.step();
    }
}

struct Scheduler<'a> {
    store: &'a TenantStore,
    batcher: &'a Batcher,
    metrics: &'a Metrics,
    backend: &'a dyn ExecutionBackend,
    pool: Arc<BlockPool>,
    max_running: usize,
    running: Vec<Sequence>,
    /// Preempted sequences awaiting re-admission, oldest arrival first.
    preempted: VecDeque<Sequence>,
    admissions: u64,
    /// The last admission pass requeued its head to wait for a
    /// background hydration (drive-loop pacing hint).
    hydration_blocked: bool,
}

impl Scheduler<'_> {
    // ---------------------------------------------------- admission

    /// Fill free running slots FCFS by arrival time, resuming preempted
    /// sequences ahead of equally-old queued requests. Head-of-line
    /// candidates that don't fit the pool wait (no bypass) — running
    /// sequences will free blocks as they finish.
    fn admit(&mut self) {
        self.hydration_blocked = false;
        while self.running.len() < self.max_running {
            let resume_first = match (self.preempted.front(), self.batcher.oldest_submitted()) {
                (Some(p), Some(q)) => p.req.submitted <= q,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => return,
            };
            let admitted = if resume_first { self.try_resume() } else { self.try_admit_new() };
            if !admitted {
                return;
            }
        }
    }

    /// Re-admit the oldest preempted sequence. Returns false when it
    /// must keep waiting for blocks.
    fn try_resume(&mut self) -> bool {
        let needed = {
            let seq = self.preempted.front().expect("caller checked");
            self.pool.blocks_for(seq.prefix_len())
        };
        if needed > self.pool.total_blocks() {
            // can never fit, even with everything else preempted
            let mut seq = self.preempted.pop_front().unwrap();
            let msg = format!(
                "sequence needs {needed} KV blocks but the pool holds {}",
                self.pool.total_blocks()
            );
            seq.state = SeqState::Done;
            Self::respond(self.metrics, &mut seq, Some(msg));
            return true;
        }
        if self.pool.free_blocks() < needed {
            return false;
        }
        let mut seq = self.preempted.pop_front().unwrap();
        let grown = seq.cache.grow(seq.prefix_len());
        debug_assert!(grown, "free-block check precedes the lease");
        seq.last_logits = None; // re-prefill prompt + generated
        self.admissions += 1;
        seq.admission = self.admissions;
        seq.state = SeqState::Active;
        self.running.push(seq);
        true
    }

    /// Admit the oldest queued request. Returns false when the queue is
    /// drained or its head must wait for blocks.
    fn try_admit_new(&mut self) -> bool {
        let Some(req) = self.batcher.pop_oldest() else {
            return false;
        };
        // validate against the model limits up front: a malformed
        // direct submission must answer with an error, not panic the
        // single drive thread inside forward_step (the gateway rejects
        // these before submission; the in-process API does not)
        let limits = self.store.base().config;
        if req.prompt.is_empty() {
            self.answer_unadmitted(req, "empty prompt".to_string());
            return true;
        }
        if req.prompt.len() > limits.max_seq {
            let msg = format!(
                "prompt of {} tokens exceeds max_seq {}",
                req.prompt.len(),
                limits.max_seq
            );
            self.answer_unadmitted(req, msg);
            return true;
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| (t as usize) >= limits.vocab_size) {
            let msg = format!("prompt token {bad} outside the vocabulary ({})", limits.vocab_size);
            self.answer_unadmitted(req, msg);
            return true;
        }
        let needed = self.pool.blocks_for(req.prompt.len());
        if needed > self.pool.total_blocks() {
            let msg = format!(
                "prompt needs {needed} KV blocks but the pool holds {}",
                self.pool.total_blocks()
            );
            self.answer_unadmitted(req, msg);
            return true;
        }
        if self.pool.free_blocks() < needed {
            // FCFS: the head waits for blocks rather than being bypassed
            self.batcher.requeue_front(req);
            return false;
        }
        match self.store.poke(&req.tenant) {
            Poke::Ready => {}
            Poke::Pending => {
                // Disk tier: the loader thread is hydrating — requeue
                // the head and keep decoding running sequences instead
                // of parking the drive thread on the hydration condvar
                self.batcher.requeue_front(req);
                self.hydration_blocked = true;
                return false;
            }
            Poke::Missing => {
                let msg = format!("tenant '{}' unavailable", req.tenant);
                self.answer_unadmitted(req, msg);
                return true;
            }
        }
        let exec_start = Instant::now();
        let Some(acquired) = self.store.acquire(&req.tenant, 1) else {
            // tenant vanished or its hydration failed — answer instead
            // of leaving the caller to time out (same as the legacy loop)
            let msg = format!("tenant '{}' unavailable", req.tenant);
            self.answer_unadmitted(req, msg);
            return true;
        };
        if acquired.promoted {
            self.metrics.promotions.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.evictions.fetch_add(acquired.evicted as u64, Ordering::Relaxed);
        let queue_wait = exec_start.duration_since(req.submitted);
        self.metrics.observe_queue_wait(queue_wait.as_secs_f64());
        let mut cache = PagedKvCache::new(self.pool.clone());
        let grown = cache.grow(req.prompt.len());
        debug_assert!(grown, "free-block check precedes the lease");
        let served_hot = matches!(acquired.view, TenantView::Hot(_));
        self.admissions += 1;
        self.running.push(Sequence {
            req,
            view: acquired.view,
            served_hot,
            cache,
            generated: Vec::new(),
            last_logits: None,
            queue_wait,
            admission: self.admissions,
            state: SeqState::Active,
        });
        true
    }

    // ---------------------------------------------------- stepping

    /// One scheduler iteration over every running sequence.
    fn step(&mut self) {
        let plan = self.plan();
        self.metrics.sched.observe_occupancy(plan.occupancy());
        let step_start = Instant::now();
        for i in plan.prefill {
            self.prefill_slot(i);
        }
        for i in plan.decode {
            self.decode_slot(i);
        }
        self.metrics.observe_batch_exec(step_start.elapsed().as_secs_f64());
        self.metrics.batches_executed.fetch_add(1, Ordering::Relaxed);
        self.metrics.sched.steps_executed.fetch_add(1, Ordering::Relaxed);
        self.sweep();
    }

    fn plan(&self) -> StepBatch {
        let mut batch = StepBatch { prefill: Vec::new(), decode: Vec::new() };
        for (i, seq) in self.running.iter().enumerate() {
            if seq.last_logits.is_none() {
                batch.prefill.push(i);
            } else {
                batch.decode.push(i);
            }
        }
        batch
    }

    /// Prefill slot: run the whole prefix (prompt, plus generated after
    /// a preemption) through the backend; blocks were leased at
    /// admission.
    fn prefill_slot(&mut self, i: usize) {
        if !matches!(self.running[i].state, SeqState::Active) {
            return; // preempted earlier in this same iteration
        }
        let tokens: Vec<u32> = {
            let seq = &self.running[i];
            seq.req.prompt.iter().chain(seq.generated.iter()).copied().collect()
        };
        let result = {
            let seq = &mut self.running[i];
            match &seq.view {
                TenantView::Hot(weights) => {
                    self.backend.prefill_step(weights.as_ref(), None, &tokens, &mut seq.cache)
                }
                TenantView::Cold(deltas) => self.backend.prefill_step(
                    self.store.base().as_ref(),
                    Some(deltas.as_ref()),
                    &tokens,
                    &mut seq.cache,
                ),
            }
        };
        match result {
            Ok(logits) => self.running[i].last_logits = Some(logits),
            Err(e) => self.backend_failure(i, &e),
        }
    }

    /// Decode slot: emit the token the last logits imply, then run one
    /// forward step for it. The decision order (max_seq check → argmax
    /// → EOS check → emit → step) mirrors `generate_with` exactly, so
    /// the emitted token sequence is bit-identical to the
    /// run-to-completion path.
    fn decode_slot(&mut self, i: usize) {
        if !matches!(self.running[i].state, SeqState::Active) {
            return;
        }
        // the token budget bounds emissions exactly like generate_with's
        // `for _ in 0..max_new` loop — checked BEFORE emitting, so
        // max_tokens = 0 yields zero tokens on both paths
        if self.running[i].generated.len() >= self.running[i].req.max_new {
            self.answer_at(i, None);
            return;
        }
        let pos = self.running[i].prefix_len();
        if pos >= self.store.base().config.max_seq {
            self.answer_at(i, None);
            return;
        }
        let next = {
            let seq = &self.running[i];
            ops::argmax_rows(seq.last_logits.as_ref().expect("decode slot has logits"))[0]
        };
        if next == vocab::EOS {
            self.answer_at(i, None);
            return;
        }
        let live = self.running[i].req.respond.send_token(next);
        self.running[i].generated.push(next);
        if !live {
            self.cancel(i);
            return;
        }
        if self.running[i].generated.len() >= self.running[i].req.max_new {
            // the token limit is reached; the forward step for this
            // token would only compute logits nobody reads
            self.answer_at(i, None);
            return;
        }
        if self.pool.blocks_for(pos + 1) > self.pool.total_blocks() {
            let msg = format!(
                "sequence of {} positions exceeds the KV pool ({} blocks)",
                pos + 1,
                self.pool.total_blocks()
            );
            self.answer_at(i, Some(msg));
            return;
        }
        if !self.ensure_capacity(i, pos + 1) {
            return; // preempted itself making room
        }
        let result = {
            let seq = &mut self.running[i];
            match &seq.view {
                TenantView::Hot(weights) => {
                    self.backend.decode_step(weights.as_ref(), None, next, pos, &mut seq.cache)
                }
                TenantView::Cold(deltas) => self.backend.decode_step(
                    self.store.base().as_ref(),
                    Some(deltas.as_ref()),
                    next,
                    pos,
                    &mut seq.cache,
                ),
            }
        };
        match result {
            Ok(logits) => self.running[i].last_logits = Some(logits),
            Err(e) => self.backend_failure(i, &e),
        }
    }

    /// Lease blocks until slot `i` can cache `positions` positions,
    /// preempting the youngest active sequence whenever the pool is
    /// dry. Returns false if `i` itself was the youngest and got
    /// preempted.
    fn ensure_capacity(&mut self, i: usize, positions: usize) -> bool {
        loop {
            if self.running[i].cache.grow(positions) {
                return true;
            }
            let victim = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, s)| matches!(s.state, SeqState::Active))
                .max_by_key(|(_, s)| s.admission)
                .map(|(j, _)| j)
                .expect("slot i is active");
            let self_preempt = victim == i;
            self.preempt(victim);
            if self_preempt {
                return false;
            }
        }
    }

    fn preempt(&mut self, j: usize) {
        let seq = &mut self.running[j];
        seq.cache.release();
        seq.last_logits = None;
        seq.state = SeqState::Preempted;
        self.metrics.sched.preempted_total.fetch_add(1, Ordering::Relaxed);
    }

    // ---------------------------------------------------- completion

    /// Answer slot `i` and free its blocks.
    fn answer_at(&mut self, i: usize, error: Option<String>) {
        self.running[i].state = SeqState::Done;
        Self::respond(self.metrics, &mut self.running[i], error);
    }

    fn backend_failure(&mut self, i: usize, e: &anyhow::Error) {
        self.metrics.backend_errors.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "backend '{}' failed for tenant '{}' request {}: {e:#}",
            self.backend.name(),
            self.running[i].req.tenant,
            self.running[i].req.id
        );
        self.answer_at(i, Some(format!("{e:#}")));
    }

    /// The stream receiver vanished: stop decoding, free the blocks and
    /// the slot. The already-streamed prefix stays valid (greedy decode
    /// is deterministic), there is just nobody left to read the rest.
    fn cancel(&mut self, i: usize) {
        let seq = &mut self.running[i];
        seq.cache.release();
        seq.state = SeqState::Cancelled;
        self.metrics.sched.cancelled_total.fetch_add(1, Ordering::Relaxed);
        self.metrics.tokens_generated.fetch_add(seq.generated.len() as u64, Ordering::Relaxed);
        self.metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Answer a request that never got a running slot (bad prompt,
    /// unknown/failed tenant, impossible block demand) — mirrors the
    /// legacy loop's unavailable-tenant response.
    fn answer_unadmitted(&self, req: Request, error: String) {
        self.metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
        let total = req.submitted.elapsed();
        req.respond.send_done(Response {
            id: req.id,
            tenant: req.tenant.clone(),
            tokens: Vec::new(),
            queue_wait: total,
            total,
            served_hot: false,
            error: Some(error),
        });
    }

    fn respond(metrics: &Metrics, seq: &mut Sequence, error: Option<String>) {
        seq.cache.release();
        let tokens = std::mem::take(&mut seq.generated);
        let total = seq.req.submitted.elapsed();
        metrics.tokens_generated.fetch_add(tokens.len() as u64, Ordering::Relaxed);
        metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
        metrics.observe_latency(total.as_secs_f64());
        seq.req.respond.send_done(Response {
            id: seq.req.id,
            tenant: seq.req.tenant.clone(),
            tokens,
            queue_wait: seq.queue_wait,
            total,
            served_hot: seq.served_hot,
            error,
        });
    }

    /// Move preempted slots to the waiting set (FCFS by arrival) and
    /// drop finished ones.
    fn sweep(&mut self) {
        let drained = std::mem::take(&mut self.running);
        for seq in drained {
            match seq.state {
                SeqState::Active => self.running.push(seq),
                SeqState::Preempted => self.queue_preempted(seq),
                SeqState::Done | SeqState::Cancelled => {}
            }
        }
    }

    fn queue_preempted(&mut self, seq: Sequence) {
        let at = self
            .preempted
            .iter()
            .position(|p| p.req.submitted > seq.req.submitted)
            .unwrap_or(self.preempted.len());
        self.preempted.insert(at, seq);
    }

    /// Refresh the shared gauges.
    fn publish(&self) {
        let s = &self.metrics.sched;
        s.running.store(self.running.len() as u64, Ordering::Relaxed);
        let waiting = self.batcher.queued() + self.preempted.len();
        s.waiting.store(waiting as u64, Ordering::Relaxed);
        s.kv_blocks_used.store(self.pool.used_blocks() as u64, Ordering::Relaxed);
        s.kv_blocks_free.store(self.pool.free_blocks() as u64, Ordering::Relaxed);
    }
}
