//! The iteration-level scheduler drive loop: per-decode-step batching
//! with FCFS admission, KV-pool admission control, preemption of the
//! youngest sequence when the pool runs dry, and batched step
//! execution — decode slots grouped by tenant into stacked `t=k`
//! forwards, long prompts prefilled in bounded chunks.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, Request, Response};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::tenant::{Poke, TenantStore, TenantView};
use crate::eval::tasks::vocab;
use crate::model::kvcache::KvSlot;
use crate::model::weights::ModelWeights;
use crate::runtime::{DecodeLane, ExecutionBackend, SharedSliceMut};
use crate::sched::block::{BlockPool, PagedKvCache};
use crate::sched::{SchedOptions, SchedStage, StepExec};
use crate::tensor::ops;
use crate::tensor::Matrix;
use crate::util::trace;

/// How long the drive loop parks when it has nothing running and
/// nothing queued (also the gauge refresh cadence while idle).
const IDLE_WAIT: Duration = Duration::from_millis(5);

/// Where a running slot is within its lifecycle after a step.
enum SeqState {
    Active,
    /// Answered (normally or with an error); blocks already freed.
    Done,
    /// Pushed back to the waiting set; blocks freed, resumes by
    /// re-prefilling prompt + generated.
    Preempted,
    /// Stream receiver vanished mid-generation; blocks freed.
    Cancelled,
}

/// One admitted sequence: the request plus everything needed to decode
/// it one step at a time.
struct Sequence {
    req: Request,
    view: TenantView,
    served_hot: bool,
    cache: PagedKvCache,
    generated: Vec<u32>,
    /// `None` → needs (re)prefill; `Some` → ready for a decode slot.
    last_logits: Option<Matrix>,
    /// Wait from submission to first admission (reported queue_wait).
    queue_wait: Duration,
    /// Monotonic admission stamp — the preemption victim is the
    /// sequence with the largest (youngest) stamp.
    admission: u64,
    /// When the sequence last gained a running slot (re-stamped on
    /// resume); bounds the `sched.exec` trace span.
    admitted_at: Instant,
    state: SeqState,
    /// This tenant's usage-ledger counters, cached at admission so
    /// per-step attribution (KV accrual, group wall, tokens) never
    /// touches the ledger's tenant map. `None` = ledger disabled.
    usage: Option<Arc<crate::usage::TenantUsage>>,
    /// When KV occupancy was last accrued into the ledger (advanced by
    /// [`Scheduler::accrue_kv`]).
    kv_stamp: Instant,
}

impl Sequence {
    /// Tokens that must be cached before the next decode: prompt plus
    /// everything generated so far.
    fn prefix_len(&self) -> usize {
        self.req.prompt.len() + self.generated.len()
    }
}

/// The plan for one scheduler iteration: which running slots run a
/// prefill and which run a single decode step. Mixed tenants share one
/// step batch — that is the whole point.
pub struct StepBatch {
    /// Slot indices that run a (possibly chunked) prefill this step.
    pub prefill: Vec<usize>,
    /// Slot indices that decode one token this step.
    pub decode: Vec<usize>,
}

impl StepBatch {
    /// Sequences touched by this step.
    pub fn occupancy(&self) -> usize {
        self.prefill.len() + self.decode.len()
    }
}

/// Drive the coordinator with iteration-level scheduling until the
/// batcher closes and drains. Spawned by `Server` in place of the
/// run-to-completion worker pool when the backend supports stepping.
pub fn drive_loop(
    store: &TenantStore,
    batcher: &Batcher,
    metrics: &Metrics,
    backend: &dyn ExecutionBackend,
    opts: &SchedOptions,
    max_running: usize,
) {
    let pool =
        Arc::new(BlockPool::new(&store.base().config, opts.kv_pool_bytes, opts.block_size));
    metrics.sched.kv_blocks_total.store(pool.total_blocks() as u64, Ordering::Relaxed);
    let mut sched = Scheduler {
        store,
        batcher,
        metrics,
        backend,
        pool,
        max_running: max_running.max(1),
        prefill_chunk: opts.prefill_chunk,
        step_exec: opts.step_exec,
        running: Vec::new(),
        preempted: VecDeque::new(),
        admissions: 0,
        hydration_blocked: false,
    };
    loop {
        sched.admit();
        sched.publish();
        if sched.running.is_empty() {
            if !batcher.wait_for_work(IDLE_WAIT) && sched.preempted.is_empty() {
                sched.publish();
                return; // closed and fully drained
            }
            if sched.hydration_blocked {
                // the queue head is waiting on a background hydration,
                // so wait_for_work returns immediately (the queue is
                // non-empty) — park instead of spinning the probe
                std::thread::sleep(IDLE_WAIT);
            }
            continue;
        }
        sched.step();
    }
}

/// Best-effort text of a caught panic payload (`panic!` with a string
/// literal or a formatted message; anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "<non-string panic payload>"
    }
}

/// Tenant-group identity for batched decode: two slots share a stacked
/// forward iff their views point at the same Arc-backed weights or
/// delta set (pointer identity — same tenant, same tier).
fn same_view(a: &TenantView, b: &TenantView) -> bool {
    match (a, b) {
        (TenantView::Hot(x), TenantView::Hot(y)) => Arc::ptr_eq(x, y),
        (TenantView::Cold(x), TenantView::Cold(y)) => Arc::ptr_eq(x, y),
        _ => false,
    }
}

struct Scheduler<'a> {
    store: &'a TenantStore,
    batcher: &'a Batcher,
    metrics: &'a Metrics,
    backend: &'a dyn ExecutionBackend,
    pool: Arc<BlockPool>,
    max_running: usize,
    /// Max prompt positions prefetched per sequence per iteration
    /// (`0` = the whole prefix at once).
    prefill_chunk: usize,
    step_exec: StepExec,
    running: Vec<Sequence>,
    /// Preempted sequences awaiting re-admission, oldest arrival first.
    preempted: VecDeque<Sequence>,
    admissions: u64,
    /// The last admission pass requeued its head to wait for a
    /// background hydration (drive-loop pacing hint).
    hydration_blocked: bool,
}

impl Scheduler<'_> {
    // ---------------------------------------------------- admission

    /// Fill free running slots FCFS by arrival time, resuming preempted
    /// sequences ahead of equally-old queued requests. Head-of-line
    /// candidates that don't fit the pool wait (no bypass) — running
    /// sequences will free blocks as they finish.
    fn admit(&mut self) {
        self.hydration_blocked = false;
        while self.running.len() < self.max_running {
            let resume_first = match (self.preempted.front(), self.batcher.oldest_submitted()) {
                (Some(p), Some(q)) => p.req.submitted <= q,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => return,
            };
            let admitted = if resume_first { self.try_resume() } else { self.try_admit_new() };
            if !admitted {
                return;
            }
        }
    }

    /// Re-admit the oldest preempted sequence. Returns false when it
    /// must keep waiting for blocks.
    fn try_resume(&mut self) -> bool {
        let front_expired = self
            .preempted
            .front()
            .expect("caller checked")
            .req
            .deadline
            .is_some_and(|d| Instant::now() >= d);
        if front_expired {
            // expired while preempted: answer without re-leasing blocks
            let mut seq = self.preempted.pop_front().unwrap();
            self.metrics.sched.deadline_expired_total.fetch_add(1, Ordering::Relaxed);
            seq.state = SeqState::Done;
            Self::respond(self.metrics, &mut seq, Some("deadline exceeded".to_string()));
            return true;
        }
        let needed = {
            let seq = self.preempted.front().expect("caller checked");
            self.pool.blocks_for(seq.prefix_len())
        };
        if needed > self.pool.total_blocks() {
            // can never fit, even with everything else preempted
            let mut seq = self.preempted.pop_front().unwrap();
            let msg = format!(
                "sequence needs {needed} KV blocks but the pool holds {}",
                self.pool.total_blocks()
            );
            seq.state = SeqState::Done;
            Self::respond(self.metrics, &mut seq, Some(msg));
            return true;
        }
        if self.pool.free_blocks() < needed {
            return false;
        }
        let mut seq = self.preempted.pop_front().unwrap();
        {
            let mut resume_span = trace::span_for("sched.resume", seq.req.id);
            resume_span.set_tenant(&seq.req.tenant);
            resume_span.attr_u64("prefix_len", seq.prefix_len() as u64);
            let grown = seq.cache.grow(seq.prefix_len());
            debug_assert!(grown, "free-block check precedes the lease");
        }
        seq.last_logits = None; // re-prefill prompt + generated
        self.admissions += 1;
        seq.admission = self.admissions;
        seq.admitted_at = Instant::now();
        seq.kv_stamp = seq.admitted_at; // fresh lease: accrual restarts here
        seq.state = SeqState::Active;
        self.running.push(seq);
        true
    }

    /// Admit the oldest queued request. Returns false when the queue is
    /// drained or its head must wait for blocks.
    fn try_admit_new(&mut self) -> bool {
        let Some(req) = self.batcher.pop_oldest() else {
            return false;
        };
        // deadline check at admission: a request that expired in the
        // queue must never lease KV blocks
        if req.deadline.is_some_and(|d| Instant::now() >= d) {
            self.metrics.sched.deadline_expired_total.fetch_add(1, Ordering::Relaxed);
            self.answer_unadmitted(req, "deadline exceeded".to_string());
            return true;
        }
        // validate against the model limits up front: a malformed
        // direct submission must answer with an error, not panic the
        // single drive thread inside forward_step (the gateway rejects
        // these before submission; the in-process API does not)
        let limits = self.store.base().config;
        if req.prompt.is_empty() {
            self.answer_unadmitted(req, "empty prompt".to_string());
            return true;
        }
        if req.prompt.len() > limits.max_seq {
            let msg = format!(
                "prompt of {} tokens exceeds max_seq {}",
                req.prompt.len(),
                limits.max_seq
            );
            self.answer_unadmitted(req, msg);
            return true;
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| (t as usize) >= limits.vocab_size) {
            let msg = format!("prompt token {bad} outside the vocabulary ({})", limits.vocab_size);
            self.answer_unadmitted(req, msg);
            return true;
        }
        let needed = self.pool.blocks_for(req.prompt.len());
        if needed > self.pool.total_blocks() {
            let msg = format!(
                "prompt needs {needed} KV blocks but the pool holds {}",
                self.pool.total_blocks()
            );
            self.answer_unadmitted(req, msg);
            return true;
        }
        if self.pool.free_blocks() < needed {
            // FCFS: the head waits for blocks rather than being bypassed
            self.batcher.requeue_front(req);
            return false;
        }
        match self.store.poke(&req.tenant) {
            Poke::Ready => {}
            Poke::Pending => {
                // Disk tier: the loader thread is hydrating — requeue
                // the head and keep decoding running sequences instead
                // of parking the drive thread on the hydration condvar
                self.batcher.requeue_front(req);
                self.hydration_blocked = true;
                return false;
            }
            Poke::Missing => {
                let msg = format!("tenant '{}' unavailable", req.tenant);
                self.answer_unadmitted(req, msg);
                return true;
            }
            Poke::Quarantined => {
                // containment: only the loader's background probe may
                // retry a quarantined tenant — requests answer instantly
                let msg = format!("tenant '{}' quarantined", req.tenant);
                self.answer_unadmitted(req, msg);
                return true;
            }
        }
        let exec_start = Instant::now();
        let Some(acquired) = self.store.acquire(&req.tenant, 1) else {
            // tenant vanished or its hydration failed — answer instead
            // of leaving the caller to time out (same as the legacy loop)
            let msg = format!("tenant '{}' unavailable", req.tenant);
            self.answer_unadmitted(req, msg);
            return true;
        };
        if acquired.promoted {
            self.metrics.promotions.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.evictions.fetch_add(acquired.evicted as u64, Ordering::Relaxed);
        let queue_wait = exec_start.duration_since(req.submitted);
        self.metrics.observe_queue_wait(queue_wait.as_secs_f64());
        trace::span_between("queue.wait", req.id, req.submitted, exec_start);
        let usage = self.metrics.usage.tenant(&req.tenant);
        if let Some(u) = &usage {
            u.add_queue_wait(queue_wait);
            u.tokens_in.fetch_add(req.prompt.len() as u64, Ordering::Relaxed);
        }
        let mut cache = PagedKvCache::new(self.pool.clone());
        {
            let mut alloc_span = trace::span_for("kv.alloc", req.id);
            alloc_span.attr_u64("blocks", needed as u64);
            let grown = cache.grow(req.prompt.len());
            debug_assert!(grown, "free-block check precedes the lease");
        }
        let served_hot = matches!(acquired.view, TenantView::Hot(_));
        self.admissions += 1;
        self.running.push(Sequence {
            req,
            view: acquired.view,
            served_hot,
            cache,
            generated: Vec::new(),
            last_logits: None,
            queue_wait,
            admission: self.admissions,
            admitted_at: exec_start,
            state: SeqState::Active,
            usage,
            kv_stamp: exec_start,
        });
        true
    }

    // ---------------------------------------------------- stepping

    /// One scheduler iteration over every running sequence. Each stage
    /// (plan/prefill/decode/emit) is timed into the per-stage
    /// histograms behind `deltadq_sched_stage_seconds`; the whole
    /// iteration records a `sched.step` trace span.
    fn step(&mut self) {
        let mut step_span = trace::span("sched.step");
        let plan_start = Instant::now();
        self.expire_deadlines();
        let plan = self.plan();
        self.metrics.sched.observe_occupancy(plan.occupancy());
        step_span.attr_u64("prefill_slots", plan.prefill.len() as u64);
        step_span.attr_u64("decode_slots", plan.decode.len() as u64);
        let prefill_start = Instant::now();
        self.metrics.sched.observe_stage(SchedStage::Plan, prefill_start - plan_start);
        for i in plan.prefill {
            self.prefill_slot(i);
        }
        let decode_start = Instant::now();
        self.metrics.sched.observe_stage(SchedStage::Prefill, decode_start - prefill_start);
        match self.step_exec {
            StepExec::PerSequence => {
                for i in plan.decode {
                    self.decode_slot(i);
                }
            }
            StepExec::Batched => self.decode_batched(&plan.decode),
        }
        let emit_start = Instant::now();
        self.metrics.sched.observe_stage(SchedStage::Decode, emit_start - decode_start);
        self.metrics.observe_batch_exec((emit_start - prefill_start).as_secs_f64());
        // the conservation denominator: this step's execution wall
        // (prefill + decode stages — exactly what the per-tenant
        // prefill-chunk and decode-group attributions partition)
        self.metrics.usage.add_exec_wall(emit_start - prefill_start);
        self.metrics.batches_executed.fetch_add(1, Ordering::Relaxed);
        self.metrics.sched.steps_executed.fetch_add(1, Ordering::Relaxed);
        // integrate KV occupancy once per step for sequences that stay
        // active (transitions accrue at their own boundary)
        for seq in &mut self.running {
            if matches!(seq.state, SeqState::Active) {
                Self::accrue_kv(seq);
            }
        }
        self.sweep();
        self.metrics.sched.observe_stage(SchedStage::Emit, emit_start.elapsed());
    }

    /// Terminate every active sequence whose deadline has passed: free
    /// its KV blocks and answer the stream with a well-formed error
    /// frame. Runs once per scheduler iteration, before planning, so an
    /// expired request costs at most one extra iteration of latency.
    fn expire_deadlines(&mut self) {
        let now = Instant::now();
        for i in 0..self.running.len() {
            if !matches!(self.running[i].state, SeqState::Active) {
                continue;
            }
            if self.running[i].req.deadline.is_some_and(|d| now >= d) {
                self.metrics.sched.deadline_expired_total.fetch_add(1, Ordering::Relaxed);
                self.answer_at(i, Some("deadline exceeded".to_string()));
            }
        }
    }

    fn plan(&self) -> StepBatch {
        let mut batch = StepBatch { prefill: Vec::new(), decode: Vec::new() };
        for (i, seq) in self.running.iter().enumerate() {
            if seq.last_logits.is_none() {
                batch.prefill.push(i);
            } else {
                batch.decode.push(i);
            }
        }
        batch
    }

    /// Prefill slot: cache the next bounded chunk of the prefix
    /// (prompt, plus generated after a preemption); blocks were leased
    /// at admission. Progress lives in the cache's own fill count, so a
    /// partially-prefilled slot simply plans as a prefill slot again
    /// next iteration — decode slots share every one of those
    /// iterations instead of stalling behind one long prompt. Only the
    /// final chunk's logits are kept (they are what a whole-prefix
    /// prefill returns, bit-for-bit).
    fn prefill_slot(&mut self, i: usize) {
        if !matches!(self.running[i].state, SeqState::Active) {
            return; // preempted earlier in this same iteration
        }
        let (tokens, start, done) = {
            let seq = &self.running[i];
            let start = seq.cache.len();
            let total = seq.prefix_len();
            let end =
                if self.prefill_chunk == 0 { total } else { total.min(start + self.prefill_chunk) };
            let tokens: Vec<u32> = seq
                .req
                .prompt
                .iter()
                .chain(seq.generated.iter())
                .skip(start)
                .take(end - start)
                .copied()
                .collect();
            (tokens, start, end == total)
        };
        let mut chunk_span = trace::span_for("prefill.chunk", self.running[i].req.id);
        chunk_span.set_tenant(&self.running[i].req.tenant);
        chunk_span.attr_u64("start_pos", start as u64);
        chunk_span.attr_u64("n_tokens", tokens.len() as u64);
        let chunk_start = Instant::now();
        let result = {
            let seq = &mut self.running[i];
            crate::util::failpoint::hit("backend.prefill").and_then(|()| match &seq.view {
                TenantView::Hot(weights) => {
                    self.backend.prefill_chunk(weights.as_ref(), None, &tokens, &mut seq.cache)
                }
                TenantView::Cold(deltas) => self.backend.prefill_chunk(
                    self.store.base().as_ref(),
                    Some(deltas.as_ref()),
                    &tokens,
                    &mut seq.cache,
                ),
            })
        };
        if let Some(u) = &self.running[i].usage {
            u.add_compute(chunk_start.elapsed());
        }
        drop(chunk_span);
        self.metrics.sched.prefill_chunks_total.fetch_add(1, Ordering::Relaxed);
        match result {
            Ok(logits) => {
                if done {
                    self.running[i].last_logits = Some(logits);
                }
            }
            Err(e) => self.backend_failure(i, &e),
        }
    }

    /// Decision half of a decode slot: emit the token the last logits
    /// imply and lease capacity for its forward step. The decision
    /// order (token budget → max_seq check → argmax → EOS check → emit
    /// → second budget check → capacity) mirrors `generate_with`
    /// exactly, so the emitted token sequence is bit-identical to the
    /// run-to-completion path. Returns `Some((token, pos))` when a
    /// forward step must run for this slot.
    fn decide_decode(&mut self, i: usize) -> Option<(u32, usize)> {
        if !matches!(self.running[i].state, SeqState::Active) {
            return None;
        }
        // the token budget bounds emissions exactly like generate_with's
        // `for _ in 0..max_new` loop — checked BEFORE emitting, so
        // max_tokens = 0 yields zero tokens on both paths
        if self.running[i].generated.len() >= self.running[i].req.max_new {
            self.answer_at(i, None);
            return None;
        }
        let pos = self.running[i].prefix_len();
        if pos >= self.store.base().config.max_seq {
            self.answer_at(i, None);
            return None;
        }
        let next = {
            let seq = &self.running[i];
            ops::argmax_rows(seq.last_logits.as_ref().expect("decode slot has logits"))[0]
        };
        if next == vocab::EOS {
            self.answer_at(i, None);
            return None;
        }
        let live = self.running[i].req.respond.send_token(next);
        self.running[i].generated.push(next);
        if !live {
            self.cancel(i);
            return None;
        }
        if self.running[i].generated.len() >= self.running[i].req.max_new {
            // the token limit is reached; the forward step for this
            // token would only compute logits nobody reads
            self.answer_at(i, None);
            return None;
        }
        if self.pool.blocks_for(pos + 1) > self.pool.total_blocks() {
            let msg = format!(
                "sequence of {} positions exceeds the KV pool ({} blocks)",
                pos + 1,
                self.pool.total_blocks()
            );
            self.answer_at(i, Some(msg));
            return None;
        }
        if !self.ensure_capacity(i, pos + 1) {
            return None; // preempted itself making room
        }
        Some((next, pos))
    }

    /// Per-sequence decode slot ([`StepExec::PerSequence`]): decide,
    /// then run the forward step immediately — the PR 5 execution
    /// order, kept as the batched path's bit-identity baseline.
    fn decode_slot(&mut self, i: usize) {
        let Some((next, pos)) = self.decide_decode(i) else {
            return;
        };
        let step_start = Instant::now();
        let result = {
            let seq = &mut self.running[i];
            crate::util::failpoint::hit("backend.decode").and_then(|()| match &seq.view {
                TenantView::Hot(weights) => {
                    self.backend.decode_step(weights.as_ref(), None, next, pos, &mut seq.cache)
                }
                TenantView::Cold(deltas) => self.backend.decode_step(
                    self.store.base().as_ref(),
                    Some(deltas.as_ref()),
                    next,
                    pos,
                    &mut seq.cache,
                ),
            })
        };
        if let Some(u) = &self.running[i].usage {
            u.add_compute(step_start.elapsed());
        }
        match result {
            Ok(logits) => self.running[i].last_logits = Some(logits),
            Err(e) => self.backend_failure(i, &e),
        }
    }

    /// Batched decode ([`StepExec::Batched`]): run every slot's
    /// *decision* in plan order (identical side effects to the
    /// per-sequence loop — forward steps never touch another slot's
    /// decision state), then group the surviving slots by tenant view
    /// and execute each group as ONE stacked forward — one fused
    /// `X·(W_b+ΔŴ)ᵀ` per (tenant, layer) — fanning independent groups
    /// over the backend's worker pool.
    ///
    /// Streams are bit-identical to the per-sequence loop: decisions
    /// are order-identical, a slot preempted after its decision lands
    /// in the same state either way (token already emitted, blocks
    /// freed, re-prefills on resume), and `decode_steps` row `i`
    /// carries the exact bits of a lone `decode_step` for lane `i`.
    fn decode_batched(&mut self, slots: &[usize]) {
        let mut pending: Vec<(usize, u32, usize)> = Vec::with_capacity(slots.len());
        for &i in slots {
            if let Some((token, pos)) = self.decide_decode(i) {
                pending.push((i, token, pos));
            }
        }
        // a later decision's ensure_capacity may have preempted an
        // earlier pending slot — its step must not run (its blocks are
        // gone; it resumes by re-prefilling)
        pending.retain(|&(i, _, _)| matches!(self.running[i].state, SeqState::Active));
        if pending.is_empty() {
            return;
        }
        // group by tenant view (Arc identity): lanes in a group share
        // one (base, Δ) pair and therefore one stacked forward
        let mut groups: Vec<(TenantView, Vec<(usize, u32, usize)>)> = Vec::new();
        for entry in pending {
            let view = self.running[entry.0].view.clone();
            match groups.iter_mut().find(|(v, _)| same_view(v, &view)) {
                Some((_, members)) => members.push(entry),
                None => groups.push((view, vec![entry])),
            }
        }
        // per-group trace identity: tenant plus the member request ids
        // (the attribute that joins the group span into each member's
        // tree and nobody else's) — and the tenant's usage counters,
        // since the whole group wall belongs to one tenant
        type GroupMeta = (String, String, Option<Arc<crate::usage::TenantUsage>>);
        let mut group_meta: Vec<GroupMeta> = Vec::with_capacity(groups.len());
        for (_, members) in &groups {
            self.metrics.sched.decode_groups_total.fetch_add(1, Ordering::Relaxed);
            self.metrics.sched.decode_lanes_total.fetch_add(members.len() as u64, Ordering::Relaxed);
            self.metrics.sched.observe_group(members.len());
            let tenant = self.running[members[0].0].req.tenant.clone();
            let usage = self.running[members[0].0].usage.clone();
            let ids: Vec<String> =
                members.iter().map(|&(slot, _, _)| self.running[slot].req.id.to_string()).collect();
            group_meta.push((tenant, ids.join(","), usage));
        }
        let mut results: Vec<Option<Result<Matrix>>> = (0..groups.len()).map(|_| None).collect();
        {
            let backend = self.backend;
            let store = self.store;
            let base: &Arc<ModelWeights> = store.base();
            let sched_counters = &self.metrics.sched;
            let n_layers = base.config.n_layers.max(1);
            let seqs = SharedSliceMut::new(&mut self.running);
            let out = SharedSliceMut::new(&mut results);
            let run_group = |gi: usize| {
                let (view, members) = &groups[gi];
                let mut group_span = trace::span("decode.group");
                let (tenant, requests, usage) = &group_meta[gi];
                group_span.set_tenant(tenant);
                group_span.attr_str("requests", requests);
                group_span.attr_u64("lanes", members.len() as u64);
                let group_start = Instant::now();
                let mut lanes: Vec<DecodeLane<'_>> = Vec::with_capacity(members.len());
                for &(slot, token, pos) in members {
                    // SAFETY: every slot index appears in exactly one
                    // group, so concurrent groups touch disjoint slots.
                    let seq = unsafe { &mut seqs.slice_mut(slot, 1)[0] };
                    lanes.push(DecodeLane { token, pos, cache: &mut seq.cache });
                }
                // Panic containment: a panicking group (backend bug, or
                // the `backend.decode` failpoint's panic policy) fails
                // only its own lanes — it lands in the same Err path an
                // ordinary backend error takes, so the drive loop keeps
                // stepping every other group.
                let call = || {
                    crate::util::failpoint::hit("backend.decode").and_then(|()| match view {
                        TenantView::Hot(weights) => {
                            backend.decode_steps(weights.as_ref(), None, &mut lanes)
                        }
                        TenantView::Cold(deltas) => {
                            backend.decode_steps(base.as_ref(), Some(deltas.as_ref()), &mut lanes)
                        }
                    })
                };
                let r = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(call)) {
                    Ok(r) => r,
                    Err(payload) => {
                        sched_counters.decode_group_panics_total.fetch_add(1, Ordering::Relaxed);
                        Err(anyhow::anyhow!(
                            "decode group panicked: {}",
                            panic_message(payload.as_ref())
                        ))
                    }
                };
                let group_wall = group_start.elapsed();
                if let Some(u) = usage {
                    // the whole stacked forward is one tenant's work
                    u.add_compute(group_wall);
                }
                let layer_ms = group_wall.as_secs_f64() * 1e3 / n_layers as f64;
                group_span.attr_f64("layer_ms", layer_ms);
                // SAFETY: result cell gi is owned by group gi alone.
                unsafe { out.slice_mut(gi, 1)[0] = Some(r) };
            };
            match backend.exec_pool() {
                // nested pool use is deadlock-free: each group's own
                // pooled matmuls run as inner jobs on the same pool
                Some(pool) if groups.len() > 1 => pool.run(groups.len(), &run_group),
                _ => {
                    for gi in 0..groups.len() {
                        run_group(gi);
                    }
                }
            }
        }
        // distribute each group's logit rows back to its slots (or fail
        // every slot of an errored group, as lane-by-lane calls would)
        let vocab = self.store.base().config.vocab_size;
        for (gi, (_, members)) in groups.iter().enumerate() {
            match results[gi].take().expect("every group ran") {
                Ok(logits) => {
                    debug_assert_eq!(logits.rows(), members.len());
                    for (li, &(slot, _, _)) in members.iter().enumerate() {
                        let row = Matrix::from_vec(1, vocab, logits.row(li).to_vec());
                        self.running[slot].last_logits = Some(row);
                    }
                }
                Err(e) => {
                    for &(slot, _, _) in members {
                        self.backend_failure(slot, &e);
                    }
                }
            }
        }
    }

    /// Lease blocks until slot `i` can cache `positions` positions,
    /// preempting the youngest active sequence whenever the pool is
    /// dry. Returns false if `i` itself was the youngest and got
    /// preempted.
    fn ensure_capacity(&mut self, i: usize, positions: usize) -> bool {
        loop {
            if self.running[i].cache.grow(positions) {
                return true;
            }
            let victim = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, s)| matches!(s.state, SeqState::Active))
                .max_by_key(|(_, s)| s.admission)
                .map(|(j, _)| j)
                .expect("slot i is active");
            let self_preempt = victim == i;
            self.preempt(victim);
            if self_preempt {
                return false;
            }
        }
    }

    /// Integrate `blocks × time-held` since the last accrual into the
    /// tenant's KV-block-seconds and advance the stamp. Must run
    /// BEFORE a `cache.release()` (afterwards the block count is 0).
    fn accrue_kv(seq: &mut Sequence) {
        let now = Instant::now();
        if let Some(u) = &seq.usage {
            let blocks = seq.cache.n_blocks() as u64;
            if blocks > 0 {
                u.add_kv_blocks(blocks, now.duration_since(seq.kv_stamp));
            }
        }
        seq.kv_stamp = now;
    }

    fn preempt(&mut self, j: usize) {
        let seq = &mut self.running[j];
        let mut preempt_span = trace::span_for("sched.preempt", seq.req.id);
        preempt_span.set_tenant(&seq.req.tenant);
        preempt_span.attr_u64("generated", seq.generated.len() as u64);
        drop(preempt_span);
        Self::accrue_kv(seq);
        seq.cache.release();
        seq.last_logits = None;
        seq.state = SeqState::Preempted;
        self.metrics.sched.preempted_total.fetch_add(1, Ordering::Relaxed);
    }

    // ---------------------------------------------------- completion

    /// Answer slot `i` and free its blocks.
    fn answer_at(&mut self, i: usize, error: Option<String>) {
        self.running[i].state = SeqState::Done;
        Self::respond(self.metrics, &mut self.running[i], error);
    }

    fn backend_failure(&mut self, i: usize, e: &anyhow::Error) {
        self.metrics.backend_errors.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "backend '{}' failed for tenant '{}' request {}: {e:#}",
            self.backend.name(),
            self.running[i].req.tenant,
            self.running[i].req.id
        );
        self.answer_at(i, Some(format!("{e:#}")));
    }

    /// The stream receiver vanished: stop decoding, free the blocks and
    /// the slot. The already-streamed prefix stays valid (greedy decode
    /// is deterministic), there is just nobody left to read the rest.
    fn cancel(&mut self, i: usize) {
        let seq = &mut self.running[i];
        Self::accrue_kv(seq);
        seq.cache.release();
        seq.state = SeqState::Cancelled;
        self.metrics.sched.cancelled_total.fetch_add(1, Ordering::Relaxed);
        self.metrics.tokens_generated.fetch_add(seq.generated.len() as u64, Ordering::Relaxed);
        self.metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
        if let Some(u) = &seq.usage {
            u.tokens_out.fetch_add(seq.generated.len() as u64, Ordering::Relaxed);
        }
    }

    /// Answer a request that never got a running slot (bad prompt,
    /// unknown/failed tenant, impossible block demand) — mirrors the
    /// legacy loop's unavailable-tenant response.
    fn answer_unadmitted(&self, req: Request, error: String) {
        trace::span_between("queue.wait", req.id, req.submitted, Instant::now());
        self.metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
        let total = req.submitted.elapsed();
        req.respond.send_done(Response {
            id: req.id,
            tenant: req.tenant.clone(),
            tokens: Vec::new(),
            queue_wait: total,
            total,
            served_hot: false,
            error: Some(error),
        });
    }

    fn respond(metrics: &Metrics, seq: &mut Sequence, error: Option<String>) {
        trace::span_between("sched.exec", seq.req.id, seq.admitted_at, Instant::now());
        Self::accrue_kv(seq);
        seq.cache.release();
        let tokens = std::mem::take(&mut seq.generated);
        if let Some(u) = &seq.usage {
            u.tokens_out.fetch_add(tokens.len() as u64, Ordering::Relaxed);
        }
        let total = seq.req.submitted.elapsed();
        metrics.tokens_generated.fetch_add(tokens.len() as u64, Ordering::Relaxed);
        metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
        // shadow-audit sampling: one atomic bump; clones only the
        // sampled 1-in-N request (before `tokens` moves into Response)
        if error.is_none() {
            metrics.audit.offer(&seq.req.tenant, &seq.req.prompt, &tokens);
        }
        metrics.observe_latency(total.as_secs_f64());
        seq.req.respond.send_done(Response {
            id: seq.req.id,
            tenant: seq.req.tenant.clone(),
            tokens,
            queue_wait: seq.queue_wait,
            total,
            served_hot: seq.served_hot,
            error,
        });
    }

    /// Move preempted slots to the waiting set (FCFS by arrival) and
    /// drop finished ones.
    fn sweep(&mut self) {
        let drained = std::mem::take(&mut self.running);
        for seq in drained {
            match seq.state {
                SeqState::Active => self.running.push(seq),
                SeqState::Preempted => self.queue_preempted(seq),
                SeqState::Done | SeqState::Cancelled => {}
            }
        }
    }

    fn queue_preempted(&mut self, seq: Sequence) {
        let at = self
            .preempted
            .iter()
            .position(|p| p.req.submitted > seq.req.submitted)
            .unwrap_or(self.preempted.len());
        self.preempted.insert(at, seq);
    }

    /// Refresh the shared gauges and stamp the drive-thread heartbeat
    /// (`/healthz` liveness).
    fn publish(&self) {
        let s = &self.metrics.sched;
        s.last_heartbeat_us.store(trace::now_us(), Ordering::Relaxed);
        s.running.store(self.running.len() as u64, Ordering::Relaxed);
        let queued = self.batcher.queued();
        let waiting = queued + self.preempted.len();
        s.waiting.store(waiting as u64, Ordering::Relaxed);
        let used = self.pool.used_blocks();
        s.kv_blocks_used.store(used as u64, Ordering::Relaxed);
        s.kv_blocks_free.store(self.pool.free_blocks() as u64, Ordering::Relaxed);
        // feed the saturation windows every iteration (and every idle
        // tick), so the 10 s means rise under load and decay after it
        let kv_frac = used as f64 / self.pool.total_blocks().max(1) as f64;
        let queue_frac = queued as f64 / self.batcher.queue_capacity().max(1) as f64;
        let audit = &self.metrics.audit;
        let pending = audit
            .sampled_total
            .load(Ordering::Relaxed)
            .saturating_sub(audit.dropped_total.load(Ordering::Relaxed))
            .saturating_sub(audit.completed_total.load(Ordering::Relaxed));
        self.metrics.usage.tick(kv_frac, queue_frac, crate::usage::backlog_frac(pending));
    }
}
