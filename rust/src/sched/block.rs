//! The paged KV-cache block pool: fixed-size blocks of KV positions,
//! leased to sequences and recycled on finish/cancel/preemption.
//!
//! A block holds `block_size` positions of K and V for *every* layer of
//! the model, so one block is the unit of both admission control and
//! preemption accounting. The pool never allocates past its configured
//! budget — `try_alloc` simply returns `None` once `total_blocks` are
//! outstanding, and the scheduler reacts by preempting the youngest
//! running sequence.
//!
//! Storage is created lazily (first lease) and recycled through a free
//! list, so an idle server with a large `kv_pool_mib` costs nothing and
//! a busy one never re-allocates block buffers on the hot path.

use std::sync::{Arc, Mutex};

use crate::model::kvcache::{attend_dense, KvSlot};
use crate::model::ModelConfig;
use crate::tensor::Matrix;

/// One leased block: `block_size × hidden` K and V matrices per layer.
/// Rows are overwritten on reuse; only rows below the owning cache's
/// fill count are ever read.
#[derive(Debug)]
pub struct KvBlock {
    keys: Vec<Matrix>,
    values: Vec<Matrix>,
}

impl KvBlock {
    fn new(n_layers: usize, block_size: usize, hidden: usize) -> KvBlock {
        KvBlock {
            keys: (0..n_layers).map(|_| Matrix::zeros(block_size, hidden)).collect(),
            values: (0..n_layers).map(|_| Matrix::zeros(block_size, hidden)).collect(),
        }
    }
}

#[derive(Debug)]
struct PoolInner {
    /// Recycled block storage, ready to lease again.
    free: Vec<KvBlock>,
    /// Blocks currently leased out (the capacity check).
    outstanding: usize,
}

/// Fixed-capacity pool of paged KV blocks. Cheap to share (`Arc`)
/// between the scheduler and every sequence's [`PagedKvCache`].
#[derive(Debug)]
pub struct BlockPool {
    block_size: usize,
    n_layers: usize,
    hidden: usize,
    total: usize,
    inner: Mutex<PoolInner>,
}

impl BlockPool {
    /// Pool sized to `budget_bytes` of KV storage for `config`'s
    /// geometry (at least one block).
    pub fn new(config: &ModelConfig, budget_bytes: u64, block_size: usize) -> BlockPool {
        let block_size = block_size.max(1);
        let per = BlockPool::block_bytes(config, block_size);
        let total = (budget_bytes / per).max(1) as usize;
        BlockPool::with_blocks(config, block_size, total)
    }

    /// Pool with an explicit block count (tests and benches).
    pub fn with_blocks(config: &ModelConfig, block_size: usize, total: usize) -> BlockPool {
        BlockPool {
            block_size: block_size.max(1),
            n_layers: config.n_layers,
            hidden: config.hidden,
            total: total.max(1),
            inner: Mutex::new(PoolInner { free: Vec::new(), outstanding: 0 }),
        }
    }

    /// Bytes of KV storage one block pins for `config`'s geometry
    /// (`block_size` positions × layers × {K,V} × hidden × f32).
    pub fn block_bytes(config: &ModelConfig, block_size: usize) -> u64 {
        (block_size.max(1) * config.n_layers * 2 * config.hidden * std::mem::size_of::<f32>())
            as u64
    }

    /// Positions one block holds.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Blocks needed to cache `positions` positions.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }

    /// Fixed block budget of the pool.
    pub fn total_blocks(&self) -> usize {
        self.total
    }

    /// Blocks currently leased out to sequences.
    pub fn used_blocks(&self) -> usize {
        self.inner.lock().unwrap().outstanding
    }

    /// Blocks still available to lease.
    pub fn free_blocks(&self) -> usize {
        self.total - self.used_blocks()
    }

    /// Lease one block, or `None` when the pool is at capacity — the
    /// admission/preemption signal. Never allocates past the budget.
    fn try_alloc(&self) -> Option<KvBlock> {
        let mut inner = self.inner.lock().unwrap();
        if inner.outstanding >= self.total {
            return None;
        }
        inner.outstanding += 1;
        let block = inner
            .free
            .pop()
            .unwrap_or_else(|| KvBlock::new(self.n_layers, self.block_size, self.hidden));
        Some(block)
    }

    fn release(&self, block: KvBlock) {
        let mut inner = self.inner.lock().unwrap();
        debug_assert!(inner.outstanding > 0, "release without a lease");
        inner.outstanding -= 1;
        inner.free.push(block);
    }
}

/// A sequence's KV cache backed by pool blocks: the per-sequence block
/// table of the paged-attention scheme. Grows block-at-a-time via
/// [`PagedKvCache::grow`]; every block returns to the pool on
/// [`PagedKvCache::release`] (or drop).
#[derive(Debug)]
pub struct PagedKvCache {
    pool: Arc<BlockPool>,
    blocks: Vec<KvBlock>,
    /// Rows written per layer (layers trail by ≤1 within a step).
    filled: Vec<usize>,
    /// Reused gather scratch for `attend` (K rows, V rows) — grown
    /// once per sequence instead of allocated per step and layer.
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
}

impl PagedKvCache {
    /// Empty cache that will lease from `pool` as it grows.
    pub fn new(pool: Arc<BlockPool>) -> PagedKvCache {
        let n_layers = pool.n_layers;
        PagedKvCache {
            pool,
            blocks: Vec::new(),
            filled: vec![0; n_layers],
            scratch_k: Vec::new(),
            scratch_v: Vec::new(),
        }
    }

    /// Positions the leased blocks can hold.
    pub fn capacity(&self) -> usize {
        self.blocks.len() * self.pool.block_size
    }

    /// Blocks currently leased by this sequence.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Lease blocks until the cache can hold `positions` positions.
    /// Returns `false` if the pool ran dry first (any blocks obtained
    /// so far are kept — the retry after preemption picks up there).
    #[must_use]
    pub fn grow(&mut self, positions: usize) -> bool {
        while self.capacity() < positions {
            match self.pool.try_alloc() {
                Some(b) => self.blocks.push(b),
                None => return false,
            }
        }
        true
    }

    /// Return every block to the pool and reset the fill counts (the
    /// free-on-finish/cancel/preempt path).
    pub fn release(&mut self) {
        for block in self.blocks.drain(..) {
            self.pool.release(block);
        }
        for f in &mut self.filled {
            *f = 0;
        }
    }
}

impl Drop for PagedKvCache {
    fn drop(&mut self) {
        self.release();
    }
}

impl KvSlot for PagedKvCache {
    fn len(&self) -> usize {
        // complete positions = rows of the last layer (layers append in
        // order within a step), matching `KvCache::len`
        self.filled.last().copied().unwrap_or(0)
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        let pos = self.filled[layer];
        assert!(pos < self.capacity(), "PagedKvCache append past leased capacity");
        let (b, off) = (pos / self.pool.block_size, pos % self.pool.block_size);
        self.blocks[b].keys[layer].row_mut(off).copy_from_slice(k);
        self.blocks[b].values[layer].row_mut(off).copy_from_slice(v);
        self.filled[layer] = pos + 1;
    }

    fn attend(
        &mut self,
        layer: usize,
        q: &Matrix,
        n_heads: usize,
        head_dim: usize,
        scale: f32,
    ) -> Matrix {
        // gather the layer's rows into the reused contiguous scratch,
        // then run the exact same kernel as the monolithic cache — same
        // values in, same float ops, bit-identical context out
        let t = self.filled[layer];
        let hidden = self.pool.hidden;
        let mut k_data = std::mem::take(&mut self.scratch_k);
        let mut v_data = std::mem::take(&mut self.scratch_v);
        k_data.clear();
        v_data.clear();
        k_data.reserve(t * hidden);
        v_data.reserve(t * hidden);
        for pos in 0..t {
            let (b, off) = (pos / self.pool.block_size, pos % self.pool.block_size);
            k_data.extend_from_slice(self.blocks[b].keys[layer].row(off));
            v_data.extend_from_slice(self.blocks[b].values[layer].row(off));
        }
        let k_all = Matrix::from_vec(t, hidden, k_data);
        let v_all = Matrix::from_vec(t, hidden, v_data);
        let ctx = attend_dense(q, &k_all, &v_all, n_heads, head_dim, scale);
        // recover the buffers for the next step
        self.scratch_k = k_all.into_vec();
        self.scratch_v = v_all.into_vec();
        ctx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kvcache::KvCache;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn pool_caps_at_total_and_recycles() {
        let pool = BlockPool::with_blocks(&tiny(), 4, 2);
        assert_eq!(pool.total_blocks(), 2);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        assert!(pool.try_alloc().is_none(), "budget is a hard cap");
        assert_eq!(pool.free_blocks(), 0);
        pool.release(a);
        assert_eq!(pool.free_blocks(), 1);
        let c = pool.try_alloc().unwrap();
        pool.release(b);
        pool.release(c);
        assert_eq!(pool.used_blocks(), 0);
    }

    #[test]
    fn budget_to_blocks_math() {
        let c = tiny();
        let per = BlockPool::block_bytes(&c, 4);
        assert_eq!(per, (4 * c.n_layers * 2 * c.hidden * 4) as u64);
        let pool = BlockPool::new(&c, per * 3 + per / 2, 4);
        assert_eq!(pool.total_blocks(), 3, "partial blocks don't count");
        assert_eq!(BlockPool::new(&c, 0, 4).total_blocks(), 1, "at least one block");
    }

    #[test]
    fn cache_grow_release_roundtrip() {
        let pool = Arc::new(BlockPool::with_blocks(&tiny(), 4, 3));
        let mut cache = PagedKvCache::new(pool.clone());
        assert!(cache.grow(5), "2 blocks for 5 positions");
        assert_eq!(cache.n_blocks(), 2);
        assert_eq!(pool.used_blocks(), 2);
        let mut other = PagedKvCache::new(pool.clone());
        assert!(other.grow(4));
        assert!(!cache.grow(9), "pool dry: 3rd block unavailable");
        drop(other);
        assert!(cache.grow(9), "freed block re-leased");
        drop(cache);
        assert_eq!(pool.used_blocks(), 0, "drop returns every block");
    }

    #[test]
    fn paged_attend_matches_monolithic_bit_for_bit() {
        // same appended rows through both cache layouts → identical
        // context, even when positions span multiple blocks
        let config = tiny();
        let (layers, hidden) = (config.n_layers, config.hidden);
        let pool = Arc::new(BlockPool::with_blocks(&config, 3, 8));
        let mut paged = PagedKvCache::new(pool);
        let mut mono = KvCache::new(layers, hidden);
        assert!(paged.grow(7));
        let mut rng = crate::tensor::Pcg64::seeded(42);
        for _pos in 0..7 {
            for layer in 0..layers {
                let k = Matrix::randn(1, hidden, 1.0, &mut rng);
                let v = Matrix::randn(1, hidden, 1.0, &mut rng);
                KvSlot::append(&mut paged, layer, k.row(0), v.row(0));
                mono.append(layer, k.row(0), v.row(0));
            }
        }
        assert_eq!(KvSlot::len(&paged), 7);
        assert_eq!(mono.len(), 7);
        let q = Matrix::randn(1, hidden, 1.0, &mut rng);
        let scale = 1.0 / ((hidden / config.n_heads) as f32).sqrt();
        for layer in 0..layers {
            let a = paged.attend(layer, &q, config.n_heads, config.head_dim(), scale);
            let b = KvSlot::attend(&mut mono, layer, &q, config.n_heads, config.head_dim(), scale);
            assert_eq!(a, b, "layer {layer}: paged == monolithic, bitwise");
        }
    }
}
