//! S14: the continuous-batching scheduler — iteration-level scheduling
//! over a paged KV-cache block pool.
//!
//! The run-to-completion worker loop (PR 1) executes a whole tenant
//! batch before touching the queue again, so one long generation
//! head-of-line-blocks every request behind it and mixed-tenant traffic
//! never shares a decode step. This module replaces that with the
//! vLLM-style scheme:
//!
//! ```text
//!   submit() ─▶ Batcher (per-tenant FIFO queues, bounded → 429)
//!                 │ oldest-head-first admission, FCFS across tenants
//!                 ▼
//!   Scheduler drive loop — every iteration:
//!     admit      while slots + KV blocks allow: pop the oldest waiting
//!                request (resuming preempted sequences first), acquire
//!                its tenant view, lease prompt blocks from the pool
//!     plan       StepBatch = {prefill slots, decode slots} over every
//!                running sequence — mixed tenants in one step
//!     execute    prefill slots cache one bounded chunk each
//!                (`prefill_chunk`); decode slots are decided in plan
//!                order, grouped by tenant, and each group runs as ONE
//!                stacked t=k forward — one fused X·(W_b+ΔŴ)ᵀ per
//!                (tenant, layer) — with independent groups fanned over
//!                the backend's worker pool. Each decoded token streams
//!                out immediately; a dead stream cancels the sequence
//!                and frees its blocks
//!     preempt    a sequence that cannot lease its next block preempts
//!                the *youngest* running sequence back to the queue
//!                (its blocks free instantly; it resumes later by
//!                re-prefilling prompt + generated — greedy decoding is
//!                deterministic, so the continuation is bit-identical)
//! ```
//!
//! The KV pool ([`BlockPool`]) is the admission controller: it never
//! leases past its byte budget, so KV memory is bounded no matter how
//! many sequences are admitted or how long they run.
//!
//! Backends opt in via [`crate::runtime::ExecutionBackend`]'s
//! `supports_stepping` / `prefill_step` / `decode_step`; backends
//! without the stepping API (pjrt) keep the legacy run-to-completion
//! loop. Streamed tokens are bit-identical between the two paths
//! (pinned by `tests/sched_serving.rs`).

pub mod block;
pub mod scheduler;

pub use block::{BlockPool, PagedKvCache};
pub use scheduler::{drive_loop, StepBatch};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::hist::LatencyHistogram;

/// One stage of a scheduler iteration, timed per step and exported as
/// a native Prometheus histogram (`deltadq_sched_stage_seconds`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedStage {
    /// Deadline sweep + step planning (building the [`StepBatch`]).
    Plan,
    /// Bounded prefill chunks for every prefill slot.
    Prefill,
    /// Decode execution (token decisions + grouped stacked forwards).
    Decode,
    /// Post-execute bookkeeping: finished-sequence sweep, slot frees,
    /// gauge publication.
    Emit,
}

impl SchedStage {
    /// Every stage, in execution order.
    pub const ALL: [SchedStage; 4] =
        [SchedStage::Plan, SchedStage::Prefill, SchedStage::Decode, SchedStage::Emit];

    /// The stage's label value on `/metrics`.
    pub fn name(self) -> &'static str {
        match self {
            SchedStage::Plan => "plan",
            SchedStage::Prefill => "prefill",
            SchedStage::Decode => "decode",
            SchedStage::Emit => "emit",
        }
    }

    fn index(self) -> usize {
        match self {
            SchedStage::Plan => 0,
            SchedStage::Prefill => 1,
            SchedStage::Decode => 2,
            SchedStage::Emit => 3,
        }
    }
}

/// How the drive loop executes the decode half of a [`StepBatch`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StepExec {
    /// Group decode slots by tenant and run one stacked `t=k` forward
    /// per group, fanning independent groups over the backend's worker
    /// pool. Streams are bit-identical to [`StepExec::PerSequence`].
    #[default]
    Batched,
    /// One `decode_step` call per slot, in plan order — the PR 5
    /// baseline, kept as the bit-identity oracle and the reference
    /// phase of `bench --name decode`.
    PerSequence,
}

/// Scheduler construction knobs (the `[sched]` config section resolved
/// to concrete values).
#[derive(Debug, Clone)]
pub struct SchedOptions {
    /// KV block-pool budget in bytes — the hard cap on paged KV memory.
    pub kv_pool_bytes: u64,
    /// Positions per KV block.
    pub block_size: usize,
    /// Max sequences decoding concurrently (`0` = inherit the server's
    /// `max_batch`).
    pub max_running: usize,
    /// Max prompt positions cached per sequence per iteration (`0` =
    /// the whole prompt in one go). Bounding the chunk keeps long
    /// prompts from stalling every decoding sequence for a full-prompt
    /// prefill; chunking never changes any cached bit.
    pub prefill_chunk: usize,
    /// Decode execution strategy (see [`StepExec`]).
    pub step_exec: StepExec,
}

impl Default for SchedOptions {
    fn default() -> SchedOptions {
        SchedOptions {
            kv_pool_bytes: 64 << 20,
            block_size: 16,
            max_running: 0,
            prefill_chunk: 64,
            step_exec: StepExec::Batched,
        }
    }
}

/// Live scheduler gauges and counters, shared between the drive loop
/// (writer) and [`crate::coordinator::Metrics`] (reader) — the same
/// pattern as the store's `TierCounters`.
#[derive(Debug, Default)]
pub struct SchedCounters {
    /// Sequences currently holding a running slot.
    pub running: AtomicU64,
    /// Requests waiting: queued in the batcher plus preempted.
    pub waiting: AtomicU64,
    /// Preemptions (youngest sequence pushed back to the queue).
    pub preempted_total: AtomicU64,
    /// Sequences cancelled because their stream receiver vanished.
    pub cancelled_total: AtomicU64,
    /// KV pool blocks currently leased.
    pub kv_blocks_used: AtomicU64,
    /// KV pool blocks available.
    pub kv_blocks_free: AtomicU64,
    /// KV pool capacity in blocks.
    pub kv_blocks_total: AtomicU64,
    /// Scheduler iterations executed.
    pub steps_executed: AtomicU64,
    /// Tenant groups executed by the batched decode path (one stacked
    /// forward each).
    pub decode_groups_total: AtomicU64,
    /// Decode lanes executed through the batched path (sequences
    /// stacked into groups; `lanes / groups` = mean group depth).
    pub decode_lanes_total: AtomicU64,
    /// Bounded prefill chunks executed (one backend call each).
    pub prefill_chunks_total: AtomicU64,
    /// Requests terminated because their deadline (TTL) expired —
    /// at admission or mid-decode; KV blocks were freed either way.
    pub deadline_expired_total: AtomicU64,
    /// Decode groups whose backend call panicked and was contained by
    /// `catch_unwind` (only that group's sequences got error frames).
    pub decode_group_panics_total: AtomicU64,
    /// Trace-epoch µs timestamp of the drive loop's latest iteration
    /// (stamped every `publish`, idle or busy) — `0` until the loop has
    /// run once. `GET /healthz` reports its age as drive-thread
    /// liveness.
    pub last_heartbeat_us: AtomicU64,
    /// Per-step batch occupancy (running sequences per iteration).
    occupancy: Mutex<LatencyHistogram>,
    /// Per-group lane count of every batched decode group executed.
    group_sizes: Mutex<LatencyHistogram>,
    /// Per-iteration wall time of each [`SchedStage`], indexed by
    /// `SchedStage::index`.
    stages: [Mutex<LatencyHistogram>; 4],
}

impl SchedCounters {
    /// Record one iteration's batch occupancy.
    pub fn observe_occupancy(&self, slots: usize) {
        self.occupancy.lock().unwrap().record(slots as f64);
    }

    /// Copy of the per-step occupancy histogram.
    pub fn occupancy_histogram(&self) -> LatencyHistogram {
        self.occupancy.lock().unwrap().clone()
    }

    /// Record one executed decode group's lane count.
    pub fn observe_group(&self, lanes: usize) {
        self.group_sizes.lock().unwrap().record(lanes as f64);
    }

    /// Copy of the per-group lane-count histogram.
    pub fn group_size_histogram(&self) -> LatencyHistogram {
        self.group_sizes.lock().unwrap().clone()
    }

    /// Record one iteration's wall time for `stage`.
    pub fn observe_stage(&self, stage: SchedStage, elapsed: Duration) {
        self.stages[stage.index()].lock().unwrap().record(elapsed.as_secs_f64());
    }

    /// Copy of one stage's per-iteration wall-time histogram.
    pub fn stage_histogram(&self, stage: SchedStage) -> LatencyHistogram {
        self.stages[stage.index()].lock().unwrap().clone()
    }

    /// Point-in-time snapshot of every gauge/counter.
    pub fn stats(&self) -> SchedStats {
        SchedStats {
            running: self.running.load(Ordering::Relaxed),
            waiting: self.waiting.load(Ordering::Relaxed),
            preempted_total: self.preempted_total.load(Ordering::Relaxed),
            cancelled_total: self.cancelled_total.load(Ordering::Relaxed),
            kv_blocks_used: self.kv_blocks_used.load(Ordering::Relaxed),
            kv_blocks_free: self.kv_blocks_free.load(Ordering::Relaxed),
            kv_blocks_total: self.kv_blocks_total.load(Ordering::Relaxed),
            steps_executed: self.steps_executed.load(Ordering::Relaxed),
            decode_groups_total: self.decode_groups_total.load(Ordering::Relaxed),
            decode_lanes_total: self.decode_lanes_total.load(Ordering::Relaxed),
            prefill_chunks_total: self.prefill_chunks_total.load(Ordering::Relaxed),
            deadline_expired_total: self.deadline_expired_total.load(Ordering::Relaxed),
            decode_group_panics_total: self.decode_group_panics_total.load(Ordering::Relaxed),
            last_heartbeat_us: self.last_heartbeat_us.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of [`SchedCounters`] (`Server::sched_stats`).
#[derive(Debug, Clone, Copy)]
pub struct SchedStats {
    /// Sequences currently holding a running slot.
    pub running: u64,
    /// Requests waiting: queued in the batcher plus preempted.
    pub waiting: u64,
    /// Preemptions (youngest sequence pushed back to the queue).
    pub preempted_total: u64,
    /// Sequences cancelled because their stream receiver vanished.
    pub cancelled_total: u64,
    /// KV pool blocks currently leased.
    pub kv_blocks_used: u64,
    /// KV pool blocks available.
    pub kv_blocks_free: u64,
    /// KV pool capacity in blocks.
    pub kv_blocks_total: u64,
    /// Scheduler iterations executed.
    pub steps_executed: u64,
    /// Tenant groups executed by the batched decode path.
    pub decode_groups_total: u64,
    /// Decode lanes executed through the batched path.
    pub decode_lanes_total: u64,
    /// Bounded prefill chunks executed.
    pub prefill_chunks_total: u64,
    /// Requests terminated by an expired deadline (TTL).
    pub deadline_expired_total: u64,
    /// Decode-group panics contained by `catch_unwind`.
    pub decode_group_panics_total: u64,
    /// Trace-epoch µs stamp of the drive loop's latest iteration
    /// (`0` until the loop has run once).
    pub last_heartbeat_us: u64,
}
