//! Binary weight file I/O — the `.dqw` format shared with the python
//! training pipeline (`python/compile/train.py` writes it, this module
//! reads and writes it).
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   b"DDQW"
//! version u32 (=1)
//! config  u32 ×6: vocab, hidden, n_layers, n_heads, ffn, max_seq
//! count   u32 number of tensors
//! tensor* name_len u16 | name utf-8 | rows u32 | cols u32 | f32 data
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::ModelConfig;
use crate::model::weights::ModelWeights;
use crate::tensor::Matrix;

const MAGIC: &[u8; 4] = b"DDQW";
const VERSION: u32 = 1;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut buf = [0u8; 2];
    r.read_exact(&mut buf)?;
    Ok(u16::from_le_bytes(buf))
}

/// Save weights to a `.dqw` file.
pub fn save_weights(path: &Path, weights: &ModelWeights) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    let c = weights.config;
    for v in [c.vocab_size, c.hidden, c.n_layers, c.n_heads, c.ffn_hidden, c.max_seq] {
        write_u32(&mut w, v as u32)?;
    }
    write_u32(&mut w, weights.len() as u32)?;
    for (name, tensor) in weights.iter() {
        let name_bytes = name.as_bytes();
        if name_bytes.len() > u16::MAX as usize {
            bail!("tensor name too long: {name}");
        }
        w.write_all(&(name_bytes.len() as u16).to_le_bytes())?;
        w.write_all(name_bytes)?;
        write_u32(&mut w, tensor.rows() as u32)?;
        write_u32(&mut w, tensor.cols() as u32)?;
        // bulk-write the row data
        let data = tensor.data();
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        w.write_all(&bytes)?;
    }
    w.flush()?;
    Ok(())
}

/// Load weights from a `.dqw` file, validating completeness and shapes.
pub fn load_weights(path: &Path) -> Result<ModelWeights> {
    let file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic {magic:?} (expected DDQW)");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("{path:?}: unsupported version {version}");
    }
    let config = ModelConfig {
        vocab_size: read_u32(&mut r)? as usize,
        hidden: read_u32(&mut r)? as usize,
        n_layers: read_u32(&mut r)? as usize,
        n_heads: read_u32(&mut r)? as usize,
        ffn_hidden: read_u32(&mut r)? as usize,
        max_seq: read_u32(&mut r)? as usize,
    };
    let count = read_u32(&mut r)? as usize;
    let mut weights = ModelWeights::empty(config);
    for _ in 0..count {
        let name_len = read_u16(&mut r)? as usize;
        let mut name_bytes = vec![0u8; name_len];
        r.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).context("tensor name utf-8")?;
        let rows = read_u32(&mut r)? as usize;
        let cols = read_u32(&mut r)? as usize;
        let n = rows
            .checked_mul(cols)
            .with_context(|| format!("tensor '{name}' size overflow"))?;
        let mut bytes = vec![0u8; n * 4];
        r.read_exact(&mut bytes)
            .with_context(|| format!("tensor '{name}' data truncated"))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        weights.insert(&name, Matrix::from_vec(rows, cols, data));
    }
    let problems = weights.validate();
    if !problems.is_empty() {
        bail!("{path:?}: invalid weights: {}", problems.join("; "));
    }
    Ok(weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("deltadq-test-io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seeded(1);
        let w = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let path = tmpfile("roundtrip.dqw");
        save_weights(&path, &w).unwrap();
        let loaded = load_weights(&path).unwrap();
        assert_eq!(loaded.config, w.config);
        assert_eq!(loaded.len(), w.len());
        for (name, tensor) in w.iter() {
            assert_eq!(loaded.get(name), tensor, "{name}");
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("bad-magic.dqw");
        std::fs::write(&path, b"NOPE0000").unwrap();
        let err = load_weights(&path).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn rejects_truncated_file() {
        let mut rng = Pcg64::seeded(2);
        let w = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let path = tmpfile("truncated.dqw");
        save_weights(&path, &w).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_weights(&path).is_err());
    }

    #[test]
    fn rejects_incomplete_tensor_set() {
        // write a file with a valid header but zero tensors
        let path = tmpfile("incomplete.dqw");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"DDQW");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        for v in [512u32, 64, 4, 4, 192, 128] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_weights(&path).unwrap_err();
        assert!(err.to_string().contains("missing tensor"), "{err}");
    }
}
