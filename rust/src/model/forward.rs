//! Transformer forward pass, generic over the weight source so the same
//! code path serves both dense fine-tuned weights and the paper's
//! **separate computation** scheme (Fig. 3): `X·W_bᵀ + X·ΔŴᵀ` with the
//! delta kept compressed.

use std::collections::BTreeMap;

use crate::compress::CompressedDelta;
use crate::model::config::ModelConfig;
use crate::model::kvcache::{KvCache, KvSlot};
use crate::model::weights::ModelWeights;
use crate::tensor::ops;
use crate::tensor::Matrix;

/// Where a layer's weights come from.
pub trait WeightSource {
    fn config(&self) -> ModelConfig;

    /// Direct tensor access (norm gains, embeddings — never compressed).
    fn dense(&self, name: &str) -> &Matrix;

    /// Linear projection `X·Wᵀ` for the named weight matrix. Dense
    /// sources do one matmul; delta sources do the separate computation.
    fn linear(&self, name: &str, x: &Matrix) -> Matrix;
}

impl WeightSource for ModelWeights {
    fn config(&self) -> ModelConfig {
        self.config
    }

    fn dense(&self, name: &str) -> &Matrix {
        self.get(name)
    }

    fn linear(&self, name: &str, x: &Matrix) -> Matrix {
        x.matmul_nt(self.get(name))
    }
}

/// Separate-computation view: a shared base model plus one tenant's
/// compressed deltas. `Y = X·W_bᵀ + X·ΔŴᵀ` per linear layer — the delta
/// term runs on the compressed representation (CSR / decomposed parts),
/// exactly the deployment scheme of §3.1.
pub struct DeltaView<'a> {
    /// The shared base model.
    pub base: &'a ModelWeights,
    /// One tenant's compressed per-tensor deltas.
    pub deltas: &'a BTreeMap<String, CompressedDelta>,
}

impl<'a> WeightSource for DeltaView<'a> {
    fn config(&self) -> ModelConfig {
        self.base.config
    }

    fn dense(&self, name: &str) -> &Matrix {
        self.base.get(name)
    }

    fn linear(&self, name: &str, x: &Matrix) -> Matrix {
        let mut out = x.matmul_nt(self.base.get(name));
        if let Some(delta) = self.deltas.get(name) {
            let delta_out = delta.matmul_nt_from_dense(x);
            out.add_assign(&delta_out);
        }
        out
    }
}

/// Multi-head causal self-attention over a full sequence.
/// `x: t×h` → `t×h`. Also returns (K, V) for cache priming.
fn attention<S: WeightSource>(
    source: &S,
    layer: usize,
    x: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    let c = source.config();
    let (t, h) = x.shape();
    let d = c.head_dim();
    let p = |tname: &str| format!("layers.{layer}.{tname}");
    let q = source.linear(&p("attn.wq"), x);
    let k = source.linear(&p("attn.wk"), x);
    let v = source.linear(&p("attn.wv"), x);
    let scale = 1.0 / (d as f32).sqrt();
    let mut ctx = Matrix::zeros(t, h);
    for head in 0..c.n_heads {
        let lo = head * d;
        let hi = lo + d;
        let qh = q.slice_cols(lo, hi);
        let kh = k.slice_cols(lo, hi);
        let vh = v.slice_cols(lo, hi);
        let mut scores = qh.matmul_nt(&kh);
        scores.scale(scale);
        ops::apply_causal_mask(&mut scores);
        ops::softmax_rows(&mut scores);
        let out = scores.matmul_nn(&vh);
        ctx.set_cols(lo, &out);
    }
    (source.linear(&p("attn.wo"), &ctx), k, v)
}

/// SwiGLU MLP: `down( silu(gate(x)) ⊙ up(x) )`.
fn mlp<S: WeightSource>(source: &S, layer: usize, x: &Matrix) -> Matrix {
    let p = |tname: &str| format!("layers.{layer}.{tname}");
    let mut gate = source.linear(&p("mlp.gate"), x);
    ops::silu(&mut gate);
    let up = source.linear(&p("mlp.up"), x);
    let fused = gate.hadamard(&up);
    source.linear(&p("mlp.down"), &fused)
}

/// Full-sequence forward: token ids → logits (`t × vocab`).
pub fn forward<S: WeightSource>(source: &S, tokens: &[u32]) -> Matrix {
    let c = source.config();
    assert!(!tokens.is_empty(), "empty sequence");
    assert!(tokens.len() <= c.max_seq, "sequence {} > max_seq {}", tokens.len(), c.max_seq);
    let mut x = ops::embed(source.dense("tok_emb"), tokens);
    let pos = source.dense("pos_emb");
    for (i, row) in x.data_mut().chunks_exact_mut(c.hidden).enumerate() {
        for (a, b) in row.iter_mut().zip(pos.row(i)) {
            *a += b;
        }
    }
    for layer in 0..c.n_layers {
        let p = |tname: &str| format!("layers.{layer}.{tname}");
        let mut normed = x.clone();
        ops::rmsnorm_rows(&mut normed, source.dense(&p("attn_norm")).row(0), 1e-6);
        let (attn_out, _, _) = attention(source, layer, &normed);
        x.add_assign(&attn_out);
        let mut normed = x.clone();
        ops::rmsnorm_rows(&mut normed, source.dense(&p("mlp_norm")).row(0), 1e-6);
        let mlp_out = mlp(source, layer, &normed);
        x.add_assign(&mlp_out);
    }
    ops::rmsnorm_rows(&mut x, source.dense("final_norm").row(0), 1e-6);
    source.linear("lm_head", &x)
}

/// One sequence's contribution to a stacked step: the token to feed,
/// its absolute position, and the KV slot it appends to / attends
/// through. Independent sequences become independent lanes of one
/// [`forward_steps`] call.
pub struct StepLane<'a, K: KvSlot + ?Sized> {
    /// Token fed at this lane's position.
    pub token: u32,
    /// Absolute position of `token` (the cache holds `0..pos`).
    pub pos: usize,
    /// The lane's per-sequence KV cache.
    pub cache: &'a mut K,
}

/// The stacked transformer step shared by [`forward_steps`] (one lane
/// per sequence, distinct caches) and [`prefill_into`] (consecutive
/// positions of one sequence, one shared cache). All dense work —
/// embeds, norms, and every linear — runs over the `t`-row stack in one
/// call; only attention is per-row, driven by `attend(layer, q, k, v)`
/// which must append row `i` before attending it (causality when rows
/// share a cache).
///
/// `last_only` restricts the lm_head projection to the final row
/// (`1 × vocab`) — the prefill case, where earlier rows' logits are
/// never read. Row bits are unchanged either way: the tiled kernel's
/// per-element sums do not depend on how many activation rows share a
/// call, so row `i` of a stacked product is bit-identical to the same
/// activation pushed through alone.
fn forward_stacked<S: WeightSource>(
    source: &S,
    tokens: &[u32],
    positions: &[usize],
    last_only: bool,
    attend: &mut dyn FnMut(usize, &Matrix, &Matrix, &Matrix) -> Matrix,
) -> Matrix {
    let c = source.config();
    let t = tokens.len();
    assert!(t > 0, "stacked step over zero lanes");
    let mut x = ops::embed(source.dense("tok_emb"), tokens);
    let pos_emb = source.dense("pos_emb");
    for (row, &pos) in x.data_mut().chunks_exact_mut(c.hidden).zip(positions) {
        for (a, b) in row.iter_mut().zip(pos_emb.row(pos)) {
            *a += b;
        }
    }
    for layer in 0..c.n_layers {
        let p = |tname: &str| format!("layers.{layer}.{tname}");
        let mut normed = x.clone();
        ops::rmsnorm_rows(&mut normed, source.dense(&p("attn_norm")).row(0), 1e-6);
        let q = source.linear(&p("attn.wq"), &normed);
        let k = source.linear(&p("attn.wk"), &normed);
        let v = source.linear(&p("attn.wv"), &normed);
        let ctx = attend(layer, &q, &k, &v);
        let attn_out = source.linear(&p("attn.wo"), &ctx);
        x.add_assign(&attn_out);
        let mut normed = x.clone();
        ops::rmsnorm_rows(&mut normed, source.dense(&p("mlp_norm")).row(0), 1e-6);
        let mlp_out = mlp(source, layer, &normed);
        x.add_assign(&mlp_out);
    }
    if last_only {
        let mut last = Matrix::from_vec(1, c.hidden, x.row(t - 1).to_vec());
        ops::rmsnorm_rows(&mut last, source.dense("final_norm").row(0), 1e-6);
        source.linear("lm_head", &last)
    } else {
        ops::rmsnorm_rows(&mut x, source.dense("final_norm").row(0), 1e-6);
        source.linear("lm_head", &x)
    }
}

/// Stacked decode step over independent sequences: one token per lane,
/// each lane with its own KV cache, all dense work fused into `t`-row
/// matmuls. Returns logits row `i` for lane `i` (`t × vocab`).
///
/// Row `i` is **bit-identical** to a separate [`forward_step`] call for
/// the same lane: the tiled matmul's per-element sums are invariant to
/// the number of activation rows in a call, norms are per-row, and each
/// lane's attention still runs as a single query row over its own
/// cache. This is the invariant the scheduler's batched drive loop
/// rests on — stacking sequences changes throughput, never bits.
pub fn forward_steps<S: WeightSource, K: KvSlot + ?Sized>(
    source: &S,
    lanes: &mut [StepLane<'_, K>],
) -> Matrix {
    let c = source.config();
    let d = c.head_dim();
    let scale = 1.0 / (d as f32).sqrt();
    let tokens: Vec<u32> = lanes.iter().map(|l| l.token).collect();
    let positions: Vec<usize> = lanes.iter().map(|l| l.pos).collect();
    for lane in lanes.iter() {
        assert!(lane.pos < c.max_seq, "position {} ≥ max_seq {}", lane.pos, c.max_seq);
        assert_eq!(
            lane.cache.len(),
            lane.pos,
            "cache holds {} positions, expected {}",
            lane.cache.len(),
            lane.pos
        );
    }
    forward_stacked(source, &tokens, &positions, false, &mut |layer, q, k, v| {
        let mut ctx = Matrix::zeros(lanes.len(), c.hidden);
        for (i, lane) in lanes.iter_mut().enumerate() {
            lane.cache.append(layer, k.row(i), v.row(i));
            let qi = Matrix::from_vec(1, c.hidden, q.row(i).to_vec());
            let out = lane.cache.attend(layer, &qi, c.n_heads, d, scale);
            ctx.row_mut(i).copy_from_slice(out.row(0));
        }
        ctx
    })
}

/// Single-token decode step with KV cache. `pos` is the absolute
/// position of `token`; the cache must hold positions `0..pos`.
/// Returns logits (`1 × vocab`).
///
/// Generic over the cache layout ([`KvSlot`]): the monolithic
/// [`KvCache`] and the scheduler's paged cache attend through the same
/// kernel, so the layout never changes a single output bit. This is
/// the one-lane case of [`forward_steps`].
pub fn forward_step<S: WeightSource, K: KvSlot + ?Sized>(
    source: &S,
    token: u32,
    pos: usize,
    cache: &mut K,
) -> Matrix {
    let mut lanes = [StepLane { token, pos, cache }];
    forward_steps(source, &mut lanes)
}

/// Step-level prefill: cache `tokens` starting at the cache's current
/// length and return the last position's logits (`1 × vocab`). This is
/// the entry point the iteration-level scheduler uses to (re)prime a
/// sequence — after a preemption, `tokens` is the prompt plus
/// everything already generated, and the deterministic greedy decode
/// continues exactly where it left off.
///
/// All positions run as one stacked pass: each layer computes its
/// q/k/v/mlp projections for the whole span in `t`-row matmuls, while
/// K/V rows are appended and attended position-by-position (append `i`,
/// attend `i`, then `i+1` — exactly the per-step order, so the cached
/// bits and the returned logits match a loop of [`forward_step`] calls
/// exactly). Chunked prefill (several `prefill_into` calls over
/// consecutive spans) is likewise bit-identical to one call: the stack
/// boundary never changes any row's arithmetic.
pub fn prefill_into<S: WeightSource, K: KvSlot + ?Sized>(
    source: &S,
    tokens: &[u32],
    cache: &mut K,
) -> Matrix {
    assert!(!tokens.is_empty(), "prefill over an empty prefix");
    let c = source.config();
    let d = c.head_dim();
    let scale = 1.0 / (d as f32).sqrt();
    let start = cache.len();
    let end = start + tokens.len();
    assert!(end <= c.max_seq, "position {} ≥ max_seq {}", end - 1, c.max_seq);
    let positions: Vec<usize> = (start..end).collect();
    forward_stacked(source, tokens, &positions, true, &mut |layer, q, k, v| {
        let mut ctx = Matrix::zeros(tokens.len(), c.hidden);
        for i in 0..tokens.len() {
            cache.append(layer, k.row(i), v.row(i));
            let qi = Matrix::from_vec(1, c.hidden, q.row(i).to_vec());
            let out = cache.attend(layer, &qi, c.n_heads, d, scale);
            ctx.row_mut(i).copy_from_slice(out.row(0));
        }
        ctx
    })
}

/// Greedy decode: feed `prompt`, then generate up to `max_new` tokens
/// (stopping at `eos` if given). Returns only the generated tokens.
pub fn generate<S: WeightSource>(
    source: &S,
    prompt: &[u32],
    max_new: usize,
    eos: Option<u32>,
) -> Vec<u32> {
    generate_with(source, prompt, max_new, eos, &mut |_| {})
}

/// [`generate`] with a per-token observer: `on_token` fires the moment
/// each token is decoded, *before* the next forward step — the hook the
/// gateway's SSE streaming rides on. The returned vector is identical
/// to `generate`'s for the same inputs (the decode loop is shared).
pub fn generate_with<S: WeightSource>(
    source: &S,
    prompt: &[u32],
    max_new: usize,
    eos: Option<u32>,
    on_token: &mut dyn FnMut(u32),
) -> Vec<u32> {
    let c = source.config();
    let mut cache = KvCache::new(c.n_layers, c.hidden);
    let mut out = Vec::new();
    let mut last_logits = Matrix::zeros(1, c.vocab_size);
    for (pos, &tok) in prompt.iter().enumerate() {
        last_logits = forward_step(source, tok, pos, &mut cache);
    }
    let mut pos = prompt.len();
    for _ in 0..max_new {
        if pos >= c.max_seq {
            break;
        }
        let next = ops::argmax_rows(&last_logits)[0];
        if Some(next) == eos {
            break;
        }
        out.push(next);
        on_token(next);
        last_logits = forward_step(source, next, pos, &mut cache);
        pos += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::tensor::Pcg64;

    fn model(seed: u64) -> ModelWeights {
        let mut rng = Pcg64::seeded(seed);
        ModelWeights::init(ModelConfig::tiny(), &mut rng)
    }

    #[test]
    fn forward_shapes() {
        let w = model(1);
        let logits = forward(&w, &[1, 2, 3, 4, 5]);
        assert_eq!(logits.shape(), (5, 512));
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position i must not depend on tokens after i
        let w = model(2);
        let full = forward(&w, &[5, 6, 7, 8]);
        let prefix = forward(&w, &[5, 6]);
        for c in 0..512 {
            assert!((full.get(0, c) - prefix.get(0, c)).abs() < 1e-4);
            assert!((full.get(1, c) - prefix.get(1, c)).abs() < 1e-4);
        }
    }

    #[test]
    fn cached_decode_matches_full_forward() {
        let w = model(3);
        let tokens = [3u32, 1, 4, 1, 5, 9];
        let full = forward(&w, &tokens);
        let mut cache = KvCache::new(w.config.n_layers, w.config.hidden);
        for (pos, &tok) in tokens.iter().enumerate() {
            let step = forward_step(&w, tok, pos, &mut cache);
            for c in 0..512 {
                assert!(
                    (full.get(pos, c) - step.get(0, c)).abs() < 1e-3,
                    "pos {pos} col {c}: {} vs {}",
                    full.get(pos, c),
                    step.get(0, c)
                );
            }
        }
    }

    #[test]
    fn delta_view_identity_when_no_deltas() {
        let w = model(4);
        let deltas = BTreeMap::new();
        let view = DeltaView { base: &w, deltas: &deltas };
        let a = forward(&w, &[1, 2, 3]);
        let b = forward(&view, &[1, 2, 3]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
    }

    #[test]
    fn delta_view_separate_computation_matches_merged() {
        // Build a fine-tuned model = base + dense deltas; check that the
        // separate-computation path (base + CSR delta) gives the same
        // logits as merging the delta into the weights.
        let base = model(5);
        let c = base.config;
        let mut rng = Pcg64::seeded(6);
        let mut dense_deltas = BTreeMap::new();
        let mut compressed = BTreeMap::new();
        for name in c.delta_tensor_names() {
            let shape = base.get(&name).shape();
            let d = Matrix::randn(shape.0, shape.1, 0.002, &mut rng);
            // keep every element: alpha=1 dropout => exact CSR of delta
            let dq = DeltaDq::new(DeltaDqConfig::dropout_only(1.0, None));
            let cd = dq.compress(&d, &LayerContext::data_free(0, &name), &mut rng);
            dense_deltas.insert(name.clone(), d);
            compressed.insert(name, cd);
        }
        let merged = base.apply_deltas(&dense_deltas);
        let view = DeltaView { base: &base, deltas: &compressed };
        let a = forward(&merged, &[7, 8, 9, 10]);
        let b = forward(&view, &[7, 8, 9, 10]);
        assert!(a.allclose(&b, 1e-3, 1e-3));
    }

    #[test]
    fn stacked_steps_bit_match_single_lane_steps() {
        // The batched drive loop's core invariant: row i of a stacked
        // forward_steps call is bit-identical to a lone forward_step for
        // the same lane, even when lanes sit at different positions.
        let w = model(11);
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[4, 5, 6, 7], &[9]];
        let decode_steps = 4;

        // Reference: each lane decodes alone.
        let mut ref_logits: Vec<Vec<Vec<f32>>> = Vec::new();
        let mut ref_streams: Vec<Vec<u32>> = Vec::new();
        for prompt in prompts {
            let mut cache = KvCache::new(w.config.n_layers, w.config.hidden);
            let logits = prefill_into(&w, prompt, &mut cache);
            let mut token = ops::argmax_rows(&logits)[0];
            let mut per_step = Vec::new();
            let mut stream = Vec::new();
            for step in 0..decode_steps {
                let l = forward_step(&w, token, prompt.len() + step, &mut cache);
                token = ops::argmax_rows(&l)[0];
                per_step.push(l.data().to_vec());
                stream.push(token);
            }
            ref_logits.push(per_step);
            ref_streams.push(stream);
        }

        // Stacked: all three lanes share each forward_steps call.
        let mut caches: Vec<KvCache> = Vec::new();
        let mut tokens: Vec<u32> = Vec::new();
        for prompt in prompts {
            let mut cache = KvCache::new(w.config.n_layers, w.config.hidden);
            let logits = prefill_into(&w, prompt, &mut cache);
            tokens.push(ops::argmax_rows(&logits)[0]);
            caches.push(cache);
        }
        let vocab = w.config.vocab_size;
        for step in 0..decode_steps {
            let mut lanes: Vec<StepLane<'_, KvCache>> = caches
                .iter_mut()
                .enumerate()
                .map(|(i, cache)| StepLane {
                    token: tokens[i],
                    pos: prompts[i].len() + step,
                    cache,
                })
                .collect();
            let stacked = forward_steps(&w, &mut lanes);
            assert_eq!(stacked.shape(), (prompts.len(), vocab));
            tokens = ops::argmax_rows(&stacked);
            for i in 0..prompts.len() {
                assert_eq!(
                    stacked.row(i),
                    &ref_logits[i][step][..],
                    "lane {i} step {step}: stacked logits diverged from solo decode"
                );
                assert_eq!(tokens[i], ref_streams[i][step]);
            }
        }
    }

    #[test]
    fn chunked_prefill_bit_matches_whole_prefill() {
        // prefill_into resumes from cache.len(), so splitting a prompt
        // into chunks of any size must reproduce the one-call run
        // bit-for-bit — final logits and every cached K/V row.
        let w = model(12);
        let prompt: Vec<u32> = vec![3, 1, 4, 1, 5, 9, 2, 6];
        let mut whole_cache = KvCache::new(w.config.n_layers, w.config.hidden);
        let whole = prefill_into(&w, &prompt, &mut whole_cache);

        for chunk in [1usize, 3, 8] {
            let mut cache = KvCache::new(w.config.n_layers, w.config.hidden);
            let mut last = None;
            for span in prompt.chunks(chunk) {
                last = Some(prefill_into(&w, span, &mut cache));
            }
            let last = last.unwrap();
            assert_eq!(
                last.data(),
                whole.data(),
                "chunk size {chunk}: final logits diverged from whole-prompt prefill"
            );
            assert_eq!(cache.len(), whole_cache.len());
            for layer in 0..w.config.n_layers {
                let (k, v) = cache.layer(layer);
                let (wk, wv) = whole_cache.layer(layer);
                assert_eq!(k.data(), wk.data(), "chunk {chunk} layer {layer}: keys diverged");
                assert_eq!(v.data(), wv.data(), "chunk {chunk} layer {layer}: values diverged");
            }
        }
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let w = model(7);
        let g1 = generate(&w, &[1, 2, 3], 8, None);
        let g2 = generate(&w, &[1, 2, 3], 8, None);
        assert_eq!(g1, g2);
        assert_eq!(g1.len(), 8);
        assert!(g1.iter().all(|&t| (t as usize) < w.config.vocab_size));
    }

    #[test]
    fn generate_respects_eos() {
        let w = model(8);
        let free = generate(&w, &[1, 2], 16, None);
        // using the first generated token as EOS must stop immediately
        let stopped = generate(&w, &[1, 2], 16, Some(free[0]));
        assert!(stopped.is_empty());
    }

    #[test]
    #[should_panic(expected = "max_seq")]
    fn over_length_sequence_panics() {
        let w = model(9);
        let tokens: Vec<u32> = (0..200).map(|i| i % 16).collect();
        let _ = forward(&w, &tokens);
    }
}
