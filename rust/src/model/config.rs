//! Transformer model configuration and the scale presets standing in
//! for the paper's WizardMath/WizardCoder parameter scales.
//!
//! The paper evaluates {7B, 13B, 70B} (math) and {7B, 13B, 34B} (code).
//! On this CPU-only testbed we map those to {tiny, small, base} presets
//! (DESIGN.md §2) and keep a `large` (~95M) preset for the end-to-end
//! driver. The *trend the paper reports across scales* ("larger models
//! are easier to compress") is what the mapping must preserve, not the
//! absolute parameter counts.

/// Architecture hyperparameters (Llama-style block: RMSNorm, multi-head
/// causal attention, SwiGLU MLP, learned positional embeddings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    /// Vocabulary size (lm_head / tok_emb rows).
    pub vocab_size: usize,
    /// Residual-stream width.
    pub hidden: usize,
    /// Transformer blocks.
    pub n_layers: usize,
    /// Attention heads per block.
    pub n_heads: usize,
    /// SwiGLU MLP inner width.
    pub ffn_hidden: usize,
    /// Max sequence length (pos_emb rows, KV capacity).
    pub max_seq: usize,
}

impl ModelConfig {
    /// ~0.16M params — stands in for the 7B tier.
    pub fn tiny() -> ModelConfig {
        ModelConfig { vocab_size: 512, hidden: 64, n_layers: 2, n_heads: 4, ffn_hidden: 128, max_seq: 64 }
    }

    /// ~0.64M params — stands in for the 13B tier.
    pub fn small() -> ModelConfig {
        ModelConfig { vocab_size: 512, hidden: 128, n_layers: 3, n_heads: 8, ffn_hidden: 256, max_seq: 64 }
    }

    /// ~2M params — stands in for the 70B (34B) tier.
    pub fn base() -> ModelConfig {
        ModelConfig { vocab_size: 512, hidden: 192, n_layers: 4, n_heads: 8, ffn_hidden: 512, max_seq: 64 }
    }

    /// ~95M params — the end-to-end driver scale (system prompt's ~100M).
    pub fn large() -> ModelConfig {
        ModelConfig {
            vocab_size: 2048,
            hidden: 768,
            n_layers: 12,
            n_heads: 12,
            ffn_hidden: 2304,
            max_seq: 256,
        }
    }

    /// Preset by name ("tiny" | "small" | "base" | "large").
    pub fn preset(name: &str) -> Option<ModelConfig> {
        match name {
            "tiny" => Some(ModelConfig::tiny()),
            "small" => Some(ModelConfig::small()),
            "base" => Some(ModelConfig::base()),
            "large" => Some(ModelConfig::large()),
            _ => None,
        }
    }

    /// Head dimension; `hidden` must divide evenly.
    pub fn head_dim(&self) -> usize {
        assert_eq!(self.hidden % self.n_heads, 0, "hidden % heads");
        self.hidden / self.n_heads
    }

    /// Total parameter count (embeddings + blocks + head + norms).
    pub fn param_count(&self) -> usize {
        let h = self.hidden;
        let emb = self.vocab_size * h + self.max_seq * h;
        let per_layer = 4 * h * h          // wq wk wv wo
            + 3 * h * self.ffn_hidden      // gate, up, down
            + 2 * h;                       // two RMSNorm gains
        let head = self.vocab_size * h + h; // lm head + final norm
        emb + self.n_layers * per_layer + head
    }

    /// Names of the seven weight *matrices* per layer that carry deltas
    /// (norm vectors are kept in fp and excluded from compression, like
    /// the paper's focus on Linear-layer weights).
    pub fn layer_tensor_names(layer: usize) -> Vec<String> {
        ["attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.gate", "mlp.up", "mlp.down"]
            .iter()
            .map(|t| format!("layers.{layer}.{t}"))
            .collect()
    }

    /// All compressible tensor names for this config, in canonical order.
    pub fn delta_tensor_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for l in 0..self.n_layers {
            names.extend(Self::layer_tensor_names(l));
        }
        names
    }

    /// Delta tensor names in sorted order — the AOT argument convention
    /// shared with `python/compile/aot.py::delta_specs`.
    pub fn delta_tensor_names_sorted(&self) -> Vec<String> {
        let mut names = self.delta_tensor_names();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["tiny", "small", "base", "large"] {
            assert!(ModelConfig::preset(name).is_some());
        }
        assert!(ModelConfig::preset("7B").is_none());
    }

    #[test]
    fn scales_are_ordered() {
        let t = ModelConfig::tiny().param_count();
        let s = ModelConfig::small().param_count();
        let b = ModelConfig::base().param_count();
        let l = ModelConfig::large().param_count();
        assert!(t < s && s < b && b < l, "{t} {s} {b} {l}");
        assert!(l > 50_000_000, "large preset should be ~100M, got {l}");
    }

    #[test]
    fn head_dim_divides() {
        assert_eq!(ModelConfig::tiny().head_dim(), 16);
        assert_eq!(ModelConfig::large().head_dim(), 64);
    }

    #[test]
    fn tensor_names_enumerate_all_layers() {
        let c = ModelConfig::tiny();
        let names = c.delta_tensor_names();
        assert_eq!(names.len(), c.n_layers * 7);
        assert_eq!(names[0], "layers.0.attn.wq");
        assert!(names.contains(&"layers.1.mlp.down".to_string()));
    }
}
