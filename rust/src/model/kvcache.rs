//! Per-sequence KV cache for incremental decoding.
//!
//! Two implementations share one contract ([`KvSlot`]):
//!
//! * [`KvCache`] — a monolithic growable buffer per layer (the original
//!   run-to-completion serving path and the offline `generate` loop).
//! * [`crate::sched::PagedKvCache`] — fixed-size blocks leased from the
//!   scheduler's [`crate::sched::BlockPool`], for iteration-level
//!   scheduling with admission control and preemption.
//!
//! Both route decode attention through [`attend_dense`], so for the
//! same cached values the computed context — and therefore every
//! decoded token — is bit-identical across cache layouts.

use crate::tensor::ops;
use crate::tensor::Matrix;

/// What [`crate::model::forward::forward_step`] needs from a KV cache:
/// append one position's K/V rows per layer, and attend a single query
/// row over everything cached for a layer.
pub trait KvSlot {
    /// Number of complete cached positions (all layers appended).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one position's K/V rows to `layer`. Layers are appended
    /// in order `0..n_layers` during a step; the final layer's append
    /// completes the position.
    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]);

    /// Multi-head attention of the single query row `q` (1×hidden)
    /// over every position cached for `layer` — including the one just
    /// appended this step. Returns the 1×hidden context row. Takes
    /// `&mut self` so paged implementations can reuse gather scratch
    /// across steps.
    fn attend(
        &mut self,
        layer: usize,
        q: &Matrix,
        n_heads: usize,
        head_dim: usize,
        scale: f32,
    ) -> Matrix;
}

/// Single-query multi-head attention over dense K/V matrices
/// (`t × hidden`). This is the one decode-attention kernel: every
/// [`KvSlot`] funnels through it, which is what makes paged and
/// monolithic caches bit-identical.
pub fn attend_dense(
    q: &Matrix,
    k_all: &Matrix,
    v_all: &Matrix,
    n_heads: usize,
    head_dim: usize,
    scale: f32,
) -> Matrix {
    let mut ctx = Matrix::zeros(1, n_heads * head_dim);
    for head in 0..n_heads {
        let lo = head * head_dim;
        let hi = lo + head_dim;
        let qh = q.slice_cols(lo, hi);
        let kh = k_all.slice_cols(lo, hi);
        let vh = v_all.slice_cols(lo, hi);
        let mut scores = qh.matmul_nt(&kh); // 1×t
        scores.scale(scale);
        ops::softmax_rows(&mut scores);
        let out = scores.matmul_nn(&vh); // 1×head_dim
        ctx.set_cols(lo, &out);
    }
    ctx
}

/// Keys and values for every layer of one sequence. Rows grow as tokens
/// are appended; all layers always hold the same number of positions.
#[derive(Debug, Clone)]
pub struct KvCache {
    keys: Vec<Matrix>,
    values: Vec<Matrix>,
    len: usize,
}

impl KvCache {
    /// Empty cache for `n_layers` layers of width `hidden`.
    pub fn new(n_layers: usize, hidden: usize) -> KvCache {
        KvCache {
            keys: (0..n_layers).map(|_| Matrix::zeros(0, hidden)).collect(),
            values: (0..n_layers).map(|_| Matrix::zeros(0, hidden)).collect(),
            len: 0,
        }
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no positions are cached yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of layers the cache covers.
    pub fn n_layers(&self) -> usize {
        self.keys.len()
    }

    /// Append one position's K/V rows to `layer`. The final layer's
    /// append advances the cache length (layers are appended in order
    /// 0..n_layers during a step).
    pub fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        self.keys[layer].push_row(k);
        self.values[layer].push_row(v);
        if layer == self.keys.len() - 1 {
            self.len += 1;
        }
        debug_assert_eq!(self.keys[layer].rows(), self.values[layer].rows());
    }

    /// (K, V) matrices of a layer: `len × hidden`.
    pub fn layer(&self, layer: usize) -> (&Matrix, &Matrix) {
        (&self.keys[layer], &self.values[layer])
    }

    /// Approximate resident bytes (coordinator memory accounting).
    pub fn bytes(&self) -> usize {
        self.keys
            .iter()
            .chain(self.values.iter())
            .map(|m| m.len() * std::mem::size_of::<f32>())
            .sum()
    }

    /// Drop all cached positions (sequence reset), keeping capacity.
    pub fn clear(&mut self) {
        let hidden = self.keys.first().map(|m| m.cols()).unwrap_or(0);
        for m in self.keys.iter_mut().chain(self.values.iter_mut()) {
            *m = Matrix::zeros(0, hidden);
        }
        self.len = 0;
    }
}

impl KvSlot for KvCache {
    fn len(&self) -> usize {
        self.len
    }

    fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        KvCache::append(self, layer, k, v);
    }

    fn attend(
        &mut self,
        layer: usize,
        q: &Matrix,
        n_heads: usize,
        head_dim: usize,
        scale: f32,
    ) -> Matrix {
        let (k_all, v_all) = self.layer(layer);
        attend_dense(q, k_all, v_all, n_heads, head_dim, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_advances_on_last_layer() {
        let mut c = KvCache::new(2, 4);
        let row = [1.0f32, 2.0, 3.0, 4.0];
        c.append(0, &row, &row);
        assert_eq!(c.len(), 0, "only layer 0 appended");
        c.append(1, &row, &row);
        assert_eq!(c.len(), 1);
        let (k, v) = c.layer(0);
        assert_eq!(k.rows(), 1);
        assert_eq!(v.row(0), &row);
    }

    #[test]
    fn bytes_grow_linearly() {
        let mut c = KvCache::new(3, 8);
        assert_eq!(c.bytes(), 0);
        let row = [0.0f32; 8];
        for l in 0..3 {
            c.append(l, &row, &row);
        }
        assert_eq!(c.bytes(), 3 * 2 * 8 * 4);
    }

    #[test]
    fn clear_resets() {
        let mut c = KvCache::new(1, 2);
        c.append(0, &[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(c.len(), 1);
        c.clear();
        assert_eq!(c.len(), 0);
        assert_eq!(c.bytes(), 0);
        // usable after clear
        c.append(0, &[5.0, 6.0], &[7.0, 8.0]);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn attend_matches_manual_single_head() {
        // one head, two cached positions: softmax(q·Kᵀ·scale)·V
        let mut c = KvCache::new(1, 2);
        c.append(0, &[1.0, 0.0], &[1.0, 2.0]);
        c.append(0, &[0.0, 1.0], &[3.0, 4.0]);
        let q = Matrix::from_vec(1, 2, vec![1.0, 0.0]);
        let ctx = KvSlot::attend(&mut c, 0, &q, 1, 2, 1.0);
        let e0 = 1.0f32.exp();
        let e1 = 0.0f32.exp();
        let w0 = e0 / (e0 + e1);
        let w1 = e1 / (e0 + e1);
        assert!((ctx.get(0, 0) - (w0 * 1.0 + w1 * 3.0)).abs() < 1e-5);
        assert!((ctx.get(0, 1) - (w0 * 2.0 + w1 * 4.0)).abs() < 1e-5);
    }
}
