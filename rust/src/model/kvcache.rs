//! Per-sequence KV cache for incremental decoding.

use crate::tensor::Matrix;

/// Keys and values for every layer of one sequence. Rows grow as tokens
/// are appended; all layers always hold the same number of positions.
#[derive(Debug, Clone)]
pub struct KvCache {
    keys: Vec<Matrix>,
    values: Vec<Matrix>,
    len: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, hidden: usize) -> KvCache {
        KvCache {
            keys: (0..n_layers).map(|_| Matrix::zeros(0, hidden)).collect(),
            values: (0..n_layers).map(|_| Matrix::zeros(0, hidden)).collect(),
            len: 0,
        }
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_layers(&self) -> usize {
        self.keys.len()
    }

    /// Append one position's K/V rows to `layer`. The final layer's
    /// append advances the cache length (layers are appended in order
    /// 0..n_layers during a step).
    pub fn append(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        self.keys[layer].push_row(k);
        self.values[layer].push_row(v);
        if layer == self.keys.len() - 1 {
            self.len += 1;
        }
        debug_assert_eq!(self.keys[layer].rows(), self.values[layer].rows());
    }

    /// (K, V) matrices of a layer: `len × hidden`.
    pub fn layer(&self, layer: usize) -> (&Matrix, &Matrix) {
        (&self.keys[layer], &self.values[layer])
    }

    /// Approximate resident bytes (coordinator memory accounting).
    pub fn bytes(&self) -> usize {
        self.keys
            .iter()
            .chain(self.values.iter())
            .map(|m| m.len() * std::mem::size_of::<f32>())
            .sum()
    }

    /// Drop all cached positions (sequence reset), keeping capacity.
    pub fn clear(&mut self) {
        let hidden = self.keys.first().map(|m| m.cols()).unwrap_or(0);
        for m in self.keys.iter_mut().chain(self.values.iter_mut()) {
            *m = Matrix::zeros(0, hidden);
        }
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_advances_on_last_layer() {
        let mut c = KvCache::new(2, 4);
        let row = [1.0f32, 2.0, 3.0, 4.0];
        c.append(0, &row, &row);
        assert_eq!(c.len(), 0, "only layer 0 appended");
        c.append(1, &row, &row);
        assert_eq!(c.len(), 1);
        let (k, v) = c.layer(0);
        assert_eq!(k.rows(), 1);
        assert_eq!(v.row(0), &row);
    }

    #[test]
    fn bytes_grow_linearly() {
        let mut c = KvCache::new(3, 8);
        assert_eq!(c.bytes(), 0);
        let row = [0.0f32; 8];
        for l in 0..3 {
            c.append(l, &row, &row);
        }
        assert_eq!(c.bytes(), 3 * 2 * 8 * 4);
    }

    #[test]
    fn clear_resets() {
        let mut c = KvCache::new(1, 2);
        c.append(0, &[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(c.len(), 1);
        c.clear();
        assert_eq!(c.len(), 0);
        assert_eq!(c.bytes(), 0);
        // usable after clear
        c.append(0, &[5.0, 6.0], &[7.0, 8.0]);
        assert_eq!(c.len(), 1);
    }
}
