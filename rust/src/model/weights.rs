//! Model weight container with named-tensor access.
//!
//! Layout convention: every linear weight is `h_out × h_in` (the layer
//! computes `X·Wᵀ`), norm gains are `1 × h` matrices. Names follow
//! `layers.<i>.<block>.<tensor>` plus the globals `tok_emb`, `pos_emb`,
//! `final_norm`, `lm_head`.

use std::collections::BTreeMap;

use crate::model::config::ModelConfig;
use crate::tensor::{Matrix, Pcg64};

/// All weights of one model, addressable by name.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    /// The architecture these weights instantiate.
    pub config: ModelConfig,
    tensors: BTreeMap<String, Matrix>,
}

impl ModelWeights {
    /// Random initialization (same scheme the python trainer uses:
    /// N(0, 0.02) for embeddings and projections, ones for norm gains).
    pub fn init(config: ModelConfig, rng: &mut Pcg64) -> ModelWeights {
        let mut w = ModelWeights { config, tensors: BTreeMap::new() };
        let h = config.hidden;
        let std = 0.02f32;
        w.insert("tok_emb", Matrix::randn(config.vocab_size, h, std, rng));
        w.insert("pos_emb", Matrix::randn(config.max_seq, h, std, rng));
        for l in 0..config.n_layers {
            let p = |t: &str| format!("layers.{l}.{t}");
            w.insert(&p("attn_norm"), Matrix::full(1, h, 1.0));
            w.insert(&p("attn.wq"), Matrix::randn(h, h, std, rng));
            w.insert(&p("attn.wk"), Matrix::randn(h, h, std, rng));
            w.insert(&p("attn.wv"), Matrix::randn(h, h, std, rng));
            w.insert(&p("attn.wo"), Matrix::randn(h, h, std, rng));
            w.insert(&p("mlp_norm"), Matrix::full(1, h, 1.0));
            w.insert(&p("mlp.gate"), Matrix::randn(config.ffn_hidden, h, std, rng));
            w.insert(&p("mlp.up"), Matrix::randn(config.ffn_hidden, h, std, rng));
            w.insert(&p("mlp.down"), Matrix::randn(h, config.ffn_hidden, std, rng));
        }
        w.insert("final_norm", Matrix::full(1, h, 1.0));
        w.insert("lm_head", Matrix::randn(config.vocab_size, h, std, rng));
        w
    }

    /// Empty container (filled by the loader).
    pub fn empty(config: ModelConfig) -> ModelWeights {
        ModelWeights { config, tensors: BTreeMap::new() }
    }

    /// Insert (or replace) a named tensor.
    pub fn insert(&mut self, name: &str, tensor: Matrix) {
        self.tensors.insert(name.to_string(), tensor);
    }

    /// Named tensor (panics if missing — loading validates completeness).
    pub fn get(&self, name: &str) -> &Matrix {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing tensor '{name}'"))
    }

    /// Mutable named tensor (panics if missing).
    pub fn get_mut(&mut self, name: &str) -> &mut Matrix {
        self.tensors
            .get_mut(name)
            .unwrap_or_else(|| panic!("missing tensor '{name}'"))
    }

    /// Named tensor, or `None` if absent.
    pub fn try_get(&self, name: &str) -> Option<&Matrix> {
        self.tensors.get(name)
    }

    /// Iterate (name, tensor) in sorted name order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Matrix)> {
        self.tensors.iter()
    }

    /// All tensor names, sorted.
    pub fn tensor_names(&self) -> Vec<String> {
        self.tensors.keys().cloned().collect()
    }

    /// Number of named tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// Whether the container holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total scalar parameters stored.
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }

    /// Resident RAM of the dense weights (f32 storage). The single
    /// source of truth for every cache-budget accounting site — the
    /// registry and the serving tenant store must agree on this number
    /// or their eviction decisions drift apart.
    pub fn resident_bytes(&self) -> u64 {
        self.param_count() as u64 * std::mem::size_of::<f32>() as u64
    }

    /// Check that every tensor the config requires is present with the
    /// right shape; returns the list of problems (empty = valid).
    pub fn validate(&self) -> Vec<String> {
        let c = self.config;
        let h = c.hidden;
        let mut problems = Vec::new();
        let mut expect = vec![
            ("tok_emb".to_string(), (c.vocab_size, h)),
            ("pos_emb".to_string(), (c.max_seq, h)),
            ("final_norm".to_string(), (1, h)),
            ("lm_head".to_string(), (c.vocab_size, h)),
        ];
        for l in 0..c.n_layers {
            let p = |t: &str| format!("layers.{l}.{t}");
            expect.push((p("attn_norm"), (1, h)));
            expect.push((p("attn.wq"), (h, h)));
            expect.push((p("attn.wk"), (h, h)));
            expect.push((p("attn.wv"), (h, h)));
            expect.push((p("attn.wo"), (h, h)));
            expect.push((p("mlp_norm"), (1, h)));
            expect.push((p("mlp.gate"), (c.ffn_hidden, h)));
            expect.push((p("mlp.up"), (c.ffn_hidden, h)));
            expect.push((p("mlp.down"), (h, c.ffn_hidden)));
        }
        for (name, shape) in expect {
            match self.tensors.get(&name) {
                None => problems.push(format!("missing tensor '{name}'")),
                Some(t) if t.shape() != shape => problems.push(format!(
                    "tensor '{name}' has shape {:?}, expected {shape:?}",
                    t.shape()
                )),
                _ => {}
            }
        }
        problems
    }

    /// Fine-tuned-weight reconstruction: `W_i = W_b + ΔW_i` applied to
    /// every delta tensor (norms/embeddings stay at base values unless
    /// the delta set includes them).
    pub fn apply_deltas(&self, deltas: &BTreeMap<String, Matrix>) -> ModelWeights {
        let mut out = self.clone();
        for (name, d) in deltas {
            let t = out.get_mut(name);
            t.add_assign(d);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_valid_and_counts_match_config() {
        let mut rng = Pcg64::seeded(1);
        let c = ModelConfig::tiny();
        let w = ModelWeights::init(c, &mut rng);
        assert!(w.validate().is_empty());
        assert_eq!(w.param_count(), c.param_count());
        assert_eq!(w.resident_bytes(), c.param_count() as u64 * 4);
    }

    #[test]
    fn missing_tensor_reported() {
        let c = ModelConfig::tiny();
        let w = ModelWeights::empty(c);
        let problems = w.validate();
        assert!(problems.iter().any(|p| p.contains("tok_emb")));
    }

    #[test]
    fn wrong_shape_reported() {
        let mut rng = Pcg64::seeded(2);
        let c = ModelConfig::tiny();
        let mut w = ModelWeights::init(c, &mut rng);
        w.insert("lm_head", Matrix::zeros(2, 2));
        assert!(w.validate().iter().any(|p| p.contains("lm_head")));
    }

    #[test]
    #[should_panic(expected = "missing tensor")]
    fn get_missing_panics() {
        let w = ModelWeights::empty(ModelConfig::tiny());
        let _ = w.get("nope");
    }

    #[test]
    fn apply_deltas_adds() {
        let mut rng = Pcg64::seeded(3);
        let c = ModelConfig::tiny();
        let base = ModelWeights::init(c, &mut rng);
        let mut deltas = BTreeMap::new();
        deltas.insert(
            "layers.0.attn.wq".to_string(),
            Matrix::full(c.hidden, c.hidden, 0.5),
        );
        let ft = base.apply_deltas(&deltas);
        let diff = ft.get("layers.0.attn.wq").sub(base.get("layers.0.attn.wq"));
        assert!(diff.allclose(&Matrix::full(c.hidden, c.hidden, 0.5), 1e-6, 0.0));
        // untouched tensors identical
        assert_eq!(ft.get("lm_head"), base.get("lm_head"));
    }
}
