//! Transformer model substrate (S6): configuration presets, weight
//! containers, the forward pass (full-sequence and KV-cached decode),
//! and `.dqw` weight-file I/O shared with the python trainer.

pub mod config;
pub mod forward;
pub mod io;
pub mod kvcache;
pub mod weights;

pub use config::ModelConfig;
pub use forward::{
    forward, forward_step, generate, generate_with, prefill_into, DeltaView, WeightSource,
};
pub use io::{load_weights, save_weights};
pub use kvcache::{attend_dense, KvCache, KvSlot};
pub use weights::ModelWeights;
