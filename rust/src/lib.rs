//! # DeltaDQ
//!
//! Production-oriented reproduction of *"DeltaDQ: Ultra-High Delta
//! Compression for Fine-Tuned LLMs via Group-wise Dropout and Separate
//! Quantization"* (Jiang et al., 2024), built as a three-layer stack:
//!
//! * **L3 (this crate)** — the serving stack: the HTTP gateway
//!   ([`gateway`]: token streaming over SSE, backpressure as 429,
//!   Prometheus `/metrics`, and the open-loop load generator), the
//!   continuous-batching scheduler ([`sched`]: iteration-level step
//!   batches over a paged KV-cache block pool, with admission control
//!   and preemption), the coordinator (multi-tenant request routing,
//!   dynamic batching, per-tenant compressed-delta registry),
//!   the tiered on-disk delta artifact store ([`store::DeltaStore`]:
//!   Disk → Cold → Hot residency with lazy paged hydration), pluggable
//!   execution backends ([`runtime::ExecutionBackend`]: the native
//!   fused sparse path, or PJRT behind `--features pjrt`), and the full
//!   native implementation of the compression algorithms (DeltaDQ plus
//!   the Magnitude / DARE / DELTAZIP baselines).
//! * **L2 (python/compile/model.py)** — the JAX transformer forward pass
//!   with separate base+delta computation, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   base+delta matmul and m-part dequantization.
//!
//! See `rust/README.md` for the build/feature matrix and quickstart.

// Index loops over matrix rows/columns are the house style of the
// numeric kernels (they mirror the math and autovectorize fine).
#![allow(clippy::needless_range_loop)]
// Every public item documents itself; CI builds rustdoc with
// `-D warnings`, which upgrades this to an error there.
#![warn(missing_docs)]

pub mod analysis;
pub mod audit;
pub mod bench_harness;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod delta;
pub mod dropout;
pub mod eval;
pub mod gateway;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod sched;
pub mod search;
pub mod sparse;
pub mod store;
pub mod tensor;
pub mod usage;
pub mod util;
