//! # DeltaDQ
//!
//! Production-oriented reproduction of *"DeltaDQ: Ultra-High Delta
//! Compression for Fine-Tuned LLMs via Group-wise Dropout and Separate
//! Quantization"* (Jiang et al., 2024), built as a three-layer stack:
//!
//! * **L3 (this crate)** — the serving coordinator: multi-tenant request
//!   routing, dynamic batching, per-tenant compressed-delta registry, and
//!   the full native implementation of the compression algorithms
//!   (DeltaDQ plus the Magnitude / DARE / DELTAZIP baselines).
//! * **L2 (python/compile/model.py)** — the JAX transformer forward pass
//!   with separate base+delta computation, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   base+delta matmul and m-part dequantization.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod bench_harness;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod delta;
pub mod dropout;
pub mod eval;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod search;
pub mod sparse;
pub mod tensor;
pub mod util;
