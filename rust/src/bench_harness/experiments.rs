//! The per-table / per-figure experiment drivers (E1–E9) plus the
//! backend-parameterized serving run (E10).
//!
//! Every driver prints rows with the same structure as the paper's
//! artifact. Determinism: all randomness derives from fixed seeds, so
//! reruns reproduce EXPERIMENTS.md bit-for-bit.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::analysis::{balanced_results_sweep, median_contrast, quant_distribution};
use crate::compress::pipeline::{
    capture_calibration, compress_model_deltas, reconstruct_weights,
};
use crate::compress::{
    CompressedDelta, Compressor, Dare, DeltaDq, DeltaDqConfig, DeltaZip, DeltaZipConfig, Magnitude,
};
use crate::coordinator::{Server, ServerOptions};
use crate::delta::{extract_deltas, DeltaSet};
use crate::dropout::{dropout, DropoutKind};
use crate::eval::{evaluate, gen_dataset, load_dataset, Sample, TaskKind};
use crate::model::{forward, load_weights, ModelConfig, ModelWeights};
use crate::quant::separate::DecomposedDelta;
use crate::runtime::pool::{resolve_threads, ThreadPool};
use crate::runtime::{fused_matmul_nt, ExecutionBackend};
use crate::search::{search_direct, search_proxy};
use crate::sparse::CsrMatrix;
use crate::store::DeltaStore;
use crate::tensor::stats::percentile;
use crate::tensor::{dot, Matrix, Pcg64};
use crate::util::bench::{bench, BenchResult};
use crate::util::json::Json;
use crate::util::table::{fmt, fmt_ratio, Table};

const SEED: u64 = 20240701;
/// Eval-set slice for table accuracy runs (single-core budget).
const EVAL_N: usize = 150;
/// Default group size used when the search is not re-run per cell
/// (Table 4 / fig5 justify the choice).
const DEFAULT_GROUP: usize = 16;

// ------------------------------------------------------------- loading

fn load_pair(models_dir: &Path, scale: &str, task: &str) -> Result<(ModelWeights, ModelWeights)> {
    let dir = models_dir.join(scale);
    let base = load_weights(&dir.join("base.dqw"))
        .with_context(|| format!("missing {scale}/base.dqw — run `make artifacts`"))?;
    let ft = load_weights(&dir.join(format!("{task}.dqw")))
        .with_context(|| format!("missing {scale}/{task}.dqw — run `make artifacts`"))?;
    Ok((base, ft))
}

fn load_eval(data_dir: &Path, task: &str, n: usize) -> Result<Vec<Sample>> {
    let samples = load_dataset(&data_dir.join(format!("{task}_eval.dqt")))
        .with_context(|| format!("missing {task}_eval.dqt — run `deltadq gen-data`"))?;
    Ok(samples.into_iter().take(n).collect())
}

/// Compress the ft−base delta with `method` and return task accuracy %.
fn compress_and_eval(
    base: &ModelWeights,
    ft: &ModelWeights,
    method: &dyn Compressor,
    calibration: &BTreeMap<String, Matrix>,
    eval_data: &[Sample],
    seed: u64,
) -> f64 {
    let deltas = extract_deltas(base, ft);
    let mut rng = Pcg64::seeded(seed);
    let set = compress_model_deltas(&deltas, method, calibration, &mut rng);
    let weights = reconstruct_weights(base, &set);
    evaluate(&weights, eval_data).percent()
}

/// The four methods at a given *total* ratio, instantiated like the
/// paper's rows (DESIGN.md §7 baseline definitions).
fn methods_for_ratio(ratio: f64, group_size: usize) -> Vec<Box<dyn Compressor>> {
    vec![
        Box::new(Magnitude::new(ratio)),
        Box::new(DeltaZip::new(DeltaZipConfig::for_total_ratio(ratio))),
        Box::new(Dare::new(ratio)),
        Box::new(DeltaDq::new(DeltaDqConfig::for_total_ratio(ratio, Some(group_size)))),
    ]
}

// ------------------------------------------------------------- table 1

/// E1 / Table 1: accuracy at α ∈ {2,4,8,16} across scales × {math,code}.
pub fn table1(models_dir: &Path, data_dir: &Path) -> Result<String> {
    let scales = ["tiny", "small", "base"];
    let tasks = ["math", "code"];
    let mut out = String::new();
    let mut t = Table::new(
        "Table 1 — accuracy vs compression ratio (scales map 7B/13B/70B → tiny/small/base)",
        &["Method", "Quant", "Ratio", "math:tiny", "math:small", "math:base", "code:tiny",
          "code:small", "code:base"],
    );

    // originals
    let mut original_row = vec!["Original".to_string(), "x".to_string(), "1".to_string()];
    let mut pairs = BTreeMap::new();
    let mut evals = BTreeMap::new();
    for task in tasks {
        let eval_data = load_eval(data_dir, task, EVAL_N)?;
        for scale in scales {
            let (base, ft) = load_pair(models_dir, scale, task)?;
            let acc = evaluate(&ft, &eval_data).percent();
            original_row.push(fmt(acc, 2));
            pairs.insert((task, scale), (base, ft));
        }
        evals.insert(task, eval_data);
    }
    t.add_row(original_row);

    for ratio in [2.0, 4.0, 8.0, 16.0] {
        for method_idx in 0..4 {
            let method = &methods_for_ratio(ratio, DEFAULT_GROUP)[method_idx];
            let quantized = matches!(method.name().as_str(), "DELTAZIP" if ratio > 8.0)
                || (method.name().starts_with("DeltaDQ") && ratio >= 16.0);
            let mut row = vec![
                method.name(),
                if quantized { "yes".into() } else { "x".into() },
                fmt_ratio(ratio),
            ];
            for task in tasks {
                for scale in scales {
                    let (base, ft) = &pairs[&(task, scale)];
                    let calib = if method.name() == "DELTAZIP" {
                        capture_calibration(ft, &evals[task][..8.min(evals[task].len())], 128)
                    } else {
                        BTreeMap::new()
                    };
                    let acc = compress_and_eval(
                        base,
                        ft,
                        method.as_ref(),
                        &calib,
                        &evals[task],
                        SEED ^ (ratio as u64) ^ (method_idx as u64) << 8,
                    );
                    row.push(fmt(acc, 2));
                }
            }
            t.add_row(row);
        }
    }
    out.push_str(&t.render());
    Ok(out)
}

// -------------------------------------------------------- tables 2 & 3

/// Ultra-high compression sweep for one scale (Table 2 = tiny/7B,
/// Table 3 = base/70B).
fn ultra_table(
    models_dir: &Path,
    data_dir: &Path,
    scale: &str,
    task: &str,
    title: &str,
    ratios: &[f64],
    deltadq_rows: &[(f64, u32, u32)], // (total, k, m) per extra DeltaDQ row
) -> Result<String> {
    let (base, ft) = load_pair(models_dir, scale, task)?;
    let eval_data = load_eval(data_dir, task, EVAL_N)?;
    let mut t = Table::new(title, &["Method", "Ratio", "Accuracy"]);
    t.add_row(vec![
        "Original".into(),
        "1".into(),
        fmt(evaluate(&ft, &eval_data).percent(), 2),
    ]);
    for &ratio in ratios {
        for (i, method) in [
            Box::new(Magnitude::new(ratio)) as Box<dyn Compressor>,
            Box::new(DeltaZip::new(DeltaZipConfig::for_total_ratio(ratio))),
            Box::new(Dare::new(ratio)),
        ]
        .iter()
        .enumerate()
        {
            let calib = if method.name() == "DELTAZIP" {
                capture_calibration(&ft, &eval_data[..8.min(eval_data.len())], 128)
            } else {
                BTreeMap::new()
            };
            let acc = compress_and_eval(
                &base,
                &ft,
                method.as_ref(),
                &calib,
                &eval_data,
                SEED ^ (ratio as u64) ^ ((i as u64) << 16),
            );
            t.add_row(vec![method.name(), fmt_ratio(ratio), fmt(acc, 2)]);
        }
        // DeltaDQ(m=1) at this ratio: keep dropout at ratio/2 + 8-bit
        let alpha_m1 = ratio / 2.0;
        let dq_m1 = DeltaDq::new(DeltaDqConfig::with_quant(alpha_m1, Some(DEFAULT_GROUP), 8, 1));
        let acc = compress_and_eval(&base, &ft, &dq_m1, &BTreeMap::new(), &eval_data, SEED ^ ratio as u64);
        t.add_row(vec![dq_m1.name(), fmt_ratio(ratio), fmt(acc, 2)]);
    }
    // the m-decomposed rows (the paper's headline)
    for &(total, k, m) in deltadq_rows {
        let cfg = match total {
            t if t.is_infinite() => {
                // the "-" extreme: m = 2^k
                DeltaDqConfig::with_quant(8.0, Some(DEFAULT_GROUP), k, m)
            }
            _ => {
                // derive alpha from total = alpha * 16/(k - log2 m)
                let final_bits = (k - m.ilog2()) as f64;
                DeltaDqConfig::with_quant(total * final_bits / 16.0, Some(DEFAULT_GROUP), k, m)
            }
        };
        let dq = DeltaDq::new(cfg);
        let acc = compress_and_eval(&base, &ft, &dq, &BTreeMap::new(), &eval_data, SEED ^ 0xDD);
        t.add_row(vec![dq.name(), fmt_ratio(dq.nominal_ratio()), fmt(acc, 2)]);
    }
    Ok(t.render())
}

/// E2 / Table 2: WizardMath-7B (tiny) ultra-high compression.
pub fn table2(models_dir: &Path, data_dir: &Path) -> Result<String> {
    // Task note: the ultra-high tables run on the *code* task — the math
    // stand-in's grokked arithmetic circuit is brittle at testbed scale
    // (even 2x dropout of its delta collapses exact-match; documented as
    // a finding in EXPERIMENTS.md §Brittleness), while code degrades
    // gracefully like the paper's GSM8k curves do at 7B+.
    ultra_table(
        models_dir,
        data_dir,
        "tiny",
        "code",
        "Table 2 — ultra-high compression, code @ tiny (7B stand-in)",
        &[32.0, 64.0, 128.0],
        &[(64.0, 4, 4), (128.0, 4, 8), (f64::INFINITY, 4, 16)],
    )
}

/// E3 / Table 3: WizardMath-70B ultra-high compression (code task —
/// see the task note on [`table2`]).
pub fn table3(models_dir: &Path, data_dir: &Path) -> Result<String> {
    ultra_table(
        models_dir,
        data_dir,
        "base",
        "code",
        "Table 3 — ultra-high compression, code @ base (70B stand-in)",
        &[128.0, 256.0, 512.0],
        &[(256.0, 4, 4), (512.0, 4, 8), (f64::INFINITY, 4, 16)],
    )
}

// ------------------------------------------------------------- table 4

/// E4 / Table 4: group-size selection, Direct vs Proxy, α ∈ {2,4,8}.
pub fn table4(models_dir: &Path, data_dir: &Path) -> Result<String> {
    let (base, ft) = load_pair(models_dir, "tiny", "code")?;
    let eval_data = load_eval(data_dir, "code", EVAL_N)?;
    let deltas = extract_deltas(&base, &ft);
    let mut t = Table::new(
        "Table 4 — group-size selection: Direct vs Proxy (times in seconds; code @ tiny)",
        &["alpha", "Selection", "Time(s)", "h_g*"],
    );
    for alpha in [2.0, 4.0, 8.0] {
        let d = search_direct(&base, &deltas, alpha, &eval_data, SEED);
        t.add_row(vec![
            fmt_ratio(alpha),
            "Direct".into(),
            fmt(d.elapsed.as_secs_f64(), 2),
            d.best_group_size.to_string(),
        ]);
        let p = search_proxy(&base, &deltas, alpha, &eval_data, 0.01, SEED);
        t.add_row(vec![
            fmt_ratio(alpha),
            "Proxy".into(),
            fmt(p.elapsed.as_secs_f64(), 2),
            p.best_group_size.to_string(),
        ]);
    }
    Ok(t.render())
}

// -------------------------------------------------------------- fig 4

/// E5 / Figure 4: Balanced Intermediate Results — variance & min-max
/// range of partial products, delta vs fine-tuned weight.
pub fn fig4(models_dir: &Path, data_dir: &Path) -> Result<String> {
    let (base, ft) = load_pair(models_dir, "tiny", "math")?;
    let eval_data = load_eval(data_dir, "math", 16)?;
    let deltas = extract_deltas(&base, &ft);
    let calib = capture_calibration(&ft, &eval_data, 64);
    let reports = balanced_results_sweep(&base, &deltas, &calib, 128);
    let (var_contrast, range_contrast) = median_contrast(&reports);
    let mut t = Table::new(
        "Figure 4 — Balanced Intermediate Results (median over sampled output elements)",
        &["Tensor", "Var(delta)", "Var(finetuned)", "Range(delta)", "Range(finetuned)"],
    );
    for r in reports.iter().take(8) {
        t.add_row(vec![
            r.tensor.clone(),
            format!("{:.3e}", r.delta_variance),
            format!("{:.3e}", r.finetuned_variance),
            format!("{:.3e}", r.delta_range),
            format!("{:.3e}", r.finetuned_range),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "median contrast (finetuned/delta): variance {var_contrast:.1}x, range {range_contrast:.1}x\n"
    ));
    Ok(out)
}

// -------------------------------------------------------------- fig 5

/// E6 / Figure 5: accuracy vs group size at fixed α.
pub fn fig5(models_dir: &Path, data_dir: &Path) -> Result<String> {
    let (base, ft) = load_pair(models_dir, "tiny", "code")?;
    let eval_data = load_eval(data_dir, "code", EVAL_N)?;
    let _deltas = extract_deltas(&base, &ft);
    let alpha = 8.0;
    let mut t = Table::new(
        "Figure 5 — accuracy vs group size h_g (code @ tiny, alpha = 8)",
        &["h_g", "Accuracy"],
    );
    for h_g in crate::dropout::group_size_grid(base.config.hidden, alpha) {
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(alpha, Some(h_g)));
        let acc =
            compress_and_eval(&base, &ft, &dq, &BTreeMap::new(), &eval_data, SEED ^ h_g as u64);
        t.add_row(vec![h_g.to_string(), fmt(acc, 2)]);
    }
    Ok(t.render())
}

// -------------------------------------------------------------- fig 6

/// E7 / Figure 6: delta distribution before/after uniform quantization.
pub fn fig6(models_dir: &Path, _data_dir: &Path) -> Result<String> {
    let (base, ft) = load_pair(models_dir, "tiny", "math")?;
    let deltas = extract_deltas(&base, &ft);
    let delta = &deltas["layers.0.attn.wq"];
    let mut out = String::from("## Figure 6 — delta weight distribution (layers.0.attn.wq)\n");
    for bits in [8u32, 4, 2] {
        let r = quant_distribution(delta, bits, 48);
        out.push_str(&format!(
            "before : {} [{:+.4}, {:+.4}]\n",
            r.before.sparkline(),
            r.before.lo,
            r.before.hi
        ));
        out.push_str(&format!(
            "after{bits}b: {} mse={:.3e}\n",
            r.after.sparkline(),
            r.mse
        ));
    }
    Ok(out)
}

// -------------------------------------------------------------- fig 7

/// E8 / Figure 7: memory & accuracy vs m at final bit k ∈ {8,4,2,1}.
pub fn fig7(models_dir: &Path, data_dir: &Path) -> Result<String> {
    let (base, ft) = load_pair(models_dir, "tiny", "code")?;
    let eval_data = load_eval(data_dir, "code", EVAL_N)?;
    let deltas = extract_deltas(&base, &ft);
    let alpha = 8.0;
    let mut t = Table::new(
        "Figure 7 — Separate Quantization: memory & accuracy vs m (code @ tiny, alpha = 8)",
        &["final bits k", "m", "storage(KiB)", "Accuracy"],
    );
    // final bit width k with m parts means quantizing at k + log2 m bits
    for final_bits in [8u32, 4, 2, 1] {
        for m in [1u32, 2, 4, 8] {
            let k = final_bits + m.ilog2();
            if k > 8 {
                continue;
            }
            let dq = DeltaDq::new(DeltaDqConfig::with_quant(alpha, Some(DEFAULT_GROUP), k, m));
            let mut rng = Pcg64::seeded(SEED ^ (final_bits as u64) << 4 ^ m as u64);
            let set = compress_model_deltas(&extract_deltas(&base, &ft), &dq, &BTreeMap::new(), &mut rng);
            let weights = reconstruct_weights(&base, &set);
            let acc = evaluate(&weights, &eval_data).percent();
            t.add_row(vec![
                final_bits.to_string(),
                m.to_string(),
                fmt(set.storage_bits() as f64 / 8.0 / 1024.0, 1),
                fmt(acc, 2),
            ]);
        }
    }
    let _ = deltas;
    let _ = alpha;
    Ok(t.render())
}

// -------------------------------------------------------------- fig 8

/// E9 / Figure 8: case study — responses before/after 128× compression.
///
/// Task note: run on the *code* fine-tune. The chat stand-in's learned
/// 64-entry style table is as brittle as the math circuit at tiny
/// scale (90% → 10% at a mere 4×; EXPERIMENTS.md §Brittleness), whereas
/// the paper's WizardLM-7B has the redundancy to survive 128× — code
/// is the task in that regime here.
pub fn fig8(
    models_dir: &Path,
    data_dir: &Path,
    backend: &Arc<dyn ExecutionBackend>,
) -> Result<String> {
    let (base, ft) = load_pair(models_dir, "tiny", "code")?;
    let eval_data = load_eval(data_dir, "code", 64)?;
    let dq = DeltaDq::new(DeltaDqConfig::with_quant(8.0, Some(DEFAULT_GROUP), 4, 8));
    let mut rng = Pcg64::seeded(SEED);
    let set = compress_model_deltas(&extract_deltas(&base, &ft), &dq, &BTreeMap::new(), &mut rng);
    let mut agree_tokens = 0usize;
    let mut total_tokens = 0usize;
    let mut identical = 0usize;
    let mut examples = String::new();
    for (i, s) in eval_data.iter().enumerate() {
        // "before" = the dense fine-tune; "after" = the compressed delta
        // served separately (the backend's Cold path)
        let eos = Some(crate::eval::tasks::vocab::EOS);
        let before = backend.generate(&ft, None, &s.prompt, s.completion.len() + 2, eos)?;
        let after = backend.generate(&base, Some(&set), &s.prompt, s.completion.len() + 2, eos)?;
        let n = before.len().max(after.len());
        let agree = before.iter().zip(&after).filter(|(a, b)| a == b).count();
        agree_tokens += agree;
        total_tokens += n;
        if before == after {
            identical += 1;
        }
        if i < 3 {
            examples.push_str(&format!(
                "prompt {:?}\n  before: {:?}\n  after : {:?}\n",
                s.prompt, before, after
            ));
        }
    }
    let mut out = String::from("## Figure 8 — case study: responses before/after 128x DeltaDQ (code @ tiny)\n");
    out.push_str(&examples);
    out.push_str(&format!(
        "identical responses: {identical}/{} ({:.1}%), token agreement {:.1}%\n",
        eval_data.len(),
        100.0 * identical as f64 / eval_data.len() as f64,
        100.0 * agree_tokens as f64 / total_tokens.max(1) as f64
    ));
    Ok(out)
}

// ----------------------------------------------------------- ablations

/// Design-choice ablations called out in DESIGN.md §5:
/// dropout granularity, storage format, and quantization granularity.
pub fn ablations(models_dir: &Path, data_dir: &Path) -> Result<String> {
    let (base, ft) = load_pair(models_dir, "tiny", "code")?;
    let eval_data = load_eval(data_dir, "code", EVAL_N)?;
    let deltas = extract_deltas(&base, &ft);
    let mut out = String::new();

    // (a) dropout granularity at alpha = 8
    let mut t = Table::new(
        "Ablation A — dropout granularity (code @ tiny, alpha = 8)",
        &["Granularity", "Accuracy"],
    );
    let alpha = 8.0;
    for (name, kind) in [
        ("global (DARE)", DropoutKind::Global),
        ("row-wise", DropoutKind::RowWise),
        ("group-wise h_g=16", DropoutKind::GroupWise { group_size: 16 }),
    ] {
        let mut rng = Pcg64::seeded(SEED ^ 0xA);
        let mut set = crate::delta::format::DeltaSet::new(name, alpha);
        for (tname, d) in &deltas {
            let r = dropout(d, alpha, kind, &mut rng);
            set.tensors.insert(
                tname.clone(),
                crate::compress::CompressedDelta::Sparse(CsrMatrix::from_dense(&r.matrix)),
            );
        }
        let weights = reconstruct_weights(&base, &set);
        t.add_row(vec![name.to_string(), fmt(evaluate(&weights, &eval_data).percent(), 2)]);
    }
    out.push_str(&t.render());

    // (b) storage accounting: CSR vs dense for the sparse delta
    let mut t = Table::new(
        "Ablation B — storage format at alpha = 8 (whole model delta)",
        &["Format", "KiB"],
    );
    let mut rng = Pcg64::seeded(SEED ^ 0xB);
    let dq = DeltaDq::new(DeltaDqConfig::dropout_only(8.0, Some(16)));
    let set = compress_model_deltas(&deltas, &dq, &BTreeMap::new(), &mut rng);
    t.add_row(vec!["dense fp16".into(), fmt(set.total_elems() as f64 * 2.0 / 1024.0, 1)]);
    t.add_row(vec!["CSR fp16+idx16".into(), fmt(set.storage_bits() as f64 / 8.0 / 1024.0, 1)]);
    let dq_q = DeltaDq::new(DeltaDqConfig::with_quant(8.0, Some(16), 4, 8));
    let mut rng = Pcg64::seeded(SEED ^ 0xB);
    let set_q = compress_model_deltas(&deltas, &dq_q, &BTreeMap::new(), &mut rng);
    t.add_row(vec![
        "CSR 1-bit codes (k=4,m=8)".into(),
        fmt(set_q.storage_bits() as f64 / 8.0 / 1024.0, 1),
    ]);
    out.push_str(&t.render());

    // (c) per-tensor vs group-wise quantization at 4-bit on the sparse delta
    let mut t = Table::new(
        "Ablation C — quantizer granularity (4-bit on alpha=8 sparse delta)",
        &["Quantizer", "Accuracy"],
    );
    for (name, group) in [("per-tensor (DeltaDQ)", None), ("group-128", Some(128usize))] {
        let mut rng = Pcg64::seeded(SEED ^ 0xC);
        let mut set = crate::delta::format::DeltaSet::new(name, 32.0);
        for (tname, d) in &deltas {
            let r = dropout(d, 8.0, DropoutKind::GroupWise { group_size: 16 }, &mut rng);
            let quantized = match group {
                None => {
                    let csr = CsrMatrix::from_dense(&r.matrix);
                    crate::compress::CompressedDelta::Quantized(
                        crate::quant::separate::DecomposedDelta::compress(&csr, 4, 1),
                    )
                }
                Some(g) => {
                    let gq = crate::quant::groupwise::group_fake_quantize_sparse(&r.matrix, 4, g);
                    crate::compress::CompressedDelta::Sparse(CsrMatrix::from_dense(&gq.matrix))
                }
            };
            set.tensors.insert(tname.clone(), quantized);
        }
        let weights = reconstruct_weights(&base, &set);
        t.add_row(vec![name.to_string(), fmt(evaluate(&weights, &eval_data).percent(), 2)]);
    }
    out.push_str(&t.render());

    // quick check that the fine-tuned model itself is healthy
    let orig = evaluate(&ft, &eval_data).percent();
    out.push_str(&format!("(original fine-tuned accuracy: {orig:.2}%)\n"));
    let _ = forward(&ft, &[1, 2, 3]); // keep forward linked in release builds
    Ok(out)
}

// ------------------------------------------------------------- serving

/// E10: the coordinator end-to-end through a pluggable execution
/// backend. Tenants are pinned Cold (`promote_after = MAX`) so the run
/// exercises the separate-computation path — on the native backend that
/// is the fused sparse kernel with zero dense-`Δ` materialization.
/// Falls back to a synthesized tiny base when artifacts are absent, so
/// this experiment runs in any environment (CI included).
pub fn serving(
    models_dir: &Path,
    _data_dir: &Path,
    backend: &Arc<dyn ExecutionBackend>,
) -> Result<String> {
    let base = match load_weights(&models_dir.join("tiny/base.dqw")) {
        Ok(w) => Arc::new(w),
        Err(_) => {
            let mut rng = Pcg64::seeded(1);
            Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng))
        }
    };
    let options = ServerOptions {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(200),
        promote_after: u64::MAX,
        ..Default::default()
    };
    let server = Server::with_backend(base.clone(), options, backend.clone());
    let tenants = ["math", "code"];
    for (i, tenant) in tenants.iter().enumerate() {
        // synthesize a fine-tune, compress its delta at 16x
        let mut rng = Pcg64::seeded(40 + i as u64);
        let mut ft = (*base).clone();
        for name in base.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            let d = Matrix::randn(r, c, 0.001, &mut rng);
            ft.get_mut(&name).add_assign(&d);
        }
        let deltas = extract_deltas(&base, &ft);
        let dq = DeltaDq::new(DeltaDqConfig::for_total_ratio(16.0, Some(DEFAULT_GROUP)));
        let set = compress_model_deltas(&deltas, &dq, &BTreeMap::new(), &mut rng);
        server.register_tenant(tenant, set);
    }

    let prompts: Vec<Vec<u32>> = gen_dataset(TaskKind::Math, 16, 5)
        .into_iter()
        .map(|s| s.prompt)
        .collect();
    let n = 24usize;
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..n)
        .filter_map(|i| {
            server
                .submit(tenants[i % tenants.len()], prompts[i % prompts.len()].clone(), 4)
                .ok()
        })
        .collect();
    for rx in &receivers {
        let _ = rx.recv_timeout(Duration::from_secs(60));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = &server.metrics;
    let completed = m.requests_completed.load(std::sync::atomic::Ordering::Relaxed);
    let errors = m.backend_errors.load(std::sync::atomic::Ordering::Relaxed);
    let mut out = format!(
        "## Serving — coordinator e2e through the '{}' backend (Cold residency)\n",
        server.backend_name()
    );
    out.push_str(&format!(
        "requests: {completed}/{n} completed ({errors} backend errors), {:.1} req/s\n",
        completed as f64 / elapsed.max(1e-9)
    ));
    out.push_str(&format!(
        "latency p50 {:.2}ms p99 {:.2}ms\n",
        m.latency_percentile(50.0) * 1e3,
        m.latency_percentile(99.0) * 1e3
    ));
    out.push_str(&format!("residency: {:?}\n", server.residency()));
    server.shutdown();
    Ok(out)
}

// ------------------------------------------------------------- kernels

/// E11: serving-kernel microbench — the tracked perf trajectory of the
/// compute core. Times the dense blocked matmul and the fused kernel
/// (CSR and decomposed deltas at several k/m points) at
/// serving-realistic shapes, each against the PR-1-era scalar reference
/// kept in [`ref_fused_scalar`], and writes machine-readable
/// `BENCH_kernels.json` (schema documented in `rust/README.md`).
///
/// `DELTADQ_BENCH_QUICK=1` switches to CI mode: small shapes, one rep —
/// enough to validate the bench path and the emitted JSON.
pub fn kernels(json_path: &Path) -> Result<String> {
    let quick = std::env::var("DELTADQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    // (h, t, full case set?) — the h=2048/t=8/CSR@0.5 row is the pinned
    // acceptance shape; h=4096 tracks scaling on the dense+CSR pair only.
    let (shapes, reps, warmup): (Vec<(usize, usize, bool)>, usize, usize) = if quick {
        (vec![(192, 1, true), (192, 8, true)], 1, 0)
    } else {
        (vec![(2048, 1, true), (2048, 8, true), (2048, 32, true), (4096, 8, false)], 5, 1)
    };
    let ref_reps = reps.div_ceil(2).max(1);
    // pooled-case parallelism: DELTADQ_BENCH_THREADS (0 = auto) wins,
    // else auto-detect clamped to the serving-typical 2..=4 range
    let pool_threads = std::env::var("DELTADQ_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(resolve_threads)
        .unwrap_or_else(|| resolve_threads(0).clamp(2, 4));
    let pool1 = ThreadPool::serial();
    // quick mode never runs the pooled case — don't spawn its workers
    let pool_n = if quick { None } else { Some(ThreadPool::new(pool_threads)) };

    let mut rep = KernelReport {
        cases: Vec::new(),
        table: Table::new(
            "Kernels microbench — blocked/pooled compute core vs PR-1 scalar reference",
            &["case", "h", "t", "thr", "mean(ms)", "p50(ms)", "GFLOP/s", "speedup"],
        ),
    };
    let mut rng = Pcg64::seeded(0xBE7C);
    let sparse = |h: usize, density: f64, rng: &mut Pcg64| {
        Matrix::from_fn(h, h, |_, _| {
            if rng.bernoulli(density) {
                rng.normal() * 0.01
            } else {
                0.0
            }
        })
    };

    for &(h, t, full) in &shapes {
        let x = Matrix::randn(t, h, 1.0, &mut rng);
        let w = Matrix::randn(h, h, 0.02, &mut rng);
        let dense_flops = (2 * t * h * h) as f64;

        let r_ref = bench("dense naive", warmup, ref_reps, || x.matmul_nt_naive(&w));
        let ref_dense = r_ref.mean.as_secs_f64();
        rep.push("dense_naive_ref", h, t, None, None, 1, &r_ref, None, dense_flops);
        let r = bench("dense blocked", warmup, reps, || x.matmul_nt(&w));
        rep.push("dense_blocked", h, t, None, None, 1, &r, Some(ref_dense), dense_flops);

        // CSR @ 50% density — the pinned acceptance case at h=2048, t=8
        let csr_half_m = CsrMatrix::from_dense(&sparse(h, 0.5, &mut rng));
        let csr_flops = dense_flops + 2.0 * t as f64 * csr_half_m.nnz() as f64;
        let csr_half = CompressedDelta::Sparse(csr_half_m);
        let r_ref = bench("fused csr.5 ref", warmup, ref_reps, || {
            ref_fused_scalar(&x, &w, &csr_half)
        });
        let ref_csr = r_ref.mean.as_secs_f64();
        rep.push("fused_csr_scalar_ref", h, t, Some(0.5), None, 1, &r_ref, None, csr_flops);
        let r = bench("fused csr.5", warmup, reps, || fused_matmul_nt(&x, &w, &csr_half, &pool1));
        rep.push("fused_csr", h, t, Some(0.5), None, 1, &r, Some(ref_csr), csr_flops);
        if let Some(pool_n) = &pool_n {
            let r = bench("fused csr.5 pooled", warmup, reps, || {
                fused_matmul_nt(&x, &w, &csr_half, pool_n)
            });
            let thr = pool_threads;
            rep.push("fused_csr_pooled", h, t, Some(0.5), None, thr, &r, Some(ref_csr), csr_flops);
        }

        if full {
            // alpha=8-style density plus two decomposition points
            let d8 = sparse(h, 0.125, &mut rng);
            let csr8 = CsrMatrix::from_dense(&d8);
            let nnz = csr8.nnz() as f64;
            let d8_flops = dense_flops + 2.0 * t as f64 * nnz;
            let csr8_delta = CompressedDelta::Sparse(csr8.clone());
            let r_ref = bench("fused csr.125 ref", warmup, ref_reps, || {
                ref_fused_scalar(&x, &w, &csr8_delta)
            });
            let ref_c8 = r_ref.mean.as_secs_f64();
            rep.push("fused_csr_scalar_ref", h, t, Some(0.125), None, 1, &r_ref, None, d8_flops);
            let r = bench("fused csr.125", warmup, reps, || {
                fused_matmul_nt(&x, &w, &csr8_delta, &pool1)
            });
            rep.push("fused_csr", h, t, Some(0.125), None, 1, &r, Some(ref_c8), d8_flops);

            for (k, m) in [(8u32, 1u32), (4, 8)] {
                let dec = CompressedDelta::Quantized(DecomposedDelta::compress(&csr8, k, m));
                let r_ref = bench("fused dec ref", warmup, ref_reps, || {
                    ref_fused_scalar(&x, &w, &dec)
                });
                let ref_d = r_ref.mean.as_secs_f64();
                let km = Some((k, m));
                let name = "fused_decomposed_scalar_ref";
                rep.push(name, h, t, Some(0.125), km, 1, &r_ref, None, d8_flops);
                let r = bench("fused dec", warmup, reps, || fused_matmul_nt(&x, &w, &dec, &pool1));
                rep.push("fused_decomposed", h, t, Some(0.125), km, 1, &r, Some(ref_d), d8_flops);
            }
        }
    }

    let KernelReport { cases, table } = rep;
    let mut root = Json::obj();
    root.set("bench", "kernels")
        .set("schema", 1u64)
        .set("quick", quick)
        .set("reps", reps)
        .set("pool_threads", pool_threads)
        .set("cases", Json::Arr(cases));
    std::fs::write(json_path, root.to_string())
        .with_context(|| format!("write {json_path:?}"))?;

    let mut out = table.render();
    out.push_str("speedup = scalar-reference mean / kernel mean at the same shape\n");

    // Compression-stage throughput (kept from the PR-1 bench so those
    // paths stay measured; report-only — the JSON tracks kernels).
    let c_reps = if quick { 2 } else { 20 };
    out.push_str("\n== compression-stage throughput (512x512 tensor) ==\n");
    let big = Matrix::randn(512, 512, 0.01, &mut rng);
    let mut drop_rng = Pcg64::seeded(2);
    let r = bench("group-wise dropout a=8 h_g=16", 1, c_reps, || {
        dropout(&big, 8.0, DropoutKind::GroupWise { group_size: 16 }, &mut drop_rng)
    });
    out.push_str(&format!("{}\n", r.report()));
    let sparse_big = sparse(512, 0.125, &mut rng);
    let csr_big = CsrMatrix::from_dense(&sparse_big);
    let r = bench("separate quantization k=4 m=8", 1, c_reps, || {
        DecomposedDelta::compress(&csr_big, 4, 8)
    });
    out.push_str(&format!("{}\n", r.report()));
    let dec_big = DecomposedDelta::compress(&csr_big, 4, 8);
    let r = bench("dequantize k=4 m=8 to dense", 1, c_reps, || dec_big.to_dense());
    out.push_str(&format!("{}\n", r.report()));

    out.push_str(&format!("wrote {}\n", json_path.display()));
    Ok(out)
}

/// Accumulates the kernels-bench output: JSON cases + the text table.
struct KernelReport {
    cases: Vec<Json>,
    table: Table,
}

impl KernelReport {
    /// One measured kernel → one JSON case + one report row.
    #[allow(clippy::too_many_arguments)]
    fn push(
        &mut self,
        name: &str,
        h: usize,
        t: usize,
        density: Option<f64>,
        km: Option<(u32, u32)>,
        threads: usize,
        r: &BenchResult,
        ref_mean_s: Option<f64>,
        flops: f64,
    ) {
        let mean = r.mean.as_secs_f64();
        let gflops = flops / mean.max(1e-12) / 1e9;
        let speedup = ref_mean_s.map(|m| m / mean.max(1e-12));
        let mut o = Json::obj();
        o.set("case", name)
            .set("h", h)
            .set("t", t)
            .set("threads", threads)
            .set("density", density.map(Json::Num).unwrap_or(Json::Null))
            .set("k", km.map(|(k, _)| Json::from(k)).unwrap_or(Json::Null))
            .set("m", km.map(|(_, m)| Json::from(m)).unwrap_or(Json::Null))
            .set("iters", r.iters)
            .set("mean_s", mean)
            .set("p50_s", r.p50.as_secs_f64())
            .set("p95_s", r.p95.as_secs_f64())
            .set("min_s", r.min.as_secs_f64())
            .set("gflops", gflops)
            .set("ref_mean_s", ref_mean_s.map(Json::Num).unwrap_or(Json::Null))
            .set("speedup_vs_scalar_ref", speedup.map(Json::Num).unwrap_or(Json::Null));
        self.cases.push(o);
        self.table.add_row(vec![
            name.to_string(),
            h.to_string(),
            t.to_string(),
            threads.to_string(),
            fmt(mean * 1e3, 3),
            fmt(r.p50.as_secs_f64() * 1e3, 3),
            fmt(gflops, 2),
            speedup.map(|s| format!("{s:.2}x")).unwrap_or_else(|| "-".into()),
        ]);
    }
}

/// The PR-1 fused kernel, kept verbatim as the speedup baseline: scalar
/// `dot` per output element for the base term, per-activation-row
/// gathers for the delta term, fresh decode buffer per weight row.
fn ref_fused_scalar(x: &Matrix, w: &Matrix, delta: &CompressedDelta) -> Matrix {
    let t = x.rows();
    let h_out = w.rows();
    let mut out = Matrix::zeros(t, h_out);
    for q in 0..h_out {
        let wrow = w.row(q);
        for p in 0..t {
            out.set(p, q, dot(x.row(p), wrow));
        }
    }
    match delta {
        CompressedDelta::Sparse(csr) => {
            for q in 0..h_out {
                let (cols, vals) = csr.row_entries(q);
                if cols.is_empty() {
                    continue;
                }
                for p in 0..t {
                    let xrow = x.row(p);
                    let mut acc = 0.0f32;
                    for (&c, &v) in cols.iter().zip(vals) {
                        acc += xrow[c as usize] * v;
                    }
                    out.set(p, q, out.get(p, q) + acc);
                }
            }
        }
        CompressedDelta::Quantized(d) => {
            for part in &d.parts {
                for q in 0..h_out {
                    let lo = part.row_offsets[q] as usize;
                    let hi = part.row_offsets[q + 1] as usize;
                    if lo == hi {
                        continue;
                    }
                    let vals: Vec<f32> = (lo..hi).map(|e| d.dequant_entry(part, e)).collect();
                    let cols = &part.col_indices[lo..hi];
                    for p in 0..t {
                        let xrow = x.row(p);
                        let mut acc = 0.0f32;
                        for (&c, &v) in cols.iter().zip(&vals) {
                            acc += xrow[c as usize] * v;
                        }
                        out.set(p, q, out.get(p, q) + acc);
                    }
                }
            }
        }
        CompressedDelta::Dense(m) => {
            for q in 0..h_out {
                let drow = m.row(q);
                for p in 0..t {
                    out.set(p, q, out.get(p, q) + dot(x.row(p), drow));
                }
            }
        }
    }
    out
}

// --------------------------------------------------------------- churn

/// E12: tenant churn at scale — the tiered store under a registered
/// population far larger than the resident `delta_budget`. Pushes N
/// tenants into a scratch [`DeltaStore`], serves them through the
/// coordinator with every tenant starting at Disk, and measures (a)
/// cold-start latency (first request per tenant: hydration + serve) and
/// (b) steady-state latency/throughput under a Zipf-distributed tenant
/// mix, where the popular head stays Cold-resident and the tail pages
/// in and out. Writes machine-readable `BENCH_churn.json`.
///
/// `DELTADQ_BENCH_QUICK=1` switches to CI mode: 10 tenants, capacity 3,
/// 40 steady requests — enough to exercise hydration, demotion, and the
/// emitted JSON.
pub fn churn(backend: &Arc<dyn ExecutionBackend>, json_path: &Path) -> Result<String> {
    let quick = std::env::var("DELTADQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (n_tenants, resident_capacity, steady_requests) =
        if quick { (10usize, 3usize, 40usize) } else { (48, 8, 400) };
    const ZIPF_S: f64 = 1.1;

    let mut rng = Pcg64::seeded(0xC1124);
    let base = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));

    // a scratch store populated with synthesized fine-tune deltas
    let root = std::env::temp_dir().join(format!("deltadq-bench-churn-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store = Arc::new(DeltaStore::open_or_create(&root)?);
    let dq = DeltaDq::new(DeltaDqConfig::for_total_ratio(16.0, Some(DEFAULT_GROUP)));
    let mut per_tenant_bytes = 0u64;
    for i in 0..n_tenants {
        let mut ft = (*base).clone();
        for name in base.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            let d = Matrix::randn(r, c, 0.001, &mut rng);
            ft.get_mut(&name).add_assign(&d);
        }
        let deltas = extract_deltas(&base, &ft);
        let set = compress_model_deltas(&deltas, &dq, &BTreeMap::new(), &mut rng);
        per_tenant_bytes = store.push(&format!("t{i}"), &set)?;
    }
    // resident budget: ~resident_capacity tenants' compressed deltas.
    // Measured against DeltaSet::storage_bits (the store accounting is
    // close but not identical); the half-tenant slack absorbs the gap.
    let delta_budget = per_tenant_bytes * resident_capacity as u64 + per_tenant_bytes / 2;

    let options = ServerOptions {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(200),
        promote_after: u64::MAX, // stay on the fused Cold path
        delta_budget: Some(delta_budget),
        ..Default::default()
    };
    let server = Server::with_store(base, options, backend.clone(), store.clone())?;

    let prompts: Vec<Vec<u32>> = gen_dataset(TaskKind::Math, 16, 5)
        .into_iter()
        .map(|s| s.prompt)
        .collect();
    let recv_timeout = Duration::from_secs(120);

    // phase 1: cold sweep — first touch of every tenant pays Disk→Cold
    let mut cold_ms: Vec<f64> = Vec::new();
    for i in 0..n_tenants {
        let rx = server.submit(&format!("t{i}"), prompts[i % prompts.len()].clone(), 2)?;
        let resp = rx.recv_timeout(recv_timeout)?;
        if let Some(e) = &resp.error {
            anyhow::bail!("cold sweep: tenant t{i} failed: {e}");
        }
        cold_ms.push(resp.total.as_secs_f64() * 1e3);
    }

    // phase 2: steady state — Zipf-distributed tenant mix in waves
    let zipf = crate::util::zipf::Zipf::new(n_tenants, ZIPF_S);
    let mut steady_ms: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    let mut submitted = 0usize;
    while submitted < steady_requests {
        let wave = 8.min(steady_requests - submitted);
        let mut rxs = Vec::with_capacity(wave);
        for _ in 0..wave {
            let tenant = format!("t{}", zipf.sample(&mut rng));
            let prompt = prompts[submitted % prompts.len()].clone();
            rxs.push(server.submit(&tenant, prompt, 2)?);
            submitted += 1;
        }
        for rx in rxs {
            let resp = rx.recv_timeout(recv_timeout)?;
            if let Some(e) = &resp.error {
                anyhow::bail!("steady phase: tenant {} failed: {e}", resp.tenant);
            }
            steady_ms.push(resp.total.as_secs_f64() * 1e3);
        }
    }
    let steady_elapsed = t0.elapsed().as_secs_f64();
    let throughput = steady_requests as f64 / steady_elapsed.max(1e-9);

    let tiers = server.metrics.tiers.clone();
    let disk_loads = tiers.disk_loads.load(std::sync::atomic::Ordering::Relaxed);
    let demotions = tiers.demotions.load(std::sync::atomic::Ordering::Relaxed);
    let bytes_read = tiers.store_bytes_read.load(std::sync::atomic::Ordering::Relaxed);
    let errors = server.metrics.backend_errors.load(std::sync::atomic::Ordering::Relaxed);
    let completed = server.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed);
    let resident_now = server
        .tier_residency()
        .into_iter()
        .filter(|(_, tier, _)| *tier != crate::coordinator::Tier::Disk)
        .count();
    server.shutdown();
    let _ = std::fs::remove_dir_all(&root);

    let mut root_json = Json::obj();
    root_json
        .set("bench", "churn")
        .set("schema", 1u64)
        .set("quick", quick)
        .set("tenants", n_tenants)
        .set("resident_capacity", resident_capacity)
        .set("delta_budget_bytes", delta_budget)
        .set("per_tenant_bytes", per_tenant_bytes)
        .set("zipf_s", ZIPF_S)
        .set("requests_steady", steady_requests)
        .set("completed", completed)
        .set("backend_errors", errors)
        .set("cold_start_ms", latency_stats(&cold_ms))
        .set("steady_ms", latency_stats(&steady_ms))
        .set("steady_throughput_rps", throughput)
        .set("disk_loads", disk_loads)
        .set("demotions", demotions)
        .set("store_bytes_read", bytes_read)
        .set("resident_tenants_end", resident_now);
    std::fs::write(json_path, root_json.to_string())
        .with_context(|| format!("write {json_path:?}"))?;

    let mut out = format!(
        "## Churn — {n_tenants} tenants through a {resident_capacity}-tenant resident budget \
         (Zipf s={ZIPF_S})\n"
    );
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    out.push_str(&format!(
        "cold start: mean {:.2}ms p50 {:.2}ms p99 {:.2}ms over {} first-touches\n",
        mean(&cold_ms),
        percentile(&cold_ms, 50.0),
        percentile(&cold_ms, 99.0),
        cold_ms.len()
    ));
    out.push_str(&format!(
        "steady state: {throughput:.1} req/s, mean {:.2}ms p50 {:.2}ms p99 {:.2}ms\n",
        mean(&steady_ms),
        percentile(&steady_ms, 50.0),
        percentile(&steady_ms, 99.0)
    ));
    out.push_str(&format!(
        "tiering: {disk_loads} disk loads, {demotions} demotions, {bytes_read} bytes read, \
         {resident_now}/{n_tenants} resident at end\n"
    ));
    out.push_str(&format!("wrote {}\n", json_path.display()));
    Ok(out)
}

/// Latency stats sub-object for the churn JSON.
fn latency_stats(xs: &[f64]) -> Json {
    let mean = xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let mut o = Json::obj();
    o.set("mean", mean)
        .set("p50", percentile(xs, 50.0))
        .set("p99", percentile(xs, 99.0))
        .set("n", xs.len());
    o
}

// -------------------------------------------------------------- decode

/// One measured request of the decode bench.
struct DecodeSample {
    long: bool,
    ttft_ms: f64,
    tokens: Vec<u32>,
}

/// Result of one decode-bench phase (one scheduling discipline).
struct DecodePhase {
    samples: Vec<DecodeSample>,
    elapsed_s: f64,
    preempted: u64,
    steps: u64,
    decode_groups: u64,
    decode_lanes: u64,
    prefill_chunks: u64,
    /// Per-step batch occupancy (all-zero for the legacy phase).
    occupancy: crate::util::hist::LatencyHistogram,
    /// Per-group lane counts (all-zero off the batched path).
    group_sizes: crate::util::hist::LatencyHistogram,
}

impl DecodePhase {
    fn total_tokens(&self) -> usize {
        self.samples.iter().map(|s| s.tokens.len()).sum()
    }

    fn tokens_per_s(&self) -> f64 {
        self.total_tokens() as f64 / self.elapsed_s.max(1e-9)
    }
}

/// Count histogram → JSON (values are integer counts, so mean is the
/// only fractional field).
fn count_hist_json(h: &crate::util::hist::LatencyHistogram) -> Json {
    let mut o = Json::obj();
    o.set("count", h.count()).set("mean", h.mean()).set("max", h.max());
    o
}

/// Result of the depth-8 stacked-decode microbenchmark.
struct StackedDepthResult {
    depth: usize,
    steps: usize,
    batched_tokens_per_s: f64,
    per_seq_tokens_per_s: f64,
}

/// Microbenchmark the tentpole kernel win in isolation: `depth`
/// identical sequences of one Cold tenant decoded for `steps`
/// iterations, once through a single [`ExecutionBackend::decode_steps`]
/// call per iteration (one fused t=depth matmul per layer) and once
/// through `depth` separate `decode_step` calls. Asserts the two paths
/// produce bit-identical token streams, then reports tokens/s of each.
fn stacked_depth_bench(
    backend: &Arc<dyn ExecutionBackend>,
    base: &ModelWeights,
    delta: &crate::delta::format::DeltaSet,
    prompt: &[u32],
    depth: usize,
    steps: usize,
) -> Result<StackedDepthResult> {
    use crate::runtime::DecodeLane;
    use crate::sched::{BlockPool, PagedKvCache};
    use crate::tensor::ops::argmax_rows;

    let positions = prompt.len() + steps + 1;
    let block_size = 16usize;
    let blocks = 2 * depth * positions.div_ceil(block_size) + 2;
    let pool = Arc::new(BlockPool::with_blocks(&base.config, block_size, blocks));

    // Prefill `depth` lanes and return (caches, first decode token per
    // lane). Lanes share a prompt, so the streams must stay identical.
    let prefill_lanes = |pool: &Arc<BlockPool>| -> Result<(Vec<PagedKvCache>, Vec<u32>)> {
        let mut caches = Vec::with_capacity(depth);
        let mut tokens = Vec::with_capacity(depth);
        for _ in 0..depth {
            let mut cache = PagedKvCache::new(pool.clone());
            anyhow::ensure!(cache.grow(prompt.len()), "stacked bench pool exhausted");
            let logits = backend.prefill_step(base, Some(delta), prompt, &mut cache)?;
            tokens.push(argmax_rows(&logits)[0]);
            caches.push(cache);
        }
        Ok((caches, tokens))
    };

    // Batched: one decode_steps call per iteration.
    let (mut caches, mut tokens) = prefill_lanes(&pool)?;
    let mut batched_stream: Vec<Vec<u32>> = vec![Vec::new(); depth];
    let t0 = Instant::now();
    for step in 0..steps {
        let pos = prompt.len() + step;
        for cache in caches.iter_mut() {
            anyhow::ensure!(cache.grow(pos + 1), "stacked bench pool exhausted");
        }
        let mut lanes: Vec<DecodeLane<'_>> = caches
            .iter_mut()
            .zip(tokens.iter())
            .map(|(cache, &token)| DecodeLane { token, pos, cache })
            .collect();
        let logits = backend.decode_steps(base, Some(delta), &mut lanes)?;
        tokens = argmax_rows(&logits);
        for (lane, &t) in batched_stream.iter_mut().zip(tokens.iter()) {
            lane.push(t);
        }
    }
    let batched_s = t0.elapsed().as_secs_f64();
    drop(caches); // blocks return to the pool for the next pass

    // Per-sequence: `depth` decode_step calls per iteration.
    let (mut caches, mut tokens) = prefill_lanes(&pool)?;
    let mut per_seq_stream: Vec<Vec<u32>> = vec![Vec::new(); depth];
    let t0 = Instant::now();
    for step in 0..steps {
        let pos = prompt.len() + step;
        for (i, cache) in caches.iter_mut().enumerate() {
            anyhow::ensure!(cache.grow(pos + 1), "stacked bench pool exhausted");
            let logits = backend.decode_step(base, Some(delta), tokens[i], pos, cache)?;
            tokens[i] = argmax_rows(&logits)[0];
            per_seq_stream[i].push(tokens[i]);
        }
    }
    let per_seq_s = t0.elapsed().as_secs_f64();
    drop(caches); // blocks return to the pool for the next pass

    anyhow::ensure!(
        batched_stream == per_seq_stream,
        "stacked decode diverged from per-sequence decode at depth {depth}"
    );
    let total = (depth * steps) as f64;
    Ok(StackedDepthResult {
        depth,
        steps,
        batched_tokens_per_s: total / batched_s.max(1e-9),
        per_seq_tokens_per_s: total / per_seq_s.max(1e-9),
    })
}

/// E14: scheduling disciplines on a mixed workload — a few long
/// generations submitted ahead of many short ones, the pattern where
/// run-to-completion head-of-line-blocks every short request behind the
/// longs. Three phases run the *same* requests:
///
/// * **continuous** — the scheduler's default batched drive loop (one
///   stacked forward per tenant group per iteration),
/// * **per_sequence** — the scheduler with [`StepExec::PerSequence`]
///   (one forward per sequence per iteration),
/// * **run_to_completion** — the legacy worker pool.
///
/// Measures per-class TTFT (streaming, in-process), aggregate tokens/s,
/// and the batched path's group-size/occupancy histograms; asserts all
/// three token streams are bit-identical; and isolates the kernel win
/// with a depth-8 stacked-decode microbenchmark
/// (`stacked_depth8.speedup`, gated > 1 in CI). Writes machine-readable
/// `BENCH_decode.json`.
///
/// `DELTADQ_BENCH_QUICK=1` switches to CI mode: 8 short + 2 long
/// requests per phase, fewer microbench iterations.
pub fn decode(backend: &Arc<dyn ExecutionBackend>, json_path: &Path) -> Result<String> {
    use crate::coordinator::StreamEvent;
    use crate::sched::{SchedOptions, StepExec};

    let quick = std::env::var("DELTADQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (shorts, longs) = if quick { (8usize, 2usize) } else { (32, 4) };
    let (short_max, long_max) = (2usize, 32usize);
    const PROMPT_LEN: usize = 6;
    const BLOCK_SIZE: usize = 16;

    anyhow::ensure!(
        backend.supports_stepping(),
        "decode bench needs a stepping backend ('{}' is run-to-completion only)",
        backend.name()
    );

    let mut rng = Pcg64::seeded(0xDEC0DE);
    let base = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));
    let dq = DeltaDq::new(DeltaDqConfig::for_total_ratio(16.0, Some(DEFAULT_GROUP)));
    let mut tenant_sets = Vec::new();
    for _ in 0..2 {
        let mut ft = (*base).clone();
        for name in base.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            ft.get_mut(&name).add_assign(&Matrix::randn(r, c, 0.001, &mut rng));
        }
        let deltas = extract_deltas(&base, &ft);
        tenant_sets.push(compress_model_deltas(&deltas, &dq, &BTreeMap::new(), &mut rng));
    }
    // request plan: longs (tenant "long") submitted first, then shorts
    // (tenant "short") — worst case for run-to-completion
    let plan: Vec<(bool, Vec<u32>)> = (0..longs + shorts)
        .map(|i| {
            let mut prompt = vec![crate::eval::tasks::vocab::BOS];
            while prompt.len() < PROMPT_LEN {
                prompt.push(
                    crate::eval::tasks::vocab::NUM0
                        + (rng.next_f64() * crate::eval::tasks::vocab::NUM_COUNT as f64) as u32,
                );
            }
            (i < longs, prompt)
        })
        .collect();

    let run_phase = |sched: Option<StepExec>| -> Result<DecodePhase> {
        let options = ServerOptions {
            workers: 1, // equivalent compute either way: one drive thread
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            queue_depth: 1024,
            sched: sched.map(|step_exec| SchedOptions {
                kv_pool_bytes: 8 << 20,
                block_size: BLOCK_SIZE,
                max_running: longs + shorts,
                step_exec,
                ..Default::default()
            }),
            ..Default::default()
        };
        let server = Arc::new(Server::with_backend(base.clone(), options, backend.clone()));
        server.register_tenant("long", tenant_sets[0].clone());
        server.register_tenant("short", tenant_sets[1].clone());
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for (long, prompt) in plan.clone() {
            let (tenant, max_tokens) = if long { ("long", long_max) } else { ("short", short_max) };
            let rx = server
                .submit_stream(tenant, prompt, max_tokens)
                .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
            let submitted = Instant::now();
            handles.push(std::thread::spawn(move || -> Result<DecodeSample> {
                let mut ttft_ms = f64::NAN;
                let mut tokens = Vec::new();
                loop {
                    match rx.recv_timeout(Duration::from_secs(300))? {
                        StreamEvent::Token(t) => {
                            if tokens.is_empty() {
                                ttft_ms = submitted.elapsed().as_secs_f64() * 1e3;
                            }
                            tokens.push(t);
                        }
                        StreamEvent::Done(resp) => {
                            if let Some(e) = resp.error {
                                anyhow::bail!("request failed: {e}");
                            }
                            // a zero-token generation's TTFT is its
                            // completion time
                            if tokens.is_empty() {
                                ttft_ms = submitted.elapsed().as_secs_f64() * 1e3;
                            }
                            return Ok(DecodeSample { long, ttft_ms, tokens });
                        }
                    }
                }
            }));
        }
        let samples: Result<Vec<DecodeSample>> = handles
            .into_iter()
            .map(|h| -> Result<DecodeSample> {
                h.join().map_err(|_| anyhow::anyhow!("collector panicked"))?
            })
            .collect();
        let samples = samples?;
        let elapsed_s = t0.elapsed().as_secs_f64();
        let stats = server.metrics.sched.stats();
        let phase = DecodePhase {
            samples,
            elapsed_s,
            preempted: stats.preempted_total,
            steps: stats.steps_executed,
            decode_groups: stats.decode_groups_total,
            decode_lanes: stats.decode_lanes_total,
            prefill_chunks: stats.prefill_chunks_total,
            occupancy: server.metrics.sched.occupancy_histogram(),
            group_sizes: server.metrics.sched.group_size_histogram(),
        };
        match Arc::try_unwrap(server) {
            Ok(s) => s.shutdown(),
            Err(_) => anyhow::bail!("server still referenced"),
        }
        Ok(phase)
    };

    let continuous = run_phase(Some(StepExec::Batched))?;
    let per_sequence = run_phase(Some(StepExec::PerSequence))?;
    let legacy = run_phase(None)?;

    let streams =
        |p: &DecodePhase| -> Vec<&Vec<u32>> { p.samples.iter().map(|s| &s.tokens).collect() };
    let tokens_match =
        streams(&continuous) == streams(&per_sequence) && streams(&continuous) == streams(&legacy);

    // The tentpole gate, isolated from scheduling noise: at batch depth
    // 8, one stacked decode_steps call per iteration must out-throughput
    // eight per-sequence decode_step calls (and bit-match them).
    let micro_steps = if quick { 12 } else { 48 };
    let micro_prompt = plan[0].1.clone();
    let stacked =
        stacked_depth_bench(backend, &base, &tenant_sets[0], &micro_prompt, 8, micro_steps)?;
    let stacked_speedup = stacked.batched_tokens_per_s / stacked.per_seq_tokens_per_s.max(1e-9);

    let phase_json = |p: &DecodePhase| -> Json {
        let short_ttft: Vec<f64> =
            p.samples.iter().filter(|s| !s.long).map(|s| s.ttft_ms).collect();
        let long_ttft: Vec<f64> =
            p.samples.iter().filter(|s| s.long).map(|s| s.ttft_ms).collect();
        let mut o = Json::obj();
        o.set("ttft_short_ms", latency_stats(&short_ttft))
            .set("ttft_long_ms", latency_stats(&long_ttft))
            .set("tokens", p.total_tokens())
            .set("tokens_per_s", p.tokens_per_s())
            .set("elapsed_s", p.elapsed_s)
            .set("preempted", p.preempted)
            .set("steps", p.steps)
            .set("decode_groups", p.decode_groups)
            .set("decode_lanes", p.decode_lanes)
            .set(
                "decode_group_mean",
                if p.decode_groups == 0 {
                    0.0
                } else {
                    p.decode_lanes as f64 / p.decode_groups as f64
                },
            )
            .set("prefill_chunks", p.prefill_chunks)
            .set("occupancy", count_hist_json(&p.occupancy))
            .set("group_sizes", count_hist_json(&p.group_sizes));
        o
    };
    let short_p99 = |p: &DecodePhase| -> f64 {
        let xs: Vec<f64> = p.samples.iter().filter(|s| !s.long).map(|s| s.ttft_ms).collect();
        percentile(&xs, 99.0)
    };
    let speedup = short_p99(&legacy) / short_p99(&continuous).max(1e-9);

    let mut stacked_json = Json::obj();
    stacked_json
        .set("depth", stacked.depth)
        .set("steps", stacked.steps)
        .set("batched_tokens_per_s", stacked.batched_tokens_per_s)
        .set("per_seq_tokens_per_s", stacked.per_seq_tokens_per_s)
        .set("speedup", stacked_speedup);

    let mut root = Json::obj();
    root.set("bench", "decode")
        .set("schema", 2u64)
        .set("quick", quick)
        .set("model", "tiny")
        .set("shorts", shorts)
        .set("longs", longs)
        .set("short_max_tokens", short_max)
        .set("long_max_tokens", long_max)
        .set("block_size", BLOCK_SIZE)
        .set("continuous", phase_json(&continuous))
        .set("per_sequence", phase_json(&per_sequence))
        .set("run_to_completion", phase_json(&legacy))
        .set("short_ttft_p99_speedup", speedup)
        .set("stacked_depth8", stacked_json)
        .set("tokens_match", tokens_match);
    std::fs::write(json_path, root.to_pretty_string())
        .with_context(|| format!("write {json_path:?}"))?;

    let mut out = format!(
        "## Decode — scheduling disciplines: {shorts} short \
         (≤{short_max} tok) + {longs} long (≤{long_max} tok) requests, longs first\n"
    );
    let phase_line = |name: &str, p: &DecodePhase| -> String {
        format!(
            "{name}: short TTFT p99 {:.2}ms, {:.1} tok/s over {:.2}s ({} steps, {} preemptions, \
             {} groups / {} lanes, mean occupancy {:.1})\n",
            short_p99(p),
            p.tokens_per_s(),
            p.elapsed_s,
            p.steps,
            p.preempted,
            p.decode_groups,
            p.decode_lanes,
            p.occupancy.mean(),
        )
    };
    out.push_str(&phase_line("continuous (batched)  ", &continuous));
    out.push_str(&phase_line("continuous (per-seq)  ", &per_sequence));
    out.push_str(&phase_line("run-to-completion     ", &legacy));
    out.push_str(&format!(
        "short-request p99 TTFT speedup: {speedup:.2}x; outputs bit-identical: {tokens_match}\n"
    ));
    out.push_str(&format!(
        "stacked depth-{}: {:.1} tok/s batched vs {:.1} tok/s per-seq ({stacked_speedup:.2}x)\n",
        stacked.depth, stacked.batched_tokens_per_s, stacked.per_seq_tokens_per_s,
    ));
    out.push_str(&format!("wrote {}\n", json_path.display()));
    anyhow::ensure!(tokens_match, "scheduler output diverged across disciplines");
    Ok(out)
}


// ------------------------------------------------------------- gateway

/// E13: HTTP serving through the network gateway — the full wire path
/// (TCP accept → HTTP parse → coordinator → SSE token streaming) driven
/// by the open-loop load generator, in-process on an ephemeral port.
/// Measures TTFT, per-token inter-arrival, and total latency for the
/// streaming path plus total latency for the batch path, and pins the
/// backpressure contract (a deliberate flood past `queue_depth` must
/// produce 429s, not hangs). Writes machine-readable
/// `BENCH_gateway.json`.
///
/// `DELTADQ_BENCH_QUICK=1` switches to CI mode: 3 tenants, 24 requests
/// per phase — enough to exercise streaming, batching, and shedding.
pub fn gateway(backend: &Arc<dyn ExecutionBackend>, json_path: &Path) -> Result<String> {
    use crate::gateway::loadgen::{self, LoadgenOptions};
    use crate::gateway::{Gateway, GatewayOptions};

    let quick = std::env::var("DELTADQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (n_tenants, requests, rps) = if quick { (3usize, 24usize, 48.0) } else { (8, 200, 64.0) };
    const ZIPF_S: f64 = 1.1;
    const MAX_TOKENS: usize = 4;

    let mut rng = Pcg64::seeded(0x6A7E);
    let base = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));
    let dq = DeltaDq::new(DeltaDqConfig::for_total_ratio(16.0, Some(DEFAULT_GROUP)));
    let options = ServerOptions {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(200),
        queue_depth: 64,
        ..Default::default()
    };
    let server = Arc::new(Server::with_backend(base.clone(), options, backend.clone()));
    for i in 0..n_tenants {
        let mut ft = (*base).clone();
        for name in base.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            let d = Matrix::randn(r, c, 0.001, &mut rng);
            ft.get_mut(&name).add_assign(&d);
        }
        let deltas = extract_deltas(&base, &ft);
        let set = compress_model_deltas(&deltas, &dq, &BTreeMap::new(), &mut rng);
        server.register_tenant(&format!("t{i}"), set);
    }
    let gw = Gateway::start(server.clone(), "127.0.0.1:0", GatewayOptions {
        max_connections: 32,
        ..Default::default()
    })?;
    let addr = gw.local_addr().to_string();
    let tenants: Vec<String> = (0..n_tenants).map(|i| format!("t{i}")).collect();

    let base_opts = LoadgenOptions {
        addr: addr.clone(),
        tenants: tenants.clone(),
        requests,
        rps,
        zipf_s: ZIPF_S,
        prompt_len: 6,
        max_tokens: MAX_TOKENS,
        seed: 0xFEED,
        ..Default::default()
    };
    let stream_report = loadgen::run(&LoadgenOptions { stream: true, ..base_opts.clone() })?;
    let batch_report = loadgen::run(&LoadgenOptions { stream: false, ..base_opts })?;

    // backpressure probe: a tiny queue flooded far past its depth must
    // shed with 429s while answering everything it accepted. The
    // throttled backend pins per-request service time at 10ms so the
    // burst outpaces the drain on any host speed.
    struct ThrottledBackend {
        inner: Arc<dyn ExecutionBackend>,
        delay: Duration,
    }
    impl ExecutionBackend for ThrottledBackend {
        fn name(&self) -> &'static str {
            "throttled"
        }
        fn prefill(
            &self,
            base: &ModelWeights,
            delta: Option<&crate::delta::format::DeltaSet>,
            tokens: &[u32],
        ) -> Result<Matrix> {
            self.inner.prefill(base, delta, tokens)
        }
        fn generate(
            &self,
            base: &ModelWeights,
            delta: Option<&crate::delta::format::DeltaSet>,
            prompt: &[u32],
            max_new: usize,
            eos: Option<u32>,
        ) -> Result<Vec<u32>> {
            std::thread::sleep(self.delay);
            self.inner.generate(base, delta, prompt, max_new, eos)
        }
    }
    let flood_server = Arc::new(Server::with_backend(
        base,
        ServerOptions {
            workers: 1,
            max_batch: 1,
            batch_window: Duration::from_micros(200),
            queue_depth: 2,
            ..Default::default()
        },
        Arc::new(ThrottledBackend { inner: backend.clone(), delay: Duration::from_millis(10) }),
    ));
    let flood_set = {
        let mut rng = Pcg64::seeded(0xF100D);
        let fresh = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));
        let mut ft = (*fresh).clone();
        for name in fresh.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            ft.get_mut(&name).add_assign(&Matrix::randn(r, c, 0.001, &mut rng));
        }
        compress_model_deltas(&extract_deltas(&fresh, &ft), &dq, &BTreeMap::new(), &mut rng)
    };
    flood_server.register_tenant("flood", flood_set);
    // worker pool + pending cap sized so even a fully simultaneous
    // burst is accepted (overflow would be a 503, polluting the probe)
    let flood_gw = Gateway::start(flood_server.clone(), "127.0.0.1:0", GatewayOptions {
        max_connections: 32,
        ..Default::default()
    })?;
    let flood_report = loadgen::run(&LoadgenOptions {
        addr: flood_gw.local_addr().to_string(),
        tenants: vec!["flood".to_string()],
        requests: if quick { 24 } else { 64 },
        rps: 2000.0, // far past what a 1-worker/depth-2 queue absorbs
        zipf_s: 0.0,
        prompt_len: 6,
        max_tokens: MAX_TOKENS,
        stream: false,
        seed: 0xF100D,
        ..Default::default()
    })?;
    flood_gw.shutdown();

    let completed =
        server.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed);
    let rejected = server.metrics.requests_rejected.load(std::sync::atomic::Ordering::Relaxed);
    let tokens = server.metrics.tokens_generated.load(std::sync::atomic::Ordering::Relaxed);
    gw.shutdown();

    let mut root = Json::obj();
    root.set("bench", "gateway")
        .set("schema", 1u64)
        .set("quick", quick)
        .set("tenants", n_tenants)
        .set("requests_per_phase", requests)
        .set("rps_target", rps)
        .set("zipf_s", ZIPF_S)
        .set("max_tokens", MAX_TOKENS)
        .set("stream", stream_report.to_json())
        .set("nonstream", batch_report.to_json())
        .set("flood", flood_report.to_json())
        .set("server_completed", completed)
        .set("server_rejected", rejected)
        .set("server_tokens_generated", tokens);
    std::fs::write(json_path, root.to_pretty_string())
        .with_context(|| format!("write {json_path:?}"))?;

    let mut out = format!(
        "## Gateway — HTTP serving over {addr}: {n_tenants} tenants, open-loop \
         {rps:.0} req/s target (Zipf s={ZIPF_S})\n"
    );
    out.push_str("streaming phase:\n");
    out.push_str(&stream_report.render());
    out.push_str("non-streaming phase:\n");
    out.push_str(&batch_report.render());
    out.push_str(&format!(
        "flood probe: {} submitted, {} ok, {} shed with 429 (queue_depth 2)\n",
        flood_report.submitted, flood_report.ok, flood_report.rejected_429
    ));
    out.push_str(&format!("wrote {}\n", json_path.display()));
    if flood_report.transport_errors > 0 {
        anyhow::bail!(
            "flood probe dropped {} accepted connections",
            flood_report.transport_errors
        );
    }
    Ok(out)
}

// --------------------------------------------------------------- chaos

/// E14: failure containment end to end — the gateway/coordinator stack
/// under injected faults ([`crate::util::failpoint`]). Three load
/// phases over one server: a fault-free baseline, a fault phase with
/// backend prefill errors and decode-group panics armed (every faulted
/// request must still get a well-formed HTTP answer), and a recovery
/// phase after disarming (throughput must come back). Two targeted
/// probes ride along: expired per-request deadlines must answer
/// `deadline exceeded`, and injected gateway socket-write failures must
/// drop only their own connection. Writes machine-readable
/// `BENCH_chaos.json`; the CI gate asserts `wedged_requests == 0` and
/// `recovery_ratio > 0.8`.
///
/// `DELTADQ_BENCH_QUICK=1` switches to the CI-sized run.
pub fn chaos(backend: &Arc<dyn ExecutionBackend>, json_path: &Path) -> Result<String> {
    use crate::gateway::http::read_response;
    use crate::gateway::loadgen::{self, LoadgenOptions};
    use crate::gateway::{Gateway, GatewayOptions};
    use crate::util::failpoint;
    use std::io::{BufReader, Write as _};
    use std::net::TcpStream;

    let quick = std::env::var("DELTADQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (n_tenants, requests, rps) = if quick { (3usize, 24usize, 32.0) } else { (6, 96, 48.0) };
    const MAX_TOKENS: usize = 4;

    // a clean slate in case the harness process armed anything earlier
    failpoint::disarm_all();
    failpoint::set_seed(0xC1A05);

    let mut rng = Pcg64::seeded(0xC1A05);
    let base = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));
    let dq = DeltaDq::new(DeltaDqConfig::for_total_ratio(16.0, Some(DEFAULT_GROUP)));
    let server = Arc::new(Server::with_backend(
        base.clone(),
        ServerOptions {
            workers: 2,
            max_batch: 4,
            batch_window: Duration::from_micros(200),
            queue_depth: 64,
            ..Default::default()
        },
        backend.clone(),
    ));
    for i in 0..n_tenants {
        let mut ft = (*base).clone();
        for name in base.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            ft.get_mut(&name).add_assign(&Matrix::randn(r, c, 0.001, &mut rng));
        }
        let set = compress_model_deltas(&extract_deltas(&base, &ft), &dq, &BTreeMap::new(), &mut rng);
        server.register_tenant(&format!("t{i}"), set);
    }
    let gw = Gateway::start(server.clone(), "127.0.0.1:0", GatewayOptions {
        max_connections: 32,
        ..Default::default()
    })?;
    let addr = gw.local_addr().to_string();
    let tenants: Vec<String> = (0..n_tenants).map(|i| format!("t{i}")).collect();
    let lg = |seed: u64| LoadgenOptions {
        addr: addr.clone(),
        tenants: tenants.clone(),
        requests,
        rps,
        zipf_s: 1.1,
        prompt_len: 6,
        max_tokens: MAX_TOKENS,
        stream: true,
        seed,
        ..Default::default()
    };

    // phase 1: fault-free baseline
    let baseline = loadgen::run(&lg(0xBA5E))?;

    // phase 2: faults armed. Both kinds are server-internal, so every
    // request still gets a well-formed answer: prefill errors surface
    // as error responses, decode panics are contained per group by the
    // scheduler's catch_unwind and surface the same way.
    failpoint::arm("backend.prefill=err(3);backend.decode=panic(2)")?;
    let fault = loadgen::run(&lg(0xFA17))?;

    // deadline probe: an already-expired TTL must answer `deadline
    // exceeded` (and free its KV blocks) rather than execute or hang
    let deadline_probe = 4usize;
    let mut deadline_expired = 0usize;
    for _ in 0..deadline_probe {
        let rx = server
            .submit_with_ttl("t0", vec![1, 2, 3], MAX_TOKENS, Duration::from_micros(1))
            .map_err(|e| anyhow::anyhow!("deadline probe submit: {e}"))?;
        let resp = rx.recv_timeout(Duration::from_secs(30))?;
        if resp.error.as_deref().is_some_and(|e| e.contains("deadline")) {
            deadline_expired += 1;
        }
    }

    // gateway-write probe: a failed socket write must drop only its
    // own connection — the worker logs it and serves the next one
    failpoint::arm("gateway.write=err(2)")?;
    let (mut gw_dropped, mut gw_ok) = (0usize, 0usize);
    for _ in 0..6 {
        let probe = (|| -> Result<u16> {
            let conn = TcpStream::connect(addr.as_str())?;
            conn.set_read_timeout(Some(Duration::from_secs(10)))?;
            let mut w = conn.try_clone()?;
            write!(w, "GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")?;
            w.flush()?;
            Ok(read_response(&mut BufReader::new(conn))?.status)
        })();
        match probe {
            Ok(200) => gw_ok += 1,
            Ok(s) => anyhow::bail!("gateway probe answered {s}"),
            Err(_) => gw_dropped += 1,
        }
    }

    let fault_counts = failpoint::triggered_counts();
    failpoint::disarm_all();

    // recovery latency: disarm → first clean end-to-end completion
    let recover_t0 = Instant::now();
    loop {
        let rx = server
            .submit("t0", vec![1, 2, 3], MAX_TOKENS)
            .map_err(|e| anyhow::anyhow!("recovery submit: {e}"))?;
        let resp = rx.recv_timeout(Duration::from_secs(30))?;
        if resp.error.is_none() {
            break;
        }
        anyhow::ensure!(
            recover_t0.elapsed() < Duration::from_secs(10),
            "server did not recover within 10s of disarming faults"
        );
    }
    let recovery_latency_ms = recover_t0.elapsed().as_secs_f64() * 1e3;

    // phase 3: recovery throughput must come back to the baseline's
    let recovery = loadgen::run(&lg(0x2EC0))?;
    gw.shutdown();

    let wedged = fault.transport_errors + recovery.transport_errors;
    let recovery_ratio = if baseline.achieved_rps() > 0.0 {
        recovery.achieved_rps() / baseline.achieved_rps()
    } else {
        0.0
    };
    let m = &server.metrics;
    let sched = m.sched.stats();
    let backend_errors = m.backend_errors.load(std::sync::atomic::Ordering::Relaxed);

    let mut counts = Json::obj();
    for (name, n) in &fault_counts {
        counts.set(name.as_str(), *n);
    }
    let mut probes = Json::obj();
    probes
        .set("deadline_submitted", deadline_probe)
        .set("deadline_expired", deadline_expired)
        .set("gateway_write_attempted", 6u64)
        .set("gateway_write_dropped", gw_dropped)
        .set("gateway_write_ok", gw_ok);
    let mut root = Json::obj();
    root.set("bench", "chaos")
        .set("schema", 1u64)
        .set("quick", quick)
        .set("tenants", n_tenants)
        .set("requests_per_phase", requests)
        .set("rps_target", rps)
        .set("baseline", baseline.to_json())
        .set("fault", fault.to_json())
        .set("recovery", recovery.to_json())
        .set("fault_counts", counts)
        .set("probes", probes)
        .set("decode_group_panics_total", sched.decode_group_panics_total)
        .set("deadline_expired_total", sched.deadline_expired_total)
        .set("backend_errors", backend_errors)
        .set("load_retries_total", m.tiers.load_retries.load(std::sync::atomic::Ordering::Relaxed))
        .set("wedged_requests", wedged)
        .set("recovery_ratio", recovery_ratio)
        .set("recovery_latency_ms", recovery_latency_ms);
    std::fs::write(json_path, root.to_pretty_string())
        .with_context(|| format!("write {json_path:?}"))?;

    let mut out = format!(
        "## Chaos — fault injection over {addr}: {n_tenants} tenants, {requests} req/phase\n"
    );
    out.push_str("baseline phase:\n");
    out.push_str(&baseline.render());
    out.push_str("fault phase (backend.prefill=err(3); backend.decode=panic(2)):\n");
    out.push_str(&fault.render());
    out.push_str("recovery phase:\n");
    out.push_str(&recovery.render());
    out.push_str(&format!(
        "faults fired: {:?}; decode-group panics contained: {}; deadline probe: {}/{} expired\n",
        fault_counts, sched.decode_group_panics_total, deadline_expired, deadline_probe
    ));
    out.push_str(&format!(
        "gateway-write probe: {gw_dropped} dropped / {gw_ok} served of 6 (workers survived)\n"
    ));
    out.push_str(&format!(
        "wedged: {wedged}; recovery ratio {recovery_ratio:.2}; \
         recovery latency {recovery_latency_ms:.1}ms\n"
    ));
    out.push_str(&format!("wrote {}\n", json_path.display()));

    anyhow::ensure!(wedged == 0, "{wedged} requests wedged (no well-formed answer)");
    anyhow::ensure!(
        deadline_expired == deadline_probe,
        "deadline probe: only {deadline_expired}/{deadline_probe} answered deadline exceeded"
    );
    anyhow::ensure!(gw_ok >= 4, "gateway workers did not survive injected write failures");
    anyhow::ensure!(
        sched.decode_group_panics_total >= 1,
        "decode panic fault armed but never contained"
    );
    Ok(out)
}

// --------------------------------------------------------------- trace

/// Synthesize one small-perturbation fine-tune delta off `base` and
/// compress it (the serving benches' standard tenant recipe).
fn synth_delta(base: &ModelWeights, dq: &DeltaDq, rng: &mut Pcg64) -> DeltaSet {
    let mut ft = base.clone();
    for name in base.config.delta_tensor_names() {
        let (r, c) = ft.get(&name).shape();
        ft.get_mut(&name).add_assign(&Matrix::randn(r, c, 0.001, rng));
    }
    compress_model_deltas(&extract_deltas(base, &ft), dq, &BTreeMap::new(), rng)
}

/// Recursive span-name census over a request_tree document.
fn count_spans(node: &Json, counts: &mut BTreeMap<String, u64>) {
    if let Some(name) = node.get("name").and_then(Json::as_str) {
        *counts.entry(name.to_string()).or_insert(0) += 1;
    }
    if let Some(kids) = node.get("children").and_then(Json::as_array) {
        for kid in kids {
            count_spans(kid, counts);
        }
    }
}

/// Fraction of the root span's interval covered by the union of its
/// direct children's intervals (clamped to the root).
fn child_coverage(tree: &Json) -> f64 {
    let root_start = tree.get("start_us").and_then(Json::as_f64).unwrap_or(0.0);
    let root_dur = tree.get("dur_us").and_then(Json::as_f64).unwrap_or(0.0);
    if root_dur <= 0.0 {
        return 0.0;
    }
    let mut intervals: Vec<(f64, f64)> = Vec::new();
    if let Some(kids) = tree.get("children").and_then(Json::as_array) {
        for kid in kids {
            let s = kid.get("start_us").and_then(Json::as_f64).unwrap_or(0.0);
            let d = kid.get("dur_us").and_then(Json::as_f64).unwrap_or(0.0);
            let lo = s.max(root_start);
            let hi = (s + d).min(root_start + root_dur);
            if hi > lo {
                intervals.push((lo, hi));
            }
        }
    }
    intervals.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut covered = 0.0;
    let mut cursor = f64::NEG_INFINITY;
    for (lo, hi) in intervals {
        let lo = lo.max(cursor);
        if hi > lo {
            covered += hi - lo;
        }
        cursor = cursor.max(hi);
    }
    covered / root_dur
}

/// E15: tracing overhead and span coverage — the flight recorder's two
/// promises, measured. Phase 1 runs the same in-process request burst
/// with the recorder enabled and disabled (alternating rounds, best-of
/// each side) and reports the throughput cost; the gate holds it at
/// ≤2%. Phase 2 serves one request for a Disk tenant out of a scratch
/// delta store with tracing on and checks the span tree: queue wait,
/// hydration, prefill chunks, and decode groups must all be present,
/// and the root's children must cover ≥90% of its interval. Writes
/// machine-readable `BENCH_trace.json`.
///
/// `DELTADQ_BENCH_QUICK=1` switches to the CI-sized run.
pub fn trace(backend: &Arc<dyn ExecutionBackend>, json_path: &Path) -> Result<String> {
    use crate::util::trace;

    let quick = std::env::var("DELTADQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (rounds, burst) = if quick { (4usize, 32usize) } else { (6, 96) };
    const MAX_TOKENS: usize = 4;
    const N_TENANTS: usize = 3;

    let was_enabled = trace::enabled();
    let mut rng = Pcg64::seeded(0x7124CE);
    let base = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));
    let dq = DeltaDq::new(DeltaDqConfig::for_total_ratio(16.0, Some(DEFAULT_GROUP)));
    let server = Arc::new(Server::with_backend(
        base.clone(),
        ServerOptions {
            workers: 2,
            max_batch: 4,
            batch_window: Duration::from_micros(200),
            queue_depth: 256,
            ..Default::default()
        },
        backend.clone(),
    ));
    for i in 0..N_TENANTS {
        server.register_tenant(&format!("t{i}"), synth_delta(&base, &dq, &mut rng));
    }
    let prompts: Vec<Vec<u32>> =
        gen_dataset(TaskKind::Math, 16, 5).into_iter().map(|s| s.prompt).collect();

    // one burst: submit a wave, drain it, return completed req/s
    let round = |on: bool| -> Result<f64> {
        trace::set_enabled(on);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(burst);
        for k in 0..burst {
            let tenant = format!("t{}", k % N_TENANTS);
            let prompt = prompts[k % prompts.len()].clone();
            let rx = server
                .submit(&tenant, prompt, MAX_TOKENS)
                .map_err(|e| anyhow::anyhow!("burst submit: {e}"))?;
            rxs.push(rx);
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120))?;
            if let Some(e) = &resp.error {
                anyhow::bail!("burst request failed: {e}");
            }
        }
        Ok(burst as f64 / t0.elapsed().as_secs_f64().max(1e-9))
    };

    round(true)?; // warm-up: lazy pools, cold caches
    let (mut best_on, mut best_off) = (0.0f64, 0.0f64);
    for _ in 0..rounds {
        best_off = best_off.max(round(false)?);
        best_on = best_on.max(round(true)?);
    }
    server.shutdown();
    // best-of-rounds on each side filters scheduler jitter; negative
    // overhead (noise) is reported as measured
    let overhead_pct = (1.0 - best_on / best_off) * 100.0;

    // phase 2: traced Disk-tenant request → span-tree shape + coverage
    trace::set_enabled(true);
    trace::clear();
    let store_root =
        std::env::temp_dir().join(format!("deltadq-bench-trace-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_root);
    let store = Arc::new(DeltaStore::open_or_create(&store_root)?);
    store.push("probe", &synth_delta(&base, &dq, &mut rng))?;
    let probe_server = Server::with_store(
        base.clone(),
        ServerOptions {
            workers: 2,
            max_batch: 4,
            batch_window: Duration::from_micros(200),
            ..Default::default()
        },
        backend.clone(),
        store,
    )?;
    let rx = probe_server
        .submit("probe", prompts[0].clone(), MAX_TOKENS)
        .map_err(|e| anyhow::anyhow!("probe submit: {e}"))?;
    let resp = rx.recv_timeout(Duration::from_secs(120))?;
    anyhow::ensure!(resp.error.is_none(), "probe request failed: {:?}", resp.error);
    // the final scheduler iteration may still be flushing its spans
    // when the response lands; give the drive loop a beat
    std::thread::sleep(Duration::from_millis(50));
    let tree = trace::request_tree(resp.id)
        .ok_or_else(|| anyhow::anyhow!("no span tree recorded for request {}", resp.id))?;
    let flight = trace::flight_json(None);
    let flight_events =
        flight.get("traceEvents").and_then(Json::as_array).map(|a| a.len()).unwrap_or(0);
    let ring_len = trace::ring_len();
    probe_server.shutdown();
    let _ = std::fs::remove_dir_all(&store_root);
    trace::set_enabled(was_enabled);

    let mut counts: BTreeMap<String, u64> = BTreeMap::new();
    count_spans(&tree, &mut counts);
    let n = |name: &str| counts.get(name).copied().unwrap_or(0);
    let coverage = child_coverage(&tree);
    let prefill_chunks = n("prefill.chunk");
    let decode_groups = n("decode.group");
    let hydrations = n("tenant.hydrate");
    let queue_waits = n("queue.wait");

    let mut root_json = Json::obj();
    root_json
        .set("bench", "trace")
        .set("schema", 1u64)
        .set("quick", quick)
        .set("rounds", rounds)
        .set("burst", burst)
        .set("rps_enabled", best_on)
        .set("rps_disabled", best_off)
        .set("overhead_pct", overhead_pct)
        .set("coverage", coverage)
        .set("prefill_chunk_spans", prefill_chunks)
        .set("decode_group_spans", decode_groups)
        .set("hydration_spans", hydrations)
        .set("queue_wait_present", queue_waits >= 1)
        .set("flight_events", flight_events)
        .set("ring_len", ring_len);
    std::fs::write(json_path, root_json.to_pretty_string())
        .with_context(|| format!("write {json_path:?}"))?;

    let mut out = format!(
        "## Trace — recorder overhead + coverage: {rounds}x{burst} requests per side\n"
    );
    out.push_str(&format!(
        "throughput: {best_on:.1} req/s traced vs {best_off:.1} req/s untraced \
         ({overhead_pct:+.2}% overhead)\n"
    ));
    out.push_str(&format!(
        "probe tree: coverage {:.1}%, {prefill_chunks} prefill chunk(s), \
         {decode_groups} decode group(s), {hydrations} hydration(s), \
         {queue_waits} queue wait(s)\n",
        coverage * 100.0
    ));
    out.push_str(&format!("flight recorder: {flight_events} events, ring {ring_len} span(s)\n"));
    out.push_str(&trace::render_tree(&tree));
    out.push_str(&format!("wrote {}\n", json_path.display()));

    anyhow::ensure!(
        overhead_pct <= 2.0,
        "tracing costs {overhead_pct:.2}% throughput (budget: 2%)"
    );
    anyhow::ensure!(
        coverage >= 0.9,
        "span tree covers {:.1}% of the root interval (need 90%)",
        coverage * 100.0
    );
    anyhow::ensure!(prefill_chunks >= 1, "no prefill.chunk span in the probe tree");
    anyhow::ensure!(decode_groups >= 1, "no decode.group span in the probe tree");
    anyhow::ensure!(hydrations >= 1, "no tenant.hydrate span in the probe tree");
    anyhow::ensure!(queue_waits >= 1, "no queue.wait span in the probe tree");
    anyhow::ensure!(flight_events > 0, "flight dump is empty");
    Ok(out)
}

// --------------------------------------------------------------- audit

/// E16: compression-quality auditor — overhead, telemetry, detection.
/// Phase 1 runs the same in-process burst against a server with the
/// auditor off and one sampling at 1-in-64 (alternating rounds, best-of
/// each side); the gate holds the cost at ≤2%. Phase 2 profiles a
/// compressed tenant per layer (reconstruction error vs the recorded
/// norm, BIR statistics). Phase 3 serves a clean store-backed tenant
/// with `sample_every = 1` and requires every shadow audit to agree
/// exactly with the served tokens; phase 4 corrupts the resident copy
/// via the `tenant.corrupt_resident` failpoint and measures how many
/// sampled audits the drift detector needs to raise its first warning.
/// Writes machine-readable `BENCH_audit.json`.
///
/// `DELTADQ_BENCH_QUICK=1` switches to the CI-sized run.
pub fn audit(backend: &Arc<dyn ExecutionBackend>, json_path: &Path) -> Result<String> {
    use crate::audit::{layer_stat_json, layer_stats, AuditConfig};
    use crate::util::failpoint;
    use std::sync::atomic::Ordering;

    let quick = std::env::var("DELTADQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (rounds, burst) = if quick { (4usize, 32usize) } else { (6, 96) };
    const MAX_TOKENS: usize = 4;
    const N_TENANTS: usize = 3;

    failpoint::disarm_all();
    let mut rng = Pcg64::seeded(0xA0D17);
    let base = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));
    let dq = DeltaDq::new(DeltaDqConfig::for_total_ratio(16.0, Some(DEFAULT_GROUP)));
    let prompts: Vec<Vec<u32>> =
        gen_dataset(TaskKind::Math, 16, 5).into_iter().map(|s| s.prompt).collect();

    let opts = |audit: AuditConfig| ServerOptions {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(200),
        queue_depth: 256,
        audit,
        ..Default::default()
    };
    let make_server = |audit: AuditConfig, rng: &mut Pcg64| -> Arc<Server> {
        let server = Arc::new(Server::with_backend(base.clone(), opts(audit), backend.clone()));
        for i in 0..N_TENANTS {
            server.register_tenant(&format!("t{i}"), synth_delta(&base, &dq, rng));
        }
        server
    };
    // identical tenant sets on both sides: clone the rng so the two
    // servers draw the same deltas
    let mut rng_off = rng.clone();
    let server_off =
        make_server(AuditConfig { enabled: false, ..AuditConfig::default() }, &mut rng_off);
    let server_on = make_server(AuditConfig::default(), &mut rng); // 1-in-64

    // one burst: submit a wave, drain it, return completed req/s
    let round = |server: &Server| -> Result<f64> {
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(burst);
        for k in 0..burst {
            let tenant = format!("t{}", k % N_TENANTS);
            let prompt = prompts[k % prompts.len()].clone();
            let rx = server
                .submit(&tenant, prompt, MAX_TOKENS)
                .map_err(|e| anyhow::anyhow!("burst submit: {e}"))?;
            rxs.push(rx);
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120))?;
            if let Some(e) = &resp.error {
                anyhow::bail!("burst request failed: {e}");
            }
        }
        Ok(burst as f64 / t0.elapsed().as_secs_f64().max(1e-9))
    };
    round(&server_off)?; // warm-up: lazy pools, cold caches
    round(&server_on)?;
    let (mut best_on, mut best_off) = (0.0f64, 0.0f64);
    for _ in 0..rounds {
        best_off = best_off.max(round(&server_off)?);
        best_on = best_on.max(round(&server_on)?);
    }
    let sampled_1in64 = server_on.metrics.audit.sampled_total.load(Ordering::Relaxed);
    server_off.shutdown();
    server_on.shutdown();
    // best-of-rounds on each side filters scheduler jitter; negative
    // overhead (noise) is reported as measured
    let overhead_pct = (1.0 - best_on / best_off) * 100.0;

    // phase 2: per-layer quality profile of one compressed tenant
    let profile_set = synth_delta(&base, &dq, &mut rng);
    let fallback_pool = ThreadPool::serial();
    let pool = backend.exec_pool().unwrap_or(&fallback_pool);
    let layers = layer_stats(&base, &profile_set, pool);
    let max_recon_error = layers.iter().map(|l| l.recon_error).fold(0.0, f64::max);
    let mean_bir_variance =
        layers.iter().map(|l| l.bir.variance).sum::<f64>() / layers.len().max(1) as f64;

    // a store-backed server auditing every request: reference = the
    // CRC-verified store copy, serving = the resident set
    let exhaustive = AuditConfig {
        enabled: true,
        sample_every: 1,
        quarantine_below: 0.9,
        enforce: false,
        window: 4,
    };
    let store_server = |tag: &str, rng: &mut Pcg64| -> Result<(Arc<Server>, std::path::PathBuf)> {
        let root =
            std::env::temp_dir().join(format!("deltadq-bench-audit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let store = Arc::new(DeltaStore::open_or_create(&root)?);
        store.push("probe", &synth_delta(&base, &dq, rng))?;
        let server =
            Arc::new(Server::with_store(base.clone(), opts(exhaustive.clone()), backend.clone(), store)?);
        Ok((server, root))
    };
    // wait for the async audit thread to drain everything it sampled
    let drain_audits = |server: &Server| -> Result<()> {
        let t0 = Instant::now();
        loop {
            let a = &server.metrics.audit;
            let sampled = a.sampled_total.load(Ordering::Relaxed);
            let done = a.completed_total.load(Ordering::Relaxed)
                + a.errors_total.load(Ordering::Relaxed);
            if done >= sampled {
                return Ok(());
            }
            anyhow::ensure!(
                t0.elapsed() < Duration::from_secs(60),
                "audit thread did not drain ({done}/{sampled}) within 60s"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    };

    // phase 3: clean tenant — every shadow audit must agree exactly
    let clean_requests = if quick { 6usize } else { 12 };
    let (clean_srv, clean_root) = store_server("clean", &mut rng)?;
    for k in 0..clean_requests {
        let rx = clean_srv
            .submit("probe", prompts[k % prompts.len()].clone(), MAX_TOKENS)
            .map_err(|e| anyhow::anyhow!("clean submit: {e}"))?;
        let resp = rx.recv_timeout(Duration::from_secs(120))?;
        anyhow::ensure!(resp.error.is_none(), "clean request failed: {:?}", resp.error);
    }
    drain_audits(&clean_srv)?;
    let clean_hub = &clean_srv.metrics.audit;
    let clean_audits = clean_hub.completed_total.load(Ordering::Relaxed);
    let clean_errors = clean_hub.errors_total.load(Ordering::Relaxed);
    let clean_agreement = clean_hub
        .tenant_summaries()
        .iter()
        .find(|(t, ..)| t == "probe")
        .map(|(_, a, ..)| *a)
        .unwrap_or(0.0);
    clean_srv.shutdown();
    let _ = std::fs::remove_dir_all(&clean_root);

    // phase 4: corrupt the resident copy at hydration and count the
    // sampled audits until the drift detector's first warning
    failpoint::set_seed(0xA0D17);
    failpoint::arm("tenant.corrupt_resident=err(1)")?;
    let (victim_srv, victim_root) = store_server("victim", &mut rng)?;
    let max_probe = 16usize;
    let mut detection_audits = 0u64;
    let mut detected = false;
    for k in 0..max_probe {
        let rx = victim_srv
            .submit("probe", prompts[k % prompts.len()].clone(), MAX_TOKENS)
            .map_err(|e| anyhow::anyhow!("victim submit: {e}"))?;
        let resp = rx.recv_timeout(Duration::from_secs(120))?;
        anyhow::ensure!(resp.error.is_none(), "victim request failed: {:?}", resp.error);
        drain_audits(&victim_srv)?;
        let hub = &victim_srv.metrics.audit;
        if hub.warn_total.load(Ordering::Relaxed) >= 1 {
            detection_audits = hub.completed_total.load(Ordering::Relaxed);
            detected = true;
            break;
        }
    }
    let victim_hub = &victim_srv.metrics.audit;
    let corrupt_agreement = victim_hub
        .tenant_summaries()
        .iter()
        .find(|(t, ..)| t == "probe")
        .map(|(_, a, ..)| *a)
        .unwrap_or(1.0);
    let corruption_fired = failpoint::triggered_counts()
        .iter()
        .any(|(name, n)| name == "tenant.corrupt_resident" && *n >= 1);
    failpoint::disarm_all();
    victim_srv.shutdown();
    let _ = std::fs::remove_dir_all(&victim_root);

    let mut detection = Json::obj();
    detection
        .set("corruption_fired", corruption_fired)
        .set("detected", detected)
        .set("audits_to_detection", detection_audits)
        .set("corrupt_agreement", corrupt_agreement);
    let mut root_json = Json::obj();
    root_json
        .set("bench", "audit")
        .set("schema", 1u64)
        .set("quick", quick)
        .set("rounds", rounds)
        .set("burst", burst)
        .set("rps_audit_off", best_off)
        .set("rps_audit_on", best_on)
        .set("sampled_at_1in64", sampled_1in64)
        .set("overhead_pct", overhead_pct)
        .set("max_recon_error", max_recon_error)
        .set("mean_bir_variance", mean_bir_variance)
        .set("layers", Json::Arr(layers.iter().map(layer_stat_json).collect()))
        .set("clean_requests", clean_requests)
        .set("clean_audits", clean_audits)
        .set("clean_errors", clean_errors)
        .set("clean_agreement", clean_agreement)
        .set("detection", detection);
    std::fs::write(json_path, root_json.to_pretty_string())
        .with_context(|| format!("write {json_path:?}"))?;

    let mut out = format!(
        "## Audit — shadow-audit overhead + detection: {rounds}x{burst} requests per side\n"
    );
    out.push_str(&format!(
        "throughput: {best_on:.1} req/s audited (1/64, {sampled_1in64} sampled) vs \
         {best_off:.1} req/s unaudited ({overhead_pct:+.2}% overhead)\n"
    ));
    out.push_str(&format!(
        "layers: max recon error {max_recon_error:.3e}, mean BIR variance {mean_bir_variance:.3e} \
         over {} tensor(s)\n",
        layers.len()
    ));
    out.push_str(&format!(
        "clean tenant: {clean_audits} audit(s), agreement {clean_agreement:.4}, \
         {clean_errors} error(s)\n"
    ));
    out.push_str(&format!(
        "corrupt tenant: warned after {detection_audits} audit(s) \
         (window agreement {corrupt_agreement:.4})\n"
    ));
    out.push_str(&format!("wrote {}\n", json_path.display()));

    anyhow::ensure!(
        overhead_pct <= 2.0,
        "auditing at 1/64 costs {overhead_pct:.2}% throughput (budget: 2%)"
    );
    anyhow::ensure!(clean_audits >= 1, "clean phase completed no audits");
    anyhow::ensure!(clean_errors == 0, "{clean_errors} clean audits errored");
    anyhow::ensure!(
        clean_agreement == 1.0,
        "clean tenant audits disagree with served tokens (agreement {clean_agreement})"
    );
    anyhow::ensure!(corruption_fired, "corrupt_resident failpoint armed but never fired");
    anyhow::ensure!(detected, "injected corruption not detected within {max_probe} audits");
    Ok(out)
}

// --------------------------------------------------------------- usage

/// E16: per-tenant usage accounting + load-derived backpressure. Phase
/// 1 runs identical request bursts against two servers that differ only
/// in `[usage] enabled` (gate: the ledger costs ≤2% throughput); phase
/// 2 checks the conservation property on the attributing server (Σ
/// per-tenant compute within 5% of the attributed exec wall); phase 3
/// floods a throttled 1-worker/depth-2 server and watches the derived
/// `Retry-After` hint rise above the 1 s floor, then decay back to it
/// once drained; phase 4 re-floods through the HTTP gateway with a
/// loadgen that honors the hints, exercising the retried/deferred
/// accounting end to end. Writes machine-readable `BENCH_usage.json`
/// (schema 1).
///
/// `DELTADQ_BENCH_QUICK=1` switches to the CI-sized run.
pub fn usage(backend: &Arc<dyn ExecutionBackend>, json_path: &Path) -> Result<String> {
    use crate::gateway::loadgen::{self, LoadgenOptions};
    use crate::gateway::{Gateway, GatewayOptions};
    use crate::usage::UsageConfig;

    let quick = std::env::var("DELTADQ_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (rounds, burst) = if quick { (4usize, 32usize) } else { (6, 96) };
    const MAX_TOKENS: usize = 6;
    const N_TENANTS: usize = 3;

    let mut rng = Pcg64::seeded(0x05A6E);
    let base = Arc::new(ModelWeights::init(ModelConfig::tiny(), &mut rng));
    let dq = DeltaDq::new(DeltaDqConfig::for_total_ratio(16.0, Some(DEFAULT_GROUP)));
    let prompts: Vec<Vec<u32>> =
        gen_dataset(TaskKind::Math, 16, 5).into_iter().map(|s| s.prompt).collect();

    let opts = |usage: UsageConfig| ServerOptions {
        workers: 2,
        max_batch: 4,
        batch_window: Duration::from_micros(200),
        queue_depth: 256,
        usage,
        ..Default::default()
    };
    let make_server = |usage: UsageConfig, rng: &mut Pcg64| -> Arc<Server> {
        let server = Arc::new(Server::with_backend(base.clone(), opts(usage), backend.clone()));
        for i in 0..N_TENANTS {
            server.register_tenant(&format!("t{i}"), synth_delta(&base, &dq, rng));
        }
        server
    };
    // identical tenant sets on both sides: clone the rng so the two
    // servers draw the same deltas
    let mut rng_off = rng.clone();
    let server_off =
        make_server(UsageConfig { enabled: false, ..UsageConfig::default() }, &mut rng_off);
    let server_on = make_server(UsageConfig::default(), &mut rng);

    // one burst: submit a wave, drain it, return completed req/s
    let round = |server: &Server| -> Result<f64> {
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(burst);
        for k in 0..burst {
            let tenant = format!("t{}", k % N_TENANTS);
            let prompt = prompts[k % prompts.len()].clone();
            let rx = server
                .submit(&tenant, prompt, MAX_TOKENS)
                .map_err(|e| anyhow::anyhow!("burst submit: {e}"))?;
            rxs.push(rx);
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120))?;
            if let Some(e) = &resp.error {
                anyhow::bail!("burst request failed: {e}");
            }
        }
        Ok(burst as f64 / t0.elapsed().as_secs_f64().max(1e-9))
    };
    round(&server_off)?; // warm-up: lazy pools, cold caches
    round(&server_on)?;
    let (mut best_on, mut best_off) = (0.0f64, 0.0f64);
    for _ in 0..rounds {
        best_off = best_off.max(round(&server_off)?);
        best_on = best_on.max(round(&server_on)?);
    }
    // best-of-rounds on each side filters scheduler jitter; negative
    // overhead (noise) is reported as measured
    let overhead_pct = (1.0 - best_on / best_off) * 100.0;

    // phase 2: conservation — the rounds above pushed identical work
    // through every tenant of the attributing server
    let conservation_ratio = server_on
        .metrics
        .usage
        .conservation_ratio()
        .context("no exec wall was attributed during the burst rounds")?;
    let conservation_err_pct = (conservation_ratio - 1.0).abs() * 100.0;
    let exec_wall_s = server_on.metrics.usage.exec_wall_us() as f64 / 1e6;
    let mut tenant_compute = Json::obj();
    for i in 0..N_TENANTS {
        let name = format!("t{i}");
        let s = server_on
            .metrics
            .usage
            .totals(&name)
            .map(|t| t.compute_us as f64 / 1e6)
            .unwrap_or(0.0);
        tenant_compute.set(&name, s);
    }
    server_off.shutdown();
    server_on.shutdown();

    // phase 3: saturation + derived Retry-After under flood. The
    // throttled backend pins service time at 10ms per request so a
    // 1-worker/depth-2 queue saturates on any host speed; it opts out
    // of the stepping API, so this server runs the legacy worker loop —
    // the path where only read-side ticks roll the saturation window.
    struct ThrottledBackend {
        inner: Arc<dyn ExecutionBackend>,
        delay: Duration,
    }
    impl ExecutionBackend for ThrottledBackend {
        fn name(&self) -> &'static str {
            "throttled"
        }
        fn prefill(
            &self,
            base: &ModelWeights,
            delta: Option<&crate::delta::format::DeltaSet>,
            tokens: &[u32],
        ) -> Result<Matrix> {
            self.inner.prefill(base, delta, tokens)
        }
        fn generate(
            &self,
            base: &ModelWeights,
            delta: Option<&crate::delta::format::DeltaSet>,
            prompt: &[u32],
            max_new: usize,
            eos: Option<u32>,
        ) -> Result<Vec<u32>> {
            std::thread::sleep(self.delay);
            self.inner.generate(base, delta, prompt, max_new, eos)
        }
    }
    // retry_max_s: 3 keeps the honor phase bounded (each pause ≤ 3 s)
    // while still letting the flood push the hint above the floor
    let flood_server = Arc::new(Server::with_backend(
        base.clone(),
        ServerOptions {
            workers: 1,
            max_batch: 1,
            batch_window: Duration::from_micros(200),
            queue_depth: 2,
            usage: UsageConfig { retry_max_s: 3, ..UsageConfig::default() },
            ..Default::default()
        },
        Arc::new(ThrottledBackend { inner: backend.clone(), delay: Duration::from_millis(10) }),
    ));
    flood_server.register_tenant("flood", synth_delta(&base, &dq, &mut rng));

    let flood_len = if quick { Duration::from_secs(2) } else { Duration::from_secs(3) };
    let flood_start = Instant::now();
    let mut peak_retry_after = 0u64;
    let mut peak_combined = 0.0f64;
    let mut flood_rxs = Vec::new();
    let mut flood_shed = 0u64;
    while flood_start.elapsed() < flood_len {
        match flood_server.submit("flood", prompts[0].clone(), 2) {
            Ok(rx) => flood_rxs.push(rx),
            Err(_) => flood_shed += 1,
        }
        // each poll both samples the gauges and reads the derived hint
        let sat = flood_server.saturation();
        peak_retry_after = peak_retry_after.max(sat.retry_after_s);
        peak_combined = peak_combined.max(sat.combined);
        std::thread::sleep(Duration::from_millis(2));
    }
    let flood_accepted = flood_rxs.len() as u64;
    for rx in flood_rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120))?;
        anyhow::ensure!(resp.error.is_none(), "flood request failed: {:?}", resp.error);
    }
    // drained: the 10 s window must slide past the flood and the hint
    // must return to the 1 s floor
    let drain_start = Instant::now();
    let floor_retry_after = loop {
        let sat = flood_server.saturation();
        if sat.retry_after_s == 1 {
            break 1u64;
        }
        anyhow::ensure!(
            drain_start.elapsed() < Duration::from_secs(20),
            "Retry-After hint never decayed to the floor (stuck at {}s, combined {:.3})",
            sat.retry_after_s,
            sat.combined
        );
        std::thread::sleep(Duration::from_millis(200));
    };
    let decay_s = drain_start.elapsed().as_secs_f64();

    // phase 4: the same flood through the HTTP gateway, with a loadgen
    // that honors the hints — tenants pause for the hinted interval and
    // re-fire instead of treating 429/503 as terminal
    let gw = Gateway::start(flood_server.clone(), "127.0.0.1:0", GatewayOptions {
        max_connections: 64,
        ..Default::default()
    })?;
    let honor_report = loadgen::run(&LoadgenOptions {
        addr: gw.local_addr().to_string(),
        tenants: vec!["flood".to_string()],
        requests: if quick { 24 } else { 48 },
        rps: 2000.0, // far past what a 1-worker/depth-2 queue absorbs
        zipf_s: 0.0,
        prompt_len: 6,
        max_tokens: 2,
        stream: false,
        honor_retry_after: true,
        seed: 0x05A6E,
        ..Default::default()
    })?;
    gw.shutdown();
    flood_server.shutdown();

    let mut root = Json::obj();
    root.set("bench", "usage")
        .set("schema", 1u64)
        .set("quick", quick)
        .set("rounds", rounds)
        .set("burst", burst)
        .set("rps_usage_off", best_off)
        .set("rps_usage_on", best_on)
        .set("overhead_pct", overhead_pct)
        .set("conservation_ratio", conservation_ratio)
        .set("conservation_err_pct", conservation_err_pct)
        .set("exec_wall_s", exec_wall_s)
        .set("tenant_compute_s", tenant_compute)
        .set("flood_accepted", flood_accepted)
        .set("flood_shed", flood_shed)
        .set("peak_combined", peak_combined)
        .set("peak_retry_after_s", peak_retry_after)
        .set("floor_retry_after_s", floor_retry_after)
        .set("decay_s", decay_s)
        .set("honor", honor_report.to_json());
    std::fs::write(json_path, root.to_pretty_string())
        .with_context(|| format!("write {json_path:?}"))?;

    let mut out = format!(
        "## Usage — per-tenant accounting + load-derived backpressure: \
         {rounds}x{burst} requests per side\n"
    );
    out.push_str(&format!(
        "throughput: {best_on:.1} req/s ledger on vs {best_off:.1} req/s off \
         ({overhead_pct:+.2}% overhead)\n"
    ));
    out.push_str(&format!(
        "conservation: Σ per-tenant compute / exec wall = {conservation_ratio:.4} \
         ({conservation_err_pct:.2}% error over {exec_wall_s:.2}s attributed)\n"
    ));
    out.push_str(&format!(
        "flood: {flood_accepted} accepted, {flood_shed} shed; Retry-After peaked at \
         {peak_retry_after}s (combined {peak_combined:.2}), back to {floor_retry_after}s \
         after {decay_s:.1}s\n"
    ));
    out.push_str(&format!(
        "honor: {} ok, {} retried, {} deferred, {} terminal 429(s)\n",
        honor_report.ok, honor_report.retried, honor_report.deferred, honor_report.rejected_429
    ));
    out.push_str(&format!("wrote {}\n", json_path.display()));

    anyhow::ensure!(
        overhead_pct <= 2.0,
        "usage ledger costs {overhead_pct:.2}% throughput (budget: 2%)"
    );
    anyhow::ensure!(
        conservation_err_pct <= 5.0,
        "attribution does not conserve: Σ per-tenant / exec wall = {conservation_ratio:.4}"
    );
    anyhow::ensure!(flood_shed > 0, "flood never saturated the queue");
    anyhow::ensure!(
        peak_retry_after > 1,
        "Retry-After hint never rose above the floor under flood (combined {peak_combined:.3})"
    );
    anyhow::ensure!(
        honor_report.retried > 0 && honor_report.deferred > 0,
        "honoring loadgen never backed off ({} retried, {} deferred)",
        honor_report.retried,
        honor_report.deferred
    );
    anyhow::ensure!(honor_report.ok > 0, "no honored request ever completed");
    anyhow::ensure!(
        honor_report.transport_errors == 0,
        "honor phase dropped {} accepted connections",
        honor_report.transport_errors
    );
    Ok(out)
}
