//! Bench harness (experiment index E1–E10 in DESIGN.md): one entry per
//! paper table/figure plus the e2e serving run, each printing the same
//! rows/series the paper reports. Invoked by `deltadq bench --name
//! <exp> [--backend native|pjrt]` and by the `cargo bench` drivers —
//! every experiment that executes a model does so through the supplied
//! [`ExecutionBackend`].

pub mod experiments;

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::ExecutionBackend;

/// Run one named experiment; returns the rendered report text.
pub fn run(
    name: &str,
    models_dir: &Path,
    data_dir: &Path,
    backend: &Arc<dyn ExecutionBackend>,
) -> Result<String> {
    match name {
        "table1" => experiments::table1(models_dir, data_dir),
        "table2" => experiments::table2(models_dir, data_dir),
        "table3" => experiments::table3(models_dir, data_dir),
        "table4" => experiments::table4(models_dir, data_dir),
        "fig4" => experiments::fig4(models_dir, data_dir),
        "fig5" => experiments::fig5(models_dir, data_dir),
        "fig6" => experiments::fig6(models_dir, data_dir),
        "fig7" => experiments::fig7(models_dir, data_dir),
        "fig8" => experiments::fig8(models_dir, data_dir, backend),
        "ablations" => experiments::ablations(models_dir, data_dir),
        "serving" => experiments::serving(models_dir, data_dir, backend),
        // kernel microbench: no models/backend needed; writes the
        // machine-readable trajectory file next to the report
        // (DELTADQ_BENCH_QUICK=1 for the CI-sized run)
        "kernels" => experiments::kernels(Path::new("BENCH_kernels.json")),
        // tenant churn through the tiered delta store: N registered ≫
        // resident budget; cold-start + steady-state under a Zipf mix
        // (DELTADQ_BENCH_QUICK=1 for the CI-sized run)
        "churn" => experiments::churn(backend, Path::new("BENCH_churn.json")),
        // HTTP gateway end to end: in-process server on an ephemeral
        // port driven by the open-loop loadgen — SSE streaming TTFT /
        // inter-token / total latency, plus a 429 backpressure probe
        // (DELTADQ_BENCH_QUICK=1 for the CI-sized run)
        "gateway" => experiments::gateway(backend, Path::new("BENCH_gateway.json")),
        // continuous-batching scheduler vs the run-to-completion loop
        // on a short-vs-long mixed workload: tokens/s and per-class
        // TTFT, plus a bit-identity check between the two paths
        // (DELTADQ_BENCH_QUICK=1 for the CI-sized run)
        "decode" => experiments::decode(backend, Path::new("BENCH_decode.json")),
        // fault injection end to end: baseline / fault / recovery load
        // phases plus deadline and gateway-write containment probes —
        // every injected fault must stay contained (no wedged requests)
        // (DELTADQ_BENCH_QUICK=1 for the CI-sized run)
        "chaos" => experiments::chaos(backend, Path::new("BENCH_chaos.json")),
        // tracing overhead + coverage: throughput with the recorder on
        // vs off (gate: ≤2% cost), then a traced store-backed request
        // whose span tree must cover ≥90% of its root interval
        // (DELTADQ_BENCH_QUICK=1 for the CI-sized run)
        "trace" => experiments::trace(backend, Path::new("BENCH_trace.json")),
        // compression-quality auditor: shadow-sampling overhead at
        // 1-in-64 (gate: ≤2% cost), per-layer recon-error/BIR profile,
        // clean-tenant exact agreement, and injected-corruption
        // detection latency (DELTADQ_BENCH_QUICK=1 for the CI-sized run)
        "audit" => experiments::audit(backend, Path::new("BENCH_audit.json")),
        // per-tenant usage ledger + load-derived backpressure: ledger
        // overhead on vs off (gate: ≤2% cost), Σ per-tenant compute vs
        // exec wall (conservation, ≤5% error), a flood that must raise
        // the Retry-After hint above the floor and decay back, and a
        // loadgen run that honors the hints
        // (DELTADQ_BENCH_QUICK=1 for the CI-sized run)
        "usage" => experiments::usage(backend, Path::new("BENCH_usage.json")),
        "all" => {
            let mut out = String::new();
            for exp in [
                "fig4", "fig5", "fig6", "fig7", "fig8", "table1", "table2", "table3", "table4",
                "ablations", "serving",
            ] {
                out.push_str(&run(exp, models_dir, data_dir, backend)?);
                out.push('\n');
            }
            Ok(out)
        }
        other => bail!("unknown experiment '{other}'"),
    }
}
