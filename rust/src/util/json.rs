//! Minimal JSON value model, serializer, and parser (no external deps).
//!
//! Used for metrics endpoints, experiment logs, and the delta store's
//! `MANIFEST.json` — the one artifact the library both writes *and*
//! reads back (configs still use the TOML-subset parser in
//! [`crate::config`]). The parser accepts standard JSON; numbers are
//! `f64` (the manifest never needs more than 2^53 integer precision).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value. `BTreeMap` keeps object keys sorted → stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Empty array.
    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Append to an array (panics on non-arrays — programmer error).
    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(items) => items.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Insert into an object (panics on non-objects — programmer error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Parse a JSON document (must consume the whole input).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing bytes at offset {pos}");
        }
        Ok(value)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric field as u64 (rejects negatives and fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// The boolean value, if this is a [`Json::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items, if this is a [`Json::Arr`].
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The key → value map, if this is a [`Json::Obj`].
    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(map) => Some(map),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with two-space indentation (for on-disk artifacts a
    /// human will diff, like the `BENCH_*.json` files). Parses back to
    /// the same value as the compact form.
    pub fn to_pretty_string(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        const INDENT: &str = "  ";
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&INDENT.repeat(depth + 1));
                    item.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&INDENT.repeat(depth));
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&INDENT.repeat(depth + 1));
                    Json::Str(k.clone()).write(out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&INDENT.repeat(depth));
                out.push('}');
            }
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------- parse

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, ch: u8) -> Result<()> {
    if bytes.get(*pos) != Some(&ch) {
        bail!("expected '{}' at offset {}", ch as char, *pos);
    }
    *pos += 1;
    Ok(())
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => bail!("unexpected end of input"),
        Some(b'{') => parse_object(bytes, pos),
        Some(b'[') => parse_array(bytes, pos),
        Some(b'"') => Ok(Json::Str(parse_string(bytes, pos)?)),
        Some(b't') => parse_literal(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_literal(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_literal(bytes, pos, "null", Json::Null),
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_literal(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("bad literal at offset {}", *pos)
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos])?;
    match text.parse::<f64>() {
        Ok(n) => Ok(Json::Num(n)),
        Err(_) => bail!("bad number '{text}' at offset {start}"),
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String> {
    expect(bytes, pos, b'"')?;
    let mut out = Vec::new();
    loop {
        match bytes.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(String::from_utf8(out)?);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push(b'"'),
                    Some(b'\\') => out.push(b'\\'),
                    Some(b'/') => out.push(b'/'),
                    Some(b'n') => out.push(b'\n'),
                    Some(b'r') => out.push(b'\r'),
                    Some(b't') => out.push(b'\t'),
                    Some(b'b') => out.push(0x08),
                    Some(b'f') => out.push(0x0C),
                    Some(b'u') => {
                        if *pos + 4 >= bytes.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&bytes[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        // BMP only — the serializer never emits surrogate
                        // pairs (it writes astral chars as raw utf-8)
                        let ch = char::from_u32(code)
                            .ok_or_else(|| anyhow::anyhow!("bad \\u{hex} escape"))?;
                        let mut buf = [0u8; 4];
                        out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                        *pos += 4;
                    }
                    _ => bail!("bad escape at offset {}", *pos),
                }
                *pos += 1;
            }
            Some(&b) => {
                out.push(b);
                *pos += 1;
            }
        }
    }
}

fn parse_array(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    expect(bytes, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at offset {}", *pos),
        }
    }
}

fn parse_object(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    expect(bytes, pos, b'{')?;
    let mut map = BTreeMap::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(bytes, pos);
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        expect(bytes, pos, b':')?;
        map.insert(key, parse_value(bytes, pos)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected ',' or '}}' at offset {}", *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3.0f64).to_string(), "3");
        assert_eq!(Json::from(3.5f64).to_string(), "3.5");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").to_string(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string(), "null");
        assert_eq!(Json::from(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn objects_sorted_and_nested() {
        let mut o = Json::obj();
        o.set("b", 2u64);
        o.set("a", vec![1u64, 2]);
        let mut inner = Json::obj();
        inner.set("x", "y");
        o.set("c", inner);
        assert_eq!(o.to_string(), r#"{"a":[1,2],"b":2,"c":{"x":"y"}}"#);
    }

    #[test]
    fn parse_roundtrips_serializer_output() {
        let mut o = Json::obj();
        o.set("name", "tenant \"a\"\n");
        o.set("bytes", 123456u64);
        o.set("ratio", 16.5f64);
        o.set("ok", true);
        o.set("gone", Json::Null);
        o.set("shards", vec!["s0".to_string(), "s1".to_string()]);
        let text = o.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, o);
        // and the reparse of the re-serialization is stable
        assert_eq!(Json::parse(&back.to_string()).unwrap(), o);
    }

    #[test]
    fn array_builder_and_pretty_roundtrip() {
        let mut a = Json::arr();
        a.push(1u64).push("two");
        let mut o = Json::obj();
        o.set("items", a).set("empty", Json::arr()).set("nested", Json::obj());
        let pretty = o.to_pretty_string();
        assert!(pretty.contains("  \"items\": [\n"), "{pretty}");
        assert!(pretty.contains("\"empty\": []"), "{pretty}");
        assert!(pretty.ends_with('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), o, "pretty form parses back");
    }

    #[test]
    fn parse_accessors() {
        let j = Json::parse(r#"{"a": [1, 2.5], "s": "x", "b": false, "n": 7}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        let arr = j.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[1].as_u64(), None, "fractional is not u64");
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn parse_whitespace_and_escapes() {
        let j = Json::parse(" { \"k\" : \"a\\u0041\\n\" } ").unwrap();
        assert_eq!(j.get("k").unwrap().as_str(), Some("aA\n"));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("nul").is_err());
    }
}
