//! Minimal JSON value model + serializer (no external deps).
//!
//! Used for metrics endpoints, experiment logs, and the `.ddq` sidecar
//! manifests. Writing only — the library never needs to parse arbitrary
//! JSON (configs use the TOML-subset parser in [`crate::config`]).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `BTreeMap` keeps object keys sorted → stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — programmer error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3.0f64).to_string(), "3");
        assert_eq!(Json::from(3.5f64).to_string(), "3.5");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").to_string(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string(), "null");
        assert_eq!(Json::from(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn objects_sorted_and_nested() {
        let mut o = Json::obj();
        o.set("b", 2u64);
        o.set("a", vec![1u64, 2]);
        let mut inner = Json::obj();
        inner.set("x", "y");
        o.set("c", inner);
        assert_eq!(o.to_string(), r#"{"a":[1,2],"b":2,"c":{"x":"y"}}"#);
    }
}
