//! Plain-text table rendering for the bench harness — every experiment
//! prints the same rows the paper's tables/figures report.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn add_row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width");
        self.rows.push(cells);
        self
    }

    /// Render with column alignment and a title rule.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                line.push(' ');
                line.push_str(cell);
                line.push_str(&" ".repeat(pad));
                line.push_str(" |");
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let mut rule = String::from("|");
        for w in &widths {
            rule.push_str(&"-".repeat(w + 2));
            rule.push('|');
        }
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals (table cells).
pub fn fmt(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a ratio column like the paper ("2", "16", "128", or "-" for
/// the degenerate extreme).
pub fn fmt_ratio(v: f64) -> String {
    if v.is_infinite() {
        "-".to_string()
    } else if (v - v.round()).abs() < 1e-9 {
        format!("{}", v.round() as i64)
    } else {
        format!("{v:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Acc"]);
        t.add_row(vec!["DeltaDQ".into(), "52.69".into()]);
        t.add_row(vec!["DARE".into(), "1.81".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| Method  | Acc   |"));
        assert!(r.contains("| DARE    | 1.81  |"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(fmt_ratio(16.0), "16");
        assert_eq!(fmt_ratio(f64::INFINITY), "-");
        assert_eq!(fmt_ratio(2.5), "2.5");
        assert_eq!(fmt(1.23456, 2), "1.23");
    }
}
