//! Minimal benchmarking harness (criterion is not vendored in this
//! container): warmup + timed iterations with mean/p50/p95 reporting.
//! Used by the `cargo bench` drivers in `rust/benches/`.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark case name.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Median per-iteration time.
    pub p50: Duration,
    /// 95th-percentile per-iteration time.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
}

impl BenchResult {
    /// "name  mean 1.23ms  p50 1.20ms  p95 1.40ms (n=100)"
    pub fn report(&self) -> String {
        format!(
            "{:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  (n={})",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            self.iters
        )
    }

    /// Throughput line given a per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64, unit: &str) -> String {
        let per_sec = items_per_iter / self.mean.as_secs_f64();
        format!("{:<44} {:>12.1} {unit}/s", self.name, per_sec)
    }
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Time `f` for `iters` iterations after `warmup` runs. The closure's
/// return value is black-boxed to keep the work alive.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
        min: samples[0],
    }
}

/// Time a closure once (for expensive whole-table runs).
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, BenchResult) {
    let t0 = Instant::now();
    let out = f();
    let d = t0.elapsed();
    (
        out,
        BenchResult {
            name: name.to_string(),
            iters: 1,
            mean: d,
            p50: d,
            p95: d,
            min: d,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 2, 20, || 1 + 1);
        assert_eq!(r.iters, 20);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn bench_once_returns_value() {
        let (v, r) = bench_once("x", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }
}
