//! Deterministic fault injection: a process-wide registry of *named
//! failpoints* that production code evaluates at the places that can
//! fail for real (shard reads, manifest commits, hydration, backend
//! steps, socket writes).
//!
//! With nothing armed — the production default — every [`hit`] is a
//! single relaxed atomic load and an early return: failpoints compile
//! to a no-op branch. Arming happens either programmatically
//! ([`arm`], used by the chaos bench and tests) or via the
//! `DELTADQ_FAILPOINTS` environment variable read once on first use:
//!
//! ```text
//! DELTADQ_FAILPOINTS='store.shard_read=err(2);backend.decode=delay(50)'
//! ```
//!
//! Policy grammar (one policy per point):
//!
//! | spec        | behaviour                                          |
//! |-------------|----------------------------------------------------|
//! | `err`       | fail every hit                                     |
//! | `err(N)`    | fail the next N hits, then no-op (`err(1)` = once) |
//! | `prob(P)`   | fail each hit with probability P (seeded RNG)      |
//! | `delay(MS)` | sleep MS milliseconds, then proceed                |
//! | `panic`     | panic every hit                                    |
//! | `panic(N)`  | panic the next N hits, then no-op                  |
//! | `off`       | disarm the point                                   |
//!
//! The probabilistic policy draws from one registry-owned generator
//! seeded by [`set_seed`] (default fixed), so a faulty run replays
//! bit-for-bit. Injected errors carry the point name
//! (`failpoint '<name>' injected error`) so logs and tests can tell
//! injected faults from organic ones.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

/// What an armed failpoint does when evaluated.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// Fail with an injected error; `None` = every hit, `Some(n)` = the
    /// next `n` hits only.
    Err(Option<u64>),
    /// Fail each hit independently with this probability.
    Prob(f64),
    /// Sleep this long on every hit, then proceed normally.
    Delay(Duration),
    /// Panic; `None` = every hit, `Some(n)` = the next `n` hits only.
    Panic(Option<u64>),
}

/// One armed point plus its accounting.
struct Point {
    policy: Policy,
    /// Remaining trigger budget for bounded policies.
    remaining: Option<u64>,
    /// Times this point fired (injected an error/delay/panic).
    triggered: u64,
}

/// The process-wide registry. `BTreeMap` keeps [`triggered_counts`]
/// output deterministic.
struct Registry {
    points: BTreeMap<String, Point>,
    /// splitmix64 state backing `prob(..)` draws.
    rng: u64,
}

const DEFAULT_SEED: u64 = 0x5EED_FA11;

/// Fast-path guard: false ⇒ [`hit`] returns immediately without
/// touching the registry lock.
static ARMED: AtomicBool = AtomicBool::new(false);
static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
/// One-shot read of `DELTADQ_FAILPOINTS` (first `hit`/`arm` wins).
static ENV_INIT: OnceLock<()> = OnceLock::new();

fn registry() -> &'static Mutex<Registry> {
    REGISTRY.get_or_init(|| {
        Mutex::new(Registry { points: BTreeMap::new(), rng: DEFAULT_SEED })
    })
}

fn env_init() {
    ENV_INIT.get_or_init(|| {
        if let Ok(spec) = std::env::var("DELTADQ_FAILPOINTS") {
            if !spec.trim().is_empty() {
                if let Err(e) = arm(&spec) {
                    eprintln!("failpoint: ignoring invalid DELTADQ_FAILPOINTS: {e:#}");
                }
            }
        }
    });
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Outcome decided under the registry lock, acted on outside it.
enum Action {
    Proceed,
    Sleep(Duration),
    Fail(u64),
    Panic,
}

/// Evaluate the failpoint `name`. Returns `Err` when an error policy
/// fires (callers propagate it exactly like the organic failure the
/// point models), sleeps through delay policies, and panics for panic
/// policies. With nothing armed this is one atomic load.
pub fn hit(name: &str) -> Result<()> {
    env_init();
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    let action = {
        let mut reg = match registry().lock() {
            Ok(g) => g,
            // a panic policy poisons the lock by design; keep injecting
            Err(p) => p.into_inner(),
        };
        // stage 1: budget check (no rng needed), policy cloned out so
        // the point borrow ends before the rng draw below needs `reg`
        let decision = match reg.points.get_mut(name) {
            None => None,
            Some(point) => {
                let in_budget = match point.remaining {
                    Some(0) => false,
                    Some(ref mut n) => {
                        *n -= 1;
                        true
                    }
                    None => true,
                };
                if in_budget {
                    Some(point.policy.clone())
                } else {
                    None
                }
            }
        };
        match decision {
            None => Action::Proceed,
            Some(policy) => {
                let fires = match policy {
                    Policy::Prob(p) => {
                        let draw = splitmix64(&mut reg.rng) as f64 / u64::MAX as f64;
                        draw < p
                    }
                    _ => true,
                };
                if !fires {
                    Action::Proceed
                } else {
                    // the point cannot have vanished: the lock is held
                    let point = reg.points.get_mut(name).expect("armed point present");
                    point.triggered += 1;
                    match policy {
                        Policy::Err(_) | Policy::Prob(_) => Action::Fail(point.triggered),
                        Policy::Panic(_) => Action::Panic,
                        Policy::Delay(d) => Action::Sleep(d),
                    }
                }
            }
        }
    };
    // every fire lands in the flight recorder (the Sleep span's
    // duration is the injected delay itself)
    let mut fire_span = match action {
        Action::Proceed => None,
        _ => {
            let mut s = crate::util::trace::span("failpoint.fire");
            s.attr_str("point", name);
            Some(s)
        }
    };
    match action {
        Action::Proceed => Ok(()),
        Action::Sleep(d) => {
            if let Some(s) = &mut fire_span {
                s.attr_str("action", "delay");
            }
            std::thread::sleep(d);
            Ok(())
        }
        Action::Fail(k) => {
            if let Some(s) = &mut fire_span {
                s.attr_str("action", "error");
            }
            Err(anyhow!("failpoint '{name}' injected error (trigger {k})"))
        }
        Action::Panic => {
            if let Some(s) = &mut fire_span {
                s.attr_str("action", "panic");
            }
            drop(fire_span);
            panic!("failpoint '{name}' injected panic")
        }
    }
}

/// Arm failpoints from a spec string: `name=policy` pairs separated by
/// `;`. Existing points with the same name are replaced; `name=off`
/// disarms one point. Whitespace around separators is ignored.
pub fn arm(spec: &str) -> Result<()> {
    env_init();
    let mut parsed: Vec<(String, Option<Policy>)> = Vec::new();
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, policy) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("failpoint spec '{part}': expected name=policy"))?;
        let (name, policy) = (name.trim(), policy.trim());
        if name.is_empty() {
            bail!("failpoint spec '{part}': empty point name");
        }
        parsed.push((name.to_string(), parse_policy(policy)?));
    }
    let mut reg = match registry().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    for (name, policy) in parsed {
        match policy {
            None => {
                reg.points.remove(&name);
            }
            Some(policy) => {
                let remaining = match policy {
                    Policy::Err(n) | Policy::Panic(n) => n,
                    Policy::Prob(_) | Policy::Delay(_) => None,
                };
                reg.points.insert(name, Point { policy, remaining, triggered: 0 });
            }
        }
    }
    ARMED.store(!reg.points.is_empty(), Ordering::Relaxed);
    Ok(())
}

/// Parse one policy spec (`None` = `off`).
fn parse_policy(s: &str) -> Result<Option<Policy>> {
    let (head, arg) = match s.find('(') {
        Some(i) if s.ends_with(')') => (&s[..i], Some(&s[i + 1..s.len() - 1])),
        Some(_) => bail!("policy '{s}': unbalanced parentheses"),
        None => (s, None),
    };
    let parse_n = |arg: Option<&str>| -> Result<Option<u64>> {
        match arg {
            None => Ok(None),
            Some(a) => Ok(Some(
                a.trim().parse::<u64>().map_err(|_| anyhow!("policy '{s}': bad count"))?,
            )),
        }
    };
    match head {
        "off" => {
            if arg.is_some() {
                bail!("policy '{s}': off takes no argument");
            }
            Ok(None)
        }
        "err" => Ok(Some(Policy::Err(parse_n(arg)?))),
        "panic" => Ok(Some(Policy::Panic(parse_n(arg)?))),
        "prob" => {
            let a = arg.ok_or_else(|| anyhow!("policy '{s}': prob needs a probability"))?;
            let p: f64 =
                a.trim().parse().map_err(|_| anyhow!("policy '{s}': bad probability"))?;
            if !(0.0..=1.0).contains(&p) {
                bail!("policy '{s}': probability must be in [0,1]");
            }
            Ok(Some(Policy::Prob(p)))
        }
        "delay" => {
            let a = arg.ok_or_else(|| anyhow!("policy '{s}': delay needs milliseconds"))?;
            let ms: u64 =
                a.trim().parse().map_err(|_| anyhow!("policy '{s}': bad milliseconds"))?;
            Ok(Some(Policy::Delay(Duration::from_millis(ms))))
        }
        other => bail!("unknown failpoint policy '{other}' (err|prob|delay|panic|off)"),
    }
}

/// Disarm every point and reset trigger accounting. The chaos bench
/// and tests call this between phases.
pub fn disarm_all() {
    env_init();
    let mut reg = match registry().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    reg.points.clear();
    ARMED.store(false, Ordering::Relaxed);
}

/// Reseed the probabilistic-policy generator (runs replay when the
/// seed and the hit order are fixed).
pub fn set_seed(seed: u64) {
    let mut reg = match registry().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    reg.rng = seed;
}

/// Times the named point actually fired (0 if never armed).
pub fn triggered(name: &str) -> u64 {
    if REGISTRY.get().is_none() {
        return 0;
    }
    let reg = match registry().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    reg.points.get(name).map_or(0, |p| p.triggered)
}

/// `(name, times fired)` for every armed point, in name order.
pub fn triggered_counts() -> Vec<(String, u64)> {
    if REGISTRY.get().is_none() {
        return Vec::new();
    }
    let reg = match registry().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    reg.points.iter().map(|(k, v)| (k.clone(), v.triggered)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the registry is process-global and unit tests share one
    // process, so every test here uses point names under `test.` that
    // no production code evaluates, and distinct names per test so
    // parallel execution cannot interleave budgets.

    #[test]
    fn unarmed_is_noop() {
        assert!(hit("test.never_armed").is_ok());
        assert_eq!(triggered("test.never_armed"), 0);
    }

    #[test]
    fn err_n_fails_exactly_n_times() {
        arm("test.err_n=err(2)").unwrap();
        let e = hit("test.err_n").unwrap_err();
        assert!(e.to_string().contains("failpoint 'test.err_n'"), "{e}");
        assert!(hit("test.err_n").is_err());
        assert!(hit("test.err_n").is_ok(), "budget exhausted → no-op");
        assert_eq!(triggered("test.err_n"), 2);
        arm("test.err_n=off").unwrap();
    }

    #[test]
    fn unbounded_err_and_off() {
        arm("test.err_always=err").unwrap();
        for _ in 0..5 {
            assert!(hit("test.err_always").is_err());
        }
        arm("test.err_always=off").unwrap();
        assert!(hit("test.err_always").is_ok());
    }

    #[test]
    fn delay_sleeps_then_proceeds() {
        arm("test.delay=delay(20)").unwrap();
        let t0 = std::time::Instant::now();
        assert!(hit("test.delay").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(15), "{:?}", t0.elapsed());
        assert_eq!(triggered("test.delay"), 1);
        arm("test.delay=off").unwrap();
    }

    #[test]
    fn panic_policy_panics_with_budget() {
        arm("test.panic=panic(1)").unwrap();
        let r = std::panic::catch_unwind(|| hit("test.panic"));
        assert!(r.is_err(), "first hit must panic");
        assert!(hit("test.panic").is_ok(), "budget spent → proceeds");
        arm("test.panic=off").unwrap();
    }

    #[test]
    fn prob_is_seeded_and_bounded() {
        arm("test.prob=prob(0.5)").unwrap();
        set_seed(42);
        let first: Vec<bool> = (0..32).map(|_| hit("test.prob").is_err()).collect();
        set_seed(42);
        let second: Vec<bool> = (0..32).map(|_| hit("test.prob").is_err()).collect();
        assert_eq!(first, second, "same seed must replay the same fault pattern");
        let fired = first.iter().filter(|b| **b).count();
        assert!(fired > 0 && fired < 32, "p=0.5 over 32 draws fired {fired} times");
        arm("test.prob=off").unwrap();
    }

    #[test]
    fn spec_parsing_rejects_garbage() {
        assert!(arm("test.bad").is_err(), "missing =policy");
        assert!(arm("test.bad=explode").is_err(), "unknown policy");
        assert!(arm("test.bad=prob(1.5)").is_err(), "probability out of range");
        assert!(arm("test.bad=err(x)").is_err(), "bad count");
        assert!(arm("=err").is_err(), "empty name");
        // a failed arm must not leave partial state behind
        assert!(hit("test.bad").is_ok());
    }

    #[test]
    fn multi_point_spec_and_counts() {
        arm("test.multi_a=err(1); test.multi_b=delay(1)").unwrap();
        assert!(hit("test.multi_a").is_err());
        assert!(hit("test.multi_b").is_ok());
        let counts = triggered_counts();
        let get = |n: &str| counts.iter().find(|(k, _)| k == n).map(|(_, v)| *v);
        assert_eq!(get("test.multi_a"), Some(1));
        assert_eq!(get("test.multi_b"), Some(1));
        arm("test.multi_a=off;test.multi_b=off").unwrap();
    }
}
