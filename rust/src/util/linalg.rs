//! Small dense linear-algebra helpers: Cholesky factorization and
//! SPD inversion. Used by the DELTAZIP baseline's SparseGPT-style
//! sparsifier, which needs `H⁻¹` of the calibration Hessian
//! `H = XᵀX + λI` (per layer, `h_in × h_in`).

use crate::tensor::Matrix;

/// Lower-triangular Cholesky factor `L` with `L·Lᵀ = A`.
///
/// Returns `None` if `A` is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j) as f64;
            for k in 0..j {
                sum -= l.get(i, k) as f64 * l.get(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l.set(i, j, sum.sqrt() as f32);
            } else {
                l.set(i, j, (sum / l.get(j, j) as f64) as f32);
            }
        }
    }
    Some(l)
}

/// Solve `L·y = b` (forward substitution) for lower-triangular `L`.
pub fn solve_lower(l: &Matrix, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l.get(i, k) as f64 * y[k] as f64;
        }
        y[i] = (sum / l.get(i, i) as f64) as f32;
    }
    y
}

/// Solve `Lᵀ·x = y` (back substitution).
pub fn solve_lower_transpose(l: &Matrix, y: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for k in (i + 1)..n {
            sum -= l.get(k, i) as f64 * x[k] as f64;
        }
        x[i] = (sum / l.get(i, i) as f64) as f32;
    }
    x
}

/// Invert a symmetric positive-definite matrix via Cholesky.
///
/// Returns `None` if not SPD. O(n³) with small constants; our layer
/// dimensions (≤ a few hundred) make this cheap.
pub fn spd_inverse(a: &Matrix) -> Option<Matrix> {
    let n = a.rows();
    let l = cholesky(a)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for col in 0..n {
        e.iter_mut().for_each(|v| *v = 0.0);
        e[col] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_transpose(&l, &y);
        for row in 0..n {
            inv.set(row, col, x[row]);
        }
    }
    Some(inv)
}

/// `XᵀX + λI` — the calibration Hessian used by SparseGPT/DELTAZIP.
/// `x: t×h_in` → `h_in×h_in`. `lambda` is the damping term (relative to
/// the mean diagonal, as in the SparseGPT reference implementation).
pub fn damped_gram(x: &Matrix, lambda_rel: f32) -> Matrix {
    let h = x.cols();
    let mut g = Matrix::zeros(h, h);
    for p in 0..x.rows() {
        let row = x.row(p);
        for i in 0..h {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            let grow = g.row_mut(i);
            for (j, &xj) in row.iter().enumerate() {
                grow[j] += xi * xj;
            }
        }
    }
    let mean_diag = (0..h).map(|i| g.get(i, i) as f64).sum::<f64>() / h as f64;
    let damp = (lambda_rel as f64 * mean_diag).max(1e-8) as f32;
    for i in 0..h {
        g.set(i, i, g.get(i, i) + damp);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        // AᵀA + I is SPD
        let mut g = a.transpose().matmul_nn(&a);
        for i in 0..n {
            g.set(i, i, g.get(i, i) + 1.0);
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(8, 1);
        let l = cholesky(&a).unwrap();
        let rebuilt = l.matmul_nt(&l); // L·Lᵀ
        assert!(rebuilt.allclose(&a, 1e-2, 1e-3));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Matrix::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solves_invert_triangular() {
        let a = random_spd(6, 2);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = (0..6).map(|i| i as f32 + 1.0).collect();
        let y = solve_lower(&l, &b);
        // L·y should be b
        for i in 0..6 {
            let got: f32 = (0..=i).map(|k| l.get(i, k) * y[k]).sum();
            assert!((got - b[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let a = random_spd(10, 3);
        let inv = spd_inverse(&a).unwrap();
        let prod = a.matmul_nn(&inv);
        assert!(prod.allclose(&Matrix::eye(10), 5e-2, 1e-2));
    }

    #[test]
    fn damped_gram_is_spd_and_symmetric() {
        let mut rng = Pcg64::seeded(4);
        let x = Matrix::randn(20, 12, 1.0, &mut rng);
        let g = damped_gram(&x, 0.01);
        assert!(g.allclose(&g.transpose(), 1e-4, 1e-4));
        assert!(cholesky(&g).is_some());
    }

    #[test]
    fn damped_gram_handles_degenerate_inputs() {
        // fewer samples than dims would make XᵀX singular; damping fixes it
        let mut rng = Pcg64::seeded(5);
        let x = Matrix::randn(2, 16, 1.0, &mut rng);
        let g = damped_gram(&x, 0.01);
        assert!(cholesky(&g).is_some(), "damping must make the Gram SPD");
    }
}
