//! Zipf(s) rank sampler shared by the churn bench and the gateway load
//! generator — the standard skewed-popularity model for multi-tenant
//! traffic (rank 0 hottest; `s = 0` degenerates to uniform).

use crate::tensor::Pcg64;

/// Inverse-CDF Zipf sampler over `n` ranks.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Sampler over `n` ranks with skew exponent `s`.
    pub fn new(n: usize, s: f64) -> Zipf {
        let weights: Vec<f64> = (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(s)).collect();
        let sum: f64 = weights.iter().sum();
        let mut acc = 0.0;
        Zipf {
            cdf: weights
                .iter()
                .map(|w| {
                    acc += w / sum;
                    acc
                })
                .collect(),
        }
    }

    /// Sample a rank in `0..n`.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        self.cdf.iter().position(|&c| u < c).unwrap_or(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skewed_toward_rank_zero_and_covers_all_ranks() {
        let z = Zipf::new(8, 1.2);
        let mut rng = Pcg64::seeded(3);
        let mut counts = [0usize; 8];
        for _ in 0..4000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[3], "{counts:?}");
        assert!(counts[0] > counts[7] * 2, "{counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "all ranks sampled: {counts:?}");
    }

    #[test]
    fn s_zero_is_roughly_uniform() {
        let z = Zipf::new(4, 0.0);
        let mut rng = Pcg64::seeded(9);
        let mut counts = [0usize; 4];
        for _ in 0..4000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((700..=1300).contains(&c), "{counts:?}");
        }
    }
}
