//! Wall-clock timing helpers used across the bench harness and the
//! coordinator metrics.

use std::time::{Duration, Instant};

/// Measure one closure invocation.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// A stopwatch that accumulates named segments (profiling the
/// compression pipeline stages).
#[derive(Debug, Default)]
pub struct SegmentTimer {
    segments: Vec<(String, Duration)>,
}

impl SegmentTimer {
    /// Stopwatch with no segments yet.
    pub fn new() -> SegmentTimer {
        SegmentTimer::default()
    }

    /// Time `f` and record it under `name` (accumulating repeats).
    pub fn run<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, dt) = time_it(f);
        if let Some(seg) = self.segments.iter_mut().find(|(n, _)| n == name) {
            seg.1 += dt;
        } else {
            self.segments.push((name.to_string(), dt));
        }
        out
    }

    /// The recorded `(name, accumulated time)` segments, in first-seen
    /// order.
    pub fn segments(&self) -> &[(String, Duration)] {
        &self.segments
    }

    /// Sum of all segment times.
    pub fn total(&self) -> Duration {
        self.segments.iter().map(|(_, d)| *d).sum()
    }

    /// Render a one-line summary "a=1.2ms b=0.3ms (total 1.5ms)".
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .segments
            .iter()
            .map(|(n, d)| format!("{n}={:.1}ms", d.as_secs_f64() * 1e3))
            .collect();
        format!("{} (total {:.1}ms)", parts.join(" "), self.total().as_secs_f64() * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value() {
        let (v, d) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() < 1_000_000_000);
    }

    #[test]
    fn segments_accumulate() {
        let mut t = SegmentTimer::new();
        t.run("a", || std::thread::sleep(Duration::from_millis(1)));
        t.run("a", || std::thread::sleep(Duration::from_millis(1)));
        t.run("b", || ());
        assert_eq!(t.segments().len(), 2);
        assert!(t.segments()[0].1 >= Duration::from_millis(2));
        assert!(t.total() >= Duration::from_millis(2));
        assert!(t.summary().contains("a="));
    }
}
