//! Log-bucketed latency histogram shared by the serving metrics and the
//! gateway load generator.
//!
//! Durations land in geometrically spaced buckets (16 per octave from
//! 1µs up; relative bucket width 2^(1/16) ≈ 4.4%), so a fixed ~4KiB of
//! counters covers nanosecond-to-hour latencies with bounded relative
//! error — unlike the previous ad-hoc scheme (a running mean plus a
//! capped ring of raw samples that forgot history under load).
//! Histograms from different worker threads [`merge`] exactly.
//!
//! [`merge`]: LatencyHistogram::merge

use crate::util::json::Json;

/// Smallest representable latency (seconds); everything below clamps
/// into the first bucket.
const MIN_S: f64 = 1e-6;
/// Sub-buckets per factor-of-two octave.
const SUB: usize = 16;
/// Octaves covered: 1µs · 2^32 ≈ 71 minutes; beyond that clamps into
/// the last bucket.
const OCTAVES: usize = 32;
const BUCKETS: usize = SUB * OCTAVES;

/// Fixed-footprint latency histogram with exact count/sum/min/max and
/// ~±2.2% percentile error.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    fn bucket_of(seconds: f64) -> usize {
        if seconds <= MIN_S {
            return 0;
        }
        let b = ((seconds / MIN_S).log2() * SUB as f64) as usize;
        b.min(BUCKETS - 1)
    }

    /// Geometric midpoint of a bucket (halves the quantization error).
    fn bucket_value(bucket: usize) -> f64 {
        MIN_S * 2f64.powf((bucket as f64 + 0.5) / SUB as f64)
    }

    /// Record one latency in seconds (non-finite samples are dropped).
    pub fn record(&mut self, seconds: f64) {
        if !seconds.is_finite() {
            return;
        }
        let s = seconds.max(0.0);
        self.counts[Self::bucket_of(s)] += 1;
        self.count += 1;
        self.sum += s;
        self.min = self.min.min(s);
        self.max = self.max.max(s);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Whether no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all recorded values (seconds).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (tracked outside the buckets).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact smallest recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile in seconds (`p` in 0..=100), accurate to the bucket
    /// width. The extreme percentiles return the exact tracked min/max.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if p <= 0.0 {
            return self.min();
        }
        if p >= 100.0 {
            return self.max;
        }
        let rank = (p / 100.0 * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // clamp to the observed envelope so tiny histograms
                // don't report beyond their own min/max
                return Self::bucket_value(b).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Cumulative `(upper_bound_seconds, cumulative_count)` pairs over
    /// the occupied log buckets — the finite `le` series of a native
    /// Prometheus histogram. Only non-empty buckets are emitted (a
    /// scrape line per occupied bucket, not per possible bucket); the
    /// caller appends the `+Inf` bucket as [`count`](Self::count).
    /// Samples clamped into the final catch-all bucket carry no finite
    /// upper bound and are folded into `+Inf` only.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate().take(BUCKETS - 1) {
            if c > 0 {
                cum += c;
                // exclusive-upper edge of bucket b (`le` is ≤, and the
                // edge itself lands in bucket b+1 — still correct)
                out.push((MIN_S * 2f64.powf((b + 1) as f64 / SUB as f64), cum));
            }
        }
        out
    }

    /// Fold another histogram into this one (exact: bucket-wise add).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// `{n, mean_ms, p50_ms, p95_ms, p99_ms, min_ms, max_ms}` summary
    /// object — the schema used by loadgen reports and BENCH JSON.
    pub fn summary_ms(&self) -> Json {
        let ms = 1e3;
        let mut o = Json::obj();
        o.set("n", self.count)
            .set("mean_ms", self.mean() * ms)
            .set("p50_ms", self.percentile(50.0) * ms)
            .set("p95_ms", self.percentile(95.0) * ms)
            .set("p99_ms", self.percentile(99.0) * ms)
            .set("min_ms", self.min() * ms)
            .set("max_ms", self.max * ms);
        o
    }

    /// One human-readable report line in milliseconds.
    pub fn report_ms(&self, name: &str) -> String {
        format!(
            "{name:<14} n={:<6} mean {:>9.3}ms  p50 {:>9.3}ms  p95 {:>9.3}ms  p99 {:>9.3}ms",
            self.count,
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_all_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.is_empty());
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn mean_is_exact_and_percentiles_bounded_error() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1ms..1s
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9, "mean is tracked exactly");
        // log-bucket quantization: ±2.5% relative
        let p50 = h.percentile(50.0);
        assert!((p50 - 0.5).abs() / 0.5 < 0.025, "p50 {p50}");
        let p99 = h.percentile(99.0);
        assert!((p99 - 0.99).abs() / 0.99 < 0.025, "p99 {p99}");
        assert_eq!(h.percentile(0.0), 1e-3);
        assert_eq!(h.percentile(100.0), 1.0);
    }

    #[test]
    fn clamps_tiny_huge_and_drops_nonfinite() {
        let mut h = LatencyHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(1e9);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 3, "non-finite samples dropped");
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e9);
        assert!(h.percentile(50.0) <= 1e9);
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for i in 1..=50 {
            a.record(i as f64 * 1e-4);
            all.record(i as f64 * 1e-4);
        }
        for i in 1..=70 {
            b.record(i as f64 * 1e-2);
            all.record(i as f64 * 1e-2);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.sum(), all.sum());
        for p in [10.0, 50.0, 95.0, 99.0] {
            assert_eq!(a.percentile(p), all.percentile(p), "p{p}");
        }
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_bound_samples() {
        let mut h = LatencyHistogram::new();
        let samples = [1e-4, 2e-4, 2e-4, 5e-3, 0.12];
        for s in samples {
            h.record(s);
        }
        let buckets = h.cumulative_buckets();
        assert!(!buckets.is_empty());
        let mut prev_le = 0.0;
        let mut prev_c = 0;
        for &(le, c) in &buckets {
            assert!(le > prev_le, "upper bounds strictly increase");
            assert!(c >= prev_c, "cumulative counts never decrease");
            // the cumulative count at `le` bounds the samples ≤ le
            let at_most = samples.iter().filter(|&&s| s <= le).count() as u64;
            assert!(c <= at_most, "le={le}: cumulative {c} > actual {at_most}");
            prev_le = le;
            prev_c = c;
        }
        assert_eq!(buckets.last().unwrap().1, h.count(), "all samples below the catch-all");
    }

    #[test]
    fn summary_json_has_schema_keys() {
        let mut h = LatencyHistogram::new();
        h.record(0.010);
        h.record(0.020);
        let s = h.summary_ms().to_string();
        for key in ["\"n\":2", "\"mean_ms\"", "\"p50_ms\"", "\"p95_ms\"", "\"p99_ms\""] {
            assert!(s.contains(key), "{key} missing from {s}");
        }
        assert!(h.report_ms("total").contains("n=2"));
    }
}
