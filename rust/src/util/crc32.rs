//! CRC-32 (IEEE 802.3 polynomial, the zlib/gzip variant) — used by the
//! `.ddq` trailing checksum and the delta store's per-layer records so
//! truncated or bit-flipped artifacts fail loudly instead of decoding
//! into garbage deltas. Table-driven, computed at compile time; no
//! dependencies.

/// Reflected polynomial of CRC-32/IEEE.
const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (matches zlib's `crc32(0, ...)`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

/// Incremental CRC-32 over a byte stream.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    /// Fresh hasher (state for an empty stream).
    pub fn new() -> Hasher {
        Hasher { state: !0 }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    /// The checksum of everything updated so far.
    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

impl Default for Hasher {
    fn default() -> Hasher {
        Hasher::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // reference values from the zlib crc32 implementation
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data = b"incremental hashing must match the one-shot path";
        let mut h = Hasher::new();
        for chunk in data.chunks(7) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0u8; 64];
        let clean = crc32(&data);
        data[33] ^= 0x10;
        assert_ne!(crc32(&data), clean);
    }
}
