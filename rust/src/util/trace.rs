//! End-to-end request tracing and the serving flight recorder (S17).
//!
//! Every stage of a request's life — gateway handling, batcher queue
//! wait, scheduler admission, tenant hydration, KV block churn, each
//! prefill chunk, each decode group, every failpoint fire — records a
//! [`Span`]: an id, a parent id, monotonic microsecond timestamps, and
//! a handful of `key=value` attributes. Spans are buffered per thread
//! (lock-light: one registry lock per flushed batch, not per span) and
//! drain into a bounded global flight-recorder ring.
//!
//! Three consumers:
//!
//! * [`request_tree`] — the span tree of one request (the gateway's
//!   `GET /debug/trace/<request_id>`), assembled from the ring: spans
//!   carrying the request id attach directly; tenant-scoped spans
//!   (hydration, decode groups) attach when their tenant matches and
//!   their interval overlaps the request.
//! * [`flight_json`] — the last N seconds of the ring in Chrome Trace
//!   Event Format (the gateway's `GET /debug/flight`), loadable in
//!   `chrome://tracing` or Perfetto; one `tid` lane per recording
//!   thread.
//! * The per-request root spans themselves ([`begin_request`] /
//!   [`end_request`]), which bound the wall time the recorded tree is
//!   benchmarked against (`bench --name trace`).
//!
//! When tracing is disabled ([`set_enabled`]) every recording call is
//! one relaxed atomic load and an early return — the serving hot path
//! pays nothing measurable (gated at ≤2% by `BENCH_trace.json`).

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Default flight-recorder ring capacity, in spans (`[trace] ring_spans`).
pub const DEFAULT_RING_SPANS: usize = 65_536;
/// Default `GET /debug/flight` window, in seconds (`[trace] flight_window_s`).
pub const DEFAULT_FLIGHT_WINDOW_S: u64 = 60;
/// Per-thread buffer size that forces a flush even mid-span-stack.
const FLUSH_EVERY: usize = 64;
/// Cap on simultaneously open request roots; the oldest are evicted to
/// the ring (marked `abandoned`) so a sink that never answers cannot
/// leak memory.
const MAX_OPEN: usize = 1024;

static ENABLED: AtomicBool = AtomicBool::new(true);
static RING_CAP: AtomicUsize = AtomicUsize::new(DEFAULT_RING_SPANS);
static FLIGHT_WINDOW_S: AtomicU64 = AtomicU64::new(DEFAULT_FLIGHT_WINDOW_S);
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static NEXT_LANE: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();

/// One attribute value on a [`Span`].
#[derive(Debug, Clone)]
pub enum AttrVal {
    /// Unsigned integer attribute.
    U64(u64),
    /// Floating-point attribute.
    F64(f64),
    /// String attribute.
    Str(String),
}

fn attr_json(v: &AttrVal) -> Json {
    match v {
        AttrVal::U64(n) => Json::from(*n),
        AttrVal::F64(x) => Json::from(*x),
        AttrVal::Str(s) => Json::from(s.as_str()),
    }
}

/// One recorded interval: a named stage of work with monotonic
/// microsecond timestamps relative to the process trace epoch.
#[derive(Debug, Clone)]
pub struct Span {
    /// Unique span id (process-wide, monotonically allocated).
    pub id: u64,
    /// Enclosing span's id on the recording thread (`0` = none).
    pub parent: u64,
    /// The request this span belongs to (`0` = not request-scoped).
    pub request: u64,
    /// Stage name, dot-namespaced (`"sched.step"`, `"prefill.chunk"`).
    pub name: &'static str,
    /// Tenant the span serves, when the work is tenant-scoped rather
    /// than request-scoped (hydration, decode groups).
    pub tenant: Option<Box<str>>,
    /// Recording thread's lane id (`0` = the cross-thread request lane).
    pub lane: u64,
    /// Start, µs since the trace epoch.
    pub start_us: u64,
    /// End, µs since the trace epoch.
    pub end_us: u64,
    /// `key=value` attributes.
    pub attrs: Vec<(&'static str, AttrVal)>,
}

#[derive(Default)]
struct Registry {
    ring: VecDeque<Span>,
    open: BTreeMap<u64, Span>,
    lanes: Vec<(u64, String)>,
}

fn lock_registry() -> MutexGuard<'static, Registry> {
    match REGISTRY.get_or_init(|| Mutex::new(Registry::default())).lock() {
        Ok(g) => g,
        // a panic mid-record leaves plain data; keep serving
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process trace epoch (monotonic; independent
/// of whether recording is enabled).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

fn us_of(at: Instant) -> u64 {
    match at.checked_duration_since(epoch()) {
        Some(d) => d.as_micros() as u64,
        None => 0, // predates the epoch by construction-order microseconds
    }
}

struct Lane {
    id: u64,
    buf: Vec<Span>,
    stack: Vec<u64>,
}

thread_local! {
    static LANE: RefCell<Lane> = RefCell::new(register_lane());
}

fn register_lane() -> Lane {
    let id = NEXT_LANE.fetch_add(1, Ordering::Relaxed);
    let name = match std::thread::current().name() {
        Some(n) => n.to_string(),
        None => format!("thread-{id}"),
    };
    lock_registry().lanes.push((id, name));
    Lane { id, buf: Vec::new(), stack: Vec::new() }
}

fn push_ring(reg: &mut Registry, span: Span) {
    let cap = RING_CAP.load(Ordering::Relaxed).max(1);
    reg.ring.push_back(span);
    while reg.ring.len() > cap {
        reg.ring.pop_front();
    }
}

fn push_batch(batch: Vec<Span>) {
    let mut reg = lock_registry();
    for span in batch {
        push_ring(&mut reg, span);
    }
}

/// Enable or disable recording. Disabled, every recording call is one
/// relaxed atomic load; the ring and any open roots are left as-is.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether recording is currently enabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Set the flight-recorder ring capacity, in spans.
pub fn configure(ring_spans: usize) {
    RING_CAP.store(ring_spans.max(1), Ordering::Relaxed);
}

/// Set the default `flight_json(None)` window, in seconds (`0` = the
/// whole ring).
pub fn set_flight_window(secs: u64) {
    FLIGHT_WINDOW_S.store(secs, Ordering::Relaxed);
}

/// Drop every recorded span and open root (tests and benches).
pub fn clear() {
    let mut reg = lock_registry();
    reg.ring.clear();
    reg.open.clear();
}

/// Number of finished spans currently in the ring.
pub fn ring_len() -> usize {
    lock_registry().ring.len()
}

/// RAII guard for an in-progress span; the span is recorded when the
/// guard drops. Guards on one thread nest: a guard opened while another
/// is live records the outer span as its parent (drop order must be
/// LIFO, which scoped `let` bindings give for free). Not `Send` — a
/// span starts and ends on one thread ([`span_between`] covers
/// cross-thread intervals, [`begin_request`] the request roots).
pub struct SpanGuard {
    span: Option<Span>,
    _not_send: PhantomData<*mut ()>,
}

/// Open a span with no request association (scheduler iterations,
/// gateway connection handling).
pub fn span(name: &'static str) -> SpanGuard {
    span_for(name, 0)
}

/// Open a span belonging to request `request` (`0` = none).
pub fn span_for(name: &'static str, request: u64) -> SpanGuard {
    if !ENABLED.load(Ordering::Relaxed) {
        return SpanGuard { span: None, _not_send: PhantomData };
    }
    let span = LANE.with(|l| {
        let mut l = l.borrow_mut();
        let parent = l.stack.last().copied().unwrap_or(0);
        let id = NEXT_SPAN.fetch_add(1, Ordering::Relaxed);
        l.stack.push(id);
        Span {
            id,
            parent,
            request,
            name,
            tenant: None,
            lane: l.id,
            start_us: now_us(),
            end_us: 0,
            attrs: Vec::new(),
        }
    });
    SpanGuard { span: Some(span), _not_send: PhantomData }
}

impl SpanGuard {
    /// Attach an unsigned integer attribute.
    pub fn attr_u64(&mut self, key: &'static str, value: u64) {
        if let Some(s) = &mut self.span {
            s.attrs.push((key, AttrVal::U64(value)));
        }
    }

    /// Attach a floating-point attribute.
    pub fn attr_f64(&mut self, key: &'static str, value: f64) {
        if let Some(s) = &mut self.span {
            s.attrs.push((key, AttrVal::F64(value)));
        }
    }

    /// Attach a string attribute.
    pub fn attr_str(&mut self, key: &'static str, value: &str) {
        if let Some(s) = &mut self.span {
            s.attrs.push((key, AttrVal::Str(value.to_string())));
        }
    }

    /// Mark the span as serving `tenant` (joins it into the span trees
    /// of that tenant's overlapping requests).
    pub fn set_tenant(&mut self, tenant: &str) {
        if let Some(s) = &mut self.span {
            s.tenant = Some(tenant.into());
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(mut span) = self.span.take() else { return };
        span.end_us = now_us();
        let batch = LANE.with(|l| {
            let mut l = l.borrow_mut();
            l.stack.pop();
            l.buf.push(span);
            if l.stack.is_empty() || l.buf.len() >= FLUSH_EVERY {
                std::mem::take(&mut l.buf)
            } else {
                Vec::new()
            }
        });
        if !batch.is_empty() {
            push_batch(batch);
        }
    }
}

/// Flush this thread's buffered spans into the ring.
pub fn flush_thread() {
    let batch = LANE.with(|l| std::mem::take(&mut l.borrow_mut().buf));
    if !batch.is_empty() {
        push_batch(batch);
    }
}

/// Record an already-measured interval for request `request` (used
/// where the start predates the recording site, e.g. queue wait
/// measured at admission from the submit timestamp).
pub fn span_between(name: &'static str, request: u64, start: Instant, end: Instant) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let (lane, parent) =
        LANE.with(|l| (l.borrow().id, l.borrow().stack.last().copied().unwrap_or(0)));
    let span = Span {
        id: NEXT_SPAN.fetch_add(1, Ordering::Relaxed),
        parent,
        request,
        name,
        tenant: None,
        lane,
        start_us: us_of(start),
        end_us: us_of(end),
        attrs: Vec::new(),
    };
    push_batch(vec![span]);
}

/// Open request `id`'s root span (at submit time). The root stays open
/// until [`end_request`]; [`request_tree`] renders in-flight requests
/// with `"open": true`.
pub fn begin_request(id: u64, tenant: &str, prompt_len: usize, max_new: usize, start: Instant) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let span = Span {
        id: NEXT_SPAN.fetch_add(1, Ordering::Relaxed),
        parent: 0,
        request: id,
        name: "request",
        tenant: Some(tenant.into()),
        lane: 0,
        start_us: us_of(start),
        end_us: 0,
        attrs: vec![
            ("prompt_len", AttrVal::U64(prompt_len as u64)),
            ("max_new", AttrVal::U64(max_new as u64)),
        ],
    };
    let mut reg = lock_registry();
    while reg.open.len() >= MAX_OPEN {
        let oldest = *reg.open.keys().next().expect("open map non-empty");
        let mut stale = reg.open.remove(&oldest).expect("key just read");
        stale.end_us = now_us();
        stale.attrs.push(("abandoned", AttrVal::U64(1)));
        push_ring(&mut reg, stale);
    }
    reg.open.insert(id, span);
}

/// Close request `id`'s root span (at response time) and flush the
/// calling thread's buffer so the finished tree is immediately
/// queryable. `error` is attached as an attribute when present.
pub fn end_request(id: u64, error: Option<&str>) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    flush_thread();
    let mut reg = lock_registry();
    if let Some(mut root) = reg.open.remove(&id) {
        root.end_us = now_us();
        if let Some(e) = error {
            root.attrs.push(("error", AttrVal::Str(e.to_string())));
        }
        push_ring(&mut reg, root);
    }
}

fn belongs(s: &Span, root: &Span, request: u64, id_str: &str) -> bool {
    if s.request == request {
        return true;
    }
    if s.request != 0 {
        return false;
    }
    // tenant-scoped span: join on tenant + interval overlap, narrowed
    // by an explicit member list when the recorder supplied one
    let Some(tenant) = &s.tenant else { return false };
    if root.tenant.as_deref() != Some(tenant.as_ref()) {
        return false;
    }
    if s.start_us > root.end_us || s.end_us < root.start_us {
        return false;
    }
    match s.attrs.iter().find(|(k, _)| *k == "requests") {
        Some((_, AttrVal::Str(list))) => list.split(',').any(|t| t == id_str),
        _ => true,
    }
}

fn span_json(s: &Span) -> Json {
    let mut j = Json::obj();
    j.set("name", s.name)
        .set("id", s.id)
        .set("start_us", s.start_us)
        .set("dur_us", s.end_us.saturating_sub(s.start_us));
    if s.request != 0 {
        j.set("request", s.request);
    }
    if let Some(t) = &s.tenant {
        j.set("tenant", t.as_ref());
    }
    if !s.attrs.is_empty() {
        let mut attrs = Json::obj();
        for (k, v) in &s.attrs {
            attrs.set(k, attr_json(v));
        }
        j.set("attrs", attrs);
    }
    j
}

fn node_json(span: &Span, members: &[Span], children: &BTreeMap<u64, Vec<usize>>) -> Json {
    let mut j = span_json(span);
    let mut kids = Json::arr();
    if let Some(list) = children.get(&span.id) {
        for &i in list {
            kids.push(node_json(&members[i], members, children));
        }
    }
    j.set("children", kids);
    j
}

/// Assemble request `request`'s span tree from the ring (and its root,
/// open or closed). Spans recorded with the request id attach directly;
/// tenant-scoped spans attach when tenant and interval match. A span
/// whose recorded parent is outside the tree becomes a child of the
/// root, so nesting survives partial ring eviction.
pub fn request_tree(request: u64) -> Option<Json> {
    let reg = lock_registry();
    let (root, open) = match reg.open.get(&request) {
        Some(r) => {
            let mut r = r.clone();
            r.end_us = now_us();
            (r, true)
        }
        None => {
            let r = reg
                .ring
                .iter()
                .rev()
                .find(|s| s.request == request && s.name == "request")?
                .clone();
            (r, false)
        }
    };
    let id_str = request.to_string();
    let members: Vec<Span> = reg
        .ring
        .iter()
        .filter(|s| s.id != root.id && belongs(s, &root, request, &id_str))
        .cloned()
        .collect();
    drop(reg);

    let ids: BTreeSet<u64> = members.iter().map(|s| s.id).collect();
    let mut children: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, s) in members.iter().enumerate() {
        let parent = if s.parent != 0 && ids.contains(&s.parent) { s.parent } else { root.id };
        children.entry(parent).or_default().push(i);
    }
    for kids in children.values_mut() {
        kids.sort_by_key(|&i| (members[i].start_us, members[i].id));
    }
    let mut tree = node_json(&root, &members, &children);
    if open {
        tree.set("open", true);
    }
    Some(tree)
}

/// Index of the most recent traced requests, newest first: in-flight
/// roots (rendered `"open": true`, duration so far), then closed roots
/// from the ring, up to `limit` total. Backs the bare `/debug/trace`
/// endpoint — each entry's `request` id keys `/debug/trace/<id>`.
pub fn recent_requests(limit: usize) -> Json {
    let reg = lock_registry();
    let now = now_us();
    let mut entries: Vec<Json> = Vec::new();
    let mut open: Vec<&Span> = reg.open.values().collect();
    open.sort_by_key(|s| std::cmp::Reverse(s.start_us));
    for s in open {
        if entries.len() >= limit {
            break;
        }
        let mut j = Json::obj();
        j.set("request", s.request)
            .set("start_us", s.start_us)
            .set("dur_us", now.saturating_sub(s.start_us))
            .set("open", true);
        if let Some(t) = &s.tenant {
            j.set("tenant", t.as_ref());
        }
        entries.push(j);
    }
    for s in reg.ring.iter().rev().filter(|s| s.name == "request") {
        if entries.len() >= limit {
            break;
        }
        let mut j = Json::obj();
        j.set("request", s.request)
            .set("start_us", s.start_us)
            .set("dur_us", s.end_us.saturating_sub(s.start_us));
        if let Some(t) = &s.tenant {
            j.set("tenant", t.as_ref());
        }
        if let Some((_, AttrVal::Str(e))) = s.attrs.iter().find(|(k, _)| *k == "error") {
            j.set("error", e.as_str());
        }
        entries.push(j);
    }
    let mut root = Json::obj();
    root.set("requests", Json::Arr(entries));
    root
}

/// Dump the ring's last `window` (default: the configured flight
/// window) as Chrome Trace Event Format JSON — `{"traceEvents": [...]}`
/// with one complete (`"ph": "X"`) event per span and `thread_name`
/// metadata per recording lane. Loadable in `chrome://tracing` and
/// Perfetto.
pub fn flight_json(window: Option<Duration>) -> Json {
    let window_s = match window {
        Some(d) => d.as_secs(),
        None => FLIGHT_WINDOW_S.load(Ordering::Relaxed),
    };
    let cutoff =
        if window_s == 0 { 0 } else { now_us().saturating_sub(window_s.saturating_mul(1_000_000)) };
    let reg = lock_registry();
    let mut events = Json::arr();
    let mut meta = Json::obj();
    let mut args = Json::obj();
    args.set("name", "requests");
    meta.set("name", "thread_name").set("ph", "M").set("pid", 1u64).set("tid", 0u64);
    meta.set("args", args);
    events.push(meta);
    for (id, name) in &reg.lanes {
        let mut m = Json::obj();
        let mut args = Json::obj();
        args.set("name", name.as_str());
        m.set("name", "thread_name").set("ph", "M").set("pid", 1u64).set("tid", *id);
        m.set("args", args);
        events.push(m);
    }
    for s in reg.ring.iter().filter(|s| s.end_us >= cutoff) {
        let mut e = Json::obj();
        e.set("name", s.name)
            .set("cat", s.name.split('.').next().unwrap_or("span"))
            .set("ph", "X")
            .set("ts", s.start_us)
            .set("dur", s.end_us.saturating_sub(s.start_us))
            .set("pid", 1u64)
            .set("tid", s.lane);
        let mut args = Json::obj();
        if s.request != 0 {
            args.set("request", s.request);
        }
        if let Some(t) = &s.tenant {
            args.set("tenant", t.as_ref());
        }
        for (k, v) in &s.attrs {
            args.set(k, attr_json(v));
        }
        e.set("args", args);
        events.push(e);
    }
    let mut root = Json::obj();
    root.set("traceEvents", events).set("displayTimeUnit", "ms");
    root
}

/// Render a [`request_tree`] JSON document as an indented text tree
/// (the `loadgen --trace-slowest` output).
pub fn render_tree(tree: &Json) -> String {
    let mut out = String::new();
    render_node(tree, 0, &mut out);
    out
}

fn render_node(node: &Json, depth: usize, out: &mut String) {
    use std::fmt::Write as _;
    let name = node.get("name").and_then(Json::as_str).unwrap_or("?");
    let start_ms = node.get("start_us").and_then(Json::as_f64).unwrap_or(0.0) / 1e3;
    let dur_ms = node.get("dur_us").and_then(Json::as_f64).unwrap_or(0.0) / 1e3;
    let _ = write!(out, "{:indent$}{name} @{start_ms:.2}ms +{dur_ms:.2}ms", "", indent = depth * 2);
    if let Some(tenant) = node.get("tenant").and_then(Json::as_str) {
        let _ = write!(out, " tenant={tenant}");
    }
    if let Some(attrs) = node.get("attrs").and_then(Json::as_object) {
        for (k, v) in attrs {
            let _ = write!(out, " {k}={}", v.to_string());
        }
    }
    out.push('\n');
    if let Some(kids) = node.get("children").and_then(Json::as_array) {
        for kid in kids {
            render_node(kid, depth + 1, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // trace state is process-global; these tests serialize against each
    // other (other modules' tests record spans but never assert on them)
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> MutexGuard<'static, ()> {
        match TEST_LOCK.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    #[test]
    fn request_tree_assembles_with_nesting() {
        let _g = locked();
        set_enabled(true);
        configure(DEFAULT_RING_SPANS);
        let rid = 0xDEAD_0001u64;
        let t0 = Instant::now();
        begin_request(rid, "trace-tt", 4, 8, t0);
        span_between("queue.wait", rid, t0, Instant::now());
        {
            let mut exec = span_for("sched.exec", rid);
            exec.attr_u64("iter", 1);
            let mut chunk = span_for("prefill.chunk", rid);
            chunk.attr_u64("n_tokens", 4);
            drop(chunk);
        }
        {
            // tenant-scoped span on an unrelated stack: joins via tenant
            let mut group = span("decode.group");
            group.set_tenant("trace-tt");
            group.attr_str("requests", &rid.to_string());
            group.attr_u64("lanes", 1);
        }
        end_request(rid, None);

        let tree = request_tree(rid).expect("tree recorded");
        assert_eq!(tree.get("name").unwrap().as_str().unwrap(), "request");
        assert_eq!(tree.get("tenant").unwrap().as_str().unwrap(), "trace-tt");
        assert!(tree.get("open").is_none(), "closed root");
        let kids = tree.get("children").unwrap().as_array().unwrap();
        let names: Vec<&str> =
            kids.iter().map(|k| k.get("name").unwrap().as_str().unwrap()).collect();
        assert!(names.contains(&"queue.wait"), "{names:?}");
        assert!(names.contains(&"sched.exec"), "{names:?}");
        assert!(names.contains(&"decode.group"), "{names:?}");
        let exec = kids
            .iter()
            .find(|k| k.get("name").unwrap().as_str() == Some("sched.exec"))
            .unwrap();
        let exec_kids = exec.get("children").unwrap().as_array().unwrap();
        assert_eq!(exec_kids.len(), 1, "prefill chunk nests under its exec span");
        assert_eq!(exec_kids[0].get("name").unwrap().as_str().unwrap(), "prefill.chunk");
    }

    #[test]
    fn recent_requests_indexes_closed_and_open_roots() {
        let _g = locked();
        set_enabled(true);
        configure(DEFAULT_RING_SPANS);
        let closed = 0xFEED_0001u64;
        let inflight = 0xFEED_0002u64;
        begin_request(closed, "idx-tt", 2, 4, Instant::now());
        end_request(closed, Some("boom"));
        begin_request(inflight, "idx-tt", 2, 4, Instant::now());

        let idx = recent_requests(64);
        let reqs = idx.get("requests").unwrap().as_array().unwrap();
        let find = |id: u64| {
            reqs.iter().find(|r| r.get("request").and_then(Json::as_u64) == Some(id))
        };
        let open = find(inflight).expect("in-flight root indexed");
        assert_eq!(open.get("open").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(open.get("tenant").and_then(Json::as_str), Some("idx-tt"));
        let done = find(closed).expect("closed root indexed");
        assert!(done.get("open").is_none());
        assert_eq!(done.get("error").and_then(Json::as_str), Some("boom"));
        // open roots list before closed ones, newest first
        let open_pos = reqs.iter().position(|r| {
            r.get("request").and_then(Json::as_u64) == Some(inflight)
        });
        let closed_pos = reqs.iter().position(|r| {
            r.get("request").and_then(Json::as_u64) == Some(closed)
        });
        assert!(open_pos < closed_pos, "{open_pos:?} vs {closed_pos:?}");
        // a limit of 1 returns exactly the newest entry
        let one = recent_requests(1);
        assert_eq!(one.get("requests").unwrap().as_array().unwrap().len(), 1);
        end_request(inflight, None);
    }

    #[test]
    fn tenant_join_excludes_other_requests_groups() {
        let _g = locked();
        set_enabled(true);
        configure(DEFAULT_RING_SPANS);
        let rid = 0xDEAD_0002u64;
        let t0 = Instant::now();
        begin_request(rid, "trace-join", 1, 1, t0);
        {
            let mut ours = span("decode.group");
            ours.set_tenant("trace-join");
            ours.attr_str("requests", &format!("{rid},42"));
        }
        {
            let mut theirs = span("decode.group");
            theirs.set_tenant("trace-join");
            theirs.attr_str("requests", "42,43");
        }
        end_request(rid, None);
        let tree = request_tree(rid).unwrap();
        let kids = tree.get("children").unwrap().as_array().unwrap();
        let groups =
            kids.iter().filter(|k| k.get("name").unwrap().as_str() == Some("decode.group"));
        assert_eq!(groups.count(), 1, "member list filters foreign groups");
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = locked();
        set_enabled(false);
        let rid = 0xDEAD_0003u64;
        begin_request(rid, "trace-off", 1, 1, Instant::now());
        {
            let _s = span_for("prefill.chunk", rid);
        }
        end_request(rid, None);
        set_enabled(true);
        assert!(request_tree(rid).is_none());
    }

    #[test]
    fn ring_is_bounded() {
        let _g = locked();
        set_enabled(true);
        configure(8);
        for i in 0..64u64 {
            let mut s = span("bounded.probe");
            s.attr_u64("i", i);
        }
        flush_thread();
        assert!(ring_len() <= 8, "ring exceeded its capacity: {}", ring_len());
        configure(DEFAULT_RING_SPANS);
    }

    #[test]
    fn flight_dump_is_chrome_trace_format() {
        let _g = locked();
        set_enabled(true);
        configure(DEFAULT_RING_SPANS);
        {
            let mut s = span("flight.probe");
            s.attr_u64("k", 1);
        }
        flush_thread();
        let flight = flight_json(None);
        let events = flight.get("traceEvents").unwrap().as_array().unwrap();
        assert!(
            events.iter().any(|e| e.get("name").and_then(Json::as_str) == Some("flight.probe")),
            "probe span missing from the flight dump"
        );
        for e in events {
            for key in ["name", "ph", "pid", "tid"] {
                assert!(e.get(key).is_some(), "event missing {key}: {e:?}");
            }
            let ph = e.get("ph").unwrap().as_str().unwrap();
            assert!(ph == "X" || ph == "M", "unexpected phase {ph}");
            if ph == "X" {
                assert!(e.get("ts").is_some() && e.get("dur").is_some(), "{e:?}");
            }
        }
        // round-trips through the parser (valid JSON)
        let text = flight.to_string();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn render_tree_is_indented_text() {
        let _g = locked();
        set_enabled(true);
        let rid = 0xDEAD_0004u64;
        begin_request(rid, "trace-render", 2, 2, Instant::now());
        {
            let mut s = span_for("prefill.chunk", rid);
            s.attr_u64("n_tokens", 2);
        }
        end_request(rid, None);
        let text = render_tree(&request_tree(rid).unwrap());
        assert!(text.starts_with("request "), "{text}");
        assert!(text.contains("\n  prefill.chunk "), "{text}");
        assert!(text.contains("n_tokens=2"), "{text}");
    }
}
