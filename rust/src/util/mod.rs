//! Shared utilities: small linear algebra, JSON emission, table
//! rendering, and timing — all in-tree because the container vendors
//! only the `xla` dependency tree (see Cargo.toml).

pub mod bench;
pub mod json;
pub mod linalg;
pub mod table;
pub mod timer;
