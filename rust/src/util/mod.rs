//! Shared utilities: small linear algebra, JSON emission/parsing,
//! CRC-32, the log-bucketed latency histogram, table rendering,
//! timing, fault injection, and the request-tracing flight recorder
//! ([`trace`]) — all in-tree because the
//! crate's only default dependency is `anyhow` (see Cargo.toml; the
//! `xla` stub rides behind the optional `pjrt` feature).

pub mod bench;
pub mod crc32;
pub mod failpoint;
pub mod hist;
pub mod json;
pub mod linalg;
pub mod table;
pub mod timer;
pub mod trace;
pub mod zipf;
