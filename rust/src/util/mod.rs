//! Shared utilities: small linear algebra, JSON emission, table
//! rendering, and timing — all in-tree because the crate's only default
//! dependency is `anyhow` (see Cargo.toml; the `xla` stub rides behind
//! the optional `pjrt` feature).

pub mod bench;
pub mod json;
pub mod linalg;
pub mod table;
pub mod timer;
