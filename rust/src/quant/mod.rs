//! Quantization substrate (S3): per-tensor uniform quantization
//! (paper Eq. 6–8), Separate Quantization decomposition (Eq. 9–12),
//! and the group-wise quantizer used by the DELTAZIP baseline.

pub mod groupwise;
pub mod separate;
pub mod uniform;

pub use groupwise::{group_fake_quantize, group_fake_quantize_sparse, GroupQuantized};
pub use separate::{DecomposedDelta, QuantPart};
pub use uniform::{fake_quantize, QuantParams};
