//! Per-tensor asymmetric uniform quantization (paper Eq. 6–8).
//!
//! ```text
//!   Q = clip(⌊ΔŴ / s⌉ + z, 0, 2^k − 1)
//!   s = (max(ΔŴ) − min(ΔŴ)) / (2^k − 1)
//!   z = ⌊−min(ΔŴ) / s⌉
//! ```

use crate::tensor::Matrix;

/// Quantization parameters: scale `s`, zero point `z`, bit width `k`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Scale `s`.
    pub scale: f32,
    /// Zero point `z`.
    pub zero_point: i32,
    /// Bit width `k`.
    pub bits: u32,
}

impl QuantParams {
    /// Fit per-tensor min/max parameters over the given values
    /// (non-zero entries of the sparse delta).
    pub fn fit(values: &[f32], bits: u32) -> QuantParams {
        assert!((1..=16).contains(&bits), "bits {bits}");
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in values {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if values.is_empty() || !lo.is_finite() {
            return QuantParams { scale: 1.0, zero_point: 0, bits };
        }
        // Degenerate constant tensors: any positive scale quantizes
        // everything to the zero point exactly.
        let levels = ((1u32 << bits) - 1) as f32;
        let range = hi - lo;
        // Degenerate constant tensor: pick scale = |v| so the single value
        // maps exactly onto one level (code 0 with z = 1 for v < 0 etc.).
        let scale = if range > 0.0 {
            range / levels
        } else if lo != 0.0 {
            lo.abs()
        } else {
            1.0
        };
        let zero_point = (-lo / scale).round() as i32;
        QuantParams { scale, zero_point, bits }
    }

    /// Number of representable levels `2^k`.
    #[inline]
    pub fn levels(&self) -> u32 {
        1u32 << self.bits
    }

    /// Quantize one value to its code.
    #[inline]
    pub fn quantize(&self, v: f32) -> u32 {
        let q = (v / self.scale).round() as i64 + self.zero_point as i64;
        q.clamp(0, (self.levels() - 1) as i64) as u32
    }

    /// Dequantize one code (Eq. 12 with offset 0).
    #[inline]
    pub fn dequantize(&self, code: u32) -> f32 {
        self.scale * (code as i64 - self.zero_point as i64) as f32
    }
}

/// Quantize a slice of values; returns codes.
pub fn quantize_values(values: &[f32], params: &QuantParams) -> Vec<u32> {
    values.iter().map(|&v| params.quantize(v)).collect()
}

/// Dequantize codes back to values.
pub fn dequantize_values(codes: &[u32], params: &QuantParams) -> Vec<f32> {
    codes.iter().map(|&c| params.dequantize(c)).collect()
}

/// Quantize-dequantize a full dense matrix (analysis / fake-quant path —
/// figure 6 uses this to show the delta distribution after quantization).
pub fn fake_quantize(m: &Matrix, bits: u32) -> (Matrix, QuantParams) {
    let params = QuantParams::fit(m.data(), bits);
    let data = m.data().iter().map(|&v| params.dequantize(params.quantize(v))).collect();
    (Matrix::from_vec(m.rows(), m.cols(), data), params)
}

/// Worst-case round-trip error bound for a fitted quantizer: half a step.
pub fn max_roundtrip_error(params: &QuantParams) -> f32 {
    0.5 * params.scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn fit_covers_min_max() {
        let vals = [-0.3f32, 0.1, 0.7];
        let p = QuantParams::fit(&vals, 8);
        // endpoints must be representable (codes 0 and 255)
        assert_eq!(p.quantize(-0.3), 0);
        assert_eq!(p.quantize(0.7), 255);
        assert!((p.dequantize(0) - -0.3).abs() < p.scale);
        assert!((p.dequantize(255) - 0.7).abs() < p.scale);
    }

    #[test]
    fn roundtrip_error_within_half_step() {
        let mut rng = Pcg64::seeded(1);
        for bits in [2u32, 4, 8] {
            let vals: Vec<f32> = (0..1000).map(|_| rng.normal() * 0.01).collect();
            let p = QuantParams::fit(&vals, bits);
            let bound = max_roundtrip_error(&p) * 1.0001;
            for &v in &vals {
                let rt = p.dequantize(p.quantize(v));
                assert!((rt - v).abs() <= bound, "bits={bits} v={v} rt={rt}");
            }
        }
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Pcg64::seeded(2);
        let vals: Vec<f32> = (0..500).map(|_| rng.normal()).collect();
        for bits in 1..=8u32 {
            let p = QuantParams::fit(&vals, bits);
            for &v in &vals {
                assert!(p.quantize(v) < p.levels());
            }
        }
    }

    #[test]
    fn constant_tensor_is_exact() {
        let vals = vec![0.42f32; 64];
        let p = QuantParams::fit(&vals, 4);
        for &v in &vals {
            let rt = p.dequantize(p.quantize(v));
            assert!((rt - v).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_values_are_safe() {
        let p = QuantParams::fit(&[], 8);
        assert_eq!(p.quantize(0.0), 0);
    }

    #[test]
    fn all_zero_values() {
        let p = QuantParams::fit(&[0.0, 0.0], 8);
        let rt = p.dequantize(p.quantize(0.0));
        assert_eq!(rt, 0.0);
    }

    #[test]
    fn one_bit_keeps_extremes() {
        let vals = [-1.0f32, 1.0];
        let p = QuantParams::fit(&vals, 1);
        assert_eq!(p.quantize(-1.0), 0);
        assert_eq!(p.quantize(1.0), 1);
    }

    #[test]
    fn fake_quantize_shrinks_with_more_bits() {
        let mut rng = Pcg64::seeded(3);
        let m = Matrix::randn(16, 16, 0.02, &mut rng);
        let (q2, _) = fake_quantize(&m, 2);
        let (q8, _) = fake_quantize(&m, 8);
        let e2 = m.sq_distance(&q2);
        let e8 = m.sq_distance(&q8);
        assert!(e8 < e2 * 0.01, "e2={e2} e8={e8}");
    }

    #[test]
    fn slice_helpers_roundtrip() {
        let vals = [0.1f32, -0.2, 0.3, 0.0];
        let p = QuantParams::fit(&vals, 8);
        let codes = quantize_values(&vals, &p);
        let back = dequantize_values(&codes, &p);
        for (v, b) in vals.iter().zip(&back) {
            assert!((v - b).abs() <= max_roundtrip_error(&p) * 1.0001);
        }
    }
}
