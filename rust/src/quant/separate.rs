//! Separate Quantization (paper §3.4, Eq. 6–12).
//!
//! After Group-wise Dropout the sparse delta is quantized to `k` bits with
//! the per-tensor uniform quantizer, then **decomposed by value** into `m`
//! parts: part `j ∈ {1..m}` keeps the non-zeros whose code lies in
//! `[2^k/m·(j−1), 2^k/m·j − 1]`, shifted by the offset coefficient
//! `o_j = −2^k/m·(j−1)` so each part's codes fit in `k − log₂ m` bits.
//!
//! With CSR storage the decomposition is nearly free: column indices and
//! code payload are *partitioned* (total size unchanged) and only the
//! row-offset array is replicated `m` times. In the extreme `m = 2^k`
//! every part's codes are identical (`0` bits/code) — only the part id,
//! the shared quant params, and the CSR structure remain.

use anyhow::{ensure, Result};

use crate::quant::uniform::QuantParams;
use crate::sparse::bitpack::PackedCodes;
use crate::sparse::csr::CsrMatrix;
use crate::tensor::Matrix;

/// One of the `m` decomposed quantized weights `Q_{i,j}`.
#[derive(Debug, Clone)]
pub struct QuantPart {
    /// Row offsets of this part's CSR structure (len = rows + 1).
    pub row_offsets: Vec<u32>,
    /// Column indices of this part's entries.
    pub col_indices: Vec<u32>,
    /// Shifted codes at `k − log₂ m` bits; `None` when the width is 0
    /// (the `m = 2^k` extreme — every code in the part is identical).
    pub codes: Option<PackedCodes>,
    /// Part index j (0-based); the paper's offset is `o_j = −step·j`.
    pub part_index: u32,
}

impl QuantPart {
    /// Number of entries stored in this part.
    pub fn nnz(&self) -> usize {
        self.col_indices.len()
    }
}

/// The full decomposed, quantized delta weight for one layer tensor.
#[derive(Debug, Clone)]
pub struct DecomposedDelta {
    rows: usize,
    cols: usize,
    /// Shared quantizer (scale `s`, zero `z`, original width `k`).
    pub params: QuantParams,
    /// Number of parts `m` (power of two, `m ≤ 2^k`).
    pub m: u32,
    /// Per-part storage.
    pub parts: Vec<QuantPart>,
}

impl DecomposedDelta {
    /// Quantize a sparse delta to `k` bits and decompose into `m` parts.
    ///
    /// `m` must be a power of two with `m ≤ 2^k`; `m = 1` is plain
    /// quantization without decomposition.
    pub fn compress(delta: &CsrMatrix, k: u32, m: u32) -> DecomposedDelta {
        assert!(m.is_power_of_two(), "m={m} must be a power of two");
        assert!((1..=16).contains(&k), "k={k}");
        assert!(m <= (1u32 << k), "m={m} exceeds 2^k={}", 1u32 << k);
        let params = QuantParams::fit(delta.values(), k);
        let step = (1u32 << k) / m; // 2^k / m codes per part
        let part_bits = k - m.ilog2(); // k − log₂ m
        let rows = delta.rows();

        // Partition nnz by part, preserving row order within each part.
        let mut part_cols: Vec<Vec<u32>> = vec![Vec::new(); m as usize];
        let mut part_codes: Vec<Vec<u32>> = vec![Vec::new(); m as usize];
        let mut part_offsets: Vec<Vec<u32>> = vec![vec![0u32]; m as usize];
        for r in 0..rows {
            let (cols, vals) = delta.row_entries(r);
            for (&c, &v) in cols.iter().zip(vals) {
                let code = params.quantize(v);
                let j = (code / step).min(m - 1) as usize;
                part_cols[j].push(c);
                // shifted code: Q + o_j  with  o_j = −step·j
                part_codes[j].push(code - step * j as u32);
            }
            for j in 0..m as usize {
                part_offsets[j].push(part_cols[j].len() as u32);
            }
        }

        let parts = (0..m as usize)
            .map(|j| QuantPart {
                row_offsets: std::mem::take(&mut part_offsets[j]),
                col_indices: std::mem::take(&mut part_cols[j]),
                codes: if part_bits == 0 {
                    None
                } else {
                    Some(PackedCodes::pack(&part_codes[j], part_bits))
                },
                part_index: j as u32,
            })
            .collect();

        DecomposedDelta { rows: delta.rows(), cols: delta.cols(), params, m, parts }
    }

    /// Rebuild from deserialized parts, validating the full structure —
    /// the `.ddq` read path, so corrupt files fail loudly (with an
    /// error, not a panic or silent mis-read) in release builds.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        params: QuantParams,
        m: u32,
        parts: Vec<QuantPart>,
    ) -> Result<DecomposedDelta> {
        ensure!((1..=16).contains(&params.bits), "bit width k={} out of range", params.bits);
        ensure!(
            m >= 1 && m.is_power_of_two() && m <= (1u32 << params.bits),
            "m={m} must be a power of two ≤ 2^k (k={})",
            params.bits
        );
        ensure!(parts.len() == m as usize, "have {} parts, expected m={m}", parts.len());
        let part_bits = params.bits - m.ilog2();
        for (j, p) in parts.iter().enumerate() {
            ensure!(p.part_index as usize == j, "part {j} carries index {}", p.part_index);
            ensure!(
                p.row_offsets.len() == rows + 1,
                "part {j}: {} row offsets, expected rows + 1 = {}",
                p.row_offsets.len(),
                rows + 1
            );
            ensure!(p.row_offsets[0] == 0, "part {j}: first row offset must be 0");
            ensure!(
                p.row_offsets.windows(2).all(|w| w[0] <= w[1]),
                "part {j}: row offsets are not monotone non-decreasing"
            );
            ensure!(
                *p.row_offsets.last().unwrap() as usize == p.nnz(),
                "part {j}: final offset {} != nnz {}",
                p.row_offsets.last().unwrap(),
                p.nnz()
            );
            ensure!(
                p.col_indices.iter().all(|&c| (c as usize) < cols),
                "part {j}: column index out of bounds (cols = {cols})"
            );
            match &p.codes {
                Some(codes) => {
                    ensure!(part_bits > 0, "part {j}: zero-width part stores code words");
                    ensure!(
                        codes.len() == p.nnz(),
                        "part {j}: {} codes for {} entries",
                        codes.len(),
                        p.nnz()
                    );
                }
                None => ensure!(
                    part_bits == 0 || p.nnz() == 0,
                    "part {j}: missing codes at width {part_bits}"
                ),
            }
        }
        Ok(DecomposedDelta { rows, cols, params, m, parts })
    }

    /// Logical (dense) row count of the delta tensor.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical (dense) column count of the delta tensor.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Logical (rows, cols) shape.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Total non-zeros across parts.
    pub fn nnz(&self) -> usize {
        self.parts.iter().map(|p| p.nnz()).sum()
    }

    /// Codes-per-part width `k − log₂ m`.
    pub fn part_bits(&self) -> u32 {
        self.params.bits - self.m.ilog2()
    }

    /// Dequantize one part's entry (Eq. 12):
    /// `DQ = s · (Q_j − z − o_j) = s · (stored + step·j − z)`.
    ///
    /// `pub(crate)` so the fused serving kernel
    /// ([`crate::runtime::fused`]) shares this exact formula — any
    /// change to quant semantics lands in one place.
    #[inline]
    pub(crate) fn dequant_entry(&self, part: &QuantPart, idx: usize) -> f32 {
        let step = (1u32 << self.params.bits) / self.m;
        let stored = match &part.codes {
            Some(c) => c.get(idx),
            None => 0,
        };
        let code = stored + step * part.part_index;
        self.params.dequantize(code)
    }

    /// Reconstruct the dequantized sparse delta as CSR (merging parts;
    /// columns within a row are re-sorted to CSR order).
    pub fn to_csr(&self) -> CsrMatrix {
        let dense = self.to_dense();
        CsrMatrix::from_dense(&dense)
    }

    /// Reconstruct the dequantized delta densely.
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.add_to_dense(&mut out, 1.0);
        out
    }

    /// Accumulate `scale · dequant(delta)` into a dense buffer — the
    /// serving-path reconstruction `W = W_b + ΔŴ` (no intermediate alloc).
    pub fn add_to_dense(&self, out: &mut Matrix, scale: f32) {
        assert_eq!(out.shape(), self.shape());
        let step = (1u32 << self.params.bits) / self.m;
        for part in &self.parts {
            let base_code = step * part.part_index;
            let mut idx = 0usize;
            for r in 0..self.rows {
                let lo = part.row_offsets[r] as usize;
                let hi = part.row_offsets[r + 1] as usize;
                let orow = out.row_mut(r);
                for e in lo..hi {
                    let c = part.col_indices[e] as usize;
                    let stored = match &part.codes {
                        Some(codes) => codes.get(e),
                        None => 0,
                    };
                    let v = self.params.dequantize(stored + base_code);
                    orow[c] += scale * v;
                    idx += 1;
                }
            }
            debug_assert_eq!(idx, part.nnz());
        }
    }

    /// Sparse-dense product `X · dequant(Δ)ᵀ` computed part-by-part —
    /// the separate-computation delta path without densifying the delta.
    ///
    /// Perf (EXPERIMENTS.md §Perf, L3 iter 1): dequantization is hoisted
    /// out of the activation-row loop — each stored entry is decoded
    /// once per matmul instead of once per row of `X` (a ~2× win at
    /// t=32 over the naive nesting).
    pub fn matmul_nt_from_dense(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.cols, "inner dims");
        let t = x.rows();
        let mut out = Matrix::zeros(t, self.rows);
        let mut vals: Vec<f32> = Vec::new();
        for part in &self.parts {
            for q in 0..self.rows {
                let lo = part.row_offsets[q] as usize;
                let hi = part.row_offsets[q + 1] as usize;
                if lo == hi {
                    continue;
                }
                // decode this delta row once
                vals.clear();
                vals.extend((lo..hi).map(|e| self.dequant_entry(part, e)));
                let cols = &part.col_indices[lo..hi];
                for p in 0..t {
                    let xrow = x.row(p);
                    let mut acc = 0.0f32;
                    for (&c, &v) in cols.iter().zip(&vals) {
                        acc += xrow[c as usize] * v;
                    }
                    out.row_mut(p)[q] += acc;
                }
            }
        }
        out
    }

    /// Storage cost in bits under the paper's accounting (§3.4, Fig. 7):
    /// per nnz: `part_bits` code + 16-bit column index; per part:
    /// `(rows+1)` 32-bit row offsets + 32-bit offset coefficient; plus
    /// shared scale/zero (2 × 32 bits).
    pub fn storage_bits(&self) -> u64 {
        let nnz = self.nnz() as u64;
        let code_bits = nnz * self.part_bits() as u64;
        let index_bits = nnz * 16;
        let offsets = self.m as u64 * (self.rows as u64 + 1) * 32;
        let per_part_params = self.m as u64 * 32;
        code_bits + index_bits + offsets + per_part_params + 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Matrix, Pcg64};

    fn sparse_delta(rows: usize, cols: usize, density: f64, std: f32, seed: u64) -> CsrMatrix {
        let mut rng = Pcg64::seeded(seed);
        let m = Matrix::from_fn(rows, cols, |_, _| {
            if rng.bernoulli(density) {
                rng.normal() * std
            } else {
                0.0
            }
        });
        CsrMatrix::from_dense(&m)
    }

    #[test]
    fn m1_matches_plain_quantization() {
        let delta = sparse_delta(8, 16, 0.3, 0.01, 1);
        let d = DecomposedDelta::compress(&delta, 8, 1);
        let dense = d.to_dense();
        // every nnz within half a quant step of the original
        let params = QuantParams::fit(delta.values(), 8);
        let orig = delta.to_dense();
        for (a, b) in orig.data().iter().zip(dense.data()) {
            if *a != 0.0 {
                assert!((a - b).abs() <= 0.5 * params.scale * 1.001, "{a} vs {b}");
            }
        }
    }

    /// DESIGN.md §7 invariant: decomposition is *exact* — reassembling the
    /// m parts reproduces the m=1 dequantized tensor bit-for-bit.
    #[test]
    fn decomposition_is_lossless_vs_m1() {
        let delta = sparse_delta(16, 32, 0.25, 0.02, 2);
        for k in [8u32, 4, 2] {
            let base = DecomposedDelta::compress(&delta, k, 1).to_dense();
            let mut m = 2;
            while m <= (1 << k).min(16) {
                let dec = DecomposedDelta::compress(&delta, k, m).to_dense();
                assert_eq!(base, dec, "k={k} m={m}");
                m *= 2;
            }
        }
    }

    #[test]
    fn nnz_is_partitioned_not_duplicated() {
        let delta = sparse_delta(12, 24, 0.4, 0.01, 3);
        for m in [1u32, 2, 4, 8] {
            let d = DecomposedDelta::compress(&delta, 8, m);
            assert_eq!(d.nnz(), delta.nnz(), "m={m}");
        }
    }

    #[test]
    fn part_bits_follow_formula() {
        let delta = sparse_delta(4, 8, 0.5, 0.01, 4);
        assert_eq!(DecomposedDelta::compress(&delta, 8, 1).part_bits(), 8);
        assert_eq!(DecomposedDelta::compress(&delta, 8, 4).part_bits(), 6);
        assert_eq!(DecomposedDelta::compress(&delta, 4, 4).part_bits(), 2);
        assert_eq!(DecomposedDelta::compress(&delta, 4, 8).part_bits(), 1);
        assert_eq!(DecomposedDelta::compress(&delta, 2, 4).part_bits(), 0);
    }

    #[test]
    fn extreme_m_equals_2k_stores_no_codes() {
        let delta = sparse_delta(6, 12, 0.5, 0.01, 5);
        let d = DecomposedDelta::compress(&delta, 2, 4);
        for p in &d.parts {
            assert!(p.codes.is_none());
        }
        // still reconstructs the same as m=1 at k=2
        let m1 = DecomposedDelta::compress(&delta, 2, 1).to_dense();
        assert_eq!(d.to_dense(), m1);
    }

    #[test]
    fn codes_fit_in_part_bits() {
        let delta = sparse_delta(10, 20, 0.3, 0.05, 6);
        let d = DecomposedDelta::compress(&delta, 8, 4);
        for p in &d.parts {
            let codes = p.codes.as_ref().unwrap();
            let max = (1u32 << d.part_bits()) - 1;
            for i in 0..codes.len() {
                assert!(codes.get(i) <= max);
            }
        }
    }

    #[test]
    fn matmul_matches_dense_reconstruction() {
        let delta = sparse_delta(9, 15, 0.3, 0.02, 7);
        let mut rng = Pcg64::seeded(8);
        let x = Matrix::randn(5, 15, 1.0, &mut rng);
        for m in [1u32, 2, 8] {
            let d = DecomposedDelta::compress(&delta, 8, m);
            let via_parts = d.matmul_nt_from_dense(&x);
            let via_dense = x.matmul_nt(&d.to_dense());
            assert!(via_parts.allclose(&via_dense, 1e-4, 1e-4), "m={m}");
        }
    }

    #[test]
    fn storage_shrinks_with_m_at_fixed_k() {
        // Fig. 7 accounting: k fixed at 8, growing m shrinks code bits per
        // nnz (k − log₂ m) while adding only row offsets.
        let delta = sparse_delta(32, 256, 0.1, 0.02, 9);
        let bits_m1 = DecomposedDelta::compress(&delta, 8, 1).storage_bits();
        let bits_m8 = DecomposedDelta::compress(&delta, 8, 8).storage_bits();
        // nnz ≈ 819; code saving ≈ 819*3 ≈ 2458 bits; offset cost ≈ 7*33*32
        // The paper's point is about *final bit width*: compare at the
        // same final bits instead — m=8@k=8 stores 5-bit codes.
        assert_eq!(DecomposedDelta::compress(&delta, 8, 8).part_bits(), 5);
        assert!(bits_m8 < bits_m1 + 8 * 33 * 32);
    }

    #[test]
    fn add_to_dense_accumulates_with_scale() {
        let delta = sparse_delta(4, 6, 0.5, 0.01, 10);
        let d = DecomposedDelta::compress(&delta, 8, 2);
        let recon = d.to_dense();
        let mut buf = Matrix::full(4, 6, 1.0);
        d.add_to_dense(&mut buf, 2.0);
        let want = Matrix::full(4, 6, 1.0).add(&recon.scaled(2.0));
        assert!(buf.allclose(&want, 1e-5, 1e-5));
    }

    #[test]
    fn empty_delta() {
        let delta = CsrMatrix::empty(3, 5);
        let d = DecomposedDelta::compress(&delta, 8, 4);
        assert_eq!(d.nnz(), 0);
        assert_eq!(d.to_dense(), Matrix::zeros(3, 5));
    }

    #[test]
    fn from_parts_roundtrips_and_rejects_corruption() {
        let delta = sparse_delta(6, 10, 0.5, 0.02, 20);
        let d = DecomposedDelta::compress(&delta, 4, 4);
        let rebuilt =
            DecomposedDelta::from_parts(6, 10, d.params, d.m, d.parts.clone()).unwrap();
        assert_eq!(rebuilt.to_dense(), d.to_dense());

        // shuffled part order
        let mut parts = d.parts.clone();
        parts.swap(0, 1);
        assert!(DecomposedDelta::from_parts(6, 10, d.params, d.m, parts).is_err());

        // column index out of bounds
        let mut parts = d.parts.clone();
        let victim = parts.iter_mut().find(|p| p.nnz() > 0).unwrap();
        victim.col_indices[0] = 10;
        assert!(DecomposedDelta::from_parts(6, 10, d.params, d.m, parts).is_err());

        // non-monotone row offsets
        let mut parts = d.parts.clone();
        let victim = parts.iter_mut().find(|p| p.nnz() > 0).unwrap();
        let last = *victim.row_offsets.last().unwrap();
        victim.row_offsets[1] = last + 1;
        assert!(DecomposedDelta::from_parts(6, 10, d.params, d.m, parts).is_err());

        // m not a power of two / part count mismatch
        assert!(DecomposedDelta::from_parts(6, 10, d.params, 3, d.parts.clone()).is_err());
    }

    #[test]
    #[should_panic]
    fn m_not_power_of_two_panics() {
        let delta = CsrMatrix::empty(2, 2);
        let _ = DecomposedDelta::compress(&delta, 8, 3);
    }

    #[test]
    #[should_panic]
    fn m_exceeding_levels_panics() {
        let delta = CsrMatrix::empty(2, 2);
        let _ = DecomposedDelta::compress(&delta, 2, 8);
    }
}
