//! Group-wise quantization (the DELTAZIP baseline's quantizer).
//!
//! DELTAZIP (Yao & Klimovic 2023) follows GPTQ-style practice: weights
//! are quantized in groups of `group_size` consecutive elements along the
//! input dimension, each group with its own scale/zero. This is *not*
//! part of DeltaDQ itself (which is deliberately per-tensor, §3.4) but is
//! required to reproduce the DELTAZIP rows of Tables 1–3.

use crate::quant::uniform::QuantParams;
use crate::tensor::Matrix;

/// Group-wise fake-quantized matrix plus its parameter table.
#[derive(Debug, Clone)]
pub struct GroupQuantized {
    /// The fake-quantized (quantize→dequantize) values.
    pub matrix: Matrix,
    /// One `QuantParams` per (row, group).
    pub params: Vec<QuantParams>,
    /// Elements per group along the input dimension.
    pub group_size: usize,
    /// Quantization bit width.
    pub bits: u32,
}

/// Quantize-dequantize `m` with per-(row,group) parameters.
pub fn group_fake_quantize(m: &Matrix, bits: u32, group_size: usize) -> GroupQuantized {
    assert!(group_size > 0);
    let (rows, cols) = m.shape();
    let gs = group_size.min(cols);
    let mut out = m.clone();
    let mut params = Vec::with_capacity(rows * cols.div_ceil(gs));
    for r in 0..rows {
        let row = out.row_mut(r);
        for group in row.chunks_mut(gs) {
            let p = QuantParams::fit(group, bits);
            for v in group.iter_mut() {
                *v = p.dequantize(p.quantize(*v));
            }
            params.push(p);
        }
    }
    GroupQuantized { matrix: out, params, group_size: gs, bits }
}

/// Like [`group_fake_quantize`] but only quantizes non-zero entries,
/// preserving sparsity (zeros stay exactly zero) — the post-sparsify
/// quantization step of the DELTAZIP pipeline.
pub fn group_fake_quantize_sparse(m: &Matrix, bits: u32, group_size: usize) -> GroupQuantized {
    assert!(group_size > 0);
    let (rows, cols) = m.shape();
    let gs = group_size.min(cols);
    let mut out = m.clone();
    let mut params = Vec::with_capacity(rows * cols.div_ceil(gs));
    let mut nz = Vec::with_capacity(gs);
    for r in 0..rows {
        let row = out.row_mut(r);
        for group in row.chunks_mut(gs) {
            nz.clear();
            nz.extend(group.iter().copied().filter(|v| *v != 0.0));
            let p = QuantParams::fit(&nz, bits);
            for v in group.iter_mut() {
                if *v != 0.0 {
                    *v = p.dequantize(p.quantize(*v));
                }
            }
            params.push(p);
        }
    }
    GroupQuantized { matrix: out, params, group_size: gs, bits }
}

/// Storage accounting for group-wise quantization: codes + per-group
/// scale/zero (fp16 scale + int zero at `bits`≈negligible → counted as
/// 32 bits per group, the common convention).
pub fn group_quant_storage_bits(nnz: u64, rows: u64, cols: u64, bits: u32, group_size: u64) -> u64 {
    let groups = rows * cols.div_ceil(group_size);
    nnz * bits as u64 + groups * 32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn group_quant_beats_per_tensor_on_heterogeneous_rows() {
        // Rows with very different magnitudes: per-tensor scale wastes
        // levels, per-group adapts.
        let mut rng = Pcg64::seeded(1);
        let m = Matrix::from_fn(8, 64, |r, _| rng.normal() * (10.0f32).powi(r as i32 % 3 - 1));
        let per_tensor = crate::quant::uniform::fake_quantize(&m, 4).0;
        let grouped = group_fake_quantize(&m, 4, 64).matrix;
        assert!(m.sq_distance(&grouped) < m.sq_distance(&per_tensor));
    }

    #[test]
    fn group_size_larger_than_cols_is_one_group_per_row() {
        let mut rng = Pcg64::seeded(2);
        let m = Matrix::randn(4, 16, 1.0, &mut rng);
        let g = group_fake_quantize(&m, 8, 1024);
        assert_eq!(g.group_size, 16);
        assert_eq!(g.params.len(), 4);
    }

    #[test]
    fn sparse_variant_preserves_zeros() {
        let mut rng = Pcg64::seeded(3);
        let m = Matrix::from_fn(6, 32, |_, _| {
            if rng.bernoulli(0.3) {
                rng.normal() * 0.01
            } else {
                0.0
            }
        });
        let g = group_fake_quantize_sparse(&m, 4, 8);
        for (orig, quant) in m.data().iter().zip(g.matrix.data()) {
            if *orig == 0.0 {
                assert_eq!(*quant, 0.0);
            }
        }
        // quantization may round small non-zeros *to* zero, but never the
        // other way around
        assert!(g.matrix.count_zeros() >= m.count_zeros());
    }

    #[test]
    fn roundtrip_error_bounded_per_group() {
        let mut rng = Pcg64::seeded(4);
        let m = Matrix::randn(4, 32, 0.02, &mut rng);
        let g = group_fake_quantize(&m, 8, 8);
        for (i, (orig, quant)) in m.data().iter().zip(g.matrix.data()).enumerate() {
            let group_idx = (i / 32) * 4 + (i % 32) / 8;
            let bound = 0.5 * g.params[group_idx].scale * 1.001;
            assert!((orig - quant).abs() <= bound);
        }
    }

    #[test]
    fn storage_accounting() {
        // 4x64, all nnz, 4-bit, group 32: codes 256*4 + 8 groups * 32
        assert_eq!(group_quant_storage_bits(256, 4, 64, 4, 32), 1024 + 256);
    }
}
