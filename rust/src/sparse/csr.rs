//! Compressed Sparse Row storage for delta weights.
//!
//! The paper stores the sparsified delta `ΔŴ` in CSR (§3.4): row offsets,
//! column indices, and non-zero values. Separate Quantization then
//! decomposes the value array into `m` parts — only the row-offset array
//! is replicated, which is the "negligible increase" the paper argues.

use anyhow::{ensure, Result};

use crate::tensor::Matrix;

/// CSR sparse matrix with `f32` values.
///
/// Column indices are stored as `u32` in memory; the *accounted* storage
/// cost (compression-ratio bookkeeping) uses the paper's 16-bit-index
/// convention via [`CsrMatrix::storage_bits`].
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// len = rows + 1; `row_offsets[r]..row_offsets[r+1]` indexes the
    /// nnz of row r within `col_indices` / `values`.
    row_offsets: Vec<u32>,
    col_indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Build from a dense matrix, keeping exact non-zeros.
    pub fn from_dense(m: &Matrix) -> CsrMatrix {
        let (rows, cols) = m.shape();
        let mut row_offsets = Vec::with_capacity(rows + 1);
        let mut col_indices = Vec::new();
        let mut values = Vec::new();
        row_offsets.push(0u32);
        for r in 0..rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    col_indices.push(c as u32);
                    values.push(v);
                }
            }
            row_offsets.push(col_indices.len() as u32);
        }
        CsrMatrix { rows, cols, row_offsets, col_indices, values }
    }

    /// Build from raw parts, validating the full CSR structure — the
    /// deserialization entry point, so corrupt `.ddq` files fail loudly
    /// (with an error, not UB or a silent mis-read) in release builds.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        row_offsets: Vec<u32>,
        col_indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<CsrMatrix> {
        ensure!(
            row_offsets.len() == rows + 1,
            "row_offsets has {} entries, expected rows + 1 = {}",
            row_offsets.len(),
            rows + 1
        );
        ensure!(
            col_indices.len() == values.len(),
            "col_indices ({}) and values ({}) lengths differ",
            col_indices.len(),
            values.len()
        );
        ensure!(row_offsets[0] == 0, "first row offset is {}, expected 0", row_offsets[0]);
        ensure!(
            *row_offsets.last().unwrap() as usize == values.len(),
            "final row offset {} != nnz {}",
            row_offsets.last().unwrap(),
            values.len()
        );
        ensure!(
            row_offsets.windows(2).all(|w| w[0] <= w[1]),
            "row offsets are not monotone non-decreasing"
        );
        ensure!(
            col_indices.iter().all(|&c| (c as usize) < cols),
            "column index out of bounds (cols = {cols})"
        );
        Ok(CsrMatrix { rows, cols, row_offsets, col_indices, values })
    }

    /// Empty matrix with no stored entries.
    pub fn empty(rows: usize, cols: usize) -> CsrMatrix {
        CsrMatrix {
            rows,
            cols,
            row_offsets: vec![0; rows + 1],
            col_indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Logical (dense) row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical (dense) column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Logical (rows, cols) shape.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Stored density = nnz / (rows·cols).
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// CSR row-offset array (`rows + 1` entries; row r spans
    /// `row_offsets[r]..row_offsets[r+1]`).
    #[inline]
    pub fn row_offsets(&self) -> &[u32] {
        &self.row_offsets
    }

    /// Column index of each stored non-zero, in row-major order.
    #[inline]
    pub fn col_indices(&self) -> &[u32] {
        &self.col_indices
    }

    /// Value of each stored non-zero, parallel to [`col_indices`](Self::col_indices).
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Mutable non-zero values (in-place requantization keeps the
    /// sparsity pattern, so indices stay shared).
    #[inline]
    pub fn values_mut(&mut self) -> &mut [f32] {
        &mut self.values
    }

    /// (column indices, values) of row r.
    #[inline]
    pub fn row_entries(&self, r: usize) -> (&[u32], &[f32]) {
        let lo = self.row_offsets[r] as usize;
        let hi = self.row_offsets[r + 1] as usize;
        (&self.col_indices[lo..hi], &self.values[lo..hi])
    }

    /// Densify.
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row_entries(r);
            let orow = out.row_mut(r);
            for (&c, &v) in cols.iter().zip(vals) {
                orow[c as usize] = v;
            }
        }
        out
    }

    /// Densify *into* an existing dense buffer, adding `scale * value`.
    /// This is the serving-path primitive: reconstruct `W_b + ΔŴ` without
    /// allocating (the buffer already holds a copy of the base weight).
    pub fn add_to_dense(&self, out: &mut Matrix, scale: f32) {
        assert_eq!(out.shape(), self.shape());
        for r in 0..self.rows {
            let (cols, vals) = self.row_entries(r);
            let orow = out.row_mut(r);
            for (&c, &v) in cols.iter().zip(vals) {
                orow[c as usize] += scale * v;
            }
        }
    }

    /// Sparse-dense product `A = X · selfᵀ` (`X: t×h_in`, `self: h_out×h_in`
    /// → `t×h_out`). This is the separate-computation delta path
    /// `X·ΔŴᵀ` (paper Fig. 3): each output column q gathers X's columns at
    /// the nnz positions of delta row q.
    pub fn matmul_nt_from_dense(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.cols, "inner dims");
        let t = x.rows();
        let mut out = Matrix::zeros(t, self.rows);
        for q in 0..self.rows {
            let (cols, vals) = self.row_entries(q);
            for p in 0..t {
                let xrow = x.row(p);
                let mut acc = 0.0f32;
                for (&c, &v) in cols.iter().zip(vals) {
                    acc += xrow[c as usize] * v;
                }
                out.set(p, q, acc);
            }
        }
        out
    }

    /// Storage cost in bits under the paper's accounting: each nnz costs
    /// `value_bits + index_bits`, each row costs one `offset_bits` entry
    /// (plus one terminal offset).
    pub fn storage_bits(&self, value_bits: u32, index_bits: u32, offset_bits: u32) -> u64 {
        let nnz = self.nnz() as u64;
        nnz * (value_bits as u64 + index_bits as u64)
            + (self.rows as u64 + 1) * offset_bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Matrix, Pcg64};

    fn sparse_random(rows: usize, cols: usize, density: f64, rng: &mut Pcg64) -> Matrix {
        Matrix::from_fn(rows, cols, |_, _| {
            if rng.bernoulli(density) {
                rng.normal()
            } else {
                0.0
            }
        })
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let m = sparse_random(13, 29, 0.2, &mut rng);
        let csr = CsrMatrix::from_dense(&m);
        assert_eq!(csr.to_dense(), m);
        assert_eq!(csr.nnz(), m.count_nonzeros());
    }

    #[test]
    fn empty_matrix() {
        let csr = CsrMatrix::empty(4, 7);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.to_dense(), Matrix::zeros(4, 7));
        assert_eq!(csr.density(), 0.0);
    }

    #[test]
    fn row_entries_are_ordered() {
        let m = Matrix::from_vec(2, 4, vec![0.0, 1.0, 0.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        let csr = CsrMatrix::from_dense(&m);
        let (c0, v0) = csr.row_entries(0);
        assert_eq!(c0, &[1, 3]);
        assert_eq!(v0, &[1.0, 2.0]);
        let (c1, v1) = csr.row_entries(1);
        assert_eq!(c1, &[0]);
        assert_eq!(v1, &[3.0]);
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Pcg64::seeded(2);
        let dw = sparse_random(9, 17, 0.15, &mut rng);
        let x = Matrix::randn(5, 17, 1.0, &mut rng);
        let csr = CsrMatrix::from_dense(&dw);
        let sparse = csr.matmul_nt_from_dense(&x);
        let dense = x.matmul_nt(&dw);
        assert!(sparse.allclose(&dense, 1e-5, 1e-5));
    }

    #[test]
    fn add_to_dense_reconstructs() {
        let mut rng = Pcg64::seeded(3);
        let base = Matrix::randn(6, 8, 1.0, &mut rng);
        let delta = sparse_random(6, 8, 0.3, &mut rng);
        let csr = CsrMatrix::from_dense(&delta);
        let mut w = base.clone();
        csr.add_to_dense(&mut w, 1.0);
        assert!(w.allclose(&base.add(&delta), 1e-6, 0.0));
        // scale = 2 applies twice the delta
        let mut w2 = base.clone();
        csr.add_to_dense(&mut w2, 2.0);
        assert!(w2.allclose(&base.add(&delta.scaled(2.0)), 1e-6, 0.0));
    }

    #[test]
    fn storage_bits_accounting() {
        // 2x4 matrix with 3 nnz: 3*(16+16) + 3*32 = 96 + 96 = 192 bits
        let m = Matrix::from_vec(2, 4, vec![0.0, 1.0, 0.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        let csr = CsrMatrix::from_dense(&m);
        assert_eq!(csr.storage_bits(16, 16, 32), 3 * 32 + 3 * 32);
    }

    #[test]
    fn from_parts_validates() {
        let csr = CsrMatrix::from_parts(2, 3, vec![0, 1, 2], vec![0, 2], vec![1.0, 2.0]).unwrap();
        assert_eq!(csr.to_dense(), Matrix::from_vec(2, 3, vec![1.0, 0.0, 0.0, 0.0, 0.0, 2.0]));
    }

    #[test]
    fn from_parts_rejects_corruption_in_release_builds() {
        // wrong offsets length
        assert!(CsrMatrix::from_parts(2, 3, vec![0, 1], vec![0], vec![1.0]).is_err());
        // indices/values length mismatch
        assert!(CsrMatrix::from_parts(2, 3, vec![0, 1, 2], vec![0], vec![1.0, 2.0]).is_err());
        // nonzero first offset
        assert!(CsrMatrix::from_parts(2, 3, vec![1, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_err());
        // final offset != nnz
        assert!(CsrMatrix::from_parts(2, 3, vec![0, 1, 3], vec![0, 1], vec![1.0, 2.0]).is_err());
        // non-monotone offsets (with a matching final offset)
        assert!(
            CsrMatrix::from_parts(3, 3, vec![0, 2, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_err()
        );
        // column index out of bounds
        assert!(CsrMatrix::from_parts(2, 3, vec![0, 1, 2], vec![0, 3], vec![1.0, 2.0]).is_err());
    }
}
