//! Bit-packed storage for low-bit quantization codes.
//!
//! Separate Quantization stores each decomposed part at `k − log₂ m`
//! bits (paper §3.4) — down to 1 bit. Codes are packed little-endian
//! into `u64` words; supported widths are 1, 2, 4, 8 and any width
//! ≤ 16 (non-power-of-two widths pack across word boundaries).

/// A vector of `n` unsigned integers, each `bits` wide, packed into u64s.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodes {
    bits: u32,
    len: usize,
    words: Vec<u64>,
}

impl PackedCodes {
    /// Pack `codes`; every code must fit in `bits`.
    pub fn pack(codes: &[u32], bits: u32) -> PackedCodes {
        assert!((1..=16).contains(&bits), "unsupported width {bits}");
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let total_bits = codes.len() as u64 * bits as u64;
        let n_words = total_bits.div_ceil(64) as usize;
        let mut words = vec![0u64; n_words];
        for (i, &c) in codes.iter().enumerate() {
            assert!(c <= mask, "code {c} does not fit in {bits} bits");
            let bit_pos = i as u64 * bits as u64;
            let word = (bit_pos / 64) as usize;
            let off = (bit_pos % 64) as u32;
            words[word] |= (c as u64) << off;
            // spill into the next word when the code straddles a boundary
            if off + bits > 64 {
                words[word + 1] |= (c as u64) >> (64 - off);
            }
        }
        PackedCodes { bits, len: codes.len(), words }
    }

    /// Number of stored codes.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no codes are stored.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Code width in bits.
    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Raw packed words (serialization).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from raw parts (deserialization).
    pub fn from_words(bits: u32, len: usize, words: Vec<u64>) -> PackedCodes {
        let need = (len as u64 * bits as u64).div_ceil(64) as usize;
        assert_eq!(words.len(), need, "word count for {len} codes @ {bits}b");
        PackedCodes { bits, len, words }
    }

    /// Extract code `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.len);
        let bits = self.bits;
        let mask = (1u64 << bits) - 1;
        let bit_pos = i as u64 * bits as u64;
        let word = (bit_pos / 64) as usize;
        let off = (bit_pos % 64) as u32;
        let mut v = self.words[word] >> off;
        if off + bits > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        (v & mask) as u32
    }

    /// Unpack all codes.
    pub fn unpack(&self) -> Vec<u32> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Unpack into an existing buffer (hot-path dequantization; no alloc).
    pub fn unpack_into(&self, out: &mut [u32]) {
        assert_eq!(out.len(), self.len);
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.get(i);
        }
    }

    /// Actual in-memory payload size in bits (whole words).
    pub fn storage_bits(&self) -> u64 {
        self.words.len() as u64 * 64
    }

    /// Ideal payload size in bits (`len * bits` — the accounting number).
    pub fn ideal_bits(&self) -> u64 {
        self.len as u64 * self.bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Pcg64;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Pcg64::seeded(1);
        for bits in 1..=16u32 {
            let max = (1u64 << bits) as u64;
            let codes: Vec<u32> = (0..517).map(|_| rng.below(max) as u32).collect();
            let packed = PackedCodes::pack(&codes, bits);
            assert_eq!(packed.unpack(), codes, "bits={bits}");
            assert_eq!(packed.len(), codes.len());
        }
    }

    #[test]
    fn boundary_straddling_widths() {
        // widths that don't divide 64 force codes across word boundaries
        for bits in [3u32, 5, 6, 7, 9, 11, 13, 15] {
            let max = 1u32 << bits;
            let codes: Vec<u32> =
                (0..200u32).map(|i| i.wrapping_mul(2654435761) % max).collect();
            let packed = PackedCodes::pack(&codes, bits);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(packed.get(i), c, "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn one_bit_codes() {
        let codes = vec![1, 0, 1, 1, 0, 0, 1, 0];
        let p = PackedCodes::pack(&codes, 1);
        assert_eq!(p.words().len(), 1);
        assert_eq!(p.unpack(), codes);
        assert_eq!(p.ideal_bits(), 8);
    }

    #[test]
    fn empty_codes() {
        let p = PackedCodes::pack(&[], 4);
        assert!(p.is_empty());
        assert_eq!(p.unpack(), Vec::<u32>::new());
        assert_eq!(p.storage_bits(), 0);
    }

    #[test]
    #[should_panic]
    fn overflow_code_panics() {
        let _ = PackedCodes::pack(&[4], 2);
    }

    #[test]
    fn storage_vs_ideal_bits() {
        let codes = vec![0u32; 100];
        let p = PackedCodes::pack(&codes, 2);
        assert_eq!(p.ideal_bits(), 200);
        assert_eq!(p.storage_bits(), 256); // 4 words
    }

    #[test]
    fn unpack_into_no_alloc() {
        let codes: Vec<u32> = (0..33).map(|i| i % 4).collect();
        let p = PackedCodes::pack(&codes, 2);
        let mut buf = vec![0u32; 33];
        p.unpack_into(&mut buf);
        assert_eq!(buf, codes);
    }

    #[test]
    fn from_words_roundtrip() {
        let codes = vec![7, 0, 3, 5, 1];
        let p = PackedCodes::pack(&codes, 3);
        let q = PackedCodes::from_words(p.bits(), p.len(), p.words().to_vec());
        assert_eq!(q.unpack(), codes);
    }
}
