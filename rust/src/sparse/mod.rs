//! Sparse storage substrate (S2): CSR matrices and bit-packed code
//! arrays — the paper's deployment storage format (§3.4).

pub mod bitpack;
pub mod csr;

pub use bitpack::PackedCodes;
pub use csr::CsrMatrix;
