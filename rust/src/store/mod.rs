//! DeltaStore (S13): the persistent, tiered delta artifact repository.
//!
//! The paper's deployment pitch is one base model plus thousands of
//! tiny per-tenant deltas — which only pays off if the serving tier
//! scales with *resident* tenants, not *registered* ones. The store is
//! the disk tier of that story:
//!
//! ```text
//!   <root>/MANIFEST.json         versioned index (atomic replace)
//!   <root>/shards/t<id>.<k>.ddq  per-tenant shard blobs
//! ```
//!
//! * **push** — a tenant's [`DeltaSet`] is encoded tensor-by-tensor,
//!   packed into shards of ~[`DEFAULT_SHARD_BUDGET`] bytes, written
//!   atomically, and committed to the manifest with a per-layer offset
//!   table (shard, offset, len, CRC-32).
//! * **load / load_tensor** — hydration reads exactly the records it
//!   needs via positioned reads (`pread`); every record's CRC-32 is
//!   verified before its bytes are decoded. A whole-set load is just
//!   the per-layer path over every layer — there is no separate eager
//!   format.
//! * **remove / gc** — removal drops the manifest entry first (the
//!   commit point), then deletes shard files best-effort; `gc` sweeps
//!   anything in `shards/` the manifest no longer references.
//!
//! Concurrency: within one process the manifest mutex guards metadata
//! only — all file I/O happens outside it, so hydrations proceed while
//! a push writes new shards. Replacing a tenant mid-hydration can fail
//! that hydration (its shard files may vanish); callers surface the
//! error and the next request retries against the new artifact. Across
//! processes, every mutating op re-reads the manifest before editing
//! (sequential `push`/`gc` from a CLI compose with a running server),
//! but truly *concurrent* cross-process writers are not coordinated —
//! std has no file locking — so run mutating CLI ops one at a time,
//! and `gc` only against a store no other process is pushing to (an
//! in-flight foreign push's shards look like orphans until its
//! manifest commit).

pub mod manifest;
mod shard;

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::compress::CompressedDelta;
use crate::delta::format::DeltaSet;
use manifest::{Manifest, TenantRecord, TensorRecord};
use shard::{SHARD_HEADER_LEN, TensorBlob};

/// Target shard payload size: tensors are greedily packed into shards
/// until one would overflow this. Small enough that cold-start paging
/// touches only the layers it needs even with read-ahead, large enough
/// to keep file counts sane at thousands of tenants.
pub const DEFAULT_SHARD_BUDGET: u64 = 1 << 20;

/// What `gc` swept.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct GcReport {
    /// Orphan shard files deleted.
    pub files_removed: usize,
    /// Bytes those files occupied.
    pub bytes_freed: u64,
}

/// The on-disk tenant repository. Cheap to share (`Arc`) between the
/// serving tier's loader thread and CLI tooling.
#[derive(Debug)]
pub struct DeltaStore {
    root: PathBuf,
    manifest: Mutex<Manifest>,
    /// Serializes the mutating control-plane ops (`push`/`remove`/`gc`)
    /// of THIS instance across their whole file-I/O window, so an
    /// in-process `gc` can never sweep the shards of a push that has
    /// reserved its id but not yet committed. Reads never take it.
    ops: Mutex<()>,
    shard_budget: u64,
    bytes_read: AtomicU64,
}

impl DeltaStore {
    /// Open an existing store (errors if `root` has no manifest).
    pub fn open(root: &Path) -> Result<DeltaStore> {
        let manifest = Manifest::load(root)?;
        Ok(DeltaStore {
            root: root.to_path_buf(),
            manifest: Mutex::new(manifest),
            ops: Mutex::new(()),
            shard_budget: DEFAULT_SHARD_BUDGET,
            bytes_read: AtomicU64::new(0),
        })
    }

    /// Open a store, initializing an empty one if `root` is new.
    pub fn open_or_create(root: &Path) -> Result<DeltaStore> {
        DeltaStore::open_or_create_with(root, DEFAULT_SHARD_BUDGET)
    }

    /// As [`open_or_create`](DeltaStore::open_or_create) with an
    /// explicit shard payload budget (tests use tiny budgets to force
    /// multi-shard tenants).
    pub fn open_or_create_with(root: &Path, shard_budget: u64) -> Result<DeltaStore> {
        if !root.join(manifest::MANIFEST_FILE).exists() {
            std::fs::create_dir_all(root.join("shards"))
                .with_context(|| format!("create store at {root:?}"))?;
            Manifest::default().save(root)?;
        }
        let manifest = Manifest::load(root)?;
        Ok(DeltaStore {
            root: root.to_path_buf(),
            manifest: Mutex::new(manifest),
            ops: Mutex::new(()),
            shard_budget: shard_budget.max(1),
            bytes_read: AtomicU64::new(0),
        })
    }

    /// Store root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Manifest lock accessor. The `expect` is infallible by invariant:
    /// nothing panics while holding either store lock — all file I/O
    /// and record decoding happen outside them — so the mutex can
    /// never be poisoned.
    fn manifest_lock(&self) -> std::sync::MutexGuard<'_, Manifest> {
        self.manifest.lock().expect("manifest lock poisoned (nothing panics under it)")
    }

    /// Ops lock accessor; same poisoning invariant as
    /// [`manifest_lock`](DeltaStore::manifest_lock).
    fn ops_lock(&self) -> std::sync::MutexGuard<'_, ()> {
        self.ops.lock().expect("ops lock poisoned (nothing panics under it)")
    }

    /// Total bytes of shard payload read since open (telemetry).
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Names of every stored tenant, sorted.
    pub fn tenants(&self) -> Vec<String> {
        self.manifest_lock().tenants.keys().cloned().collect()
    }

    /// Whether a tenant exists in the store.
    pub fn contains(&self, tenant: &str) -> bool {
        self.manifest_lock().tenants.contains_key(tenant)
    }

    /// Number of stored tenants.
    pub fn tenant_count(&self) -> usize {
        self.manifest_lock().tenants.len()
    }

    /// Manifest entry for one tenant (cloned snapshot).
    pub fn tenant_info(&self, tenant: &str) -> Option<TenantRecord> {
        self.manifest_lock().tenants.get(tenant).cloned()
    }

    /// Total payload bytes across all registered tenants.
    pub fn total_bytes(&self) -> u64 {
        self.manifest_lock().tenants.values().map(|t| t.bytes).sum()
    }

    /// Re-read `MANIFEST.json` into the locked in-memory copy. Every
    /// mutating op calls this first, so sequential operations from
    /// different processes (a serving daemon plus `deltadq push/gc/ls`)
    /// compose instead of saving a stale snapshot over each other's
    /// commits. Truly concurrent cross-process writers remain
    /// uncoordinated (no file locking in std) — see the module docs.
    fn reload_locked(&self, m: &mut Manifest) -> Result<()> {
        *m = Manifest::load(&self.root)?;
        Ok(())
    }

    /// Register (or replace) a tenant's deltas on disk. Returns the
    /// payload bytes written. The manifest commit is the atomicity
    /// point; a crash before it leaves orphan shards for [`gc`].
    pub fn push(&self, tenant: &str, set: &DeltaSet) -> Result<u64> {
        if set.tensors.is_empty() {
            bail!("refusing to push tenant '{tenant}' with an empty delta set");
        }
        // encode everything before taking any lock
        let mut blobs: Vec<TensorBlob> = Vec::with_capacity(set.tensors.len());
        for (name, tensor) in &set.tensors {
            blobs.push(shard::encode_tensor(name, tensor)?);
        }
        let _ops = self.ops_lock();
        let id = {
            let mut m = self.manifest_lock();
            self.reload_locked(&mut m)?;
            let id = m.next_id;
            m.next_id += 1;
            // persist the reservation so a later process (or a crash
            // before commit) can never reuse this id's shard filenames
            m.save(&self.root)?;
            id
        };

        // greedy pack into shards; write each file atomically
        let mut shards: Vec<String> = Vec::new();
        let mut tensors: Vec<TensorRecord> = Vec::new();
        let mut total = 0u64;
        let mut start = 0usize;
        while start < blobs.len() {
            let mut end = start + 1;
            let mut payload = blobs[start].bytes.len() as u64;
            while end < blobs.len() {
                let next = blobs[end].bytes.len() as u64;
                if payload + next > self.shard_budget {
                    break;
                }
                payload += next;
                end += 1;
            }
            let rel = format!("shards/t{id}.{}.ddq", shards.len());
            let group: Vec<&TensorBlob> = blobs[start..end].iter().collect();
            shard::write_shard(&self.root.join(&rel), &group)?;
            let mut offset = SHARD_HEADER_LEN;
            for blob in &group {
                let len = blob.bytes.len() as u64;
                tensors.push(TensorRecord {
                    name: blob.name.clone(),
                    shard: shards.len(),
                    offset,
                    len,
                    crc32: blob.crc32,
                    norm: set.norms.get(&blob.name).copied().unwrap_or(0.0),
                });
                offset += len;
                total += len;
            }
            shards.push(rel);
            start = end;
        }

        let record = TenantRecord {
            id,
            method: set.method.clone(),
            nominal_ratio: set.nominal_ratio,
            bytes: total,
            shards,
            tensors,
        };
        let replaced = {
            let mut m = self.manifest_lock();
            self.reload_locked(&mut m)?;
            let old = m.tenants.insert(tenant.to_string(), record);
            // `store.manifest_commit` models a crash/IO failure between
            // the shard writes above and the manifest commit: the shards
            // are on disk but unreachable (orphans for `gc`), and the
            // tenant must be absent — not half-present — on reopen
            let commit = crate::util::failpoint::hit("store.manifest_commit")
                .and_then(|()| m.save(&self.root));
            if let Err(e) = commit {
                // disk is the commit point: a failed save must leave
                // the in-memory manifest agreeing with it, so the new
                // record (pointing at soon-to-be-orphan shards) is
                // rolled back rather than served from memory
                match old {
                    Some(prev) => {
                        m.tenants.insert(tenant.to_string(), prev);
                    }
                    None => {
                        m.tenants.remove(tenant);
                    }
                }
                return Err(e).with_context(|| format!("committing tenant '{tenant}'"));
            }
            old
        };
        // the old artifact is unreachable now; delete best-effort
        if let Some(old) = replaced {
            for rel in &old.shards {
                let _ = std::fs::remove_file(self.root.join(rel));
            }
        }
        Ok(total)
    }

    /// Remove a tenant. Returns whether it existed.
    pub fn remove(&self, tenant: &str) -> Result<bool> {
        let _ops = self.ops_lock();
        let removed = {
            let mut m = self.manifest_lock();
            self.reload_locked(&mut m)?;
            let removed = m.tenants.remove(tenant);
            if removed.is_some() {
                m.save(&self.root)?;
            }
            removed
        };
        match removed {
            Some(record) => {
                for rel in &record.shards {
                    let _ = std::fs::remove_file(self.root.join(rel));
                }
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Sweep `shards/` for files the manifest no longer references
    /// (crashed pushes, failed removals, stale `.tmp` files).
    pub fn gc(&self) -> Result<GcReport> {
        self.sweep(false)
    }

    /// Report what [`gc`](DeltaStore::gc) *would* sweep — orphan file
    /// count and bytes — without deleting anything.
    pub fn gc_dry_run(&self) -> Result<GcReport> {
        self.sweep(true)
    }

    fn sweep(&self, dry_run: bool) -> Result<GcReport> {
        let _ops = self.ops_lock();
        let live: std::collections::BTreeSet<PathBuf> = {
            let mut m = self.manifest_lock();
            self.reload_locked(&mut m)?;
            m.tenants
                .values()
                .flat_map(|t| t.shards.iter().map(|rel| self.root.join(rel)))
                .collect()
        };
        let mut report = GcReport::default();
        let dir = self.root.join("shards");
        for entry in std::fs::read_dir(&dir).with_context(|| format!("read_dir {dir:?}"))? {
            let path = entry?.path();
            if !path.is_file() || live.contains(&path) {
                continue;
            }
            let bytes = path.metadata().map(|m| m.len()).unwrap_or(0);
            if !dry_run {
                std::fs::remove_file(&path).with_context(|| format!("remove {path:?}"))?;
            }
            report.files_removed += 1;
            report.bytes_freed += bytes;
        }
        Ok(report)
    }

    /// One shard-record read under the containment policy: any failure
    /// — I/O error or CRC mismatch — earns exactly one immediate
    /// re-read. A transient medium error heals on the retry; truly
    /// corrupt bytes fail the CRC again and the error propagates (the
    /// hydration layer then quarantines the tenant). Bad bytes are
    /// never decoded: `read_record` verifies the CRC before returning.
    /// Fault injection: `store.shard_read`.
    fn read_record_contained(
        &self,
        file: &std::fs::File,
        path: &Path,
        rec: &TensorRecord,
    ) -> Result<Vec<u8>> {
        let read = || {
            crate::util::failpoint::hit("store.shard_read")
                .and_then(|()| shard::read_record(file, path, rec.offset, rec.len, rec.crc32))
        };
        match read() {
            Ok(raw) => Ok(raw),
            Err(first) => {
                read().with_context(|| format!("after one re-read (first error: {first:#})"))
            }
        }
    }

    /// Page in one tensor: a single positioned read + CRC verify.
    pub fn load_tensor(&self, tenant: &str, name: &str) -> Result<CompressedDelta> {
        let record = self.tenant_info(tenant);
        let record = record.with_context(|| format!("tenant '{tenant}' is not in the store"))?;
        let rec = record.tensors.iter().find(|t| t.name == name);
        let rec = rec.with_context(|| format!("tenant '{tenant}' has no tensor '{name}'"))?;
        let rel = &record.shards[rec.shard];
        let path = self.root.join(rel);
        let file = shard::open_shard(&path)?;
        let raw = self.read_record_contained(&file, &path, rec)?;
        self.bytes_read.fetch_add(rec.len, Ordering::Relaxed);
        shard::decode_tensor(name, &raw)
    }

    /// Hydrate a tenant's full [`DeltaSet`] — the per-layer paged path
    /// over every layer, one shard file handle per shard.
    pub fn load(&self, tenant: &str) -> Result<DeltaSet> {
        let record = self.tenant_info(tenant);
        let record = record.with_context(|| format!("tenant '{tenant}' is not in the store"))?;
        let mut set = DeltaSet::new(&record.method, record.nominal_ratio);
        let mut files: BTreeMap<usize, std::fs::File> = BTreeMap::new();
        for rec in &record.tensors {
            let path = self.root.join(&record.shards[rec.shard]);
            let file = match files.entry(rec.shard) {
                Entry::Occupied(e) => e.into_mut(),
                Entry::Vacant(v) => v.insert(shard::open_shard(&path)?),
            };
            let raw = self
                .read_record_contained(file, &path, rec)
                .with_context(|| format!("tenant '{tenant}', tensor '{}'", rec.name))?;
            let tensor = shard::decode_tensor(&rec.name, &raw)
                .with_context(|| format!("tenant '{tenant}'"))?;
            set.tensors.insert(rec.name.clone(), tensor);
            if rec.norm != 0.0 {
                set.norms.insert(rec.name.clone(), rec.norm);
            }
        }
        self.bytes_read.fetch_add(record.bytes, Ordering::Relaxed);
        Ok(set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, DeltaDq, DeltaDqConfig, LayerContext};
    use crate::tensor::{Matrix, Pcg64};

    fn tmp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("deltadq-test-store")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_set(seed: u64, quant: Option<(u32, u32)>) -> DeltaSet {
        let mut rng = Pcg64::seeded(seed);
        let dq = DeltaDq::new(DeltaDqConfig { alpha: 4.0, group_size: Some(8), quant });
        let mut set = DeltaSet::new(&dq.name(), dq.nominal_ratio());
        for i in 0..4 {
            let d = Matrix::randn(16, 32, 0.01, &mut rng);
            let name = format!("layers.{i}.attn.wq");
            let c = dq.compress(&d, &LayerContext::data_free(i, &name), &mut rng);
            set.tensors.insert(name, c);
        }
        set
    }

    fn assert_sets_equal(a: &DeltaSet, b: &DeltaSet) {
        assert_eq!(a.method, b.method);
        assert_eq!(a.nominal_ratio, b.nominal_ratio);
        assert_eq!(a.tensors.len(), b.tensors.len());
        for (name, t) in &a.tensors {
            assert_eq!(t.to_dense(), b.tensors[name].to_dense(), "{name}");
        }
    }

    #[test]
    fn push_load_roundtrip() {
        let root = tmp_store("roundtrip");
        let store = DeltaStore::open_or_create(&root).unwrap();
        for (tenant, seed, quant) in
            [("math", 2u64, None), ("code", 3, Some((8u32, 4u32))), ("chat", 4, Some((4, 8)))]
        {
            let set = sample_set(seed, quant);
            let bytes = store.push(tenant, &set).unwrap();
            assert!(bytes > 0);
            assert_sets_equal(&store.load(tenant).unwrap(), &set);
        }
        assert_eq!(store.tenant_count(), 3);
        assert!(store.bytes_read() > 0);
    }

    #[test]
    fn lazy_single_tensor_read() {
        let root = tmp_store("lazy");
        let store = DeltaStore::open_or_create(&root).unwrap();
        let set = sample_set(5, Some((8, 1)));
        store.push("t", &set).unwrap();
        let before = store.bytes_read();
        let one = store.load_tensor("t", "layers.2.attn.wq").unwrap();
        assert_eq!(one.to_dense(), set.tensors["layers.2.attn.wq"].to_dense());
        let read = store.bytes_read() - before;
        let info = store.tenant_info("t").unwrap();
        assert!(read < info.bytes, "one layer read {read} < whole artifact {}", info.bytes);
        assert!(store.load_tensor("t", "nope").is_err());
    }

    #[test]
    fn tiny_shard_budget_forces_multiple_shards() {
        let root = tmp_store("multishard");
        // budget below any single tensor record → one shard per tensor
        let store = DeltaStore::open_or_create_with(&root, 16).unwrap();
        let set = sample_set(6, None);
        store.push("t", &set).unwrap();
        let info = store.tenant_info("t").unwrap();
        assert_eq!(info.shards.len(), set.tensors.len());
        assert_sets_equal(&store.load("t").unwrap(), &set);
    }

    #[test]
    fn reopen_preserves_manifest() {
        let root = tmp_store("reopen");
        let set = sample_set(7, Some((4, 2)));
        {
            let store = DeltaStore::open_or_create(&root).unwrap();
            store.push("persist", &set).unwrap();
        }
        let store = DeltaStore::open(&root).unwrap();
        assert!(store.contains("persist"));
        assert_sets_equal(&store.load("persist").unwrap(), &set);
        // a directory without a manifest is not a store
        assert!(DeltaStore::open(&root.join("shards")).is_err());
    }

    #[test]
    fn push_replaces_and_drops_old_shards() {
        let root = tmp_store("replace");
        let store = DeltaStore::open_or_create(&root).unwrap();
        store.push("t", &sample_set(8, None)).unwrap();
        let old = store.tenant_info("t").unwrap();
        let newer = sample_set(9, Some((8, 4)));
        store.push("t", &newer).unwrap();
        let new = store.tenant_info("t").unwrap();
        assert_ne!(old.id, new.id);
        for rel in &old.shards {
            assert!(!root.join(rel).exists(), "stale shard {rel} must be gone");
        }
        assert_sets_equal(&store.load("t").unwrap(), &newer);
    }

    #[test]
    fn remove_then_gc_sweeps_orphans() {
        let root = tmp_store("gc");
        let store = DeltaStore::open_or_create(&root).unwrap();
        store.push("a", &sample_set(10, None)).unwrap();
        store.push("b", &sample_set(11, None)).unwrap();
        assert!(store.remove("a").unwrap());
        assert!(!store.remove("a").unwrap());
        // simulate a crashed push: orphan file in shards/
        std::fs::write(root.join("shards/orphan.ddq"), b"DDQS....junk").unwrap();
        let report = store.gc().unwrap();
        assert_eq!(report.files_removed, 1);
        assert!(report.bytes_freed > 0);
        // the live tenant is untouched
        assert_sets_equal(&store.load("b").unwrap(), &sample_set(11, None));
        assert!(store.load("a").is_err());
    }

    #[test]
    fn gc_dry_run_reports_without_deleting() {
        let root = tmp_store("gc-dry");
        let store = DeltaStore::open_or_create(&root).unwrap();
        store.push("keep", &sample_set(13, None)).unwrap();
        let orphan = root.join("shards/orphan.ddq");
        std::fs::write(&orphan, b"DDQS....junk").unwrap();

        let dry = store.gc_dry_run().unwrap();
        assert_eq!(dry.files_removed, 1, "one orphan reported");
        assert!(dry.bytes_freed > 0);
        assert!(orphan.exists(), "dry run must not delete");
        assert_sets_equal(&store.load("keep").unwrap(), &sample_set(13, None));

        // a real sweep removes exactly what the dry run promised
        let real = store.gc().unwrap();
        assert_eq!(real, dry);
        assert!(!orphan.exists());
        assert_eq!(store.gc_dry_run().unwrap(), GcReport::default());
    }

    #[test]
    fn norms_roundtrip_through_store() {
        let root = tmp_store("norms");
        let store = DeltaStore::open_or_create(&root).unwrap();
        let mut set = sample_set(14, Some((8, 4)));
        for (i, name) in set.tensors.keys().cloned().enumerate() {
            set.norms.insert(name, 0.5 + i as f64);
        }
        store.push("t", &set).unwrap();
        let loaded = store.load("t").unwrap();
        assert_eq!(loaded.norms, set.norms);
    }

    #[test]
    fn corrupt_shard_fails_hydration() {
        let root = tmp_store("corrupt");
        let store = DeltaStore::open_or_create(&root).unwrap();
        store.push("t", &sample_set(12, Some((8, 1)))).unwrap();
        let info = store.tenant_info("t").unwrap();
        let path = root.join(&info.shards[0]);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = store.load("t").unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }
}
