//! Shard blob files: the on-disk unit of the delta store.
//!
//! A shard is a flat container of tensor records — the same `kind +
//! payload` bytes a `.ddq` file holds, minus the set-level header. The
//! byte position of every record lives in the store manifest, so a
//! reader pages in exactly one layer with one positioned read
//! (`read_exact_at`) and verifies its CRC-32 before decoding; nothing
//! else in the file is touched.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic    b"DDQS"
//! version  u32 (=1)
//! record*  kind u8 + tensor payload   (format.rs tensor encoding)
//! ```

use std::fs::File;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::compress::CompressedDelta;
use crate::delta::format::{read_tensor, write_tensor};
use crate::util::crc32::crc32;

pub(crate) const SHARD_MAGIC: &[u8; 4] = b"DDQS";
pub(crate) const SHARD_VERSION: u32 = 1;
/// Byte offset of the first record (magic + version).
pub(crate) const SHARD_HEADER_LEN: u64 = 8;

/// One encoded tensor, ready to be placed into a shard.
pub(crate) struct TensorBlob {
    pub name: String,
    pub bytes: Vec<u8>,
    pub crc32: u32,
}

/// Encode one tensor into its shard record bytes.
pub(crate) fn encode_tensor(name: &str, tensor: &CompressedDelta) -> Result<TensorBlob> {
    let mut bytes: Vec<u8> = Vec::new();
    write_tensor(&mut bytes, tensor).with_context(|| format!("encode tensor '{name}'"))?;
    let crc = crc32(&bytes);
    Ok(TensorBlob { name: name.to_string(), bytes, crc32: crc })
}

/// Decode one tensor record; the record must be consumed exactly.
pub(crate) fn decode_tensor(name: &str, bytes: &[u8]) -> Result<CompressedDelta> {
    let mut r: &[u8] = bytes;
    let tensor = read_tensor(&mut r).with_context(|| format!("decode tensor '{name}'"))?;
    if !r.is_empty() {
        bail!("tensor '{name}': {} trailing bytes after payload", r.len());
    }
    Ok(tensor)
}

/// Write a shard file atomically (tmp + rename): header, then the
/// records back to back. Returns nothing — record offsets are computed
/// by the caller from the blob lengths.
pub(crate) fn write_shard(path: &Path, blobs: &[&TensorBlob]) -> Result<()> {
    let tmp = path.with_extension("ddq.tmp");
    {
        let mut f = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        f.write_all(SHARD_MAGIC)?;
        f.write_all(&SHARD_VERSION.to_le_bytes())?;
        for blob in blobs {
            f.write_all(&blob.bytes)?;
        }
        let _ = f.sync_all(); // best effort — not all filesystems support it
    }
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Open a shard for positioned reads, verifying its header once.
pub(crate) fn open_shard(path: &Path) -> Result<File> {
    let file = File::open(path).with_context(|| format!("open shard {path:?}"))?;
    let mut header = [0u8; 8];
    read_at(&file, path, 0, &mut header).with_context(|| format!("read header {path:?}"))?;
    if &header[..4] != SHARD_MAGIC {
        bail!("{path:?}: bad shard magic (expected DDQS)");
    }
    let version = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
    if version != SHARD_VERSION {
        bail!("{path:?}: unsupported shard version {version}");
    }
    Ok(file)
}

/// Read one record (`len` bytes at `offset`) and verify its CRC-32.
pub(crate) fn read_record(
    file: &File,
    path: &Path,
    offset: u64,
    len: u64,
    expect_crc: u32,
) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; len as usize];
    read_at(file, path, offset, &mut buf).with_context(|| {
        format!("{path:?}: short read at offset {offset} (+{len}) — shard truncated?")
    })?;
    let actual = crc32(&buf);
    if actual != expect_crc {
        bail!(
            "{path:?}: record checksum failure at offset {offset}: stored {expect_crc:#010x}, \
             computed {actual:#010x}"
        );
    }
    Ok(buf)
}

/// Positioned exact read. On unix this is `pread` (no seek, safe to
/// share one `File` across threads); elsewhere each read opens a fresh
/// handle from `path` — a `try_clone` would share the file cursor, so
/// concurrent seek+read pairs on clones would race.
fn read_at(file: &File, path: &Path, offset: u64, buf: &mut [u8]) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        let _ = path;
        file.read_exact_at(buf, offset)
    }
    #[cfg(not(unix))]
    {
        use std::io::{Read, Seek, SeekFrom};
        let _ = file;
        let mut f = File::open(path)?;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::CsrMatrix;
    use crate::tensor::{Matrix, Pcg64};

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("deltadq-test-shard");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_tensor(seed: u64) -> CompressedDelta {
        let mut rng = Pcg64::seeded(seed);
        let m = Matrix::from_fn(8, 16, |_, _| {
            if rng.bernoulli(0.3) {
                rng.normal() * 0.01
            } else {
                0.0
            }
        });
        CompressedDelta::Sparse(CsrMatrix::from_dense(&m))
    }

    #[test]
    fn record_roundtrip_with_positioned_reads() {
        let t0 = sample_tensor(1);
        let t1 = sample_tensor(2);
        let b0 = encode_tensor("a", &t0).unwrap();
        let b1 = encode_tensor("b", &t1).unwrap();
        let path = tmpdir().join("roundtrip.ddq");
        write_shard(&path, &[&b0, &b1]).unwrap();

        let file = open_shard(&path).unwrap();
        let off0 = SHARD_HEADER_LEN;
        let off1 = off0 + b0.bytes.len() as u64;
        // read the SECOND record first — order independence is the point
        let raw1 = read_record(&file, &path, off1, b1.bytes.len() as u64, b1.crc32).unwrap();
        let got1 = decode_tensor("b", &raw1).unwrap();
        assert_eq!(got1.to_dense(), t1.to_dense());
        let raw0 = read_record(&file, &path, off0, b0.bytes.len() as u64, b0.crc32).unwrap();
        let got0 = decode_tensor("a", &raw0).unwrap();
        assert_eq!(got0.to_dense(), t0.to_dense());
    }

    #[test]
    fn corrupt_record_fails_crc() {
        let t = sample_tensor(3);
        let b = encode_tensor("x", &t).unwrap();
        let path = tmpdir().join("corrupt.ddq");
        write_shard(&path, &[&b]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = SHARD_HEADER_LEN as usize + b.bytes.len() / 2;
        bytes[mid] ^= 0x08;
        std::fs::write(&path, &bytes).unwrap();
        let file = open_shard(&path).unwrap();
        let err = read_record(&file, &path, SHARD_HEADER_LEN, b.bytes.len() as u64, b.crc32)
            .unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    }

    #[test]
    fn bad_header_rejected() {
        let path = tmpdir().join("badmagic.ddq");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(open_shard(&path).is_err());
    }
}
