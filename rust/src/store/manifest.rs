//! The store manifest: `MANIFEST.json` at the store root.
//!
//! The manifest is the only authority on what the store contains — a
//! tenant exists iff it has an entry here, and every entry carries the
//! full per-layer offset table (shard index, byte offset, length,
//! CRC-32) so a reader can page in any single layer without touching
//! the rest of the shard. Updates are atomic: the new manifest is
//! written to a temp file and renamed over the old one, so a crash
//! mid-push leaves the previous manifest intact and at worst some
//! orphan shard files for `gc` to sweep.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// File name of the manifest inside a store root.
pub const MANIFEST_FILE: &str = "MANIFEST.json";
/// Manifest schema version (`"version"` in the JSON).
pub const MANIFEST_VERSION: u64 = 1;
/// The `"format"` marker distinguishing a store root from random JSON.
pub const MANIFEST_FORMAT: &str = "deltastore";

/// Where one tensor's record lives: `shards[shard]` at `offset..offset+len`.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorRecord {
    /// Tensor name (matches the delta set's tensor key).
    pub name: String,
    /// Index into the owning tenant's `shards` list.
    pub shard: usize,
    /// Byte offset of the record inside the shard file.
    pub offset: u64,
    /// Record length in bytes.
    pub len: u64,
    /// CRC-32 of the record bytes (verified on read).
    pub crc32: u32,
    /// Pre-quantization Frobenius norm of the delta tensor (0.0 when the
    /// pushing client predates norm capture) — the audit subsystem's
    /// reconstruction-error reference.
    pub norm: f64,
}

/// One tenant's artifact: shard files plus the per-layer offset table.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantRecord {
    /// Store-assigned numeric id (names the shard files, so tenant ids
    /// never need filesystem-safe escaping).
    pub id: u64,
    /// Compression method recorded at push time.
    pub method: String,
    /// Target compression ratio recorded at push time.
    pub nominal_ratio: f64,
    /// Total payload bytes across all tensor records.
    pub bytes: u64,
    /// Store-relative shard paths ("shards/t<id>.<k>.ddq").
    pub shards: Vec<String>,
    /// Location of every tensor across the shard files.
    pub tensors: Vec<TensorRecord>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Manifest {
    /// Next store-assigned numeric tenant id.
    pub next_id: u64,
    /// Tenant records keyed by tenant name.
    pub tenants: BTreeMap<String, TenantRecord>,
}

impl Manifest {
    /// Serialize to the on-disk JSON shape.
    pub fn to_json(&self) -> Json {
        let mut tenants = Json::obj();
        for (name, t) in &self.tenants {
            let mut o = Json::obj();
            o.set("id", t.id)
                .set("method", t.method.as_str())
                .set("nominal_ratio", t.nominal_ratio)
                .set("bytes", t.bytes)
                .set("shards", t.shards.clone());
            let mut tensors = Vec::with_capacity(t.tensors.len());
            for rec in &t.tensors {
                let mut r = Json::obj();
                r.set("name", rec.name.as_str())
                    .set("shard", rec.shard)
                    .set("offset", rec.offset)
                    .set("len", rec.len)
                    .set("crc32", rec.crc32)
                    .set("norm", rec.norm);
                tensors.push(r);
            }
            o.set("tensors", Json::Arr(tensors));
            tenants.set(name, o);
        }
        let mut root = Json::obj();
        root.set("format", MANIFEST_FORMAT)
            .set("version", MANIFEST_VERSION)
            .set("next_id", self.next_id)
            .set("tenants", tenants);
        root
    }

    /// Parse a manifest, validating format marker and version.
    pub fn from_json(j: &Json) -> Result<Manifest> {
        if j.get("format").and_then(Json::as_str) != Some(MANIFEST_FORMAT) {
            bail!("not a delta store manifest (missing format marker)");
        }
        match j.get("version").and_then(Json::as_u64) {
            Some(MANIFEST_VERSION) => {}
            Some(v) => bail!("unsupported manifest version {v}"),
            None => bail!("manifest has no version"),
        }
        let next_id = field_u64(j, "next_id")?;
        let mut tenants = BTreeMap::new();
        let table = j.get("tenants").and_then(Json::as_object);
        let table = table.context("manifest has no tenants object")?;
        for (name, t) in table {
            let mut tensors = Vec::new();
            let recs = t.get("tensors").and_then(Json::as_array);
            let recs = recs.with_context(|| format!("tenant '{name}': no tensors array"))?;
            for rec in recs {
                tensors.push(TensorRecord {
                    name: field_str(rec, "name")?,
                    shard: field_u64(rec, "shard")? as usize,
                    offset: field_u64(rec, "offset")?,
                    len: field_u64(rec, "len")?,
                    crc32: field_u64(rec, "crc32")? as u32,
                    // absent in manifests written before norm capture
                    norm: rec.get("norm").and_then(Json::as_f64).unwrap_or(0.0),
                });
            }
            let arr = t.get("shards").and_then(Json::as_array);
            let arr = arr.with_context(|| format!("tenant '{name}': no shards array"))?;
            let mut shards = Vec::with_capacity(arr.len());
            for s in arr {
                let s = s.as_str();
                let s = s.with_context(|| format!("tenant '{name}': non-string shard"))?;
                shards.push(s.to_string());
            }
            let ratio = t.get("nominal_ratio").and_then(Json::as_f64);
            let ratio = ratio.with_context(|| format!("tenant '{name}': no nominal_ratio"))?;
            let record = TenantRecord {
                id: field_u64(t, "id")?,
                method: field_str(t, "method")?,
                nominal_ratio: ratio,
                bytes: field_u64(t, "bytes")?,
                shards,
                tensors,
            };
            for rec in &record.tensors {
                if rec.shard >= record.shards.len() {
                    bail!(
                        "tenant '{name}': tensor '{}' references shard {} of {}",
                        rec.name,
                        rec.shard,
                        record.shards.len()
                    );
                }
            }
            tenants.insert(name.clone(), record);
        }
        Ok(Manifest { next_id, tenants })
    }

    /// Load `MANIFEST.json` from a store root.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let json = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        Manifest::from_json(&json).with_context(|| format!("validate {path:?}"))
    }

    /// Atomically write `MANIFEST.json` (temp file, fsync, rename).
    /// The fsync before the rename matters: without it a crash can
    /// persist the rename ahead of the data and leave an empty
    /// manifest — the one failure worse than losing the last push.
    pub fn save(&self, root: &Path) -> Result<()> {
        let path = root.join(MANIFEST_FILE);
        let tmp = root.join("MANIFEST.json.tmp");
        {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
            f.write_all(self.to_json().to_string().as_bytes())
                .with_context(|| format!("write {tmp:?}"))?;
            f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
        }
        std::fs::rename(&tmp, &path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
        // best effort: make the rename itself durable
        if let Ok(dir) = std::fs::File::open(root) {
            let _ = dir.sync_all();
        }
        Ok(())
    }
}

fn field_u64(j: &Json, key: &str) -> Result<u64> {
    let n = j.get(key).and_then(Json::as_u64);
    n.with_context(|| format!("missing/invalid u64 field '{key}'"))
}

fn field_str(j: &Json, key: &str) -> Result<String> {
    let s = j.get(key).and_then(Json::as_str);
    Ok(s.with_context(|| format!("missing/invalid string field '{key}'"))?.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let mut m = Manifest { next_id: 3, tenants: BTreeMap::new() };
        m.tenants.insert(
            "math".to_string(),
            TenantRecord {
                id: 1,
                method: "DeltaDQ".to_string(),
                nominal_ratio: 16.0,
                bytes: 2048,
                shards: vec!["shards/t1.0.ddq".to_string(), "shards/t1.1.ddq".to_string()],
                tensors: vec![
                    TensorRecord {
                        name: "layers.0.attn.wq".to_string(),
                        shard: 0,
                        offset: 8,
                        len: 1024,
                        crc32: 0xDEAD_BEEF,
                        norm: 0.125,
                    },
                    TensorRecord {
                        name: "layers.0.attn.wk".to_string(),
                        shard: 1,
                        offset: 8,
                        len: 1024,
                        crc32: 7,
                        norm: 0.0,
                    },
                ],
            },
        );
        m
    }

    #[test]
    fn json_roundtrip_exact() {
        let m = sample();
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("deltadq-test-manifest");
        std::fs::create_dir_all(&dir).unwrap();
        let m = sample();
        m.save(&dir).unwrap();
        assert_eq!(Manifest::load(&dir).unwrap(), m);
    }

    #[test]
    fn rejects_foreign_json() {
        assert!(Manifest::from_json(&Json::parse(r#"{"hello": 1}"#).unwrap()).is_err());
        let wrong_version =
            r#"{"format": "deltastore", "version": 99, "next_id": 0, "tenants": {}}"#;
        assert!(Manifest::from_json(&Json::parse(wrong_version).unwrap()).is_err());
    }

    #[test]
    fn missing_norm_field_defaults_to_zero() {
        // manifests written before norm capture have no "norm" key
        let text = r#"{"format": "deltastore", "version": 1, "next_id": 2, "tenants": {
            "old": {"id": 1, "method": "DeltaDQ", "nominal_ratio": 16.0, "bytes": 8,
                    "shards": ["shards/t1.0.ddq"],
                    "tensors": [{"name": "lm_head", "shard": 0, "offset": 0,
                                 "len": 8, "crc32": 1}]}}}"#;
        let m = Manifest::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(m.tenants["old"].tensors[0].norm, 0.0);
    }

    #[test]
    fn rejects_dangling_shard_index() {
        let mut m = sample();
        m.tenants.get_mut("math").unwrap().tensors[1].shard = 9;
        let err = Manifest::from_json(&m.to_json()).unwrap_err();
        assert!(format!("{err:#}").contains("references shard"), "{err:#}");
    }
}
