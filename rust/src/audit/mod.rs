//! Compression-quality observability (S12): online shadow audit,
//! per-layer reconstruction/BIR telemetry, and drift-triggered
//! quarantine.
//!
//! Serving a compressed delta is a lossy bet — DeltaDQ's group-wise
//! dropout and separate quantization are tuned so the served
//! distribution stays indistinguishable from the dense fine-tune, but
//! nothing in the hot path *verifies* that bet once a tenant is live.
//! This module closes the loop:
//!
//! ```text
//!   request completes ──▶ AuditHub::offer  (1-in-N counter, lock-free)
//!                            │ sampled? clone (tenant, prompt, tokens)
//!                            ▼ bounded try_send (overflow → dropped++)
//!   "deltadq-audit" thread ──▶ shadow_compare:
//!       reference  = dense reconstruction of a FRESH store load
//!       serving    = fused separate-computation over the resident set
//!       → token agreement, final-position logit max-abs / KL
//!                            │
//!                            ▼ per-tenant sliding window
//!   windowed agreement < quarantine_below ──▶ warn (always) and, in
//!   enforce mode, route the tenant into the load-failure quarantine
//!   lifecycle (probe-heal rehydrates from the store and clears it).
//! ```
//!
//! Everything here runs *off* the hot path: completion threads pay one
//! atomic increment per request plus a clone on the sampled 1-in-N;
//! reconstruction, prefills, and per-layer stats all happen on the
//! dedicated audit thread. The audit queue is bounded — under load,
//! samples are dropped (and counted) rather than queued without bound.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::compress::pipeline::reconstruct_weights;
use crate::coordinator::TenantStore;
use crate::delta::format::DeltaSet;
use crate::eval::accuracy::{argmax, logit_kl, logit_maxabs};
use crate::model::ModelWeights;
use crate::runtime::{fused_matmul_nt_sampled, BirSink, ExecutionBackend, ThreadPool};
use crate::tensor::stats::SampleStats;
use crate::tensor::{Matrix, Pcg64};
use crate::util::json::Json;

/// Bound on the audit job queue: shadow audits are best-effort, and a
/// slow audit thread must exert zero backpressure on completion paths.
pub const AUDIT_QUEUE_DEPTH: usize = 32;

/// Resolved `[audit]` configuration (see [`crate::config::ServeConfig`]).
#[derive(Debug, Clone)]
pub struct AuditConfig {
    /// Master switch: when false no audit thread is spawned and
    /// [`AuditHub::offer`] is a single load-and-return.
    pub enabled: bool,
    /// Sample every Nth completed request for shadow comparison.
    pub sample_every: u64,
    /// Windowed token-agreement threshold below which a tenant is
    /// flagged as drifted. `0.0` disables drift detection (telemetry
    /// only — the shipped default).
    pub quarantine_below: f64,
    /// When a tenant drifts: `false` (default) only warns and counts;
    /// `true` additionally routes the tenant into the quarantine
    /// lifecycle (served 503s until a background probe heals it).
    pub enforce: bool,
    /// Sliding-window length (audited requests per tenant) over which
    /// agreement is averaged before the threshold is applied.
    pub window: usize,
}

impl Default for AuditConfig {
    fn default() -> AuditConfig {
        AuditConfig {
            enabled: true,
            sample_every: 64,
            quarantine_below: 0.0,
            enforce: false,
            window: 16,
        }
    }
}

/// One shadow comparison's result: the served token stream re-scored
/// against the dense reference reconstruction.
#[derive(Debug, Clone, Copy)]
pub struct ShadowReport {
    /// Served tokens compared.
    pub tokens: usize,
    /// Fraction of served tokens matching the reference argmax.
    pub agreement: f64,
    /// Max-abs logit difference (reference vs serving path) at the
    /// final position.
    pub logit_maxabs: f64,
    /// `KL(ref ‖ serving)` in nats at the final position.
    pub logit_kl: f64,
}

/// Per-layer static + dynamic quality telemetry for one tenant's
/// resident delta set.
#[derive(Debug, Clone)]
pub struct LayerStat {
    /// Tensor name ("layers.3.attn.wq" …).
    pub name: String,
    /// Output dimension (rows of `Δ`).
    pub rows: usize,
    /// Input dimension (cols of `Δ`).
    pub cols: usize,
    /// Stored non-zeros / total elements.
    pub density: f64,
    /// Measured storage bits per parameter.
    pub bits_per_param: f64,
    /// Pre-quantization Frobenius norm recorded at compression time
    /// (0.0 when the artifact predates norm capture).
    pub recorded_norm: f64,
    /// Frobenius norm of the reconstructed (densified) delta.
    pub recon_norm: f64,
    /// Relative norm drift `|recon − recorded| / recorded` (0.0 when no
    /// recorded norm exists) — the reconstruction-error proxy.
    pub recon_error: f64,
    /// Balanced-intermediate-result statistics of sampled `X·ΔŴᵀ` rows
    /// (paper Fig. 4): small variance/range is the property separate
    /// quantization exploits; a corrupt delta blows it up.
    pub bir: SampleStats,
}

/// A unit of work for the audit thread.
#[derive(Debug)]
pub enum AuditJob {
    /// Re-score one served request against the dense reference.
    Shadow {
        /// Tenant that served the request.
        tenant: String,
        /// Prompt tokens as submitted.
        prompt: Vec<u32>,
        /// Tokens the serving path returned.
        served: Vec<u32>,
    },
    /// (Re)compute per-layer stats for a tenant's resident set.
    LayerStats {
        /// Tenant to profile.
        tenant: String,
    },
}

/// Drift verdict returned by [`AuditHub::record_shadow`].
#[derive(Debug, Clone, Copy)]
pub struct DriftVerdict {
    /// Mean token agreement over the tenant's sliding window.
    pub window_agreement: f64,
    /// Audited requests currently in the window.
    pub window_len: usize,
    /// Whether the windowed agreement fell below the configured
    /// threshold (always false when the threshold is 0.0).
    pub drifted: bool,
}

/// Shared state between completion paths (producers), the audit thread
/// (consumer), and the observability endpoints (readers). Lives in
/// [`crate::coordinator::Metrics`]; all hot-path interaction is the
/// lock-free [`offer`](AuditHub::offer) fast path.
#[derive(Debug, Default)]
pub struct AuditHub {
    enabled: AtomicBool,
    sample_every: AtomicU64,
    /// f64 bits of the agreement threshold (atomics have no f64).
    quarantine_below_bits: AtomicU64,
    enforce: AtomicBool,
    window: AtomicU64,
    /// Completed requests seen by `offer` (the sampling clock).
    offers: AtomicU64,
    /// Requests sampled into the audit queue.
    pub sampled_total: AtomicU64,
    /// Samples dropped because the audit queue was full (budget cap).
    pub dropped_total: AtomicU64,
    /// Shadow comparisons completed by the audit thread.
    pub completed_total: AtomicU64,
    /// Drift warnings raised (windowed agreement below threshold).
    pub warn_total: AtomicU64,
    /// Tenants quarantined by the auditor (enforce mode only).
    pub quarantined_total: AtomicU64,
    /// Audit jobs that failed (missing tenant, backend error, …).
    pub errors_total: AtomicU64,
    windows: Mutex<BTreeMap<String, VecDeque<ShadowReport>>>,
    layers: Mutex<BTreeMap<String, Vec<LayerStat>>>,
    tx: Mutex<Option<SyncSender<AuditJob>>>,
}

impl AuditHub {
    /// Apply resolved `[audit]` settings (done once at server start).
    pub fn configure(&self, cfg: &AuditConfig) {
        self.enabled.store(cfg.enabled, Ordering::Relaxed);
        self.sample_every.store(cfg.sample_every.max(1), Ordering::Relaxed);
        self.quarantine_below_bits.store(cfg.quarantine_below.to_bits(), Ordering::Relaxed);
        self.enforce.store(cfg.enforce, Ordering::Relaxed);
        self.window.store(cfg.window.max(1) as u64, Ordering::Relaxed);
    }

    /// The currently applied configuration.
    pub fn config(&self) -> AuditConfig {
        AuditConfig {
            enabled: self.enabled.load(Ordering::Relaxed),
            sample_every: self.sample_every.load(Ordering::Relaxed).max(1),
            quarantine_below: f64::from_bits(self.quarantine_below_bits.load(Ordering::Relaxed)),
            enforce: self.enforce.load(Ordering::Relaxed),
            window: self.window.load(Ordering::Relaxed).max(1) as usize,
        }
    }

    /// Attach the audit thread's job channel.
    pub fn connect(&self, tx: SyncSender<AuditJob>) {
        *self.tx.lock().unwrap() = Some(tx);
    }

    /// Detach the job channel (shutdown: the audit thread's `recv`
    /// unblocks with a hangup once the last sender drops).
    pub fn disconnect(&self) {
        *self.tx.lock().unwrap() = None;
    }

    /// Completion-path hook: count the request and, on the sampled
    /// 1-in-N, clone it into the audit queue. Never blocks; a full
    /// queue increments `dropped_total` and moves on.
    pub fn offer(&self, tenant: &str, prompt: &[u32], served: &[u32]) {
        if !self.enabled.load(Ordering::Relaxed) || served.is_empty() {
            return;
        }
        let n = self.offers.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.sample_every.load(Ordering::Relaxed).max(1) != 0 {
            return;
        }
        let sent = self.send(AuditJob::Shadow {
            tenant: tenant.to_string(),
            prompt: prompt.to_vec(),
            served: served.to_vec(),
        });
        if sent {
            self.sampled_total.fetch_add(1, Ordering::Relaxed);
        } else {
            self.dropped_total.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Request per-layer stats for `tenant` (lazy: fired on the first
    /// quality scrape, never at registration — layer profiling
    /// densifies, which the serving path must never do). Does not touch
    /// the sampling counters: a dropped profiling job is simply
    /// re-requested by the next scrape.
    pub fn request_layer_stats(&self, tenant: &str) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        if self.layers.lock().unwrap().contains_key(tenant) {
            return; // already profiled; re-push replaces via set_layer_stats
        }
        let _ = self.send(AuditJob::LayerStats { tenant: tenant.to_string() });
    }

    /// Non-blocking enqueue; `false` = queue full or no thread attached.
    fn send(&self, job: AuditJob) -> bool {
        let tx = self.tx.lock().unwrap();
        matches!(tx.as_ref().map(|tx| tx.try_send(job)), Some(Ok(())))
    }

    /// Fold one shadow result into the tenant's sliding window and
    /// return the drift verdict. Raises `warn_total` on drift; acting
    /// on the verdict (quarantine) is the caller's job.
    pub fn record_shadow(&self, tenant: &str, report: ShadowReport) -> DriftVerdict {
        self.completed_total.fetch_add(1, Ordering::Relaxed);
        let window = self.window.load(Ordering::Relaxed).max(1) as usize;
        let mut windows = self.windows.lock().unwrap();
        let ring = windows.entry(tenant.to_string()).or_default();
        ring.push_back(report);
        while ring.len() > window {
            ring.pop_front();
        }
        let window_len = ring.len();
        let window_agreement =
            ring.iter().map(|r| r.agreement).sum::<f64>() / window_len as f64;
        drop(windows);
        let threshold = f64::from_bits(self.quarantine_below_bits.load(Ordering::Relaxed));
        let drifted = threshold > 0.0 && window_agreement < threshold;
        if drifted {
            self.warn_total.fetch_add(1, Ordering::Relaxed);
        }
        DriftVerdict { window_agreement, window_len, drifted }
    }

    /// Clear a tenant's audit window (after a quarantine or re-push the
    /// stale samples describe weights that are no longer serving).
    pub fn reset_tenant(&self, tenant: &str) {
        self.windows.lock().unwrap().remove(tenant);
        self.layers.lock().unwrap().remove(tenant);
    }

    /// Install freshly computed per-layer stats for a tenant.
    pub fn set_layer_stats(&self, tenant: &str, stats: Vec<LayerStat>) {
        self.layers.lock().unwrap().insert(tenant.to_string(), stats);
    }

    /// Per-tenant audit summaries for the Prometheus endpoint:
    /// `(tenant, windowed agreement, window length, last max-abs, last KL)`.
    pub fn tenant_summaries(&self) -> Vec<(String, f64, usize, f64, f64)> {
        let windows = self.windows.lock().unwrap();
        windows
            .iter()
            .map(|(t, ring)| {
                let n = ring.len().max(1);
                let agree = ring.iter().map(|r| r.agreement).sum::<f64>() / n as f64;
                let last = ring.back().copied().unwrap_or(ShadowReport {
                    tokens: 0,
                    agreement: 0.0,
                    logit_maxabs: 0.0,
                    logit_kl: 0.0,
                });
                (t.clone(), agree, ring.len(), last.logit_maxabs, last.logit_kl)
            })
            .collect()
    }

    /// Cached per-layer stats, per tenant (empty until the first
    /// quality scrape or offline audit triggers profiling).
    pub fn layer_snapshot(&self) -> Vec<(String, Vec<LayerStat>)> {
        self.layers.lock().unwrap().iter().map(|(t, s)| (t.clone(), s.clone())).collect()
    }

    /// The `/debug/quality` JSON document. `tenant = Some(..)` narrows
    /// to one tenant (and triggers lazy layer profiling for it).
    pub fn quality_json(&self, tenant: Option<&str>) -> Json {
        let cfg = self.config();
        let mut config = Json::obj();
        config
            .set("enabled", cfg.enabled)
            .set("sample_every", cfg.sample_every)
            .set("quarantine_below", cfg.quarantine_below)
            .set("enforce", cfg.enforce)
            .set("window", cfg.window);
        let mut counters = Json::obj();
        counters
            .set("sampled", self.sampled_total.load(Ordering::Relaxed))
            .set("dropped", self.dropped_total.load(Ordering::Relaxed))
            .set("completed", self.completed_total.load(Ordering::Relaxed))
            .set("warns", self.warn_total.load(Ordering::Relaxed))
            .set("quarantines", self.quarantined_total.load(Ordering::Relaxed))
            .set("errors", self.errors_total.load(Ordering::Relaxed));

        let windows = self.windows.lock().unwrap();
        let layers = self.layers.lock().unwrap();
        let mut tenants = Json::obj();
        let mut names: Vec<&String> = windows.keys().chain(layers.keys()).collect();
        names.sort();
        names.dedup();
        for name in names {
            if let Some(want) = tenant {
                if name.as_str() != want {
                    continue;
                }
            }
            let mut t = Json::obj();
            if let Some(ring) = windows.get(name.as_str()) {
                let n = ring.len().max(1);
                let agree = ring.iter().map(|r| r.agreement).sum::<f64>() / n as f64;
                t.set("window_agreement", agree).set("window_len", ring.len());
                let mut arr = Vec::with_capacity(ring.len());
                for r in ring {
                    let mut o = Json::obj();
                    o.set("tokens", r.tokens)
                        .set("agreement", r.agreement)
                        .set("logit_maxabs", r.logit_maxabs)
                        .set("logit_kl", r.logit_kl);
                    arr.push(o);
                }
                t.set("window", Json::Arr(arr));
            }
            if let Some(stats) = layers.get(name.as_str()) {
                t.set("layers", Json::Arr(stats.iter().map(layer_stat_json).collect()));
            }
            tenants.set(name, t);
        }
        let mut root = Json::obj();
        root.set("config", config).set("counters", counters).set("tenants", tenants);
        root
    }
}

/// JSON shape of one [`LayerStat`] (shared by `/debug/quality` and the
/// `deltadq audit --json` CLI).
pub fn layer_stat_json(s: &LayerStat) -> Json {
    let mut o = Json::obj();
    o.set("name", s.name.as_str())
        .set("rows", s.rows)
        .set("cols", s.cols)
        .set("density", s.density)
        .set("bits_per_param", s.bits_per_param)
        .set("recorded_norm", s.recorded_norm)
        .set("recon_norm", s.recon_norm)
        .set("recon_error", s.recon_error)
        .set("bir_variance", s.bir.variance)
        .set("bir_min", s.bir.min)
        .set("bir_max", s.bir.max);
    o
}

/// Re-score one served request: reconstruct the dense reference from
/// `reference`, prefill the full prompt+served sequence through both
/// the dense reference and the fused serving path over `serving`, and
/// compare greedy argmax per served position plus final-position logit
/// divergence.
pub fn shadow_compare(
    backend: &dyn ExecutionBackend,
    base: &ModelWeights,
    reference: &DeltaSet,
    serving: &DeltaSet,
    prompt: &[u32],
    served: &[u32],
) -> Result<ShadowReport> {
    let mut seq = Vec::with_capacity(prompt.len() + served.len());
    seq.extend_from_slice(prompt);
    seq.extend_from_slice(served);
    let dense_ref = reconstruct_weights(base, reference);
    let ref_logits = backend.prefill(&dense_ref, None, &seq).context("reference prefill")?;
    let serve_logits = backend.prefill(base, Some(serving), &seq).context("serving prefill")?;
    // position p predicts token p+1: served[i] was emitted from position
    // prompt.len()-1+i of the sequence fed back through prefill
    let p0 = prompt.len().saturating_sub(1);
    let mut agree = 0usize;
    for (i, &tok) in served.iter().enumerate() {
        let row = ref_logits.row(p0 + i);
        if argmax(row) as u32 == tok {
            agree += 1;
            continue;
        }
        // the dense reference and the cached/fused serving decode are
        // numerically close but not bit-identical (the repo's forward
        // tests bound the cross-path drift at ~1e-3); a served token
        // whose reference logit sits within that drift of the argmax is
        // a near-tie between the paths, not drift — real corruption
        // moves logits by orders of magnitude more
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let tol = 1e-3 * max.abs().max(1.0);
        if row.get(tok as usize).is_some_and(|&l| l >= max - tol) {
            agree += 1;
        }
    }
    let last = seq.len() - 1;
    Ok(ShadowReport {
        tokens: served.len(),
        agreement: if served.is_empty() { 1.0 } else { agree as f64 / served.len() as f64 },
        logit_maxabs: logit_maxabs(ref_logits.row(last), serve_logits.row(last)),
        logit_kl: logit_kl(ref_logits.row(last), serve_logits.row(last)),
    })
}

/// Per-layer static + dynamic profiling of a delta set against its
/// base weights: density, measured bits/param, reconstruction-norm
/// drift vs the recorded pre-quantization norm, and BIR statistics of
/// sampled `X·ΔŴᵀ` rows under a fixed seeded probe. Densifies each
/// layer once — audit/offline use only, never the serving path.
pub fn layer_stats(base: &ModelWeights, set: &DeltaSet, pool: &ThreadPool) -> Vec<LayerStat> {
    let mut rng = Pcg64::seeded(0xA0D17);
    let mut out = Vec::with_capacity(set.tensors.len());
    for (name, delta) in &set.tensors {
        let (rows, cols) = delta.shape();
        let elems = (rows * cols) as f64;
        let recon_norm = delta.to_dense().frobenius_norm() as f64;
        let recorded_norm = set.norms.get(name).copied().unwrap_or(0.0);
        let recon_error = if recorded_norm > 0.0 {
            (recon_norm - recorded_norm).abs() / recorded_norm
        } else {
            0.0
        };
        // BIR probe: a fixed 4-row activation; sample up to 64 output
        // columns on a regular lattice through the instrumented kernel
        let x = Matrix::randn(4, cols, 1.0, &mut rng);
        let sink = BirSink::new((rows / 64).max(1), 64);
        let _ = fused_matmul_nt_sampled(&x, base.get(name), delta, pool, &sink);
        out.push(LayerStat {
            name: name.clone(),
            rows,
            cols,
            density: delta.nnz() as f64 / elems,
            bits_per_param: delta.storage_bits() as f64 / elems,
            recorded_norm,
            recon_norm,
            recon_error,
            bir: sink.finalize(),
        });
    }
    out
}

/// The audit thread's body: drain jobs until every sender hangs up
/// ([`AuditHub::disconnect`] at server shutdown). Runs shadow
/// comparisons against a fresh store load when a store is attached
/// (CRC-verified ground truth — detects resident corruption), falling
/// back to the resident set; executes quarantine verdicts in enforce
/// mode.
pub fn worker_loop(
    rx: Receiver<AuditJob>,
    hub: Arc<AuditHub>,
    backend: Arc<dyn ExecutionBackend>,
    tenants: Arc<TenantStore>,
) {
    let fallback_pool = ThreadPool::serial();
    while let Ok(job) = rx.recv() {
        match job {
            AuditJob::Shadow { tenant, prompt, served } => {
                let resident = tenants.resident_deltas(&tenant);
                let reference = match fresh_reference(&tenants, &tenant) {
                    Some(set) => set,
                    None => match resident.clone() {
                        Some(set) => set,
                        None => {
                            hub.errors_total.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    },
                };
                let serving = resident.unwrap_or_else(|| reference.clone());
                let report = match shadow_compare(
                    backend.as_ref(),
                    tenants.base(),
                    &reference,
                    &serving,
                    &prompt,
                    &served,
                ) {
                    Ok(r) => r,
                    Err(e) => {
                        hub.errors_total.fetch_add(1, Ordering::Relaxed);
                        eprintln!("audit: tenant '{tenant}': shadow comparison failed: {e:#}");
                        continue;
                    }
                };
                let verdict = hub.record_shadow(&tenant, report);
                if verdict.drifted {
                    eprintln!(
                        "audit: tenant '{tenant}' drifted: window agreement {:.4} over {} \
                         audits (threshold {:.4})",
                        verdict.window_agreement,
                        verdict.window_len,
                        hub.config().quarantine_below,
                    );
                    if hub.config().enforce && tenants.quarantine(&tenant) {
                        hub.quarantined_total.fetch_add(1, Ordering::Relaxed);
                        hub.reset_tenant(&tenant);
                        eprintln!("audit: tenant '{tenant}' quarantined (probe will re-hydrate)");
                    }
                }
            }
            AuditJob::LayerStats { tenant } => {
                let set = match tenants
                    .resident_deltas(&tenant)
                    .or_else(|| fresh_reference(&tenants, &tenant))
                {
                    Some(set) => set,
                    None => {
                        hub.errors_total.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                };
                let pool = backend.exec_pool().unwrap_or(&fallback_pool);
                let stats = layer_stats(tenants.base(), &set, pool);
                hub.set_layer_stats(&tenant, stats);
            }
        }
    }
}

/// Load a tenant's delta set fresh from the attached store (CRC paths
/// verify every record); `None` when no store is attached or the load
/// fails.
fn fresh_reference(tenants: &TenantStore, tenant: &str) -> Option<Arc<DeltaSet>> {
    let store = tenants.store()?;
    match store.load(tenant) {
        Ok(set) => Some(Arc::new(set)),
        Err(_) => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::compress_model_deltas;
    use crate::compress::{DeltaDq, DeltaDqConfig};
    use crate::delta::extract_deltas;
    use crate::eval::tasks::vocab;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::runtime::NativeBackend;

    fn tiny_pair() -> (ModelWeights, DeltaSet) {
        let mut rng = Pcg64::seeded(5);
        let base = ModelWeights::init(ModelConfig::tiny(), &mut rng);
        let mut ft = base.clone();
        let mut rng2 = Pcg64::seeded(6);
        for name in base.config.delta_tensor_names() {
            let (r, c) = ft.get(&name).shape();
            ft.get_mut(&name).add_assign(&Matrix::randn(r, c, 0.001, &mut rng2));
        }
        let deltas = extract_deltas(&base, &ft);
        let dq = DeltaDq::new(DeltaDqConfig::dropout_only(1.0, None)); // lossless
        let mut rng3 = Pcg64::seeded(7);
        let set = compress_model_deltas(&deltas, &dq, &BTreeMap::new(), &mut rng3);
        (base, set)
    }

    #[test]
    fn offer_samples_one_in_n() {
        let hub = AuditHub::default();
        hub.configure(&AuditConfig { sample_every: 2, ..Default::default() });
        let (tx, rx) = std::sync::mpsc::sync_channel(8);
        hub.connect(tx);
        for _ in 0..6 {
            hub.offer("t", &[1, 2], &[3]);
        }
        hub.disconnect();
        assert_eq!(rx.iter().count(), 3);
        assert_eq!(hub.sampled_total.load(Ordering::Relaxed), 3);
        assert_eq!(hub.dropped_total.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn offer_counts_drops_when_queue_full_or_disconnected() {
        let hub = AuditHub::default();
        hub.configure(&AuditConfig { sample_every: 1, ..Default::default() });
        // no channel connected: everything sampled is a drop
        hub.offer("t", &[1], &[2]);
        assert_eq!(hub.dropped_total.load(Ordering::Relaxed), 1);
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        hub.connect(tx);
        hub.offer("t", &[1], &[2]); // fills the queue
        hub.offer("t", &[1], &[2]); // overflows
        assert_eq!(hub.sampled_total.load(Ordering::Relaxed), 1);
        assert_eq!(hub.dropped_total.load(Ordering::Relaxed), 2);
        drop(rx);
    }

    #[test]
    fn disabled_hub_offers_nothing() {
        let hub = AuditHub::default();
        hub.configure(&AuditConfig { enabled: false, sample_every: 1, ..Default::default() });
        let (tx, rx) = std::sync::mpsc::sync_channel(8);
        hub.connect(tx);
        hub.offer("t", &[1], &[2]);
        hub.disconnect();
        assert_eq!(rx.iter().count(), 0);
        assert_eq!(hub.sampled_total.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn drift_window_warns_below_threshold() {
        let hub = AuditHub::default();
        hub.configure(&AuditConfig {
            quarantine_below: 0.9,
            window: 4,
            ..Default::default()
        });
        let good = ShadowReport { tokens: 8, agreement: 1.0, logit_maxabs: 0.0, logit_kl: 0.0 };
        let bad = ShadowReport { tokens: 8, agreement: 0.25, logit_maxabs: 3.0, logit_kl: 1.0 };
        assert!(!hub.record_shadow("t", good).drifted);
        assert!(!hub.record_shadow("t", good).drifted);
        // one bad audit: window mean (1+1+0.25)/3 = 0.75 < 0.9 → drift
        let v = hub.record_shadow("t", bad);
        assert!(v.drifted, "window agreement {}", v.window_agreement);
        assert_eq!(hub.warn_total.load(Ordering::Relaxed), 1);
        // window slides: four goods push the bad sample out
        for _ in 0..4 {
            hub.record_shadow("t", good);
        }
        let v = hub.record_shadow("t", good);
        assert!(!v.drifted);
        assert_eq!(v.window_len, 4);
        assert_eq!(v.window_agreement, 1.0);
    }

    #[test]
    fn zero_threshold_never_drifts() {
        let hub = AuditHub::default(); // quarantine_below = 0.0
        let awful = ShadowReport { tokens: 4, agreement: 0.0, logit_maxabs: 9.0, logit_kl: 9.0 };
        assert!(!hub.record_shadow("t", awful).drifted);
        assert_eq!(hub.warn_total.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shadow_compare_clean_set_has_full_agreement() {
        let (base, set) = tiny_pair();
        let backend = NativeBackend::new(1);
        let prompt = vec![1u32, 20, 4, 21, 3];
        let served = backend.generate(&base, Some(&set), &prompt, 6, Some(vocab::EOS)).unwrap();
        assert!(!served.is_empty());
        let r = shadow_compare(&backend, &base, &set, &set, &prompt, &served).unwrap();
        assert_eq!(r.tokens, served.len());
        assert_eq!(r.agreement, 1.0, "lossless set must re-score cleanly");
        // merged-dense vs separate-computation differ only in float
        // association order
        assert!(r.logit_maxabs < 1e-3, "maxabs {}", r.logit_maxabs);
        assert!(r.logit_kl < 1e-6, "kl {}", r.logit_kl);
    }

    #[test]
    fn shadow_compare_detects_corrupt_serving_set() {
        let (base, set) = tiny_pair();
        let backend = NativeBackend::new(1);
        let prompt = vec![1u32, 20, 4, 21, 3];
        // serve from a corrupted resident set: 256x-scaled deltas
        // dominate the model (the same transform the
        // `tenant.corrupt_resident` failpoint applies), so greedy
        // tokens drift off the clean reference
        let mut corrupt = set.clone();
        for (_, t) in corrupt.tensors.iter_mut() {
            *t = crate::compress::CompressedDelta::Dense(t.to_dense().scaled(256.0));
        }
        let served =
            backend.generate(&base, Some(&corrupt), &prompt, 6, Some(vocab::EOS)).unwrap();
        let r = shadow_compare(&backend, &base, &set, &corrupt, &prompt, &served).unwrap();
        // the serving-path re-run scores the corrupt weights directly,
        // so the divergence is visible regardless of token flips
        assert!(r.logit_maxabs > 1e-3, "maxabs {}", r.logit_maxabs);
        assert!(r.agreement < 1.0, "agreement {}", r.agreement);
    }

    #[test]
    fn layer_stats_profile_clean_and_corrupt_sets() {
        let (base, set) = tiny_pair();
        let pool = ThreadPool::serial();
        let stats = layer_stats(&base, &set, &pool);
        assert_eq!(stats.len(), set.tensors.len());
        for s in &stats {
            // lossless compression: reconstruction norm matches recorded
            assert!(s.recon_error < 1e-3, "{}: recon_error {}", s.name, s.recon_error);
            assert!(s.recorded_norm > 0.0);
            assert!(s.density > 0.9, "{}: density {}", s.name, s.density);
            assert!(s.bir.variance.is_finite());
        }
        // corrupt one layer 8x: its recon_error stands out
        let mut corrupt = set.clone();
        let name = corrupt.tensors.keys().next().unwrap().clone();
        let t = corrupt.tensors.get_mut(&name).unwrap();
        *t = crate::compress::CompressedDelta::Dense(t.to_dense().scaled(8.0));
        let stats = layer_stats(&base, &corrupt, &pool);
        let bad = stats.iter().find(|s| s.name == name).unwrap();
        assert!((bad.recon_error - 7.0).abs() < 0.01, "recon_error {}", bad.recon_error);
    }

    #[test]
    fn quality_json_renders_config_counters_and_tenants() {
        let hub = AuditHub::default();
        hub.configure(&AuditConfig::default());
        let r = ShadowReport { tokens: 8, agreement: 1.0, logit_maxabs: 0.001, logit_kl: 0.0 };
        hub.record_shadow("math", r);
        let (base, set) = tiny_pair();
        hub.set_layer_stats("math", layer_stats(&base, &set, &ThreadPool::serial()));
        let j = hub.quality_json(None);
        assert_eq!(j.get("config").and_then(|c| c.get("sample_every")).and_then(Json::as_u64),
                   Some(64));
        let t = j.get("tenants").and_then(|t| t.get("math")).unwrap();
        assert_eq!(t.get("window_len").and_then(Json::as_u64), Some(1));
        assert!(t.get("layers").and_then(Json::as_array).unwrap().len() > 1);
        // narrowed view drops other tenants
        hub.record_shadow("code", r);
        let j = hub.quality_json(Some("math"));
        assert!(j.get("tenants").and_then(|t| t.get("code")).is_none());
        assert!(j.get("tenants").and_then(|t| t.get("math")).is_some());
    }
}
